"""Hypothesis shim: property tests degrade to seeded sweeps when absent.

The CI image carries hypothesis (see pyproject's `dev` extra), but minimal
environments may not.  Importing `given/settings/st` from here instead of
from hypothesis keeps every test module collectable either way: with
hypothesis installed the real library runs; without it, each `@given` test
runs `max_examples` deterministic draws from a seeded RNG over the same
strategy ranges (no shrinking, but the property still gets exercised).

Only the strategy surface this repo uses is shimmed: `st.integers` and
`st.sampled_from`.
"""
from __future__ import annotations


try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def draw(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

    def given(**strategies):
        def decorate(fn):
            # deliberately NOT functools.wraps: the wrapper must present a
            # zero-arg signature or pytest treats the drawn parameters as
            # missing fixtures
            def run():
                n = getattr(run, "_max_examples", 10)
                for i in range(n):
                    rng = np.random.default_rng(0xC0FFEE + i)
                    drawn = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    fn(**drawn)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return decorate

    def settings(max_examples: int = 10, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate
