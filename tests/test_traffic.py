"""Traffic subsystem: open-loop generation, SLO accounting, admission.

Everything runs on a FakeClock (virtual time, deterministic): the driver
takes its clock from the serve loop, so arrival pacing, deadline cuts and
latency components are exact functions of the schedule — no wall-clock
flakiness in tier-1.
"""
import copy

import numpy as np
import pytest

from repro.data import corpus as corpus_lib
from repro.serve import PIRServeLoop, PipelinedServeLoop
from repro.serve.engine import DeadlineBatcher, Request
from repro.traffic import (AdmissionController, OpenLoopDriver, TrafficSpec,
                           poisson_arrivals, summarize)
from repro.traffic.slo import SERVED, SHED, RequestRecord
from repro.update import LiveIndex, journal as journal_lib

N_DOCS = 120
EMB = 16


class FakeClock:
    """Monotone virtual clock advancing a fixed step per reading."""

    def __init__(self, step: float = 1e-4):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


_BASE: dict = {}


def _get_base():
    if not _BASE:
        corp = corpus_lib.make_corpus(3, N_DOCS, emb_dim=EMB, n_topics=5)
        live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=5,
                               impl="xla", kmeans_iters=5, compact_every=2)
        live.system.enable_batch(kappa=4)
        _BASE["corp"], _BASE["live"] = corp, live
    return _BASE["corp"], _BASE["live"]


def _mutator(rng):
    doc = int(rng.integers(N_DOCS))
    return journal_lib.replace(doc, f"mut {doc}".encode(),
                               rng.standard_normal(EMB).astype(np.float32))


# ---------------------------------------------------------------------------
# Arrival process
# ---------------------------------------------------------------------------

def test_poisson_arrivals_rate_and_determinism():
    t1 = poisson_arrivals(np.random.default_rng(7), 100.0, 10.0)
    t2 = poisson_arrivals(np.random.default_rng(7), 100.0, 10.0)
    assert np.array_equal(t1, t2)                  # seeded ⇒ reproducible
    assert np.all(np.diff(t1) > 0) and t1[-1] < 10.0
    assert 800 < len(t1) < 1200                    # ~1000 ± Poisson noise
    assert poisson_arrivals(np.random.default_rng(0), 0.0, 5.0).size == 0


# ---------------------------------------------------------------------------
# SLO fold
# ---------------------------------------------------------------------------

def test_summarize_counts_shed_against_attainment_and_p99():
    recs = [RequestRecord(i, 0, t_arrival=0.0, t_done=0.005)
            for i in range(98)]                    # 5 ms each
    recs += [RequestRecord(98, 0, t_arrival=0.0, outcome=SHED),
             RequestRecord(99, 0, t_arrival=0.0, outcome=SHED)]
    s = summarize(recs, deadline_ms=10.0, wall_s=1.0)
    assert s["offered"] == 100 and s["served"] == 98 and s["shed"] == 2
    assert s["attainment"] == 0.98                 # shed = missed
    assert s["p50_ms"] == 5.0
    assert s["p99_ms"] == float("inf")             # the tail IS the sheds
    assert s["served_qps"] == 98.0
    assert set(s["components"]) == {"queue_ms", "encode_ms", "gemm_ms",
                                    "decode_ms", "hint_sync_ms"}
    empty = summarize([], deadline_ms=10.0, wall_s=0.0)
    assert empty["offered"] == 0 and empty["attainment"] == 1.0


# ---------------------------------------------------------------------------
# Engine observability + admission primitives (deterministic, no driver)
# ---------------------------------------------------------------------------

def _req(rid, t):
    return Request(rid, np.zeros(EMB, np.float32), t, epoch=0)


def test_batcher_depth_age_and_shed_tail():
    b = DeadlineBatcher(max_batch=8, deadline_ms=20.0)
    assert b.depth == 0 and b.oldest_age_ms(5.0) == 0.0
    for i in range(6):
        b.submit(_req(i, 1.0 + i * 0.001))
    assert b.depth == 6
    assert b.oldest_age_ms(1.010) == pytest.approx(10.0)
    shed = b.shed_tail(2)
    assert [r.rid for r in shed] == [4, 5]         # youngest, arrival order
    assert b.depth == 4
    assert [r.rid for r in b.cut()] == [0, 1, 2, 3]
    assert b.shed_tail(3) == []                    # empty queue: no-op


def test_admission_sheds_defers_and_adapts_depth():
    corp, live0 = _get_base()
    live = copy.deepcopy(live0)
    loop = PipelinedServeLoop(live, max_batch=4, deadline_ms=5.0,
                              clock=FakeClock(), depth=1)
    ctl = AdmissionController(max_queue=8, defer_queue=4,
                              min_depth=1, max_depth=3).attach(loop)
    for i in range(14):
        loop.submit(i, corp.embeddings[i % N_DOCS])
    loop.submit_mutation(_mutator(np.random.default_rng(0)))
    loop.tick()                                    # gated: queue is deep
    assert loop.epoch == 0 and ctl.deferred_commits >= 1
    shed = ctl.step(loop.clock())
    assert loop.batcher.depth <= 8
    assert len(shed) == ctl.shed_total > 0
    assert {r.rid for r in shed} <= set(range(14))
    assert loop.depth == 2                         # ceil(8 / 4)
    loop.drain()                                   # drain bypasses the gate
    assert loop.epoch == 1 and loop.batcher.depth == 0
    # backlog cleared: gate opens and depth relaxes back down
    loop.submit_mutation(_mutator(np.random.default_rng(1)))
    loop.tick()
    assert loop.epoch == 2
    ctl.step(loop.clock())
    assert loop.depth == 1
    stats = ctl.stats()
    assert stats["shed"] == len(shed) and stats["allowed_commits"] >= 1


# ---------------------------------------------------------------------------
# End-to-end open-loop runs (virtual time)
# ---------------------------------------------------------------------------

def test_open_loop_run_serves_everything_with_components():
    corp, live0 = _get_base()
    live = copy.deepcopy(live0)
    loop = PipelinedServeLoop(live, max_batch=8, deadline_ms=5.0,
                              clock=FakeClock(), depth=2)
    spec = TrafficSpec(qps=60.0, duration_s=1.0, n_sessions=4,
                       probe_mix=((1, 0.7), (2, 0.3)), seed=11)
    res = OpenLoopDriver(loop, corp.embeddings, spec).run()
    assert len(res.records) > 30
    assert all(r.outcome == SERVED and r.t_done is not None
               for r in res.records)
    for r in res.records:
        assert r.t_done > r.t_arrival
        assert r.queue_ms > 0 and r.encode_ms > 0 and r.decode_ms > 0
    s = res.summary(deadline_ms=1000.0)
    assert s["served"] == s["offered"] == len(res.records)
    assert s["shed"] == 0 and s["attainment"] == 1.0
    assert 0 < s["p50_ms"] <= s["p99_ms"] < float("inf")
    assert s["components"]["queue_ms"]["mean"] > 0


def test_open_loop_with_mutations_syncs_sessions_exactly():
    """Commits during the run leave sessions behind; every synced byte is
    charged to exactly one request record (proactive or reactive)."""
    corp, live0 = _get_base()
    live = copy.deepcopy(live0)
    loop = PIRServeLoop(live, max_batch=4, deadline_ms=5.0,
                        clock=FakeClock())
    spec = TrafficSpec(qps=50.0, duration_s=1.2, n_sessions=3,
                       probe_mix=((1, 1.0),), staleness_tolerance=0,
                       mutation_qps=5.0, seed=5)
    res = OpenLoopDriver(loop, corp.embeddings, spec,
                         mutator=_mutator).run()
    assert res.commits >= 1
    assert all(r.outcome == SERVED for r in res.records)
    charged = sum(r.hint_sync_bytes for r in res.records)
    assert charged == res.session_sync_bytes > 0
    s = res.summary(deadline_ms=1000.0)
    assert s["commits"] == res.commits
    assert s["hint_sync_bytes"] == charged
    assert s["components"]["hint_sync_ms"]["mean"] >= 0


def test_open_loop_mixed_lookup_traffic_per_kind_slo():
    """lookup_mix routes a reproducible share of arrivals through
    `submit_lookup` on a keyed system; the SLO fold reports each kind's
    attainment separately and the per-kind counts partition the total."""
    rng = np.random.default_rng(21)
    table = rng.standard_normal((144, 8)).astype(np.float32)
    live = LiveIndex.build_keyed(table, kappa=6, impl="xla", seed=0)
    loop = PipelinedServeLoop(live, max_batch=8, deadline_ms=5.0,
                              clock=FakeClock(), depth=2)
    spec = TrafficSpec(qps=60.0, duration_s=1.0, n_sessions=4,
                       probe_mix=((1, 1.0),), lookup_mix=0.5,
                       lookup_kappa=6, seed=13)
    res = OpenLoopDriver(loop, table, spec).run()
    kinds = {r.kind for r in res.records}
    assert kinds == {"query", "lookup"}
    assert all(r.outcome == SERVED for r in res.records)
    s = res.summary(deadline_ms=1000.0)
    assert set(s["kinds"]) == {"query", "lookup"}
    assert (s["kinds"]["query"]["offered"] + s["kinds"]["lookup"]["offered"]
            == s["offered"])
    for k in ("query", "lookup"):
        assert s["kinds"][k]["offered"] > 5          # the mix really mixes
        assert s["kinds"][k]["served"] == s["kinds"][k]["offered"]
        assert s["kinds"][k]["attainment"] == 1.0
        assert 0 < s["kinds"][k]["p50_ms"] <= s["kinds"][k]["p99_ms"]
    # determinism: the same seed reproduces the same kind sequence
    live2 = LiveIndex.build_keyed(table, kappa=6, impl="xla", seed=0)
    loop2 = PipelinedServeLoop(live2, max_batch=8, deadline_ms=5.0,
                               clock=FakeClock(), depth=2)
    res2 = OpenLoopDriver(loop2, table, spec).run()
    assert [r.kind for r in res.records] == [r.kind for r in res2.records]


def test_generate_ms_component_with_generator_loop():
    """A generator-equipped loop stamps `generate_ms` on every served
    record (tokenize + prefill + decode from Response.rag) and the SLO
    summary grows exactly one new component for it."""
    from repro.rag import Generator

    corp, live0 = _get_base()
    gen = Generator.tiny(seed=2, context_budget=64, max_new_tokens=4)
    loop = PipelinedServeLoop(copy.deepcopy(live0), max_batch=8,
                              deadline_ms=5.0, clock=FakeClock(), depth=2,
                              gen_coalesce=2, generator=gen)
    spec = TrafficSpec(qps=60.0, duration_s=0.8, n_sessions=3,
                       probe_mix=((1, 1.0),), seed=9)
    res = OpenLoopDriver(loop, corp.embeddings, spec).run()
    served = [r for r in res.records if r.outcome == SERVED]
    assert served and all(r.generate_ms > 0 for r in served)
    s = res.summary(deadline_ms=1000.0)
    assert s["components"]["generate_ms"]["mean"] > 0
    assert s["components"]["generate_ms"]["p99"] < float("inf")
    # end-to-end latency covers generation: t_done is the generation
    # completion time, so p50 must not undercut the generate component
    assert s["p50_ms"] > 0


def test_generate_ms_percentiles_propagate_inf():
    """An unserved (shed/failed) generating stream: generate_ms folds with
    the same inf-propagating rank rule as every latency percentile."""
    recs = [RequestRecord(rid=i, session=0, t_arrival=0.0, t_done=1e-3,
                          generate_ms=5.0) for i in range(98)]
    recs += [RequestRecord(rid=98, session=0, t_arrival=0.0, outcome=SHED,
                           generate_ms=float("inf")),
             RequestRecord(rid=99, session=0, t_arrival=0.0, t_done=1e-3,
                           generate_ms=float("inf"))]
    from repro.traffic import summarize
    s = summarize(recs, wall_s=1.0, deadline_ms=10.0)
    comp = s["components"]["generate_ms"]
    # p99 reaches into the served-inf record; the mean is inf-poisoned too
    assert comp["p99"] == float("inf") and comp["mean"] == float("inf")
    assert s["attainment"] < 1.0                 # the shed counts as a miss


def test_query_only_summary_byte_identical_regression():
    """Stream-preservation regression: a retrieval-only run's summary is
    byte-for-byte what it was before the generation stage existed — same
    component set (no `generate_ms` key), deterministic under FakeClock."""
    corp, live0 = _get_base()
    spec = TrafficSpec(qps=50.0, duration_s=0.8, n_sessions=3,
                       probe_mix=((1, 0.7), (2, 0.3)), seed=13)

    def run_once():
        loop = PIRServeLoop(copy.deepcopy(live0), max_batch=4,
                            deadline_ms=5.0, clock=FakeClock())
        res = OpenLoopDriver(loop, corp.embeddings, spec).run()
        return res.summary(deadline_ms=1000.0)

    import json
    a, b = run_once(), run_once()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert "generate_ms" not in a["components"]
    assert list(a["components"]) == ["queue_ms", "encode_ms", "gemm_ms",
                                     "decode_ms", "hint_sync_ms"]


def test_open_loop_overload_sheds_and_bounds_queue():
    """Offered load far above the virtual service rate: the controller
    sheds the excess, every offered request is accounted exactly once, and
    the queue never outgrows max_queue + one arrival burst."""
    corp, live0 = _get_base()
    live = copy.deepcopy(live0)
    # big clock step makes service slow in VIRTUAL time: each clock read
    # costs 2 ms, so a tick (several reads) can't keep up with 400 qps
    loop = PipelinedServeLoop(live, max_batch=2, deadline_ms=1.0,
                              clock=FakeClock(step=2e-3), depth=1)
    spec = TrafficSpec(qps=400.0, duration_s=0.6, n_sessions=2,
                       probe_mix=((1, 1.0),), seed=9)
    ctl = AdmissionController(max_queue=6, defer_queue=3, max_depth=2)
    res = OpenLoopDriver(loop, corp.embeddings, spec, controller=ctl).run()
    s = res.summary(deadline_ms=50.0)
    assert s["shed"] == ctl.shed_total > 0
    assert s["served"] + s["shed"] == s["offered"]
    assert s["attainment"] < 1.0
    assert s["p99_ms"] == float("inf")             # sheds dominate the tail
    served_lat = [r.latency_ms for r in res.records if r.outcome == SERVED]
    assert max(served_lat) < float("inf")
    assert loop.batcher.depth == 0                 # drained at the end
    assert s["admission"]["shed"] == s["shed"]
