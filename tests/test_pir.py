"""End-to-end SimplePIR protocol tests: exact private column retrieval."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lwe, pir


def _setup(m=192, n=512, q_switch=1 << 16, seed=0, impl="xla"):
    rng = np.random.default_rng(seed)
    db = jnp.asarray(rng.integers(0, 256, (m, n), dtype=np.uint8))
    cfg = pir.make_config(m, n, impl=impl, q_switch=q_switch)
    server = pir.PIRServer(cfg, db)
    hint = server.setup()
    client = pir.PIRClient(cfg, hint)
    return db, cfg, server, client


@pytest.mark.parametrize("q_switch", [None, 1 << 16])
def test_e2e_exact_retrieval(q_switch):
    db, cfg, server, client = _setup(q_switch=q_switch)
    for i, idx in enumerate([0, 7, 511]):
        qu, state = client.query(jax.random.PRNGKey(100 + i), idx)
        ans = server.answer(qu)
        got = client.recover(ans, state)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(db[:, idx]))


def test_e2e_with_pallas_server():
    db, cfg, server, client = _setup(m=64, n=128, impl="pallas")
    qu, state = client.query(jax.random.PRNGKey(0), 42)
    ans = server.answer(qu)
    np.testing.assert_array_equal(np.asarray(client.recover(ans, state)),
                                  np.asarray(db[:, 42]))


def test_batched_answers_match_individual():
    """Server GEMM over stacked queries == per-query GEMVs (multi-client)."""
    db, cfg, server, client = _setup()
    keys = [jax.random.PRNGKey(i) for i in range(4)]
    idxs = [3, 99, 200, 511]
    qus, states = zip(*[client.query(k, i) for k, i in zip(keys, idxs)])
    batch = jnp.stack(qus, axis=1)                      # (n, B)
    ans_b = server.answer(batch)                        # (m, B)
    for j, (state, idx) in enumerate(zip(states, idxs)):
        got = client.recover(ans_b[:, j], state)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(db[:, idx]))


def test_uplink_downlink_accounting():
    _, cfg, _, _ = _setup(m=1000, n=256)
    assert cfg.uplink_bytes == 256 * 4
    assert cfg.downlink_bytes == 1000 * 2      # modulus-switched u16
    cfg_raw = pir.make_config(1000, 256, q_switch=None)
    assert cfg_raw.downlink_bytes == 1000 * 4  # raw u32
    assert cfg.hint_bytes == 1000 * cfg.params.k * 4


def test_config_rejects_unsafe_noise():
    params = lwe.LWEParams(p=256, sigma=1e7)
    with pytest.raises(ValueError):
        pir.PIRConfig(m=8, n=1 << 14, params=params)


def test_two_queries_same_column_different_ciphertexts():
    """Fresh randomness per query: same index ⇒ different uplink bytes."""
    _, _, server, client = _setup()
    qu1, _ = client.query(jax.random.PRNGKey(1), 5)
    qu2, _ = client.query(jax.random.PRNGKey(2), 5)
    assert not np.array_equal(np.asarray(qu1), np.asarray(qu2))
