"""Fault-tolerant fleet serving: the degradation contract, property-tested.

The contract under test (docs/fleet.md): with NO faults injected, a
`FleetServeLoop` over a replica group is bit-identical to a plain
`PipelinedServeLoop` — same responses, same payloads, same virtual-clock
trajectory.  Under injected faults, every degradation is bounded and
observable: answers degrade to bounded staleness (never wrong payloads),
retries are budgeted (terminal FAILED, never an unbounded loop), corrupt
hint chains cost one full re-sync (never a wrong hint), and a recovered
host is bit-identical to one that never failed (journal-replay recovery).

Chaos properties draw seeded random fault plans × random interleavings;
the 8-fake-device placement case is slow-marked for CI's multi-device step.
"""
import copy

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _mesh_harness import run_sub

from repro.data import corpus as corpus_lib
from repro.fleet import (FaultEvent, FaultPlan, FleetServeLoop, ReplicaGroup,
                         RetryPolicy, SITE_ANSWER_DELAY, SITE_ANSWER_DROP,
                         SITE_CHAIN_CORRUPT, SITE_COMMIT_FAIL,
                         SITE_SHARD_LOSS, readmit)
from repro.fleet import recovery
from repro.serve import PipelinedServeLoop
from repro.traffic import OpenLoopDriver, TrafficSpec
from repro.traffic.slo import FAILED, SERVED, SHED
from repro.update import LiveIndex, journal as journal_lib
from repro.update.epochs import CorruptPatchError, HintCache

N_DOCS = 120
EMB = 16
SYNC_LAG = 2


class FakeClock:
    """Monotone virtual clock advancing a fixed step per reading."""

    def __init__(self, step: float = 1e-4):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


_BASE: dict = {}


def _get_base():
    if not _BASE:
        corp = corpus_lib.make_corpus(5, N_DOCS, emb_dim=EMB, n_topics=5)
        live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=5,
                               impl="xla", kmeans_iters=5, compact_every=2)
        _BASE["corp"], _BASE["live"] = corp, live
    return _BASE["corp"], _BASE["live"]


def _mutation(i: int, corp):
    return journal_lib.replace(i % N_DOCS, f"mut {i}".encode(),
                               corp.embeddings[(i + 1) % N_DOCS])


def _signature(loop):
    return [(r.rid, r.epoch, r.retries, r.batch_size, r.failed,
             getattr(r, "staleness", 0),
             tuple((d, t) for d, _, t in r.top)) for r in loop.responses]


def _drive(loop, corp, *, n_ops: int = 30, seed: int = 0):
    """A seeded submit/mutate/tick interleaving, identical across loops."""
    rng = np.random.default_rng(seed)
    for i in range(n_ops):
        loop.submit(i, corp.embeddings[int(rng.integers(N_DOCS))], top_k=3)
        roll = int(rng.integers(10))
        if roll < 2:
            loop.submit_mutation(_mutation(i, corp))
        if roll >= 7:
            loop.tick()
    loop.drain()


def _fleet(live, *, faults=None, n_replicas=2, n_shards=4, retry=None,
           **group_kwargs):
    group = ReplicaGroup.from_live(copy.deepcopy(live),
                                   n_replicas=n_replicas, n_shards=n_shards,
                                   sync_lag=SYNC_LAG, **group_kwargs)
    kwargs = {} if retry is None else {"retry": retry}
    loop = FleetServeLoop(group, max_batch=4, deadline_ms=1e9,
                          clock=FakeClock(), seed=0, depth=2, faults=faults,
                          **kwargs)
    return group, loop


# ---------------------------------------------------------------------------
# No-fault bit-identity (the regression that keeps the fleet layer free)
# ---------------------------------------------------------------------------

def test_no_fault_fleet_identical_to_pipelined():
    """Fleet wrapper with no faults ≡ plain pipelined loop, bit for bit.

    Clock END TIME is compared too: the fleet tick must not add a single
    virtual-clock reading on the un-faulted path.
    """
    corp, base = _get_base()
    plain = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                               deadline_ms=1e9, clock=FakeClock(), seed=0,
                               depth=2)
    _drive(plain, corp)
    for faults in (None, FaultPlan.none().compile()):
        group, fleet = _fleet(base, faults=faults)
        _drive(fleet, corp)
        assert _signature(fleet) == _signature(plain)
        assert fleet.clock.t == plain.clock.t          # same clock reads
        assert fleet.epoch == plain.epoch
        assert fleet.stale_retries == plain.stale_retries
        assert group.failovers == 0 and not group.outage
        assert all(r.staleness == 0 for r in fleet.responses)


def test_group_build_ranks_identical():
    """from_live/build replicas start bit-identical; placement is disjoint."""
    corp, base = _get_base()
    group = ReplicaGroup.from_live(copy.deepcopy(base), n_replicas=2,
                                   n_shards=4)
    h0, h1 = group.hosts[0].live, group.hosts[1].live
    assert np.array_equal(np.asarray(h0.system.hint),
                          np.asarray(h1.system.hint))
    assert h0.epoch == h1.epoch
    rows = [set(int(d) for d in row) for row in group.placement]
    assert rows[0].isdisjoint(rows[1])
    assert group.rank_state(0) == "healthy"
    assert group.device_state(5) == "healthy"


# ---------------------------------------------------------------------------
# Bounded retries: terminal FAILED instead of ping-pong
# ---------------------------------------------------------------------------

def test_stale_retry_budget_is_terminal():
    """A client that keeps losing the epoch race fails after the budget.

    Each tick commits a fresh epoch, so the re-admitted epoch is stale
    again immediately — without a budget this ping-pongs forever (the PR's
    satellite bug).  With max_retries=2 the request FAILS at retry 3 and
    served + failed == submitted.
    """
    corp, base = _get_base()
    loop = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                              deadline_ms=0.0, clock=FakeClock(), seed=0,
                              depth=1, retry=RetryPolicy(max_retries=2))
    loop.submit_mutation(_mutation(0, corp))
    loop.tick()                                    # epoch 1; client stays at 0
    loop.submit(0, corp.embeddings[0], top_k=3, epoch=0)
    for i in range(1, 8):                          # commit every tick: always stale
        loop.submit_mutation(_mutation(i, corp))
        loop.tick()
    loop.drain()
    assert loop.failed_requests == 1
    assert len(loop.responses) == 1
    r = loop.responses[0]
    assert r.failed and r.retries == 3 and r.top == []


def test_backoff_requeue_is_deterministic():
    """Nonzero backoff holds retries for a bounded, seeded delay."""
    corp, base = _get_base()
    sigs = []
    for _ in range(2):
        loop = PipelinedServeLoop(
            copy.deepcopy(base), max_batch=4, deadline_ms=1e9,
            clock=FakeClock(), seed=0, depth=1,
            retry=RetryPolicy(max_retries=8, backoff_base_ms=1.0))
        loop.submit_mutation(_mutation(0, corp))
        loop.tick()
        loop.submit(0, corp.embeddings[0], top_k=3, epoch=0)
        loop.submit(1, corp.embeddings[1], top_k=3)
        loop.drain()
        assert {r.rid for r in loop.responses} == {0, 1}
        assert not any(r.failed for r in loop.responses)
        sigs.append(_signature(loop))
    assert sigs[0] == sigs[1]


# ---------------------------------------------------------------------------
# Injected faults, one site at a time
# ---------------------------------------------------------------------------

def test_commit_fault_retries_to_identical_state():
    """A failed staged commit retries with backoff; no mutation is lost.

    The journal keeps the pending batch across injected failures, so the
    eventual retried commit folds EVERY accumulated mutation — fewer,
    fatter epochs than the clean run (freshness degrades during the
    outage), but the final database/hint content is bit-identical.
    """
    corp, base = _get_base()

    def run(faults):
        loop = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                                  deadline_ms=1e9, clock=FakeClock(),
                                  seed=0, depth=1, faults=faults)
        for i in range(3):
            loop.submit_mutation(_mutation(i, corp))
            loop.submit(i, corp.embeddings[i], top_k=3)
            loop.tick()
        loop.drain()
        return loop

    clean = run(None)
    plan = FaultPlan((FaultEvent(SITE_COMMIT_FAIL, at=0),
                      FaultEvent(SITE_COMMIT_FAIL, at=1)))
    faulted = run(plan.compile())
    assert clean.epoch == 3
    assert 1 <= faulted.epoch <= 3           # retried commits fold batches
    assert np.array_equal(np.asarray(faulted.live.system.hint),
                          np.asarray(clean.live.system.hint))
    assert faulted.obs.metrics.counter("fleet.commit_failures").value == 2
    assert len(faulted.responses) == 3
    assert not any(r.failed for r in faulted.responses)


def test_answer_drop_charges_retry_and_serves():
    """A dropped answer is retried (budgeted) and eventually served."""
    corp, base = _get_base()
    plan = FaultPlan((FaultEvent(SITE_ANSWER_DROP, at=0),))
    loop = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                              deadline_ms=1e9, clock=FakeClock(), seed=0,
                              depth=1, faults=plan.compile())
    loop.submit(0, corp.embeddings[0], top_k=3)
    loop.drain()
    (r,) = loop.responses
    assert not r.failed and r.retries == 1 and len(r.top) == 3
    assert loop.obs.metrics.counter("fleet.answer_drops").value == 1


def test_answer_delay_holds_then_serves():
    """A delayed answer is late (loop-clock time), not lost: no retry."""
    corp, base = _get_base()
    plan = FaultPlan((FaultEvent(SITE_ANSWER_DELAY, at=0, delay_s=0.05),))
    loop = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                              deadline_ms=1e9, clock=FakeClock(), seed=0,
                              depth=1, faults=plan.compile())
    loop.submit(0, corp.embeddings[0], top_k=3)
    loop.drain()
    (r,) = loop.responses
    assert not r.failed and r.retries == 0 and len(r.top) == 3
    assert r.t_done - r.t_arrival > 0.05           # held for the delay window
    assert loop.obs.metrics.counter("fleet.answer_delays").value == 1


def test_chain_corruption_costs_one_full_resync():
    """A corrupt downloaded patch → checksum catch → one full re-sync.

    The client's hint must come out EXACT (bit-identical to the log's),
    and the cost is observable: wasted chain bytes + one full download.
    """
    corp, base = _get_base()
    live = copy.deepcopy(base)
    cache = HintCache(live.system.hint, live.system.cfg, epoch=0)
    for i in range(3):
        live.journal.append(_mutation(i, corp))
        live.commit()
    plan = FaultPlan((FaultEvent(SITE_CHAIN_CORRUPT, at=0),))
    live.epochs.faults = plan.compile()
    before = cache.bytes_downloaded
    cache.sync(live.epochs)
    assert cache.resyncs == 1
    assert cache.epoch == live.epoch
    assert np.array_equal(np.asarray(cache.hint),
                          np.asarray(live.system.hint))
    # paid: the (wasted) chain plus at least one full hint download
    assert cache.bytes_downloaded - before > live.system.cfg.hint_bytes
    # same corruption with no fallback is a hard error, never a wrong hint
    cache2 = HintCache(base.system.hint, base.system.cfg, epoch=0)
    live.epochs.faults = FaultPlan(
        (FaultEvent(SITE_CHAIN_CORRUPT, at=0),)).compile()
    live.epochs.full_fetch = None
    with pytest.raises(CorruptPatchError):
        cache2.sync(live.epochs)


# ---------------------------------------------------------------------------
# Failover / failback / recovery
# ---------------------------------------------------------------------------

def test_failover_failback_and_bitwise_recovery():
    """The headline scenario: lose rank 0's device, fail over, come back.

    Asserts the full degradation contract: exactly one failover and one
    failback, bounded staleness on every response, and the recovered rank
    0 bit-identical to a never-failed host (fresh copy + journal replay).
    """
    corp, base = _get_base()
    plan = FaultPlan.single_shard_loss(at_tick=3, device=0, down_ticks=6)
    group, fleet = _fleet(base, faults=plan.compile())
    _drive(fleet, corp, n_ops=40)
    assert group.failovers == 1 and group.failbacks == 1
    assert group.authority_rank == 0
    assert group.hosts[0].readmissions == 1
    assert len(group.replay_reports) == 1 and group.replay_reports[0].wall_s >= 0
    # every request answered; staleness never exceeded the follower lag bound
    assert len(fleet.responses) == 40
    assert all(r.staleness <= SYNC_LAG for r in fleet.responses)
    # recovered ≡ never-failed: replay rank 0's journal into a fresh copy
    fresh = copy.deepcopy(base)
    readmit(fresh, group.hosts[0].live.journal)
    h0 = group.hosts[0].live
    assert fresh.epoch == h0.epoch
    assert np.array_equal(np.asarray(fresh.system.hint),
                          np.asarray(h0.system.hint))


def test_total_outage_queues_then_recovers():
    """Both ranks down: the loop queues (sheds nothing silently) and
    serves everything once a device returns."""
    corp, base = _get_base()
    plan = FaultPlan(tuple(
        FaultEvent(SITE_SHARD_LOSS, at=2, device=d, down_ticks=5)
        for d in range(8)))
    group, fleet = _fleet(base, faults=plan.compile())
    _drive(fleet, corp, n_ops=20)
    assert group.obs.metrics.counter("fleet.outages").value >= 1
    assert len(fleet.responses) == 20
    assert not any(r.failed for r in fleet.responses)


def test_recovery_replay_is_exact():
    """epoch_batches groups the journal by commit; replay reproduces it."""
    corp, base = _get_base()
    live = copy.deepcopy(base)
    for i in range(4):
        live.journal.append(_mutation(2 * i, corp))
        live.journal.append(_mutation(2 * i + 1, corp))
        live.commit()
    batches = recovery.epoch_batches(live.journal, 0)
    assert [e for e, _ in batches] == [1, 2, 3, 4]
    assert all(len(b) == 2 for _, b in batches)
    assert recovery.epoch_batches(live.journal, 2) == batches[2:]
    cold = copy.deepcopy(base)
    report = recovery.readmit(cold, live.journal)
    assert (report.from_epoch, report.to_epoch) == (0, 4)
    assert report.epochs == 4 and report.mutations == 8
    assert np.array_equal(np.asarray(cold.system.hint),
                          np.asarray(live.system.hint))
    # the recovered journal is complete: it can source the NEXT recovery
    cold2 = copy.deepcopy(base)
    recovery.readmit(cold2, cold.journal)
    assert np.array_equal(np.asarray(cold2.system.hint),
                          np.asarray(live.system.hint))


# ---------------------------------------------------------------------------
# Chaos: random fault plans × random interleavings
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_chaos_invariants(seed):
    """Under ANY seeded fault schedule: every request terminates (served or
    failed), staleness stays within the lag bound, the run is replayable
    bit-for-bit, and all ranks converge to identical state after replay."""
    corp, base = _get_base()
    plan = FaultPlan.random(seed, n_events=6, horizon=12, n_devices=8,
                            max_down_ticks=6, max_delay_s=0.01)
    n_ops = 24

    def run():
        group, fleet = _fleet(base, faults=plan.compile(),
                              retry=RetryPolicy(max_retries=8))
        _drive(fleet, corp, n_ops=n_ops, seed=seed)
        return group, fleet

    group, fleet = run()
    assert len(fleet.responses) == n_ops           # served + failed == offered
    assert fleet.failed_requests == sum(r.failed for r in fleet.responses)
    assert all(r.staleness <= SYNC_LAG for r in fleet.responses)
    assert fleet.inflight == 0 and not fleet.batcher.queue
    # determinism: the same plan replays to the same responses
    group2, fleet2 = run()
    assert _signature(fleet) == _signature(fleet2)
    # convergence: replaying every rank to the head leaves them identical
    head = max((h.live for h in group.hosts), key=lambda l: l.epoch)
    for host in group.hosts:
        if host.live.epoch < head.epoch:
            recovery.readmit(host.live, head.journal)
        assert host.live.epoch == head.epoch
        assert np.array_equal(np.asarray(host.live.system.hint),
                              np.asarray(head.system.hint))


# ---------------------------------------------------------------------------
# Traffic over a faulted fleet: SLO accounting stays conserved
# ---------------------------------------------------------------------------

def test_traffic_accounting_under_faults():
    """Open-loop traffic over a faulted fleet: served+shed+failed==offered,
    session sync bytes stay exact, and the summary carries the failures."""
    corp, base = _get_base()
    plan = FaultPlan((
        FaultEvent(SITE_SHARD_LOSS, at=4, device=0, down_ticks=4),
        FaultEvent(SITE_ANSWER_DROP, at=2),
        FaultEvent(SITE_ANSWER_DELAY, at=5, delay_s=0.005),
        FaultEvent(SITE_COMMIT_FAIL, at=1),
    ))
    group, fleet = _fleet(base, faults=plan.compile())
    spec = TrafficSpec(qps=150.0, duration_s=1.0, n_sessions=4,
                       mutation_qps=25.0, staleness_tolerance=1,
                       max_retries=6, seed=3)
    driver = OpenLoopDriver(fleet, corp.embeddings, spec,
                            mutator=lambda rng: _mutation(
                                int(rng.integers(N_DOCS)), corp))
    res = driver.run()
    s = res.summary(deadline_ms=1e9)
    assert s["offered"] == s["served"] + s["shed"] + s["failed"]
    assert s["served"] > 0
    charged = sum(r.hint_sync_bytes for r in res.records)
    assert charged <= res.session_sync_bytes or res.session_resyncs >= 0
    assert res.failed == sum(r.outcome == FAILED for r in res.records)


# ---------------------------------------------------------------------------
# Placement on 8 fake devices (CI multi-device step)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_replica_meshes_8dev():
    """R=2 × S=4 disjoint meshes on 8 fake devices: both ranks build the
    same sharded index, and the group fails over across real placements."""
    run_sub('''
import copy
from repro.data import corpus as corpus_lib
from repro.fleet import FaultPlan, FleetServeLoop, ReplicaGroup
from repro.launch.mesh import make_replica_meshes
from repro.update import LiveIndex, journal as journal_lib

meshes = make_replica_meshes(2, 4)
assert len(meshes) == 2
devs = [set(d.id for d in m.devices.ravel()) for m in meshes]
assert devs[0] == {0, 1, 2, 3} and devs[1] == {4, 5, 6, 7}

corp = corpus_lib.make_corpus(9, 96, emb_dim=16, n_topics=4)
group = ReplicaGroup.build(corp.texts, corp.embeddings, n_replicas=2,
                           n_shards=4, meshes=meshes, n_clusters=4,
                           impl="xla", kmeans_iters=3)
h0, h1 = group.hosts[0].live, group.hosts[1].live
assert np.array_equal(np.asarray(h0.system.hint), np.asarray(h1.system.hint))

class FakeClock:
    def __init__(self): self.t = 0.0
    def __call__(self): self.t += 1e-4; return self.t

plan = FaultPlan.single_shard_loss(at_tick=2, device=1, down_ticks=4)
loop = FleetServeLoop(group, max_batch=4, deadline_ms=1e9,
                      clock=FakeClock(), seed=0, faults=plan.compile())
for i in range(12):
    loop.submit(i, corp.embeddings[i], top_k=3)
    if i % 3 == 0:
        loop.submit_mutation(journal_lib.replace(
            i, b"m", corp.embeddings[(i + 1) % 96]))
        loop.tick()
loop.drain()
assert group.failovers >= 1 and group.failbacks >= 1
assert len(loop.responses) == 12
print("OK 8dev")
''')
