"""HLO structural analysis: trip-count-aware FLOPs and collective bytes."""
import subprocess
import sys


def run_sub(body: str):
    prelude = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis as ha
"""
    proc = subprocess.run(
        [sys.executable, "-c", prelude + body], capture_output=True,
        text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_scan_flops_are_trip_scaled():
    """cost_analysis counts the while body once; ours multiplies by trips."""
    run_sub("""
w = jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)
x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
def scanned(w, x):
    def body(c, wi): return c @ wi, None
    return jax.lax.scan(body, x, w)[0]
cc = jax.jit(scanned).lower(w, x).compile()
stats = ha.analyze(cc.as_text())
want = 16 * 2 * 8 * 64 * 64            # 16 iterations of (8,64)@(64,64)
got = stats.total_flops
assert abs(got - want) / want < 0.01, (got, want)
ca = cc.cost_analysis()
if isinstance(ca, (list, tuple)):      # jax < 0.5 wraps it in a list
    ca = ca[0] if ca else {}
xla = ca.get("flops", 0)
assert xla < want / 2                   # demonstrates the undercount
print("OK", got, xla)
""")


def test_collective_bytes_allreduce():
    run_sub("""
mesh = jax.make_mesh((8,), ("d",))
x = jax.ShapeDtypeStruct((64, 128), jnp.float32, sharding=NamedSharding(mesh, P("d", None)))
f = jax.jit(lambda x: jax.lax.with_sharding_constraint(jnp.sum(x, axis=0, keepdims=True) * 2.0, NamedSharding(mesh, P(None, None))) , )
cc = f.lower(x).compile()
stats = ha.analyze(cc.as_text())
assert stats.total_collective_bytes > 0
assert "all-reduce" in stats.collective_bytes or "all-gather" in stats.collective_bytes, stats.collective_bytes
print("OK", stats.collective_bytes)
""")


def test_matmul_tp_collectives_and_flops():
    """Megatron-style 2-way TP matmul: per-device flops = half; all-reduce
    wire bytes match 2·S·(g-1)/g."""
    run_sub("""
mesh = jax.make_mesh((1, 2), ("data", "model"))
B, D, F = 32, 128, 256
x = jax.ShapeDtypeStruct((B, D), jnp.float32, sharding=NamedSharding(mesh, P(None, None)))
w1 = jax.ShapeDtypeStruct((D, F), jnp.float32, sharding=NamedSharding(mesh, P(None, "model")))
w2 = jax.ShapeDtypeStruct((F, D), jnp.float32, sharding=NamedSharding(mesh, P("model", None)))
def f(x, w1, w2):
    h = jax.nn.relu(x @ w1)
    y = h @ w2
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P(None, None)))
cc = jax.jit(f).lower(x, w1, w2).compile()
stats = ha.analyze(cc.as_text())
want_flops = (2*B*D*F + 2*B*F*D) / 2          # per device
assert abs(stats.total_flops - want_flops) / want_flops < 0.05, (stats.total_flops, want_flops)
ar = stats.collective_bytes.get("all-reduce", 0)
want_ar = 2 * (B * D * 4) * (2 - 1) / 2       # ring all-reduce of y
assert abs(ar - want_ar) / want_ar < 0.05, (ar, want_ar)
print("OK", stats.total_flops, ar)
""")
