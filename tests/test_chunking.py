"""Chunk-transposed DB: serialization round-trips exactly (property-tested)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import chunking


def _corpus(rng, n_docs, emb_dim, max_text=100):
    texts = [rng.integers(0, 256, rng.integers(1, max_text),
                          dtype=np.uint8).tobytes() for _ in range(n_docs)]
    embs = rng.standard_normal((n_docs, emb_dim)).astype(np.float32)
    return texts, embs


def test_build_and_roundtrip_all_clusters():
    rng = np.random.default_rng(0)
    texts, embs = _corpus(rng, 40, 8)
    assign = rng.integers(0, 5, 40)
    db = chunking.build_chunked_db(texts, embs, assign, 5, chunk_size=64)
    assert db.m % 64 == 0
    assert db.matrix.dtype == np.uint8
    seen = set()
    for j in range(5):
        docs = chunking.deserialize_docs(db.matrix[:, j], db.emb_dim)
        assert len(docs) == int((assign == j).sum())
        for doc_id, emb, text in docs:
            assert text == texts[doc_id]
            # u8 quantization error bound: half a step of the affine grid
            step = (embs[doc_id].max() - embs[doc_id].min()) / 255.0
            assert np.abs(emb - embs[doc_id]).max() <= step / 2 + 1e-6
            seen.add(doc_id)
    assert seen == set(range(40))


@settings(max_examples=15, deadline=None)
@given(n_docs=st.integers(1, 30), n_clusters=st.integers(1, 6),
       emb_dim=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_property_pack_unpack_identity(n_docs, n_clusters, emb_dim, seed):
    rng = np.random.default_rng(seed)
    texts, embs = _corpus(rng, n_docs, emb_dim, max_text=40)
    assign = rng.integers(0, n_clusters, n_docs)
    db = chunking.build_chunked_db(texts, embs, assign, n_clusters)
    recovered = {}
    for j in range(n_clusters):
        for doc_id, _, text in chunking.deserialize_docs(db.matrix[:, j],
                                                         emb_dim):
            recovered[doc_id] = text
    assert recovered == {i: t for i, t in enumerate(texts)}


def test_empty_cluster_column_is_parseable():
    rng = np.random.default_rng(1)
    texts, embs = _corpus(rng, 4, 4)
    assign = np.zeros(4, np.int64)         # everything in cluster 0
    db = chunking.build_chunked_db(texts, embs, assign, 3)
    assert chunking.deserialize_docs(db.matrix[:, 1], 4) == []
    assert chunking.deserialize_docs(db.matrix[:, 2], 4) == []


def test_pad_fraction_reported():
    rng = np.random.default_rng(2)
    texts, embs = _corpus(rng, 20, 8)
    skew = np.zeros(20, np.int64)          # maximally skewed
    db_skew = chunking.build_chunked_db(texts, embs, skew, 4)
    even = np.arange(20) % 4               # balanced
    db_even = chunking.build_chunked_db(texts, embs, even, 4)
    assert db_skew.pad_fraction > db_even.pad_fraction
    assert db_skew.m > db_even.m           # downlink driver: max cluster bytes
