"""Height-aware bucket→device placement: LPT properties + bitwise parity.

`collectives.balanced_bucket_order` reorders the bucket stack so skewed
per-bucket heights spread evenly across devices.  The placement is pure
bookkeeping — each bucket's GEMM is complete on its owning device — so the
contract is twofold: the load balance properties hold on any height
profile, and the reorder is INVISIBLE to callers (answers bit-identical to
the 1-device / unsorted layout).  The multi-device parity case runs under
the 8-fake-device subprocess harness and is slow-marked; the pure
host-side properties run in tier-1.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _mesh_harness import run_sub
from repro.distributed import collectives


def _loads(heights, n_shards, order):
    return collectives.shard_row_loads(heights, n_shards, order=order)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_shards=st.sampled_from([2, 4, 8]),
       n_buckets=st.integers(2, 96))
def test_lpt_order_is_balanced_capacity_exact_permutation(seed, n_shards,
                                                          n_buckets):
    """The order is a proper permutation, fills every device with exactly
    B'/S buckets, and never loses to the sequential layout on max-load."""
    rng = np.random.default_rng(seed)
    heights = np.maximum(1, (rng.lognormal(0.0, 0.8, n_buckets)
                             * 1024)).astype(np.int64)
    order = collectives.balanced_bucket_order(heights, n_shards)
    b_pad = -(-n_buckets // n_shards) * n_shards
    assert sorted(order) == list(range(b_pad))         # permutation incl. pads
    lpt, seq = _loads(heights, n_shards, order), _loads(heights, n_shards,
                                                        None)
    assert lpt.sum() == seq.sum() == heights.sum()     # no rows lost
    assert lpt.max() <= seq.max()                      # never worse than seq


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_lpt_order_permutation_stable(seed):
    """Permuting the input heights permutes the assignment but reproduces
    the same per-device load MULTISET — placement depends on the height
    set, not on bucket numbering."""
    rng = np.random.default_rng(seed)
    n_shards = int(rng.choice([2, 4, 8]))
    heights = rng.integers(1, 10_000, int(n_shards * rng.integers(1, 12)))
    base = np.sort(_loads(heights, n_shards,
                          collectives.balanced_bucket_order(heights,
                                                            n_shards)))
    perm = rng.permutation(len(heights))
    shuf = np.sort(_loads(heights[perm], n_shards,
                          collectives.balanced_bucket_order(heights[perm],
                                                            n_shards)))
    np.testing.assert_array_equal(base, shuf)


def test_lpt_reduces_imbalance_on_skewed_heights():
    """On a heavy-tailed profile the win is material, not epsilon: the
    benchmark-reported max/mean metric must drop."""
    rng = np.random.default_rng(0)
    heights = np.maximum(128, (rng.lognormal(0.0, 0.6, 48)
                               * 8192)).astype(np.int64)
    seq, lpt = (_loads(heights, 8, None),
                _loads(heights, 8, collectives.balanced_bucket_order(
                    heights, 8)))
    assert lpt.max() / lpt.mean() < 0.9 * (seq.max() / seq.mean())


@pytest.mark.slow
def test_sharded_keyed_answers_bit_identical_across_layouts():
    """8-device height-aware stack ≡ 1-device layout, bit for bit, through
    a mutation epoch — with a genuinely non-identity LPT permutation."""
    out = run_sub("""
from repro.update import LiveIndex

rng = np.random.default_rng(4)
table = rng.standard_normal((600, 8)).astype(np.float32)
mesh = jax.make_mesh((8,), ("chunks",))
live1 = LiveIndex.build_keyed(table, kappa=8, impl="xla", seed=0)
live8 = LiveIndex.build_keyed(table, kappa=8, impl="xla", seed=0, mesh=mesh)
sys1, sys8 = live1.system, live8.system
assert sys8.batch.server.mesh is not None

ids = ((rng.zipf(1.2, size=8) - 1) % 600).astype(np.int64)
r1, _ = sys1.lookup(ids, key=jax.random.PRNGKey(2))
r8, _ = sys8.lookup(ids, key=jax.random.PRNGKey(2))
np.testing.assert_array_equal(r1, table[ids])
np.testing.assert_array_equal(r1, r8)

# the placement must actually be exercised: skewed keyed heights (the
# short last group plus granule rounding) or padding must move buckets
srv = sys8.batch.server
assert srv._order is not None
print("ORDER_NONTRIVIAL",
      bool((srv._order != np.arange(len(srv._order))).any()))

# mutation epoch patches the stack through the slot indirection
new = rng.standard_normal((2, 8)).astype(np.float32)
for live in (live1, live8):
    live.replace_row(int(ids[0]), new[0])
    live.replace_row(599, new[1])
    live.commit()
table[int(ids[0])], table[599] = new[0], new[1]
ask = np.concatenate([ids, [599]])
p1, _ = live1.lookup(ask, epoch=live1.epoch, key=jax.random.PRNGKey(3))
p8, _ = live8.lookup(ask, epoch=live8.epoch, key=jax.random.PRNGKey(3))
np.testing.assert_array_equal(p1, table[ask])
np.testing.assert_array_equal(p1, p8)
print("OK")
""")
    assert "ORDER_NONTRIVIAL True" in out, out
    assert "OK" in out
