"""Reusable multi-fake-device subprocess harness for sharding tests.

XLA pins the host-platform device count at first jax init, so a test that
needs N devices cannot run in the pytest process (which already initialised
jax with 1 CPU device).  Every multi-device case instead runs in a child
interpreter whose environment sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax imports.

Usage (from any test module):

    from _mesh_harness import run_sub

    def test_something_on_8_devices():
        run_sub('''
    mesh = jax.make_mesh((8,), ("model",))
    ...
    print("OK")
    ''')

The prelude the body runs under imports jax/jnp/np and the sharding names
(`Mesh`, `NamedSharding`, `P`) and asserts the device count, so bodies can
use them directly.  `run_sub` asserts a zero exit status and returns the
child's stdout for content assertions.
"""
from __future__ import annotations

import subprocess
import sys

ENV_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
assert jax.device_count() == {n}, jax.device_count()
"""


def run_sub(body: str, *, n_devices: int = 8, timeout: float = 600) -> str:
    """Run `body` in a child interpreter with n_devices fake CPU devices."""
    prelude = ENV_PRELUDE.format(n=n_devices)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + body],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout
