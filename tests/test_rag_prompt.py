"""Packing properties of the RAG prompt builder (`repro.rag.prompt`).

Four invariants the generation stage leans on:

  determinism      — same texts + spec → bitwise-same tokens, always
  budget exact     — packed length ≤ context_budget, GEN always fits
  whole-doc        — a document is packed in full or dropped in full,
                     never split (its bytes appear contiguously)
  accounting sums  — packed_bytes + dropped_bytes == bytes offered, and
                     n_docs + n_docs_dropped == docs offered
"""
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.rag import prompt as pl


def _texts(rng, n_docs, max_len):
    return [bytes(rng.integers(0, 256, int(rng.integers(0, max_len + 1)))
                  .astype(np.uint8)) for _ in range(n_docs)]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_docs=st.integers(0, 8),
       budget=st.integers(2, 120), max_len=st.integers(0, 60))
def test_pack_docs_properties(seed, n_docs, budget, max_len):
    rng = np.random.default_rng(seed)
    texts = _texts(rng, n_docs, max_len)
    spec = pl.PromptSpec(context_budget=budget)
    p = pl.pack_docs(texts, spec)

    # determinism: a second pack of the same inputs is bitwise identical
    q = pl.pack_docs(texts, spec)
    np.testing.assert_array_equal(p.tokens, q.tokens)

    # budget exact: never exceeds the cap, and the frame is always there
    assert 2 <= p.length <= budget
    assert p.tokens[0] == pl.BOS and p.tokens[-1] == pl.GEN

    # accounting sums exactly — nothing partially counted
    assert p.packed_bytes + p.dropped_bytes == sum(len(t) for t in texts)
    assert p.n_docs + p.n_docs_dropped == len(texts)
    assert p.n_docs + p.n_docs_dropped == n_docs

    # whole-doc: the payload between BOS and GEN is exactly the packed
    # docs' bytes joined by SEP, in rank order — no split, no reorder
    body = p.tokens[1:-1]
    expect = []
    used, kept = 1, []
    for t in texts:
        if used + len(t) + 1 + 1 <= spec.context_budget:
            kept.append(t)
            used += len(t) + 1
    for t in kept:
        expect.extend(int(b) for b in t)
        expect.append(pl.SEP)
    np.testing.assert_array_equal(body, np.asarray(expect, np.int32))
    assert p.n_docs == len(kept)
    assert p.packed_bytes == sum(len(t) for t in kept)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), batch=st.integers(1, 6),
       budget=st.integers(4, 80))
def test_pack_batch_grid_properties(seed, batch, budget):
    rng = np.random.default_rng(seed)
    spec = pl.PromptSpec(context_budget=budget)
    prompts = [pl.pack_docs(_texts(rng, int(rng.integers(0, 5)), 30), spec)
               for _ in range(batch)]
    grid, lengths = pl.pack_batch(prompts, spec)

    assert grid.shape == (batch, budget)       # static S per batch size
    assert grid.dtype == np.int32 and lengths.dtype == np.int32
    for i, p in enumerate(prompts):
        assert lengths[i] == p.length
        np.testing.assert_array_equal(grid[i, :p.length], p.tokens)
        assert (grid[i, p.length:] == pl.PAD).all()


def test_round_trip_bytes():
    """decode_tokens(pack(texts)) recovers the packed payload bytes."""
    spec = pl.PromptSpec(context_budget=64)
    texts = [b"hello world", b"second doc", b"x" * 200, b"tail"]
    p = pl.pack_docs(texts, spec)
    assert p.n_docs_dropped == 1 and p.dropped_bytes == 200
    assert pl.decode_tokens(p.tokens) == b"hello worldsecond doctail"


def test_long_doc_does_not_shadow_short_one():
    """An over-budget rank-2 doc is skipped; rank-3 still packs."""
    spec = pl.PromptSpec(context_budget=16)
    p = pl.pack_docs([b"aaaa", b"b" * 50, b"cc"], spec)
    assert p.n_docs == 2 and p.n_docs_dropped == 1
    assert pl.decode_tokens(p.tokens) == b"aaaacc"


def test_min_budget_degenerate():
    """budget=2 packs nothing but stays well-formed: [BOS][GEN]."""
    p = pl.pack_docs([b"a"], pl.PromptSpec(context_budget=2))
    assert p.length == 2 and p.n_docs == 0 and p.n_docs_dropped == 1
    np.testing.assert_array_equal(p.tokens, [pl.BOS, pl.GEN])
