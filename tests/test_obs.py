"""Privacy-safe observability (ISSUE 7): scrub gate, registry, tracer.

Three layers under test:

  * `scrub` — the typed allowlist is the privacy boundary: arrays, bytes
    and free-form strings must raise at RECORD time, in tests and
    production alike.
  * `MetricsRegistry` — counters/gauges/histograms merge associatively
    (property: any fold shape yields the identical fleet view), and the
    histogram percentile shares the slo fold's rank rule (inf propagation
    included).
  * `Tracer` — FakeClock-driven span trees are deterministic (stable ids,
    byte-identical exports) and correctly nested across the pipelined
    engine's in-flight depth; a full serve-loop export contains zero
    query-derived payload bytes (the audit greps the serialized JSON).
"""
import copy
import json

import numpy as np
import pytest

from test_serve_engine import (FakeClock, N_DOCS, _drive_scripted,
                               _get_base, _script_from_rng)

from repro.obs import (Histogram, MetricsRegistry, Obs, PrivacyViolation,
                       Span, Tracer, percentile, scrub, span_coverage,
                       validate_chrome_trace)
from repro.obs import trace as trace_mod
from repro.serve import PipelinedServeLoop
from repro.traffic.slo import _pct


# -- scrub: the privacy boundary ---------------------------------------------

def test_scrub_allows_numbers_and_registered_enums():
    assert scrub(True) is True
    assert scrub(np.bool_(False)) is False
    assert scrub(7) == 7 and type(scrub(np.int64(7))) is int
    assert scrub(1.5) == 1.5 and type(scrub(np.float32(1.5))) is float
    assert scrub(float("inf")) == float("inf")
    assert scrub("pipelined") == "pipelined"
    assert scrub("shed") == "shed"


@pytest.mark.parametrize("bad", [
    np.zeros(8),                      # a query embedding
    np.zeros((4, 4), np.uint32),      # an LWE ciphertext block
    b"decoded plaintext",
    bytearray(b"x"),
    "SELECT secret",                  # free-form string: not in the vocab
    None,
    [1, 2, 3],
    {"k": 1},
    complex(1, 2),
])
def test_scrub_rejects_payload_types(bad):
    with pytest.raises(PrivacyViolation):
        scrub(bad, where="test.attr")


def test_span_attrs_pass_through_scrub():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(PrivacyViolation):
        tr.span("t", query=np.zeros(4))
    with tr.span("t", n=3, engine="sync"):
        pass
    assert tr.spans[-1].attrs == {"n": 3, "engine": "sync"}
    reg = MetricsRegistry()
    with pytest.raises(PrivacyViolation):
        reg.counter("c").inc(np.zeros(2))
    with pytest.raises(PrivacyViolation):
        reg.histogram("h").record(b"bytes")


# -- registry: merge algebra + the shared rank rule --------------------------

def _random_registry(seed: int) -> MetricsRegistry:
    rng = np.random.default_rng(seed)
    reg = MetricsRegistry()
    for _ in range(40):
        roll = rng.integers(0, 3)
        if roll == 0:
            reg.counter(f"c{rng.integers(0, 4)}").inc(int(rng.integers(1, 9)))
        elif roll == 1:
            reg.gauge(f"g{rng.integers(0, 3)}").set(float(rng.normal()))
        else:
            h = reg.histogram(f"h{rng.integers(0, 3)}")
            v = float(rng.exponential(20.0))
            h.record(float("inf") if rng.integers(0, 10) == 0 else v)
    return reg


@pytest.mark.parametrize("seeds", [(1, 2, 3), (10, 11, 12), (5, 5, 9)])
def test_registry_merge_is_associative(seeds):
    a, b, c = (_random_registry(s) for s in seeds)
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert json.dumps(left.to_dict(), sort_keys=True) == \
        json.dumps(right.to_dict(), sort_keys=True)
    # operands untouched (merge is pure)
    assert json.dumps(a.to_dict()) == \
        json.dumps(_random_registry(seeds[0]).to_dict())


def test_registry_merge_identity_and_disjoint():
    a, empty = _random_registry(4), MetricsRegistry()
    assert a.merge(empty).to_dict() == a.to_dict()
    b = MetricsRegistry()
    b.counter("only_b").inc(2)
    merged = a.merge(b).to_dict()
    assert merged["only_b"] == 2
    assert merged["c0"] == a.to_dict()["c0"]


def test_percentile_shared_rank_rule_matches_slo():
    """slo._pct and obs.percentile are literally the same rank rule."""
    for vals in ([1.0, 2.0, 3.0], [5.0] * 98 + [float("inf")] * 2,
                 [float("inf")], [], [7.5]):
        arr = np.asarray(vals, np.float64)
        for q in (50, 90, 99):
            assert _pct(arr, q) == percentile(vals, q)
    assert percentile([5.0] * 98 + [float("inf")] * 2, 99) == float("inf")
    assert percentile([5.0] * 98 + [float("inf")] * 2, 50) == 5.0


def test_histogram_percentile_consistent_with_exact():
    """Bucketed percentile lands in the same bucket as the exact one."""
    rng = np.random.default_rng(0)
    vals = list(rng.exponential(30.0, size=500)) + [float("inf")] * 6
    h = Histogram("lat")
    for v in vals:
        h.record(v)
    for q in (50, 90, 99):
        exact = percentile(vals, q)
        bucketed = h.percentile(q)
        if np.isinf(exact):
            assert np.isinf(bucketed)
        else:
            # the bucket's upper edge is >= the exact order statistic and
            # no more than one bucket above it
            assert bucketed >= exact
            below = [b for b in h.bounds if b < bucketed]
            assert not below or below[-1] <= exact
    assert h.percentile(100) == float("inf")
    assert h.n == 506 and h.n_inf == 6


def test_histogram_merge_requires_same_bounds():
    a = Histogram("x", bounds=(1.0, 2.0))
    b = Histogram("x", bounds=(1.0, 3.0))
    with pytest.raises(AssertionError):
        a.merge_from(b)
    with pytest.raises(AssertionError):
        Histogram("nan").record(float("nan"))


def test_registry_rejects_type_confusion():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(AssertionError):
        reg.gauge("m")


# -- tracer: deterministic trees, nesting, export ----------------------------

def _nested_schedule(tr: Tracer):
    with tr.span("a", n=1):
        with tr.span("b"):
            tr.instant("mark", n=2)
        with tr.span("c"):
            pass
    with tr.span("d"):
        pass


def test_span_tree_deterministic_under_fake_clock():
    exports = []
    for _ in range(2):
        tr = Tracer(clock=FakeClock())
        _nested_schedule(tr)
        exports.append(json.dumps(tr.to_chrome(), sort_keys=True))
    assert exports[0] == exports[1]
    tr = Tracer(clock=FakeClock())
    _nested_schedule(tr)
    by_name = {s.name: s for s in tr.spans}
    assert by_name["a"].parent is None and by_name["d"].parent is None
    assert by_name["b"].parent == by_name["a"].sid
    assert by_name["c"].parent == by_name["a"].sid
    assert tr.instants[0].parent == by_name["b"].sid
    # sequential sids in open order: a=0, b=1, mark=2, c=3, d=4
    assert [by_name[n].sid for n in "abcd"] == [0, 1, 3, 4]


def test_untraced_runs_read_the_clock_identically():
    """keep=False must not change virtual time: BatchTiming parity depends
    on traced and untraced runs making the SAME clock reads."""
    clocks = []
    for keep in (False, True):
        fc = FakeClock()
        tr = Tracer(clock=fc, keep=keep)
        with tr.span("a", n=1):
            with tr.span("b"):
                pass
        clocks.append(fc.t)
    assert clocks[0] == clocks[1]
    tr = Tracer(clock=FakeClock(), keep=False)
    _nested_schedule(tr)
    assert tr.spans == [] and tr.instants == []


def test_span_coverage():
    def sp(t0, t1, parent=None):
        return Span(name="s", sid=0, parent=parent, t0=t0, t1=t1)
    assert span_coverage([sp(0, 1), sp(1, 2)]) == 1.0
    assert span_coverage([sp(0, 1), sp(3, 4)]) == pytest.approx(0.5)
    assert span_coverage([sp(0, 2), sp(1, 4)]) == 1.0
    # nested spans don't double-cover under roots_only
    assert span_coverage([sp(0, 4), sp(1, 2, parent=0)]) == 1.0
    assert span_coverage([]) == 0.0


def test_validate_chrome_trace():
    tr = Tracer(clock=FakeClock())
    _nested_schedule(tr)
    obj = tr.to_chrome()
    assert validate_chrome_trace(obj) == []
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    assert validate_chrome_trace([1, 2]) == ["top level must be an object"]


def test_kernel_annotation_zero_overhead_when_disabled():
    assert not trace_mod.kernel_annotations_enabled()
    ctx = trace_mod.kernel_annotation("pirrag.modmatmul.xla")
    assert ctx is trace_mod.kernel_annotation("other")   # shared no-op
    trace_mod.enable_kernel_annotations(True)
    try:
        from jax.profiler import TraceAnnotation
        assert isinstance(trace_mod.kernel_annotation("k"), TraceAnnotation)
    finally:
        trace_mod.enable_kernel_annotations(False)


# -- the serve loop under trace: nesting, determinism, privacy ---------------

def _traced_loop(base, *, depth=2, trace=True):
    fc = FakeClock()
    obs = Obs(clock=fc, trace=trace)
    return PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                              deadline_ms=1e9, clock=fc, seed=0,
                              depth=depth, obs=obs), obs


def test_serve_trace_spans_nest_across_inflight_depth(base_live):
    """Plan spans parent under THEIR tick; the gemm/complete spans of a
    batch retired `depth` ticks later parent under the RETIRING tick —
    the pipeline overlap made visible in the trace structure."""
    corp, base = base_live
    loop, obs = _traced_loop(base, depth=3)
    for rid in range(16):
        loop.submit(rid, corp.embeddings[rid % N_DOCS])
        loop.tick()
    loop.drain()
    spans = obs.tracer.spans
    by_sid = {s.sid: s for s in spans}
    ticks = [s for s in spans if s.name == "serve.tick"]
    assert len(ticks) >= 4
    roots = {s.name for s in spans if s.parent is None}
    assert roots <= {"serve.tick", "serve.drain"}
    for s in spans:
        if s.name in ("serve.plan", "serve.gemm", "serve.complete"):
            parent = by_sid[s.parent]
            assert parent.name in ("serve.tick", "serve.drain")
            assert parent.t0 <= s.t0 and s.t1 <= parent.t1
    # with depth 3 some batch's complete span must sit under a YOUNGER
    # tick than its plan span (the in-flight window is real)
    plan_parents = [s.parent for s in spans if s.name == "serve.plan"]
    done_parents = [s.parent for s in spans if s.name == "serve.complete"]
    assert len(plan_parents) == len(done_parents)
    assert any(d > p for p, d in zip(plan_parents, done_parents))


def test_serve_trace_deterministic(base_live):
    """Same scripted schedule, same FakeClock: byte-identical exports."""
    corp, base = base_live
    ops = _script_from_rng(np.random.default_rng(23), 40)
    exports = []
    for _ in range(2):
        loop, obs = _traced_loop(base)
        _drive_scripted(loop, corp, ops)
        exports.append(json.dumps(obs.tracer.to_chrome(), sort_keys=True))
    assert exports[0] == exports[1]


def test_serve_trace_privacy_audit(base_live):
    """Full serve-loop export (mutations, multi-probe, retries): every args
    value re-passes the allowlist, and the serialized JSON contains no
    document payload bytes and no embedding-derived digit strings."""
    corp, base = base_live
    ops = _script_from_rng(np.random.default_rng(7), 50)
    loop, obs = _traced_loop(base)
    _drive_scripted(loop, corp, ops)
    assert loop.responses, "audit needs a real run"
    trace = obs.tracer.to_chrome()
    assert validate_chrome_trace(trace) == []
    for ev in trace["traceEvents"]:
        for key, val in ev["args"].items():
            scrub(val, where=f"{ev['name']}.{key}")     # raises on leak
    blob = json.dumps(trace)
    for text, _ in list(loop_docs(loop))[:20]:
        assert text.decode("latin-1") not in blob
    # embedding components serialize with long mantissas; no args float
    # should reproduce one (timings/counts never equal embedding values)
    emb_strs = {f"{v:.6f}" for v in np.asarray(corp.embeddings[:20]).ravel()
                if abs(v) > 1e-3}
    assert not any(s in blob for s in emb_strs)
    # metrics export is clean too
    json.dumps(obs.metrics_dict())


def loop_docs(loop):
    """The live index's (text, emb) pairs (test helper)."""
    return loop.live._docs.values()


def test_rag_trace_privacy_audit(base_live):
    """Generation spans record counts and timings ONLY — never token ids,
    prompt bytes or document text.  The audit drives a generator-equipped
    pipelined loop (coalesced micro-batches included), re-scrubs every
    exported args value, and greps the serialized JSON for each response's
    token ids and each packed document's payload."""
    import os

    from repro.rag import Generator

    corp, base = base_live
    fc = FakeClock()
    obs = Obs(clock=fc, trace=True)
    gen = Generator.tiny(seed=1, context_budget=64, max_new_tokens=4)
    loop = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                              deadline_ms=1e9, clock=fc, seed=0, depth=2,
                              gen_coalesce=2, obs=obs, generator=gen)
    for rid in range(16):
        loop.submit(rid, corp.embeddings[rid % N_DOCS], top_k=3)
        loop.tick()
    loop.drain()
    assert all(r.tokens is not None for r in loop.responses)

    trace = obs.tracer.to_chrome()
    assert validate_chrome_trace(trace) == []
    names = {ev["name"] for ev in trace["traceEvents"]}
    assert {"rag.tokenize", "rag.prefill", "rag.generate"} <= names
    # every emitted name is registered in the schema's closed vocabulary
    # (what scripts/check_trace.py enforces in CI)
    with open(os.path.join(os.path.dirname(__file__), "..", "scripts",
                           "trace_schema.json")) as f:
        allowed = set(json.load(f)["$spanNames"])
    assert names <= allowed, names - allowed
    for ev in trace["traceEvents"]:
        for key, val in ev["args"].items():
            scrub(val, where=f"{ev['name']}.{key}")     # raises on leak
    blob = json.dumps(trace)
    # no generated token sequence appears in any serialized form
    for r in loop.responses:
        assert str(list(r.tokens)) not in blob
        assert ",".join(str(t) for t in r.tokens) not in blob
    # no retrieved document payload appears either
    for text, _ in list(loop_docs(loop))[:20]:
        assert text.decode("latin-1") not in blob
    # generation counters are aggregates, never per-token values
    m = obs.metrics_dict()
    assert m["rag.generated_tokens"] == 16 * gen.max_new_tokens
    json.dumps(m)


def test_serve_metrics_populated(base_live):
    corp, base = base_live
    loop, obs = _traced_loop(base, trace=False)
    for rid in range(12):
        loop.submit(rid, corp.embeddings[rid % N_DOCS])
        loop.tick()
    loop.drain()
    m = obs.metrics_dict()
    assert m["serve.responses"] == 12
    assert m["serve.batch_size"]["n"] >= 1
    assert m["serve.latency_ms"]["n"] == 12
    assert m["serve.queue_depth"]["hi"] >= 1


def test_commit_spans_and_counters(base_live):
    from repro.update import journal as journal_lib
    corp, base = base_live
    loop, obs = _traced_loop(base)
    for rid in range(8):
        loop.submit(rid, corp.embeddings[rid % N_DOCS])
        if rid % 3 == 0:
            d = rid % N_DOCS
            loop.submit_mutation(journal_lib.replace(
                d, f"obs {d}".encode(), corp.embeddings[d]))
        loop.tick()
    loop.drain()
    names = {s.name for s in obs.tracer.spans}
    assert {"commit.stage", "commit.publish"} <= names
    m = obs.metrics_dict()
    assert m["commit.epochs"] == loop.epoch >= 1
    assert m["commit.patch_bytes"]["n"] == loop.epoch


@pytest.fixture(scope="module")
def base_live():
    return _get_base()
