"""Multi-device correctness on 8 fake CPU devices (subprocess-isolated).

The subprocess pattern lives in tests/_mesh_harness.py (XLA pins the device
count at first jax init, so every case runs in a child interpreter with
XLA_FLAGS=--xla_force_host_platform_device_count=8); the sharded-PIR
serving subsystem's equivalence suite (tests/test_sharded_pir.py) shares it.
"""
from _mesh_harness import run_sub


def test_sharded_embedding_lookup_matches_take():
    run_sub("""
from repro.distributed.collectives import sharded_embedding_lookup
mesh = jax.make_mesh((8,), ("model",))
table = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
idx = jax.random.randint(jax.random.PRNGKey(1), (32,), 0, 64)
table_sh = jax.device_put(table, NamedSharding(mesh, P("model", None)))
fn = jax.jit(sharded_embedding_lookup(mesh, "model"))
got = fn(table_sh, idx)
want = jnp.take(table, idx, axis=0)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
print("OK")
""")


def test_split_s_decode_attention_matches_reference():
    run_sub("""
from repro.distributed.collectives import split_s_decode_attention
mesh = jax.make_mesh((8,), ("seq",))
B, T, H, hd = 2, 64, 4, 8
kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(kq, (B, H, hd))
k = jax.random.normal(kk, (B, T, H, hd))
v = jax.random.normal(kv, (B, T, H, hd))
lengths = jnp.array([50, 64], jnp.int32)
scale = 1.0 / np.sqrt(hd)
k_sh = jax.device_put(k, NamedSharding(mesh, P(None, "seq")))
v_sh = jax.device_put(v, NamedSharding(mesh, P(None, "seq")))
fn = jax.jit(split_s_decode_attention(mesh, "seq", scale=scale))
got = fn(q, k_sh, v_sh, lengths)
# reference: plain masked softmax attention
s = jnp.einsum("bhd,bthd->bht", q, k) * scale
mask = jnp.arange(T)[None, None, :] < lengths[:, None, None]
s = jnp.where(mask, s, -1e30)
p = jax.nn.softmax(s, axis=-1)
want = jnp.einsum("bht,bthd->bhd", p, v)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
print("OK")
""")


def test_ring_psum_equals_allreduce():
    run_sub("""
from repro.distributed.collectives import ring_psum
mesh = jax.make_mesh((8,), ("data",))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None)))
# each device contributes its (1,4) row; ring sum = column-sum broadcast
from jax.experimental.shard_map import shard_map
fn = jax.jit(shard_map(lambda b: b, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
got = ring_psum(mesh, "data")(x_sh)
np.testing.assert_allclose(np.asarray(got)[0], np.asarray(x).sum(0) / 1.0, rtol=1e-6)
print("OK")
""")


def test_dp_train_step_identical_to_single_device():
    """Data-parallel pjit train step == single-device step on same batch."""
    run_sub("""
from repro.configs import base as cfgbase
from repro.launch import steps as steps_lib
from repro.distributed import sharding as sh

arch = cfgbase.get("qwen3-4b")
bundle = steps_lib.make_bundle(arch, "train_4k", smoke=True)
batch = steps_lib.materialize_inputs(arch, "train_4k", jax.random.PRNGKey(0))
state = bundle.init_state(jax.random.PRNGKey(1))

# single-device reference
ref_state, ref_out = jax.jit(bundle.fn)(
    jax.tree.map(lambda x: x, state), jax.tree.map(lambda x: x, batch))

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = bundle.rules_for(False)
state_sh = sh.shardings_from_axes(mesh, bundle.state_axes, rules)
batch_sh = {k: NamedSharding(mesh, P(*[rules.get(a) for a in ax]))
            for k, ax in bundle.batch_axes.items()}
def wrapped(state, batch):
    with sh.use_rules(mesh, rules):
        return bundle.fn(state, batch)
fn = jax.jit(wrapped, in_shardings=(state_sh, batch_sh))
got_state, got_out = fn(jax.device_put(state, state_sh),
                        {k: jax.device_put(v, batch_sh[k]) for k, v in batch.items()})
np.testing.assert_allclose(float(got_out["loss"]), float(ref_out["loss"]), rtol=2e-2)
# updated params must match too (optimizer step determinism across shardings)
pa = jax.tree.leaves(ref_state["params"]); pb = jax.tree.leaves(got_state["params"])
worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) for a, b in zip(pa, pb))
assert worst < 5e-2, worst
print("OK", worst)
""")


def test_pir_row_sharded_answer_bitwise_equal():
    """Row-sharded PIR answer == single-device answer, bit for bit, and the
    compiled HLO contains NO collective ops on the hot path."""
    run_sub("""
from repro.kernels import ref as kref
mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
db = jnp.asarray(rng.integers(0, 256, (512, 128), dtype=np.uint8))
q = jnp.asarray(rng.integers(0, 2**32, (128, 4), dtype=np.uint32))
db_sh = jax.device_put(db, NamedSharding(mesh, P("model", None)))
q_rep = jax.device_put(q, NamedSharding(mesh, P()))
fn = jax.jit(kref.modmatmul_ref,
             in_shardings=(NamedSharding(mesh, P("model", None)), NamedSharding(mesh, P())),
             out_shardings=NamedSharding(mesh, P("model", None)))
got = fn(db_sh, q_rep)
np.testing.assert_array_equal(np.asarray(got), np.asarray(kref.modmatmul_ref(db, q)))
hlo = fn.lower(db_sh, q_rep).compile().as_text()
for coll in ["all-reduce", "all-gather", "all-to-all", "collective-permute", "reduce-scatter"]:
    assert coll not in hlo, coll
print("OK zero-collective")
""")


def test_checkpoint_reshard_8_to_4_devices():
    run_sub("""
import tempfile
from repro.checkpoint import store
mesh8 = jax.make_mesh((8,), ("data",))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
state = {"w": jax.device_put(x, NamedSharding(mesh8, P("data", None))),
         "step": jnp.asarray(3)}
with tempfile.TemporaryDirectory() as d:
    store.save(d, state, step=3)
    mesh4 = jax.make_mesh((4,), ("data",))
    shardings = {"w": NamedSharding(mesh4, P(None, "data")), "step": NamedSharding(mesh4, P())}
    restored = store.restore(d, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
    assert int(restored["step"]) == 3
print("OK")
""")
