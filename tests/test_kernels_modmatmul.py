"""Pallas modmatmul kernel: bitwise-exact vs the pure-jnp u32 oracle.

Integer crypto ⇒ exact equality, not allclose.  Sweeps shapes (aligned and
ragged), batch sizes, and block configurations, in interpret mode on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.modmatmul import modmatmul_pallas


def _rand_db_q(seed, m, n, b):
    rng = np.random.default_rng(seed)
    db = rng.integers(0, 256, (m, n), dtype=np.uint8)
    q = rng.integers(0, 2**32, (n, b), dtype=np.uint32)
    return jnp.asarray(db), jnp.asarray(q)


@pytest.mark.parametrize("m,n,b", [
    (256, 512, 128),          # exactly one block
    (512, 1024, 128),         # multi-block contraction
    (256, 512, 256),          # multi-block batch
    (768, 1536, 128),         # 3x3 grid
])
def test_kernel_exact_aligned(m, n, b):
    db, q = _rand_db_q(0, m, n, b)
    got = modmatmul_pallas(db, q, interpret=True)
    want = ref.modmatmul_ref(db, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,b", [
    (100, 300, 1),            # ragged everything + matvec
    (257, 513, 3),
    (31, 1025, 129),
])
def test_ops_wrapper_pads_ragged(m, n, b):
    db, q = _rand_db_q(1, m, n, b)
    qq = q[:, 0] if b == 1 else q
    got = ops.modmatmul(db, qq, impl="pallas")
    want = ref.modmatmul_ref(db, qq)
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [(128, 256, 128), (256, 512, 128),
                                   (512, 512, 256)])
def test_block_configs(block):
    db, q = _rand_db_q(2, 512, 1024, 256)
    got = ops.modmatmul(db, q, impl="pallas", block=block)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.modmatmul_ref(db, q)))


def test_extreme_values_wraparound():
    """All-255 DB × all-(2^32−1) queries stresses every carry path."""
    m, n, b = 256, 512, 128
    db = jnp.full((m, n), 255, jnp.uint8)
    q = jnp.full((n, b), 0xFFFFFFFF, jnp.uint32)
    got = modmatmul_pallas(db, q, interpret=True)
    want = ref.modmatmul_ref(db, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xla_impl_matches_numpy_u64():
    rng = np.random.default_rng(3)
    db = rng.integers(0, 256, (64, 96), dtype=np.uint8)
    q = rng.integers(0, 2**32, (96, 5), dtype=np.uint32)
    got = np.asarray(ops.modmatmul(jnp.asarray(db), jnp.asarray(q), impl="xla"))
    want = ((db.astype(np.uint64) @ q.astype(np.uint64)) & 0xFFFFFFFF)
    np.testing.assert_array_equal(got, want.astype(np.uint32))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 64), n=st.integers(1, 128), b=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_property_pallas_equals_oracle(m, n, b, seed):
    db, q = _rand_db_q(seed, m, n, b)
    got = ops.modmatmul(db, q, impl="pallas", block=(32, 64, 32))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.modmatmul_ref(db, q)))


def test_dtype_guards():
    db, q = _rand_db_q(4, 8, 8, 1)
    with pytest.raises(TypeError):
        ops.modmatmul(db.astype(jnp.int32), q)
    with pytest.raises(TypeError):
        ops.modmatmul(db, q.astype(jnp.int64))
