"""Pallas modmatmul kernel: bitwise-exact vs the pure-jnp u32 oracle.

Integer crypto ⇒ exact equality, not allclose.  Sweeps shapes (aligned and
ragged), batch sizes, and block configurations, in interpret mode on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.modmatmul import modmatmul_pallas


def _rand_db_q(seed, m, n, b):
    rng = np.random.default_rng(seed)
    db = rng.integers(0, 256, (m, n), dtype=np.uint8)
    q = rng.integers(0, 2**32, (n, b), dtype=np.uint32)
    return jnp.asarray(db), jnp.asarray(q)


@pytest.mark.parametrize("m,n,b", [
    (256, 512, 128),          # exactly one block
    (512, 1024, 128),         # multi-block contraction
    (256, 512, 256),          # multi-block batch
    (768, 1536, 128),         # 3x3 grid
])
def test_kernel_exact_aligned(m, n, b):
    db, q = _rand_db_q(0, m, n, b)
    got = modmatmul_pallas(db, q, interpret=True)
    want = ref.modmatmul_ref(db, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,b", [
    (100, 300, 1),            # ragged everything + matvec
    (257, 513, 3),
    (31, 1025, 129),
])
def test_ops_wrapper_pads_ragged(m, n, b):
    db, q = _rand_db_q(1, m, n, b)
    qq = q[:, 0] if b == 1 else q
    got = ops.modmatmul(db, qq, impl="pallas")
    want = ref.modmatmul_ref(db, qq)
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [(128, 256, 128), (256, 512, 128),
                                   (512, 512, 256)])
def test_block_configs(block):
    db, q = _rand_db_q(2, 512, 1024, 256)
    got = ops.modmatmul(db, q, impl="pallas", block=block)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.modmatmul_ref(db, q)))


def test_extreme_values_wraparound():
    """All-255 DB × all-(2^32−1) queries stresses every carry path."""
    m, n, b = 256, 512, 128
    db = jnp.full((m, n), 255, jnp.uint8)
    q = jnp.full((n, b), 0xFFFFFFFF, jnp.uint32)
    got = modmatmul_pallas(db, q, interpret=True)
    want = ref.modmatmul_ref(db, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xla_impl_matches_numpy_u64():
    rng = np.random.default_rng(3)
    db = rng.integers(0, 256, (64, 96), dtype=np.uint8)
    q = rng.integers(0, 2**32, (96, 5), dtype=np.uint32)
    got = np.asarray(ops.modmatmul(jnp.asarray(db), jnp.asarray(q), impl="xla"))
    want = ((db.astype(np.uint64) @ q.astype(np.uint64)) & 0xFFFFFFFF)
    np.testing.assert_array_equal(got, want.astype(np.uint32))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 64), n=st.integers(1, 128), b=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_property_pallas_equals_oracle(m, n, b, seed):
    db, q = _rand_db_q(seed, m, n, b)
    got = ops.modmatmul(db, q, impl="pallas", block=(32, 64, 32))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.modmatmul_ref(db, q)))


def test_dtype_guards():
    db, q = _rand_db_q(4, 8, 8, 1)
    with pytest.raises(TypeError):
        ops.modmatmul(db.astype(jnp.int32), q)
    with pytest.raises(TypeError):
        ops.modmatmul(db, q.astype(jnp.int64))


def test_bucketed_xla_stacked_columns_bitwise():
    """The xla bucketed path now sends all C client columns of a bucket
    through ONE (m_b, W) @ (W, C) call — bitwise equal to the per-column
    matvec loop it replaced and to the u64 numpy oracle."""
    rng = np.random.default_rng(9)
    dbs = [jnp.asarray(rng.integers(0, 256, (rows, 64), dtype=np.uint8))
           for rows in (128, 384, 256)]
    qs = jnp.asarray(rng.integers(0, 2**32, (3, 64, 5), dtype=np.uint32))
    got = ops.bucketed_modmatmul(dbs, qs, impl="xla")
    for b, d in enumerate(dbs):
        d64 = np.asarray(d).astype(np.uint64)
        for c in range(5):
            want = (d64 @ np.asarray(qs[b, :, c]).astype(np.uint64)
                    ) % (1 << 32)
            np.testing.assert_array_equal(np.asarray(got[b][:, c]),
                                          want.astype(np.uint32))
    # (B, W) vector form still returns per-bucket vectors
    got_vec = ops.bucketed_modmatmul(dbs, qs[:, :, 0], impl="xla")
    for b in range(3):
        np.testing.assert_array_equal(np.asarray(got_vec[b]),
                                      np.asarray(got[b][:, 0]))


def test_pallas_pad_cache_reuses_and_invalidates():
    """Hot-loop calls with the SAME db array hit the padded-layout cache;
    a functionally updated db (new array object) misses and recomputes."""
    db, q = _rand_db_q(4, 100, 300, 2)
    ops._db_pad_cache.clear()
    h0, m0 = ops._db_pad_cache.hits, ops._db_pad_cache.misses
    a1 = ops.modmatmul(db, q, impl="pallas")
    a2 = ops.modmatmul(db, q, impl="pallas")
    assert ops._db_pad_cache.misses == m0 + 1
    assert ops._db_pad_cache.hits == h0 + 1
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    db2 = db.at[0, 0].set(7)          # new array object → cache miss
    a3 = ops.modmatmul(db2, q, impl="pallas")
    assert ops._db_pad_cache.misses == m0 + 2
    np.testing.assert_array_equal(np.asarray(a3),
                                  np.asarray(ref.modmatmul_ref(db2, q)))


def test_bucket_stack_cache_keyed_on_identity():
    """The pallas bucket stack is cached across calls and rebuilt when any
    sub-DB is swapped (as an epoch commit does)."""
    rng = np.random.default_rng(10)
    dbs = [jnp.asarray(rng.integers(0, 256, (128, 64), dtype=np.uint8))
           for _ in range(2)]
    qs = jnp.asarray(rng.integers(0, 2**32, (2, 64), dtype=np.uint32))
    ops._bucket_stack_cache.clear()
    ops.bucketed_modmatmul(dbs, qs, impl="pallas")
    ops.bucketed_modmatmul(dbs, qs, impl="pallas")
    assert ops._bucket_stack_cache.hits >= 1
    assert ops._bucket_stack_cache.misses == 1
    dbs2 = [dbs[0], dbs[1].at[3, 3].set(1)]
    got = ops.bucketed_modmatmul(dbs2, qs, impl="pallas")
    assert ops._bucket_stack_cache.misses == 2
    want = ops.bucketed_modmatmul(dbs2, qs, impl="xla")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_scatter_and_add_helpers_exact():
    """scatter_columns / add_delta (the donated commit primitives) match
    their functional equivalents bitwise; donation consumes the operand."""
    rng = np.random.default_rng(11)
    db = jnp.asarray(rng.integers(0, 256, (64, 16), dtype=np.uint8))
    cols = jnp.asarray([3, 9])
    new = jnp.asarray(rng.integers(0, 256, (64, 2), dtype=np.uint8))
    want = np.asarray(db.at[:, cols].set(new))
    got = ops.scatter_columns(db, cols, new, donate=False)
    np.testing.assert_array_equal(np.asarray(got), want)
    got_don = ops.scatter_columns(db, cols, new, donate=True)
    np.testing.assert_array_equal(np.asarray(got_don), want)
    with pytest.raises(RuntimeError):
        np.asarray(db)                 # donated buffer is consumed

    hint = jnp.asarray(rng.integers(0, 2**32, (64, 8), dtype=np.uint32))
    delta = jnp.asarray(rng.integers(0, 2**32, (64, 8), dtype=np.uint32))
    want_h = np.asarray(hint) + np.asarray(delta)      # u32 wraparound
    got_h = ops.add_delta(hint, delta)
    np.testing.assert_array_equal(np.asarray(got_h), want_h)
    np.asarray(hint)                   # the HINT is never donated
    with pytest.raises(RuntimeError):
        np.asarray(delta)              # the transient delta is
