"""Batch-PIR subsystem: partition, placement, kernel, protocol, live deltas."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import batchpir
from repro.batchpir.partition import CuckooPartition, PlacementError
from repro.core import pipeline
from repro.data import corpus as corpus_lib
from repro.data import metrics
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# partition + placement
# ---------------------------------------------------------------------------

def test_partition_balanced_and_deterministic():
    p1 = CuckooPartition.build(64, 12, seed=3)
    p2 = CuckooPartition.build(64, 12, seed=3)
    assert (p1.candidates == p2.candidates).all()        # seed-deterministic
    assert (np.sort(p1.candidates, axis=1)[:, :-1]
            != np.sort(p1.candidates, axis=1)[:, 1:]).all()   # distinct rows
    loads = np.bincount(p1.candidates.ravel(), minlength=12)
    assert loads.max() - loads.min() <= 1                # balanced replicas
    # members/width consistency: every cluster in exactly its 3 candidates
    total = sum(len(m) for m in p1.members)
    assert total == 3 * 64
    assert p1.width == 16                                # next pow2 of 3n/B

def test_position_roundtrip():
    part = CuckooPartition.build(40, 9, seed=0)
    for j in (0, 7, 39):
        for b in part.buckets_of(j):
            assert part.members[b][part.position(b, j)] == j
    with pytest.raises(KeyError):
        bad = next(b for b in range(9) if b not in part.buckets_of(0))
        part.position(bad, 0)


@settings(max_examples=25, deadline=None)
@given(kappa=st.integers(min_value=1, max_value=8),
       seed=st.integers(min_value=0, max_value=10_000))
def test_placement_succeeds_or_retries_cleanly(kappa, seed):
    """Cuckoo placement for random κ ≤ 8 at B = 3κ: a valid one-to-one
    placement into candidate buckets, or a clean PlacementError."""
    n = 48
    part = CuckooPartition.build(n, 3 * kappa, seed=seed)
    rng = np.random.default_rng(seed)
    probes = rng.choice(n, size=kappa, replace=False)
    try:
        placement = part.place(probes, walk_seed=seed)
    except PlacementError as e:                 # clean, typed failure
        assert len(e.clusters) == kappa
        return
    assert sorted(placement.values()) == sorted(int(c) for c in probes)
    for b, c in placement.items():
        assert b in part.buckets_of(c)          # placed at a candidate
    assert len(placement) == kappa              # one bucket per probe


def test_placement_rejects_duplicates_and_overflow():
    part = CuckooPartition.build(20, 6, seed=1)
    with pytest.raises(ValueError):
        part.place([3, 3])
    with pytest.raises(PlacementError):
        part.place(list(range(7)))              # κ > B can never place


# ---------------------------------------------------------------------------
# bucketed kernel
# ---------------------------------------------------------------------------

def test_bucketed_modmatmul_matches_ref():
    rng = np.random.default_rng(0)
    dbs = [jnp.asarray(rng.integers(0, 256, (m_b, 32), dtype=np.uint8))
           for m_b in (64, 128, 96)]
    qs = jnp.asarray(rng.integers(0, 2**32, (3, 32), dtype=np.uint32))
    out = ops.bucketed_modmatmul(dbs, qs, impl="xla")
    for b, d in enumerate(dbs):
        exp = np.asarray(ref.modmatmul_ref(d, qs[b]))
        assert (np.asarray(out[b]) == exp).all()


def test_bucketed_modmatmul_pallas_bitwise():
    """vmapped MXU kernel (interpret mode off-TPU) is bit-equal to XLA."""
    rng = np.random.default_rng(1)
    dbs = [jnp.asarray(rng.integers(0, 256, (m_b, 64), dtype=np.uint8))
           for m_b in (128, 256)]
    qs = jnp.asarray(rng.integers(0, 2**32, (2, 64, 3), dtype=np.uint32))
    out_x = ops.bucketed_modmatmul(dbs, qs, impl="xla")
    out_p = ops.bucketed_modmatmul(dbs, qs, impl="pallas",
                                   block=(128, 64, 128))
    for a, b in zip(out_x, out_p):
        assert a.shape == b.shape
        assert (np.asarray(a) == np.asarray(b)).all()


def test_bucketed_modmatmul_validates():
    db = jnp.zeros((8, 4), jnp.uint8)
    q = jnp.zeros((1, 4), jnp.uint32)
    with pytest.raises(ValueError):
        ops.bucketed_modmatmul([db, db], q)          # B mismatch
    with pytest.raises(TypeError):
        ops.bucketed_modmatmul([db], q.astype(jnp.int32))


# ---------------------------------------------------------------------------
# end-to-end protocol
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_system():
    corp = corpus_lib.make_corpus(0, 400, emb_dim=24, n_topics=12)
    sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                       n_clusters=12, impl="xla", seed=0)
    sysm.enable_batch(kappa=4, seed=5)
    return sysm, corp


def test_batch_query_columns_byte_exact(small_system):
    sysm, corp = small_system
    bp = sysm.batch
    probes = [0, 3, 7, 11]
    qs, st = bp.client.query(jax.random.PRNGKey(3), probes)
    assert qs.shape == (bp.partition.n_buckets, bp.partition.width)
    cols = bp.client.recover(bp.server.answer_batch(qs), st)
    for c in probes:
        exp = sysm.db.matrix[:, c]
        got = cols[c]
        assert (got == exp[:len(got)]).all()         # truncated replica
        assert not exp[len(got):].any()              # only padding dropped


def test_batch_query_shape_hides_probe_count(small_system):
    """κ=1 and κ=4 produce byte-identical wire shapes (dummies fill in)."""
    sysm, _ = small_system
    bp = sysm.batch
    q1, _ = bp.client.query(jax.random.PRNGKey(0), [5])
    q4, _ = bp.client.query(jax.random.PRNGKey(0), [5, 2, 9, 1])
    assert q1.shape == q4.shape
    assert q1.dtype == q4.dtype


def test_batch_mode_matches_legacy_docs(small_system):
    sysm, corp = small_system
    q = corp.embeddings[17]
    top_l, st_l = sysm.query(q, top_k=8, multi_probe=3, mode="legacy",
                             key=jax.random.PRNGKey(1))
    top_b, st_b = sysm.query(q, top_k=8, multi_probe=3, mode="batch",
                             key=jax.random.PRNGKey(2))
    assert [d for d, _, _ in top_l] == [d for d, _, _ in top_b]
    assert st_l.mode == "legacy" and st_b.mode == "batch"


def test_batch_accounting_exact(small_system):
    sysm, corp = small_system
    bp = sysm.batch
    _, st = sysm.query(corp.embeddings[3], multi_probe=4, mode="batch",
                       key=jax.random.PRNGKey(4))
    assert st.probes == 4
    assert st.n_buckets == bp.partition.n_buckets
    assert st.uplink_bytes == sum(c.uplink_bytes for c in bp.server.cfgs)
    assert st.downlink_bytes == sum(c.downlink_bytes for c in bp.server.cfgs)
    assert st.hint_bytes == sum(c.hint_bytes for c in bp.server.cfgs)
    # per-bucket wire atoms: uplink W u32 words, downlink m_b switched words
    for cfg in bp.server.cfgs:
        assert cfg.uplink_bytes == bp.partition.width * 4
        assert cfg.downlink_bytes == cfg.m * 2


def test_query_batch_multiprobe_without_batchpir_still_probes():
    """No silent downgrade: multi_probe>1 without enable_batch() must fetch
    P clusters per request via the legacy stacked GEMM."""
    corp = corpus_lib.make_corpus(5, 300, emb_dim=24, n_topics=10)
    sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                       n_clusters=10, impl="xla", seed=0)
    assert sysm.batch is None
    key = jax.random.PRNGKey(3)
    got = sysm.query_batch(corp.embeddings[:3], top_k=6, multi_probe=3,
                           key=key)
    for i in range(3):
        exp, _ = sysm.query(corp.embeddings[i], top_k=6, multi_probe=3,
                            mode="legacy", key=jax.random.PRNGKey(9))
        assert [d for d, _, _ in got[i]] == [d for d, _, _ in exp]


def test_single_probe_stays_legacy(small_system):
    sysm, corp = small_system
    _, st = sysm.query(corp.embeddings[0], multi_probe=1,
                       key=jax.random.PRNGKey(0))
    assert st.mode == "legacy"


def test_keyless_queries_use_split_stream(small_system, monkeypatch):
    """No OS-entropy fallback: keyless queries never touch np.random."""
    sysm, corp = small_system

    def boom(*a, **k):
        raise AssertionError("np.random.default_rng used for LWE keying")
    monkeypatch.setattr(np.random, "default_rng", boom)
    top1, _ = sysm.query(corp.embeddings[11], top_k=5)
    top2, _ = sysm.query(corp.embeddings[11], top_k=5)
    assert [d for d, _, _ in top1] == [d for d, _, _ in top2]
    k1, k2 = sysm.next_query_key(), sysm.next_query_key()
    assert not np.array_equal(np.asarray(k1), np.asarray(k2))


# ---------------------------------------------------------------------------
# multi-probe quality on the boundary-recall fixture
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def boundary_batch_setup():
    corp = corpus_lib.make_corpus(0, 600, emb_dim=96, n_topics=24,
                                  topic_spread=1.0, encoder_noise=0.35)
    qs = corpus_lib.make_queries(1, corp, 8, n_relevant=20, noise=0.5)
    sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                       n_clusters=40, impl="xla", seed=0)
    sysm.enable_batch(kappa=4, seed=2)
    return sysm, corp, qs


def _mean_ndcg(sysm, qs, probe, mode):
    vals = []
    for i in range(len(qs.embeddings)):
        top, st = sysm.query(qs.embeddings[i], top_k=10, multi_probe=probe,
                             mode=mode, key=jax.random.PRNGKey(100 + i))
        assert st.mode == mode
        ids = np.array([d for d, _, _ in top])
        vals.append(metrics.ndcg_at_k(ids, qs.relevant[i], qs.gains[i], 10))
    return float(np.mean(vals))


def test_batch_ndcg_matches_legacy_exactly(boundary_batch_setup):
    """Same κ clusters fetched ⇒ identical rerank pool ⇒ identical nDCG@10."""
    sysm, _, qs = boundary_batch_setup
    n_legacy = _mean_ndcg(sysm, qs, 4, "legacy")
    n_batch = _mean_ndcg(sysm, qs, 4, "batch")
    assert n_batch == pytest.approx(n_legacy, abs=0.0)


def test_batch_multi_probe_beats_single(boundary_batch_setup):
    sysm, _, qs = boundary_batch_setup
    n1 = _mean_ndcg(sysm, qs, 1, "legacy")
    n4 = _mean_ndcg(sysm, qs, 4, "batch")
    assert n4 > n1


# ---------------------------------------------------------------------------
# serving + live index integration
# ---------------------------------------------------------------------------

def test_serve_loop_plumbs_topk_and_multiprobe(small_system):
    from repro.launch.serve import PIRServeLoop
    sysm, corp = small_system
    loop = PIRServeLoop(sysm, max_batch=4, deadline_ms=1e9)
    for rid in range(4):
        loop.submit(rid, corp.embeddings[rid * 11], top_k=3,
                    multi_probe=2 if rid % 2 else 1)
    loop.drain()
    assert len(loop.responses) == 4
    for r in loop.responses:
        assert len(r.top) == 3                      # top_k honored, not 5
        anchor = r.rid * 11
        assert anchor in [d for d, _, _ in r.top]


def test_live_mutation_patches_bucket_hints_bit_identical():
    from repro.update import LiveIndex
    corp = corpus_lib.make_corpus(2, 300, emb_dim=16, n_topics=8)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=8,
                           impl="xla", kmeans_iters=5)
    live.system.enable_batch(kappa=3, n_buckets=9, seed=4)
    bp = live.system.batch
    before = [np.asarray(h).copy() for h in bp.server.hints]

    live.replace(7, b"patched seven", corp.embeddings[7])
    live.replace(211, b"patched two-eleven", corp.embeddings[211])
    live.delete(100)
    live.commit()

    assert live.system.batch is bp                  # delta path, no rebuild
    assert any((np.asarray(h) != b).any()
               for h, b in zip(bp.server.hints, before))
    fresh = bp.server.setup()                       # from-scratch bucket hints
    for h, f in zip(bp.server.hints, fresh):
        assert (np.asarray(h) == np.asarray(f)).all()
    # and the batch query path serves the mutated content
    top, st = live.system.query(corp.embeddings[7], top_k=5, multi_probe=2,
                                key=jax.random.PRNGKey(0))
    assert st.mode == "batch"
    assert [t for d, _, t in top if d == 7] == [b"patched seven"]


def test_batch_survives_full_rebuild_epoch():
    """A full-rebuild commit re-bucketizes with the same geometry knobs."""
    from repro.update import LiveIndex
    corp = corpus_lib.make_corpus(3, 200, emb_dim=16, n_topics=6)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=6,
                           impl="xla", kmeans_iters=4)
    live.system.enable_batch(kappa=2, n_buckets=6, seed=9)
    old_bp = live.system.batch
    # an insert too large for any column forces the overflow rebuild
    live.insert(9999, b"x" * (live.system.db.m + 1), corp.embeddings[0])
    live.commit()
    assert live.commits[-1].full_rebuild
    bp = live.system.batch
    assert bp is not None and bp is not old_bp
    assert bp.partition.n_buckets == 6 and bp.seed == 9
    top, st = live.system.query(corp.embeddings[5], top_k=3, multi_probe=2,
                                key=jax.random.PRNGKey(1))
    assert st.mode == "batch" and len(top) == 3
