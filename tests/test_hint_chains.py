"""Hint-delivery layer: patch composition, compaction, chain exactness.

The acceptance property (ISSUE 6): for ANY mutation sequence split into
epochs at any points, with ANY compaction configuration, a client syncing
from any past epoch through `EpochLog.chain_since` ends bit-identical to a
fresh full-hint download — while downloading no more bytes than the raw
per-epoch patch chain.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.update import HintCache, LiveIndex, journal as journal_lib
from repro.update.epochs import (EpochLog, HintPatch, StaleEpochError,
                                 compact_chain, compose_patches)

EMB = 8


def _build_live(seed=0, n_docs=60, **kw):
    from repro.data import corpus as corpus_lib
    corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=EMB, n_topics=4)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=4,
                           impl="xla", kmeans_iters=4, **kw)
    return live, corp


def _mutate(live, rng, n_ops):
    ids = set(live.doc_ids())
    for _ in range(n_ops):
        op = int(rng.integers(3))
        if op == 0:
            nid = int(10_000 + rng.integers(10_000))
            if nid not in ids:
                live.insert(nid, f"ins {nid}".encode(),
                            rng.standard_normal(EMB).astype(np.float32))
                ids.add(nid)
        elif op == 1 and len(ids) > 20:
            d = int(rng.choice(sorted(ids)))
            live.delete(d)
            ids.discard(d)
        else:
            d = int(rng.choice(sorted(ids)))
            live.replace(d, f"rep {d}".encode(),
                         rng.standard_normal(EMB).astype(np.float32))


# ---------------------------------------------------------------------------
# The acceptance property
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), compact_every=st.sampled_from([2, 3]))
def test_property_chain_sync_bit_identical_from_every_epoch(
        seed, compact_every):
    """Any epoch split × any start epoch × compaction ⇒ exact sync.

    Snapshots the hint at every epoch, then replays a client stranded at
    EACH epoch (mid-segment starts included) through the compacted chain
    and demands bit-identity with the live hint — and a downlink no larger
    than the raw patch-per-epoch chain.
    """
    live, _ = _build_live(seed=seed % 5, compact_every=compact_every)
    rng = np.random.default_rng(seed)
    snaps = [(np.asarray(live.system.hint), live.system.cfg)]
    for _ in range(int(rng.integers(4, 7))):      # epoch split points
        _mutate(live, rng, int(rng.integers(1, 5)))
        if live.commit() is not None:
            snaps.append((np.asarray(live.system.hint), live.system.cfg))
    log = live.epochs
    final = jnp.asarray(live.system.hint)
    for e0, (hint_e0, cfg_e0) in enumerate(snaps):
        cache = HintCache(hint_e0, cfg_e0, epoch=e0)
        nbytes = cache.sync(log)
        assert cache.epoch == log.epoch
        assert jnp.array_equal(jnp.asarray(cache.hint), final)
        raw = sum(p.wire_bytes for p in log.patches_since(e0))
        assert nbytes == log.chain_bytes(e0) <= raw
        assert len(log.chain_since(e0)) <= len(log.patches_since(e0))


def test_compacted_chain_is_shorter_and_cheaper():
    """8 commits at compact_every=4: a stranded client downloads ~2 segments
    + tail, not 8 patches, and far less than the full hint."""
    live, _ = _build_live(compact_every=4)
    rng = np.random.default_rng(1)
    h0 = np.asarray(live.system.hint)
    commits = 0
    while commits < 8:
        _mutate(live, rng, 3)
        if live.commit() is not None:
            commits += 1
    log = live.epochs
    chain = log.chain_since(0)
    assert len(chain) == 2                         # two aligned segments
    assert [(p.from_epoch, p.to_epoch) for p in chain] == [(0, 4), (4, 8)]
    assert log.chain_bytes(0) <= sum(
        p.wire_bytes for p in log.patches_since(0))
    assert log.chain_bytes(0) < live.system.cfg.hint_bytes
    # mid-segment client: raw prefix to the boundary, then a segment
    mid = log.chain_since(3)
    assert [(p.from_epoch, p.to_epoch) for p in mid] == [(3, 4), (4, 8)]
    cache = HintCache(h0, live.system.cfg, epoch=0)
    cache.sync(log)
    assert jnp.array_equal(jnp.asarray(cache.hint),
                           jnp.asarray(live.system.hint))


def test_chain_since_until_bound():
    """`until=` stops the walk mid-log and never hands out an overshooting
    segment (partial catch-up accounting for reactive session syncs)."""
    live, _ = _build_live(compact_every=2)
    rng = np.random.default_rng(2)
    commits = 0
    while commits < 5:
        _mutate(live, rng, 2)
        if live.commit() is not None:
            commits += 1
    log = live.epochs
    for e0 in range(6):
        for e1 in range(e0, 6):
            chain = log.chain_since(e0, e1)
            at = e0
            for p in chain:
                assert p.from_epoch == at and p.to_epoch <= e1
                at = p.to_epoch
            assert at == e1
            assert log.chain_bytes(e0, e1) == sum(
                p.wire_bytes for p in chain)
    with pytest.raises(StaleEpochError):
        log.chain_since(2, 7)                      # past the head
    with pytest.raises(StaleEpochError):
        log.chain_since(4, 2)                      # backwards


# ---------------------------------------------------------------------------
# Composition algebra
# ---------------------------------------------------------------------------

def _delta_patch(e0, rng, m, r, n_cols):
    """Synthetic delta patch with u8-bounded entries (as real packs have)."""
    cols = np.sort(rng.choice(m, size=n_cols, replace=False)).astype(np.int64)
    delta = rng.integers(-255, 256, size=(r, n_cols)).astype(np.int16)
    return HintPatch(from_epoch=e0, to_epoch=e0 + 1, cols=cols, delta=delta)


def test_compose_delta_delta_matches_sequential_apply():
    """delta∘delta applied once == the two deltas applied in sequence."""
    rng = np.random.default_rng(3)
    m, k, r = 32, 16, 10
    hint = jnp.asarray(rng.integers(0, 2**32, size=(m, k), dtype=np.uint32))
    a_mat = jnp.asarray(rng.integers(0, 2**32, size=(m, k), dtype=np.uint32))
    a = _delta_patch(0, rng, m, r, 6)
    b = _delta_patch(1, rng, m, r - 2, 4)
    seq = b.apply(a.apply(hint, a_mat), a_mat)
    one = compose_patches(a, b)
    assert (one.from_epoch, one.to_epoch) == (0, 2)
    assert not one.is_full
    assert jnp.array_equal(one.apply(hint, a_mat), seq)
    assert one.wire_bytes <= a.wire_bytes + b.wire_bytes


def test_compose_with_full_patch_subsumes_and_folds():
    """anything∘full spans from the left edge; full∘delta folds the delta
    into the carried hint via the seed-derived A (server-side apply)."""
    live, _ = _build_live()
    rng = np.random.default_rng(4)
    cfg = live.system.cfg
    full = HintPatch(from_epoch=2, to_epoch=3,
                     full_hint=np.asarray(live.system.hint), cfg=cfg)
    d = _delta_patch(1, rng, cfg.m, 8, 5)
    sub = compose_patches(d, full)                 # delta ∘ full
    assert sub.is_full and (sub.from_epoch, sub.to_epoch) == (1, 3)
    assert np.array_equal(sub.full_hint, full.full_hint)
    d2 = dataclasses.replace(_delta_patch(0, rng, cfg.m, 8, 5),
                             from_epoch=3, to_epoch=4)
    folded = compose_patches(full, d2)             # full ∘ delta
    assert folded.is_full and (folded.from_epoch, folded.to_epoch) == (2, 4)
    from repro.core import lwe
    a_mat = lwe.gen_public_matrix(cfg.a_seed, cfg.n, cfg.params.k)
    want = d2.apply(jnp.asarray(full.full_hint, jnp.uint32), a_mat)
    assert jnp.array_equal(jnp.asarray(folded.full_hint), want)


def test_full_patch_in_log_subsumes_chain_and_segments():
    """A rebuild epoch inside a compacted span: the client chain starts at
    the full patch (or a segment that absorbed it) — never earlier."""
    rng = np.random.default_rng(5)
    m, r = 32, 6
    log = EpochLog(compact_every=2)
    fake_hint = rng.integers(0, 2**32, size=(m, 8), dtype=np.uint32)
    log.publish(_delta_patch(0, rng, m, r, 4))
    log.publish(HintPatch(from_epoch=1, to_epoch=2, full_hint=fake_hint))
    log.publish(_delta_patch(2, rng, m, r, 4))
    log.publish(_delta_patch(3, rng, m, r, 4))
    chain = log.chain_since(0)
    assert chain[0].is_full                        # nothing before travels
    assert chain[0].from_epoch in (0, 1)
    assert chain[-1].to_epoch == 4
    assert log.stored_bytes >= sum(p.wire_bytes for p in log.patches_since(0))


def test_compact_chain_left_fold_matches_pairwise():
    rng = np.random.default_rng(6)
    patches = [_delta_patch(i, rng, 24, 5, 3) for i in range(4)]
    one = compact_chain(patches)
    two = compose_patches(compose_patches(patches[0], patches[1]),
                          compose_patches(patches[2], patches[3]))
    assert (one.from_epoch, one.to_epoch) == (two.from_epoch, two.to_epoch)
    assert np.array_equal(one.cols, two.cols)
    assert np.array_equal(one.delta, two.delta)
