"""Live-index subsystem: delta-hint exactness, epochs, triggers, journal."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import chunking
from repro.update import (HintCache, LiveIndex, StaleEpochError,
                          journal as journal_lib, routing)
from repro.update.planner import plan_updates


def _build_live(seed=0, n_docs=120, emb_dim=12, n_clusters=5, **kw):
    from repro.data import corpus as corpus_lib
    corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=emb_dim,
                                  n_topics=n_clusters)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=n_clusters,
                           impl="xla", kmeans_iters=6, **kw)
    return live, corp


def _random_mutations(live, rng, n_ops, emb_dim):
    """Apply a random insert/delete/replace batch to the journal.

    Tracks the id set as mutations accumulate so the batch never targets a
    doc it already deleted (which the planner rightly rejects).
    """
    ids = set(live.doc_ids())
    for _ in range(n_ops):
        op = int(rng.integers(3))
        if op == 0:
            nid = int(10_000 + rng.integers(10_000))
            if nid not in ids:
                live.insert(nid, f"ins {nid}".encode(),
                            rng.standard_normal(emb_dim).astype(np.float32))
                ids.add(nid)
        elif op == 1 and len(ids) > 20:
            d = int(rng.choice(sorted(ids)))
            live.delete(d)
            ids.discard(d)
        else:
            d = int(rng.choice(sorted(ids)))
            live.replace(d, f"rep {d}".encode(),
                         rng.standard_normal(emb_dim).astype(np.float32))


@pytest.fixture(scope="module")
def live_and_corpus():
    return _build_live()


# ---------------------------------------------------------------------------
# Delta-hint exactness (the acceptance criterion)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_batches=st.integers(1, 3))
def test_property_patched_hint_equals_full_rebuild(seed, n_batches):
    """After ANY mutation sequence: patched hint == setup() bit-for-bit,
    both server-side and through the client's HintPatch chain."""
    live, _ = _build_live(seed=seed % 7, n_docs=80, emb_dim=8, n_clusters=4)
    cache = HintCache(live.system.hint, live.system.cfg)
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        _random_mutations(live, rng, int(rng.integers(1, 5)), 8)
        if live.commit() is None:
            continue
        fresh = jax.block_until_ready(live.system.server.setup())
        assert jnp.array_equal(fresh, live.system.hint)
        cache.sync(live.epochs)
        assert cache.epoch == live.epoch
        assert jnp.array_equal(jnp.asarray(cache.hint), live.system.hint)


def test_delta_columns_match_from_scratch_pack():
    """Incrementally rebuilt columns are byte-identical to a fresh pack."""
    live, corp = _build_live()
    rng = np.random.default_rng(3)
    live.replace(10, b"touched ten", rng.standard_normal(12).astype(np.float32))
    live.delete(11)
    live.insert(5000, b"the new doc", rng.standard_normal(12).astype(np.float32))
    live.commit()
    db = live.system.db
    members = {j: [] for j in range(db.n)}
    for i, cl in live._cluster_of.items():
        text, emb = live._docs[i]
        members[cl].append((i, emb, text))
    for j in range(db.n):
        payload = np.frombuffer(chunking.pack_column(members[j]), np.uint8)
        assert np.array_equal(db.matrix[:len(payload), j], payload)
        assert not db.matrix[len(payload):, j].any()        # zero padding
        assert db.used_bytes[j] == len(payload)


# ---------------------------------------------------------------------------
# End-to-end freshness: queries see mutations at the right epoch
# ---------------------------------------------------------------------------

def test_query_returns_updated_content_at_new_epoch():
    live, corp = _build_live(n_docs=150, emb_dim=16, n_clusters=6)
    cache = HintCache(live.system.hint, live.system.cfg)
    e0 = live.epoch

    live.replace(7, b"revised seven", corp.embeddings[7])
    live.delete(23)
    new_emb = corp.embeddings[40] + 0.01
    live.insert(7777, b"inserted doc", new_emb)
    live.commit()
    assert live.epoch == e0 + 1

    # stale client is refused before any crypto runs
    with pytest.raises(StaleEpochError):
        live.query(corp.embeddings[7], epoch=e0)
    cache.sync(live.epochs)

    top, _ = live.query(corp.embeddings[7], epoch=cache.epoch, top_k=3,
                        key=jax.random.PRNGKey(0))
    assert [t for d, _, t in top if d == 7] == [b"revised seven"]

    top, _ = live.query(np.asarray(new_emb), epoch=cache.epoch, top_k=3,
                        key=jax.random.PRNGKey(1))
    assert 7777 in [d for d, _, _ in top]

    top, _ = live.query(corp.embeddings[23], epoch=cache.epoch, top_k=10,
                        key=jax.random.PRNGKey(2))
    assert 23 not in [d for d, _, _ in top]                 # deleted


# ---------------------------------------------------------------------------
# Full-rebuild triggers
# ---------------------------------------------------------------------------

def test_overflow_triggers_full_rebuild():
    live, corp = _build_live()
    cache = HintCache(live.system.hint, live.system.cfg)
    m0 = live.system.db.m
    live.insert(8888, b"x" * (m0 + 1), corp.embeddings[0])
    patch = live.commit()
    assert patch.is_full
    assert live.commits[-1].reason == "overflow"
    assert live.system.db.m > m0
    cache.sync(live.epochs)
    assert cache.cfg == live.system.cfg
    assert jnp.array_equal(jnp.asarray(cache.hint), live.system.hint)
    top, _ = live.query(corp.embeddings[0], epoch=live.epoch, top_k=1,
                        key=jax.random.PRNGKey(4))
    assert top


def test_pad_degradation_triggers_full_rebuild():
    live, _ = _build_live(max_pad_fraction=0.7)
    for i in list(live.doc_ids())[:100]:
        live.delete(i)
    patch = live.commit()
    assert patch.is_full
    assert live.commits[-1].reason == "pad-degradation"
    assert live.pad_fraction() <= 0.7


def test_planner_flags_overflow_without_committing():
    live, corp = _build_live()
    live.insert(9999, b"y" * (live.system.db.m + 1), corp.embeddings[1])
    plan = plan_updates(
        live.journal.pending(), docs=live._docs,
        cluster_of=live._cluster_of, centroids=live.system.centroids,
        m=live.system.db.m, used_bytes=live._used,
        n_clusters=live.system.db.n, emb_dim=live.system.db.emb_dim)
    assert plan.full_rebuild and plan.reason == "overflow"


# ---------------------------------------------------------------------------
# Patch accounting + journal wire format
# ---------------------------------------------------------------------------

def test_patch_bytes_much_smaller_than_hint(live_and_corpus):
    live, corp = live_and_corpus
    live.replace(3, b"small edit", corp.embeddings[3])
    patch = live.commit()
    assert not patch.is_full
    assert patch.wire_bytes < live.system.cfg.hint_bytes / 10
    # documented wire format: header + col ids (u32) + int16 delta rows
    assert patch.wire_bytes == 16 + 4 * len(patch.cols) + 2 * patch.delta.size
    assert patch.delta.dtype == np.int16


def test_journal_roundtrip_and_replay():
    j = journal_lib.MutationJournal()
    emb = np.arange(4, dtype=np.float32)
    j.append(journal_lib.insert(3, b"three", emb))
    j.append(journal_lib.delete(1))
    j.append(journal_lib.replace(2, b"two!", emb * 2))
    back = journal_lib.MutationJournal.from_bytes(j.to_bytes())
    assert len(back) == 3
    for a, b in zip(j.pending(), back.pending()):
        assert (a.kind, a.doc_id, a.text) == (b.kind, b.doc_id, b.text)
        if a.emb is not None:
            assert np.array_equal(a.emb, b.emb)
    base = {1: (b"one", emb), 2: (b"two", emb)}
    docs = journal_lib.replay(base, back.pending())
    assert set(docs) == {2, 3}
    assert docs[2][0] == b"two!"

    j.mark_committed(epoch=1)
    assert [e for e, _ in j.committed_records()] == [1, 1, 1]
    assert j.pending() == []


def test_commit_empty_journal_is_noop(live_and_corpus):
    live, _ = live_and_corpus
    e = live.epoch
    assert live.commit() is None
    assert live.epoch == e


def test_external_doc_ids_survive_delta_and_rebuild():
    """LiveIndex.build(doc_ids=...) keys every map by the external id space,
    through both the delta path and a forced full rebuild."""
    from repro.data import corpus as corpus_lib
    corp = corpus_lib.make_corpus(2, 60, emb_dim=8, n_topics=3)
    ids = [int(i) for i in 1000 + np.arange(60) * 3]
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=3,
                           impl="xla", kmeans_iters=5, doc_ids=ids)
    assert live.doc_ids() == ids
    live.replace(ids[4], b"external-id edit", corp.embeddings[4])
    live.commit()
    fresh = jax.block_until_ready(live.system.server.setup())
    assert jnp.array_equal(fresh, live.system.hint)
    top, _ = live.query(corp.embeddings[4], epoch=live.epoch, top_k=3,
                        key=jax.random.PRNGKey(5))
    assert [t for d, _, t in top if d == ids[4]] == [b"external-id edit"]
    # overflow-triggered full rebuild must not re-pass doc_ids twice
    live.insert(5, b"z" * (live.system.db.m + 1), corp.embeddings[0])
    patch = live.commit()
    assert patch.is_full
    assert 5 in live.doc_ids() and ids[4] in live.doc_ids()


def test_db_mirror_tracks_mutations():
    live, corp = _build_live(n_docs=60, emb_dim=8, n_clusters=3)
    n0 = live.system.db.n_docs
    live.delete(0)
    live.commit()
    assert live.system.db.n_docs == n0 - 1
    sizes = [len(chunking.deserialize_docs(live.system.db.matrix[:, j], 8))
             for j in range(3)]
    assert np.array_equal(live.system.db.cluster_sizes, sizes)


# ---------------------------------------------------------------------------
# Donation-rollback safety (ISSUE 6 satellite): an aborted or dropped
# donating stage must leave the serving buffers intact
# ---------------------------------------------------------------------------

def test_aborted_donating_stage_keeps_serving(monkeypatch):
    """stage(donate=True) raising mid-stage leaves server.db valid.

    Donating scatters are deferred into the publish-side apply(), so the
    retiring buffer is never consumed by a stage that doesn't complete —
    the query below would decode garbage (or crash on a deleted buffer)
    under the old eager-donation ordering.
    """
    live, corp = _build_live(n_docs=100, emb_dim=12, n_clusters=5)
    live.system.enable_batch(kappa=4)
    live.replace(3, b"doomed edit", corp.embeddings[3])

    def boom(*a, **k):
        raise RuntimeError("mid-stage failure")

    monkeypatch.setattr(routing, "stage_batch_hints", boom)
    with pytest.raises(RuntimeError, match="mid-stage"):
        live.stage(donate=True)
    monkeypatch.undo()

    # old epoch still serves, bit-exactly: content is the pre-edit text
    top, _ = live.query(corp.embeddings[3], epoch=live.epoch, top_k=3,
                        key=jax.random.PRNGKey(11))
    assert [t for d, _, t in top if d == 3] == [corp.texts[3]]
    # the journal survived the abort: a retried donating commit lands
    patch = live.commit(donate=True)
    assert patch is not None and live.epoch == 1
    top, _ = live.query(corp.embeddings[3], epoch=live.epoch, top_k=3,
                        key=jax.random.PRNGKey(12))
    assert [t for d, _, t in top if d == 3] == [b"doomed edit"]
    fresh = jax.block_until_ready(live.system.server.setup())
    assert jnp.array_equal(fresh, live.system.hint)


def test_dropped_donating_staged_epoch_is_harmless():
    """A StagedEpoch built with donate=True and never published leaves the
    live epoch serving (single- and multi-probe) and can be re-staged."""
    live, corp = _build_live(n_docs=100, emb_dim=12, n_clusters=5)
    live.system.enable_batch(kappa=4)
    live.replace(5, b"five v2", corp.embeddings[5])
    staged = live.stage(donate=True)
    assert staged is not None
    del staged                                     # dropped, never published

    top, _ = live.query(corp.embeddings[5], epoch=live.epoch, top_k=3,
                        key=jax.random.PRNGKey(13))
    assert [t for d, _, t in top if d == 5] == [corp.texts[5]]
    top, _ = live.query(corp.embeddings[5], epoch=live.epoch, top_k=3,
                        multi_probe=2, key=jax.random.PRNGKey(14))
    assert [t for d, _, t in top if d == 5] == [corp.texts[5]]

    patch = live.publish(live.stage(donate=True))  # re-stage then publish
    assert patch is not None and live.epoch == 1
    top, _ = live.query(corp.embeddings[5], epoch=live.epoch, top_k=3,
                        multi_probe=2, key=jax.random.PRNGKey(15))
    assert [t for d, _, t in top if d == 5] == [b"five v2"]
