"""Transformer LM: KV-cache consistency, MoE dispatch, loss sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import nn, transformer as tf
from repro.models.moe import MoEConfig, capacity, moe_apply, moe_init


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=128, attn_chunk_q=8,
                attn_chunk_kv=8, ce_chunk=8, remat=False)
    base.update(kw)
    return tf.LMConfig(**base)


CFGS = {
    "dense": _cfg(),
    "qknorm_bias": _cfg(qk_norm=True, qkv_bias=True),
    "moe_top1_shared": _cfg(moe=MoEConfig(n_experts=4, top_k=1, d_ff=64,
                                          n_shared=1, every=2,
                                          capacity_factor=8.0)),
    "moe_top2": _cfg(moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, every=1,
                                   capacity_factor=8.0)),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_decode_path_matches_full_forward(name):
    """prefill + two decode steps == teacher-forced forward.

    Prefill is bit-exact (same blockwise kernel).  Decode uses a one-shot
    softmax (vs online) with bf16 P·V, so logits agree to flash-decoding
    tolerance; argmax must agree exactly."""
    cfg = CFGS[name]
    params = tf.init(jax.random.PRNGKey(0), cfg)
    B, S, S0 = 2, 16, 13
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    x, _ = tf.forward(params, toks, cfg)
    full = np.asarray(tf.logits_from_hidden(params, x, cfg))

    cache = tf.init_cache(cfg, B, S)
    lg, cache = tf.prefill(params, toks[:, :S0], cache, cfg)
    np.testing.assert_allclose(np.asarray(lg), full[:, S0 - 1], atol=1e-3)
    lens = jnp.full((B,), S0, jnp.int32)
    for t in range(2):
        lg, cache = tf.decode_step(params, cache, toks[:, S0 + t], lens + t,
                                   cfg)
        np.testing.assert_allclose(np.asarray(lg), full[:, S0 + t],
                                   atol=0.08)
        np.testing.assert_array_equal(np.argmax(np.asarray(lg), -1),
                                      np.argmax(full[:, S0 + t], -1))


def test_blockwise_attention_matches_naive():
    cfg = _cfg(attn_chunk_q=8, attn_chunk_kv=8)
    B, S, H, KV, hd = 2, 21, 4, 2, 16          # ragged vs both chunk sizes
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    got = tf.blockwise_attention(q, k, v, cfg)
    # naive reference
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,btkh->bqkgt", qg, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bqkgt,btkh->bqkgh", p, v).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_loss_decreases_with_sgd():
    cfg = _cfg()
    params = tf.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(
            lambda p: tf.lm_loss(p, batch, cfg), has_aux=True)(p)
        return l, jax.tree.map(lambda w, gw: w - 0.5 * gw.astype(w.dtype),
                               p, g)

    l0, params = step(params)
    for _ in range(10):
        l1, params = step(params)
    assert float(l1) < float(l0)


def test_chunked_xent_matches_dense():
    cfg = _cfg()
    params = tf.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    x, _ = tf.forward(params, toks, cfg)
    got = tf.chunked_xent(params, x, labels, cfg)
    logits = tf.logits_from_hidden(params, x, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    want = jnp.mean(logz - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_moe_capacity_drop_and_conservation():
    """With cf→large, every token is processed exactly once per expert slot;
    moe output must then equal a dense per-token expert mixture oracle."""
    moe = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=8.0)
    d = 16
    p = moe_init(jax.random.PRNGKey(0), d, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    y, aux = moe_apply(p, x, moe, compute_dtype=jnp.float32)
    # oracle: compute every expert densely, mix by (renormalized) top-k probs
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    def expert(e, v):
        h = jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])
        return h @ p["w_down"][e]
    all_e = jnp.stack([expert(e, xf) for e in range(4)], 1)   # (T, E, d)
    want = jnp.einsum("tk,tkd->td", w,
                      jnp.take_along_axis(all_e, idx[..., None], axis=1))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_actually_drops_when_tight():
    moe = MoEConfig(n_experts=4, top_k=1, d_ff=16, capacity_factor=0.25)
    d = 8
    p = moe_init(jax.random.PRNGKey(0), d, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d), jnp.float32)
    y, _ = moe_apply(p, x, moe, compute_dtype=jnp.float32)
    # capacity 8 < 64 tokens: some outputs must be exactly zero (dropped)
    zeros = np.sum(np.all(np.asarray(y.reshape(-1, d)) == 0, axis=1))
    assert zeros > 0
    assert capacity(64, moe) == 8


def test_param_axes_structure_matches_params():
    for name, cfg in CFGS.items():
        params = tf.init(jax.random.PRNGKey(0), cfg)
        axes = tf.param_axes(cfg)
        jax.tree.map(lambda p, a: None, params, axes,
                     is_leaf=lambda v: isinstance(v, tuple))
        # every leaf's rank must equal its axes tuple length
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=lambda v: isinstance(v, tuple))
        assert len(flat_p) == len(flat_a), name
        for arr, ax in zip(flat_p, flat_a):
            assert arr.ndim == len(ax), (name, arr.shape, ax)


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("batch", [1, 3, 4])
def test_kv_decode_matches_full_forward_rerun(seed, batch):
    """Property: incremental KV-cache decode == full-forward re-run, exactly.

    `Generator.generate` fills a cache once and appends one token per
    step; `generate_nocache` re-runs the whole teacher-forced forward
    from scratch every step and takes argmax at the cursor.  In f32 the
    two paths must produce BIT-IDENTICAL token grids across seeds,
    ragged prompt lengths and batch sizes — the invariant the serve
    engines' generation equivalence rests on.
    """
    from repro.rag.generate import Generator

    rng = np.random.default_rng(seed)
    gen = Generator.tiny(seed=seed, context_budget=48, max_new_tokens=5)
    doc_lists = []
    for b in range(batch):
        n_docs = int(rng.integers(1, 4))
        docs = [(d, 1.0 - 0.1 * d,
                 bytes(rng.integers(97, 123, int(rng.integers(3, 30)))
                       .astype(np.uint8)))
                for d in range(n_docs)]
        doc_lists.append(docs)
    rids = list(range(100, 100 + batch))
    cached = gen.generate(doc_lists, rids)
    rerun = gen.generate_nocache(doc_lists, rids)
    np.testing.assert_array_equal(cached, rerun)


def test_kv_decode_batch_invariant():
    """Rows of a coalesced generation micro-batch decode independently.

    Generating two groups separately must equal generating their
    concatenation in one batch, bitwise — the property that lets the
    pipelined engine coalesce parked generation groups without changing
    a single token.
    """
    from repro.rag.generate import Generator

    gen = Generator.tiny(seed=0, context_budget=48, max_new_tokens=5)
    docs_a = [[(0, 1.0, b"alpha beta gamma")], [(1, 0.9, b"delta")]]
    docs_b = [[(2, 0.8, b"epsilon zeta eta theta")]]
    sep = np.concatenate([gen.generate(docs_a, [10, 11]),
                          gen.generate(docs_b, [12])])
    joint = gen.generate(docs_a + docs_b, [10, 11, 12])
    np.testing.assert_array_equal(sep, joint)


def test_rope_relative_shift_invariance():
    """RoPE attention scores depend only on relative positions."""
    cfg = _cfg()
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 16))
    p0 = jnp.arange(4)[None, :]
    s0 = jnp.einsum("bqhd,bkhd->bhqk", tf._rope(q, p0, 1e4),
                    tf._rope(k, p0, 1e4))
    s7 = jnp.einsum("bqhd,bkhd->bhqk", tf._rope(q, p0 + 7, 1e4),
                    tf._rope(k, p0 + 7, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s7), atol=1e-4)
