"""Sharded offline build == single-device build, bit for bit.

The contract (ISSUE 5 tentpole): `PirRagSystem.build(mesh=...)` — mesh-
parallel K-means, sharded assignment sweeps, per-shard column packing and
in-place DB placement — produces exactly the artifacts of the mesh=None
build: centroids, assignment, packed columns, used-bytes accounting, hint,
and end-to-end top-k.  Property-tested as a seeded sweep over corpus
shapes/seeds inside one multi-device child interpreter (the fake-device
harness; see tests/_mesh_harness.py for why a subprocess is required).

All cases are slow-marked: CI runs them in the dedicated 8-fake-device step
alongside tests/test_sharded_pir.py.
"""
import pytest

from _mesh_harness import run_sub

pytestmark = pytest.mark.slow


def test_build_bit_identical_across_mesh_widths():
    out = run_sub('''
from repro.core import pipeline
from repro.data import corpus as corpus_lib

# property sweep: (seed, n_docs, n_clusters, emb_dim, balance_factor)
CASES = [
    (0, 480, 12, 32, None),
    (1, 600, 16, 16, None),
    (2, 512, 8, 32, 1.3),     # balanced assignment path
    (3, 450, 12, 16, 1.2),
]
for seed, n_docs, k, d, bf in CASES:
    corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=d, n_topics=k)
    kw = dict(n_clusters=k, kmeans_iters=8, impl="xla", seed=seed,
              balance_factor=bf)
    ref = pipeline.PirRagSystem.build(corp.texts, corp.embeddings, **kw)
    probe = corp.embeddings[seed + 5]
    top_ref, _ = ref.query(probe, top_k=4, key=jax.random.PRNGKey(seed))
    for n_dev in (2, 8):
        mesh = jax.make_mesh((n_dev,), ("chunks",),
                             devices=jax.devices()[:n_dev])
        got = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                          mesh=mesh, **kw)
        assert np.array_equal(ref.centroids, got.centroids), (seed, n_dev)
        assert np.array_equal(ref.assignment, got.assignment), (seed, n_dev)
        assert np.array_equal(ref.db.matrix, got.db.matrix), (seed, n_dev)
        assert np.array_equal(ref.db.used_bytes, got.db.used_bytes)
        assert np.array_equal(np.asarray(ref.hint), np.asarray(got.hint))
        assert ref.cfg.a_seed == got.cfg.a_seed
        # in-place construction: the sharded DB rows live one slice per
        # device, assembled without a single-device materialize
        assert len(got.server.db.sharding.device_set) == n_dev
        top_got, _ = got.query(probe, top_k=4, key=jax.random.PRNGKey(seed))
        assert top_ref == top_got, (seed, n_dev)
print("CASES_OK", len(CASES))
''')
    assert "CASES_OK 4" in out


def test_sharded_kmeans_and_sweeps_bit_identical():
    out = run_sub('''
from repro.core import clustering

rng = np.random.default_rng(0)
for seed, n, d, k in [(0, 1203, 32, 13), (1, 777, 16, 9)]:
    x = rng.standard_normal((n, d)).astype(np.float32)
    key = jax.random.PRNGKey(seed)
    ref = clustering.kmeans_fit(key, jnp.asarray(x), k=k, iters=7,
                                n_blocks=8)
    cents = np.asarray(ref.centroids)
    for n_dev in (2, 4, 8):
        mesh = jax.make_mesh((n_dev,), ("chunks",),
                             devices=jax.devices()[:n_dev])
        got = clustering.kmeans_fit_sharded(key, x, k=k, iters=7,
                                            mesh=mesh, n_blocks=8)
        assert np.array_equal(cents, np.asarray(got.centroids))
        assert np.array_equal(np.asarray(ref.assignment),
                              np.asarray(got.assignment))
        assert np.array_equal(np.asarray(ref.inertia),
                              np.asarray(got.inertia))
        d2_ref = np.asarray(clustering.blocked_sqdist(x, cents, n_blocks=8))
        d2_got = np.asarray(clustering.blocked_sqdist(x, cents, n_blocks=8,
                                                      mesh=mesh))
        assert np.array_equal(d2_ref, d2_got)
        a_ref = np.asarray(clustering.assign_to_centroids(
            jnp.asarray(x), jnp.asarray(cents)))
        a_got = np.asarray(clustering.assign_to_centroids(x, cents,
                                                          mesh=mesh))
        assert np.array_equal(a_ref, a_got)
print("KMEANS_OK")
''')
    assert "KMEANS_OK" in out


def test_live_index_full_rebuild_stays_sharded():
    out = run_sub('''
from repro.data import corpus as corpus_lib
from repro.update.live import LiveIndex

corp = corpus_lib.make_corpus(1, 400, emb_dim=32, n_topics=8)

def mutate_and_rebuild(mesh):
    li = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=8,
                         impl="xla", seed=2, mesh=mesh)
    li.replace(5, b"edited doc five", corp.embeddings[5])
    li.insert(400, b"fresh doc", corp.embeddings[7] + 0.01)
    li.commit()                                   # sparse delta epoch
    li.insert(401, b"x" * (li.system.db.m + 100), corp.embeddings[3])
    li.commit()                                   # overflow -> full rebuild
    assert li.commits[-1].full_rebuild
    return li

mesh = jax.make_mesh((8,), ("chunks",))
ref = mutate_and_rebuild(None)
got = mutate_and_rebuild(mesh)
# the rebuilt epoch went through the SAME sharded build, not a host-side
# fallback that would materialize-then-reshard
assert got.system.mesh is mesh
assert got.system.server.n_shards == 8
assert len(got.system.server.db.sharding.device_set) == 8
assert np.array_equal(ref.system.db.matrix, got.system.db.matrix)
assert np.array_equal(np.asarray(ref.system.hint),
                      np.asarray(got.system.hint))
assert ref.system.cfg.a_seed == got.system.cfg.a_seed
q = corp.embeddings[10]
ta, _ = ref.query(q, epoch=ref.epoch, top_k=4, key=jax.random.PRNGKey(9))
tb, _ = got.query(q, epoch=got.epoch, top_k=4, key=jax.random.PRNGKey(9))
assert ta == tb
print("REBUILD_OK")
''')
    assert "REBUILD_OK" in out
