"""Data pipeline: determinism (resume), graph sampler invariants, metrics."""
import numpy as np

from repro.data import graph_sampler, lm_data, metrics


def test_lm_batches_deterministic_and_step_indexed():
    b1 = lm_data.batch_at(7, 42, batch=4, seq=16, vocab=97)
    b2 = lm_data.batch_at(7, 42, batch=4, seq=16, vocab=97)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_data.batch_at(7, 43, batch=4, seq=16, vocab=97)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_lm_task_is_affine_recurrence():
    b = lm_data.batch_at(0, 0, batch=2, seq=8, vocab=31)
    t, l = b["tokens"][0].astype(np.int64), b["labels"][0].astype(np.int64)
    # exists (a, b): l[i] == (a*t[i]+b) % 31 for all i
    found = False
    for a in range(1, 31):
        bb = (l[0] - a * t[0]) % 31
        if ((a * t + bb) % 31 == l).all():
            found = True
            break
    assert found


def test_graph_sampler_fanout_bounds():
    g = graph_sampler.CSRGraph.random(0, n_nodes=500, avg_degree=8)
    seeds = np.arange(16)
    sub = graph_sampler.sample_fanout(g, seeds, [5, 3], seed=1)
    n_nodes = int(sub.node_mask.sum())
    n_edges = int(sub.edge_mask.sum())
    assert n_edges <= 16 * 5 + 16 * 5 * 3
    assert n_nodes <= 16 + n_edges
    # every edge endpoint is a valid local node
    assert sub.src[:n_edges].max() < n_nodes
    assert sub.dst[:n_edges].max() < n_nodes
    # seeds map to themselves
    np.testing.assert_array_equal(sub.nodes[:16], seeds)


def test_graph_sampler_edges_exist_in_graph():
    g = graph_sampler.CSRGraph.random(3, n_nodes=100, avg_degree=6)
    sub = graph_sampler.sample_fanout(g, np.array([1, 2]), [4], seed=0)
    ne = int(sub.edge_mask.sum())
    for i in range(ne):
        u = int(sub.nodes[sub.dst[i]])       # message dst = the sampled-for
        v = int(sub.nodes[sub.src[i]])
        assert v in g.neighbors(u)


def test_minibatch_lg_shape_is_feasible():
    """The assigned minibatch_lg buffers must hold any fanout-[15,10] draw."""
    from repro.configs.base import GNN_SHAPES
    m = GNN_SHAPES["minibatch_lg"].meta
    assert m["n_edges_raw"] == 1024 * 15 + 1024 * 15 * 10
    assert m["n_edges"] >= m["n_edges_raw"]          # mesh padding
    assert m["n_edges"] % 512 == 0
    assert m["n_nodes"] == 1024 + m["n_edges_raw"]


def test_ndcg_hand_example():
    retrieved = np.array([5, 9, 2])
    relevant = np.array([5, 2])
    gains = np.array([1.0, 0.5])
    got = metrics.ndcg_at_k(retrieved, relevant, gains, 3)
    want_dcg = 1.0 / np.log2(2) + 0.5 / np.log2(4)
    want_ideal = 1.0 / np.log2(2) + 0.5 / np.log2(3)
    np.testing.assert_allclose(got, want_dcg / want_ideal, rtol=1e-6)


def test_precision_recall():
    retrieved = np.array([1, 2, 3, 4])
    relevant = np.array([2, 4, 9])
    assert metrics.precision_at_k(retrieved, relevant, 4) == 0.5
    np.testing.assert_allclose(metrics.recall_at_k(retrieved, relevant, 4),
                               2 / 3)


def test_corpus_quality_oracle_consistency():
    from repro.data import corpus as corpus_lib
    corp = corpus_lib.make_corpus(0, 200, emb_dim=16, n_topics=4)
    qs = corpus_lib.make_queries(1, corp, 5, n_relevant=20)
    # the oracle ranking must achieve NDCG 1.0 against itself
    for i in range(5):
        got = metrics.ndcg_at_k(qs.relevant[i], qs.relevant[i], qs.gains[i],
                                10)
        np.testing.assert_allclose(got, 1.0, rtol=1e-6)
