"""Fused k-means assignment kernel vs the unfused jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _data(seed, n, k, d):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((n, d)), jnp.float32),
            jnp.asarray(rng.standard_normal((k, d)), jnp.float32))


@pytest.mark.parametrize("n,k,d", [
    (256, 512, 64),           # one block
    (512, 1024, 128),         # multi-block both axes
    (300, 700, 96),           # ragged → wrapper pads
    (64, 8, 32),              # K smaller than a block
])
def test_assign_matches_oracle(n, k, d):
    x, c = _data(0, n, k, d)
    got_a, got_d = ops.kmeans_assign(x, c, impl="pallas")
    want_a, want_d = ref.kmeans_assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-4, atol=1e-4)


def test_padded_centroids_never_win():
    x, c = _data(1, 128, 5, 16)     # K=5 pads to 512
    got_a, _ = ops.kmeans_assign(x, c, impl="pallas", block=(128, 512))
    assert int(np.asarray(got_a).max()) < 5


def test_earliest_index_tie_break():
    """Duplicate centroids: kernel must pick the first, like jnp.argmin."""
    x = jnp.asarray(np.random.default_rng(2).standard_normal((64, 8)),
                    jnp.float32)
    c0 = jnp.asarray(np.random.default_rng(3).standard_normal((4, 8)),
                     jnp.float32)
    c = jnp.concatenate([c0, c0], axis=0)        # exact duplicates
    got_a, _ = ops.kmeans_assign(x, c, impl="pallas", block=(64, 4))
    assert int(np.asarray(got_a).max()) < 4


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 80), k=st.integers(1, 40), d=st.integers(1, 24),
       seed=st.integers(0, 2**31 - 1))
def test_property_matches_oracle(n, k, d, seed):
    x, c = _data(seed, n, k, d)
    got_a, _ = ops.kmeans_assign(x, c, impl="pallas", block=(32, 32))
    want_a, _ = ref.kmeans_assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))


def test_consistent_with_clustering_module():
    from repro.core import clustering
    x, c = _data(5, 200, 16, 32)
    via_kernel, _ = ops.kmeans_assign(x, c, impl="pallas")
    via_module = clustering.assign_to_centroids(x, c)
    np.testing.assert_array_equal(np.asarray(via_kernel),
                                  np.asarray(via_module))
