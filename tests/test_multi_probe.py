"""Multi-probe PIR-RAG (beyond-paper): boundary recall vs downlink trade."""
import jax
import numpy as np
import pytest

from repro.core import pipeline
from repro.data import corpus as corpus_lib
from repro.data import metrics


@pytest.fixture(scope="module")
def boundary_setup():
    """Corpus with encoder noise + many small clusters: the regime where
    single-cluster pruning loses boundary recall (the Fig-3 gap)."""
    corp = corpus_lib.make_corpus(0, 900, emb_dim=128, n_topics=30,
                                  topic_spread=1.0, encoder_noise=0.35)
    qs = corpus_lib.make_queries(1, corp, 12, n_relevant=20, noise=0.5)
    sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                       n_clusters=60, impl="xla", seed=0)
    return sysm, corp, qs


def _mean_ndcg(sysm, qs, probe):
    vals = []
    for i in range(len(qs.embeddings)):
        top, _ = sysm.query(qs.embeddings[i], top_k=10, multi_probe=probe,
                            key=jax.random.PRNGKey(100 + i))
        ids = np.array([d for d, _, _ in top])
        vals.append(metrics.ndcg_at_k(ids, qs.relevant[i], qs.gains[i], 10))
    return float(np.mean(vals))


def test_multi_probe_improves_boundary_recall(boundary_setup):
    sysm, corp, qs = boundary_setup
    n1 = _mean_ndcg(sysm, qs, 1)
    n3 = _mean_ndcg(sysm, qs, 3)
    assert n3 > n1, (n1, n3)          # fetching 3 cells recovers boundaries


def test_multi_probe_accounting_scales(boundary_setup):
    sysm, _, qs = boundary_setup
    _, s1 = sysm.query(qs.embeddings[0], multi_probe=1,
                       key=jax.random.PRNGKey(0))
    _, s3 = sysm.query(qs.embeddings[0], multi_probe=3,
                       key=jax.random.PRNGKey(0))
    assert s3.downlink_bytes == 3 * s1.downlink_bytes
    assert s3.uplink_bytes == 3 * s1.uplink_bytes


def test_multi_probe_exactness(boundary_setup):
    """Every returned doc's text is byte-exact (crypto adds no error)."""
    sysm, corp, qs = boundary_setup
    top, _ = sysm.query(qs.embeddings[3], top_k=8, multi_probe=2,
                        key=jax.random.PRNGKey(7))
    assert len(top) == 8
    for doc_id, _, text in top:
        assert text == corp.texts[doc_id]


def test_single_probe_matches_legacy_path(boundary_setup):
    """multi_probe=1 returns the same docs as the paper-faithful query."""
    sysm, corp, _ = boundary_setup
    q = corp.embeddings[17]
    t1, st1 = sysm.query(q, top_k=5, multi_probe=1,
                         key=jax.random.PRNGKey(1))
    t2, st2 = sysm.query(q, top_k=5, multi_probe=1,
                         key=jax.random.PRNGKey(2))
    assert [d for d, _, _ in t1] == [d for d, _, _ in t2]
    assert st1.cluster_index == st2.cluster_index
