"""Optimizers + gradient compression: reference math and convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import compression, optimizers as opt_lib


def _quadratic(w):
    t = jnp.arange(1.0, 5.0)
    return jnp.sum((w - t) ** 2)


def test_adamw_matches_numpy_reference():
    opt = opt_lib.adamw(1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                        clip_norm=None)
    w = jnp.asarray([0.5, -1.0])
    state = opt.init(w)
    g = jnp.asarray([0.2, -0.4])
    new_w, state = opt.update(g, state, w)
    # closed form for step 1: update = lr * g/|g| elementwise (bias-corrected
    # m/√v = g/|g| exactly at t=1)
    want = np.asarray(w) - 1e-2 * np.sign(np.asarray(g)) * (
        np.abs(np.asarray(g)) / (np.abs(np.asarray(g)) + 1e-8 * np.sqrt(1e-3)))
    np.testing.assert_allclose(np.asarray(new_w), want, rtol=1e-4)


def test_adamw_converges_on_quadratic():
    opt = opt_lib.adamw(0.1, clip_norm=None)
    w = jnp.zeros(4)
    state = opt.init(w)
    for _ in range(300):
        g = jax.grad(_quadratic)(w)
        w, state = opt.update(g, state, w)
    np.testing.assert_allclose(np.asarray(w), np.arange(1.0, 5.0), atol=1e-2)


def test_adafactor_converges_and_state_is_factored():
    opt = opt_lib.adafactor(0.3, min_dim_factored=4)
    w = jnp.zeros((8, 8))
    tgt = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                      jnp.float32)
    state = opt.init(w)
    v = state["v"]
    assert set(v.keys()) == {"vr", "vc"}           # factored second moment
    assert v["vr"].shape == (8,) and v["vc"].shape == (8,)
    for _ in range(400):
        g = jax.grad(lambda w: jnp.sum((w - tgt) ** 2))(w)
        w, state = opt.update(g, state, w)
    assert float(jnp.mean(jnp.abs(w - tgt))) < 0.1


def test_adafactor_memory_is_sublinear():
    """The reason 1T-param training fits: state ≪ 2× params."""
    opt = opt_lib.adafactor(1e-2)
    params = {"w": jnp.zeros((4096, 4096), jnp.bfloat16)}
    state = jax.eval_shape(opt.init, params)
    p_elems = 4096 * 4096
    s_elems = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state))
    assert s_elems < p_elems / 100


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, gn = opt_lib.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)


def test_cosine_schedule_shape():
    lr = opt_lib.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1e-3, rtol=1e-5)
    assert float(lr(jnp.asarray(100))) < 1e-5


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_ef_quantize_roundtrip_error_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, s = compression.quantize_int8(g)
    err = np.abs(np.asarray(compression.dequantize_int8(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_longrun():
    """With EF, Σ compressed grads → Σ true grads (residual telescopes)."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.standard_normal(64), jnp.float32)
             for _ in range(50)]
    res = jnp.zeros(64)
    total_c = jnp.zeros(64)
    for g in grads:
        (q, s, res) = compression.ef_compress_tree(g, res)
        total_c = total_c + compression.dequantize_int8(q, s)
    total_t = sum(np.asarray(g) for g in grads)
    # residual bound: remaining error ≤ final residual magnitude
    np.testing.assert_allclose(np.asarray(total_c) + np.asarray(res),
                               total_t, rtol=1e-4, atol=1e-4)


def test_compressed_sgd_converges():
    opt = opt_lib.sgd(0.05)
    w = jnp.zeros(4)
    state = opt.init(w)
    res = compression.init_residuals(w)
    for _ in range(300):
        g = jax.grad(_quadratic)(w)
        g_c, res = compression.compressed_mean_grads(g, res, axis=None)
        w, state = opt.update(g_c, state, w)
    np.testing.assert_allclose(np.asarray(w), np.arange(1.0, 5.0), atol=0.05)
