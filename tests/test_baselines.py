"""Baseline architectures: correctness vs plaintext oracles + trade-offs."""
import jax
import numpy as np
import pytest

from repro.core.baselines import common, graph_pir, tiptoe
from repro.data import corpus as corpus_lib
from repro.data import metrics


@pytest.fixture(scope="module")
def corp():
    return corpus_lib.make_corpus(0, 400, emb_dim=48, n_topics=10)


# ---------------------------------------------------------------------------
# Graph-PIR
# ---------------------------------------------------------------------------

def test_knn_graph_is_exact(corp):
    g = graph_pir.build_knn_graph(corp.embeddings[:50], k=5)
    nn = corp.embeddings[:50]
    nn = nn / np.linalg.norm(nn, axis=1, keepdims=True)
    sims = nn @ nn.T
    np.fill_diagonal(sims, -np.inf)
    want = np.argsort(-sims, axis=1)[:, :5]
    np.testing.assert_array_equal(g, want.astype(np.uint32))


@pytest.fixture(scope="module")
def gsys(corp):
    return graph_pir.GraphPIRSystem.build(corp.embeddings, degree=12,
                                          n_entry=4, impl="xla")


def test_graph_search_recall_vs_bruteforce(corp, gsys):
    nn = corp.embeddings / np.linalg.norm(corp.embeddings, axis=1,
                                          keepdims=True)
    recalls = []
    for qi in range(8):
        q = corp.embeddings[qi * 37] + 0.02
        ids, stats = gsys.search(q, top_k=10, beam=8, max_hops=6, seed=qi)
        oracle = np.argsort(-(nn @ (q / np.linalg.norm(q))))[:10]
        recalls.append(len(set(ids.tolist()) & set(oracle.tolist())) / 10)
        assert stats.hops >= 2
        assert stats.uplink_bytes > 0 and stats.downlink_bytes > 0
    assert np.mean(recalls) >= 0.7          # fine-grained traversal quality


def test_graph_search_flat_downlink(corp, gsys):
    """Downlink is per-node records (KBs), not cluster content (MBs)."""
    q = corp.embeddings[3]
    _, stats = gsys.search(q, top_k=10, beam=8, max_hops=6)
    assert stats.downlink_bytes < 500_000


# ---------------------------------------------------------------------------
# Tiptoe-style
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tsys(corp):
    return tiptoe.TiptoeSystem.build(corp.embeddings, n_clusters=10,
                                     impl="xla", seed=1)


def test_tiptoe_scores_match_plaintext_quantized(corp, tsys):
    """Decrypted homomorphic scores == plaintext quantized dot products."""
    q = corp.embeddings[123] + 0.01
    ids, stats = tsys.search(q, top_k=5, key=jax.random.PRNGKey(2))
    cl = stats.cluster_index
    dq = tsys.quant.unshift(tsys.cluster_mats[cl])        # signed ints
    qq = tsys.quant.unshift(tsys.quant.quantize(q.astype(np.float32)))
    plain = dq @ qq
    oracle_ids = tsys.cluster_doc_ids[cl][np.argsort(-plain)[:5]]
    np.testing.assert_array_equal(ids, oracle_ids)


def test_tiptoe_quantization_is_coarse(tsys):
    """The mechanism behind Fig 3's quality gap: few signed levels."""
    assert tsys.quant.levels <= 15


def test_tiptoe_comm_is_small(corp, tsys):
    _, stats = tsys.search(corp.embeddings[7], key=jax.random.PRNGKey(3))
    assert stats.uplink_bytes == corp.d * 4
    assert stats.downlink_bytes < 64_000   # scores only, no content


# ---------------------------------------------------------------------------
# Retrieve-then-fetch tail (what makes the baselines RAG-incomplete)
# ---------------------------------------------------------------------------

def test_doc_content_pir_fetch_exact(corp):
    dc = common.DocContentPIR.build(corp.texts[:100], corp.embeddings[:100],
                                    impl="xla")
    for did in (0, 57, 99):
        got_id, emb, text = dc.fetch(jax.random.PRNGKey(did), did)
        assert got_id == did
        assert text == corp.texts[did]
        step = (corp.embeddings[did].max() - corp.embeddings[did].min()) / 255
        assert np.abs(emb - corp.embeddings[did]).max() <= step / 2 + 1e-6


def test_rag_ready_requires_k_more_fetches(corp):
    """Fetching K docs costs K × (uplink+downlink) — PIR-RAG's whole point."""
    dc = common.DocContentPIR.build(corp.texts[:100], corp.embeddings[:100],
                                    impl="xla")
    docs = dc.fetch_many(0, [1, 2, 3])
    assert [d[0] for d in docs] == [1, 2, 3]
    assert dc.per_fetch_uplink == 100 * 4
    assert dc.per_fetch_downlink > 0
