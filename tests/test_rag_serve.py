"""Generation equivalence across serve engines (the closed RAG loop).

The contract (ISSUE 10): attaching a `Generator` to a serve loop adds
tokens to its responses but changes NOTHING else — retrieval payloads,
epochs, retries and batching are byte-identical to a generator-free run —
and the tokens themselves are bit-identical across the sync, pipelined
(any `gen_coalesce`) and fleet engines over the same schedule, mutations
and faults included.  The pipelined engine defers and COALESCES
generation micro-batches, so these tests are what pins "moving and
merging generation work never changes a token".
"""
import copy

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.data import corpus as corpus_lib
from repro.fleet import FaultPlan, FleetServeLoop, ReplicaGroup
from repro.rag import Generator
from repro.serve import PIRServeLoop, PipelinedServeLoop
from repro.update import LiveIndex, journal as journal_lib

N_DOCS = 120
SYNC_LAG = 2


class FakeClock:
    """Monotone virtual clock advancing a fixed step per reading."""

    def __init__(self, step: float = 1e-4):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


_BASE: dict = {}


def _get_base():
    """Corpus + live index + generators, built once per process.

    Not a fixture: the hypothesis property below runs under the
    `_hypothesis_compat` shim, whose `given` wrapper presents a zero-arg
    signature.  Engine runs get deepcopies of the live index; the
    generators are shared on purpose (params are read-only and sharing
    reuses the per-batch-size jit caches).
    """
    if not _BASE:
        corp = corpus_lib.make_corpus(7, N_DOCS, emb_dim=16, n_topics=5)
        live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=5,
                               impl="xla", kmeans_iters=5)
        _BASE["corp"], _BASE["live"] = corp, live
        # sampled, not greedy: greedy tokens ignore rids, which would mask
        # a coalescing bug that mis-slices the (B_total, N) grid back to
        # its groups — sampling keys off (seed, rid, step), so any row
        # landing on the wrong request changes its tokens
        _BASE["gen"] = Generator.tiny(seed=3, context_budget=64,
                                      max_new_tokens=4, temperature=0.8)
        _BASE["greedy"] = Generator.tiny(seed=3, context_budget=64,
                                         max_new_tokens=4)
    return _BASE["corp"], _BASE["live"], _BASE["gen"], _BASE["greedy"]


def _sig_retrieval(loop):
    """Everything retrieval promised pre-RAG — must never change."""
    return [(r.rid, r.epoch, r.retries, r.batch_size, r.failed,
             tuple((d, t) for d, _, t in r.top)) for r in loop.responses]


def _tokens(loop):
    return {r.rid: r.tokens for r in loop.responses}


def _drive(loop, corp, *, n_ops: int = 36, seed: int = 0):
    """Seeded submit/mutate/tick interleaving, identical across engines."""
    rng = np.random.default_rng(seed)
    for i in range(n_ops):
        loop.submit(i, corp.embeddings[int(rng.integers(N_DOCS))], top_k=3)
        roll = int(rng.integers(10))
        if roll < 2:
            loop.submit_mutation(journal_lib.replace(
                i % N_DOCS, f"mut {i}".encode(),
                corp.embeddings[(i + 1) % N_DOCS]))
        if roll >= 7:
            loop.tick()
    loop.drain()


def _kw():
    return dict(max_batch=4, deadline_ms=1e9, clock=FakeClock(), seed=0)


def test_tokens_identical_three_engines_under_mutations():
    """sync == pipelined (coalesced or not) == fleet, token for token."""
    corp, base, gen, _ = _get_base()
    sync = PIRServeLoop(copy.deepcopy(base), generator=gen, **_kw())
    _drive(sync, corp)
    ref_tok, ref_sig = _tokens(sync), _sig_retrieval(sync)
    assert all(t is not None and len(t) == 4 for t in ref_tok.values())
    assert all(r.rag is not None for r in sync.responses)

    for gc in (1, 3):
        pipe = PipelinedServeLoop(copy.deepcopy(base), generator=gen,
                                  depth=2, gen_coalesce=gc, **_kw())
        _drive(pipe, corp)
        assert _tokens(pipe) == ref_tok, f"gen_coalesce={gc}"
        assert _sig_retrieval(pipe) == ref_sig, f"gen_coalesce={gc}"
        assert not pipe._gen_pending

    group = ReplicaGroup.from_live(copy.deepcopy(base), n_replicas=2,
                                   n_shards=4, sync_lag=SYNC_LAG)
    fleet = FleetServeLoop(group, generator=gen, depth=2, gen_coalesce=2,
                           **_kw())
    _drive(fleet, corp)
    assert _tokens(fleet) == ref_tok
    assert _sig_retrieval(fleet) == ref_sig
    assert group.failovers == 0


def test_retrieval_byte_identical_to_generator_free_run():
    """The generation stage is purely additive: a generator-free run of the
    SAME schedule produces byte-identical retrieval responses (and no
    tokens) — attaching a generator must not perturb batching, epochs,
    retries or payloads."""
    corp, base, gen, _ = _get_base()
    plain = PIRServeLoop(copy.deepcopy(base), **_kw())
    _drive(plain, corp)
    ragged = PIRServeLoop(copy.deepcopy(base), generator=gen, **_kw())
    _drive(ragged, corp)
    assert _sig_retrieval(plain) == _sig_retrieval(ragged)
    assert all(r.tokens is None and r.rag is None for r in plain.responses)
    assert all(r.tokens is not None for r in ragged.responses)


def test_tokens_are_pure_function_of_retrieval_under_faults():
    """Faults may move WHICH docs a response carries (failover staleness,
    retries) but never how they generate: every served response's tokens
    must equal a from-scratch `Generator.generate` of its own payload.
    Batch invariance makes the B=1 recompute a valid oracle for tokens
    produced inside arbitrary coalesced micro-batches."""
    corp, base, _, greedy = _get_base()
    plan = FaultPlan.single_shard_loss(at_tick=3, device=0, down_ticks=6)
    group = ReplicaGroup.from_live(copy.deepcopy(base), n_replicas=2,
                                   n_shards=4, sync_lag=SYNC_LAG)
    fleet = FleetServeLoop(group, generator=greedy, depth=2, gen_coalesce=3,
                           faults=plan.compile(), **_kw())
    _drive(fleet, corp, n_ops=40)
    assert group.failovers == 1                       # the fault really hit
    checked = 0
    for r in fleet.responses:
        if r.failed or r.tokens is None:
            continue
        want = greedy.generate([list(r.top)], [r.rid])[0]
        assert tuple(int(t) for t in want) == r.tokens, r.rid
        checked += 1
    assert checked >= 30


def test_coalesce_bound_flushes_on_idle_and_drain():
    """A partial micro-batch (fewer than gen_coalesce groups parked) must
    not strand responses: idle ticks and drain flush everything."""
    corp, base, _, greedy = _get_base()
    loop = PipelinedServeLoop(copy.deepcopy(base), generator=greedy,
                              depth=1, gen_coalesce=8, **_kw())
    for rid in range(8):                       # 2 batches — under the bound
        loop.submit(rid, corp.embeddings[rid], top_k=3)
        loop.tick()
    loop.drain()
    assert len(loop.responses) == 8
    assert all(r.tokens is not None for r in loop.responses)
    assert not loop._gen_pending


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**9))
def test_property_random_interleavings_with_generation(seed):
    """Random schedules × random depth × random gen_coalesce: tokens and
    retrieval signatures identical between the sync and pipelined loops."""
    corp, base, gen, _ = _get_base()
    rng = np.random.default_rng(seed)
    n_ops = int(rng.integers(20, 50))
    depth = int(rng.integers(1, 4))
    gen_coalesce = int(rng.integers(1, 6))
    sync = PIRServeLoop(copy.deepcopy(base), generator=gen, **_kw())
    _drive(sync, corp, n_ops=n_ops, seed=seed)
    pipe = PipelinedServeLoop(copy.deepcopy(base), generator=gen,
                              depth=depth, gen_coalesce=gen_coalesce,
                              **_kw())
    _drive(pipe, corp, n_ops=n_ops, seed=seed)
    assert _tokens(sync) == _tokens(pipe)
    assert _sig_retrieval(sync) == _sig_retrieval(pipe)
