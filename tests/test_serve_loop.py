"""Deadline batcher + serving loop: batching policy and correctness."""
import numpy as np
import pytest

from repro.core import pipeline
from repro.data import corpus as corpus_lib
from repro.launch.serve import DeadlineBatcher, PIRServeLoop, Request


def test_batcher_cuts_at_max_batch():
    b = DeadlineBatcher(max_batch=4, deadline_ms=1e9)
    for i in range(5):
        b.submit(Request(i, np.zeros(2), t_arrival=0.0))
    assert b.ready(now=0.0)                 # 5 ≥ max_batch
    cut = b.cut()
    assert [r.rid for r in cut] == [0, 1, 2, 3]
    assert len(b.queue) == 1


def test_batcher_cuts_on_deadline():
    b = DeadlineBatcher(max_batch=100, deadline_ms=20.0)
    b.submit(Request(0, np.zeros(2), t_arrival=1.000))
    assert not b.ready(now=1.010)           # 10ms old
    assert b.ready(now=1.025)               # 25ms old → deadline


def test_batcher_empty_never_ready():
    b = DeadlineBatcher()
    assert not b.ready(now=123.0)


@pytest.fixture(scope="module")
def system():
    corp = corpus_lib.make_corpus(0, 250, emb_dim=24, n_topics=8)
    sys = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                      n_clusters=8, impl="xla")
    return sys, corp


def test_serve_loop_end_to_end(system):
    sys, corp = system
    loop = PIRServeLoop(sys, max_batch=4, deadline_ms=1e9)
    for rid in range(6):
        loop.submit(rid, corp.embeddings[rid * 11])
        loop.tick()
    loop.drain()
    assert len(loop.responses) == 6
    # each response's top-1 must be the anchor doc (exact private retrieval)
    for r in loop.responses:
        top_ids = [d for d, _, _ in r.top]
        assert r.rid * 11 in top_ids
    # first four went out as one batch of 4
    assert loop.responses[0].batch_size == 4
