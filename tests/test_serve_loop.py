"""Deadline batcher + serving loop: batching policy and correctness."""
import numpy as np
import pytest

from repro.core import pipeline
from repro.data import corpus as corpus_lib
from repro.launch.serve import DeadlineBatcher, PIRServeLoop, Request


def test_batcher_cuts_at_max_batch():
    b = DeadlineBatcher(max_batch=4, deadline_ms=1e9)
    for i in range(5):
        b.submit(Request(i, np.zeros(2), t_arrival=0.0))
    assert b.ready(now=0.0)                 # 5 ≥ max_batch
    cut = b.cut()
    assert [r.rid for r in cut] == [0, 1, 2, 3]
    assert len(b.queue) == 1


def test_batcher_cuts_on_deadline():
    b = DeadlineBatcher(max_batch=100, deadline_ms=20.0)
    b.submit(Request(0, np.zeros(2), t_arrival=1.000))
    assert not b.ready(now=1.010)           # 10ms old
    assert b.ready(now=1.025)               # 25ms old → deadline


def test_batcher_empty_never_ready():
    b = DeadlineBatcher()
    assert not b.ready(now=123.0)


def test_requeue_front_preserves_fifo_among_retries():
    """Regression: stale requests re-queued as a group must keep their cut
    order — requeue'ing one-by-one in iteration order would reverse
    same-epoch retries relative to each other."""
    b = DeadlineBatcher(max_batch=8, deadline_ms=1e9)
    for i in range(3):
        b.submit(Request(100 + i, np.zeros(2), t_arrival=0.0))  # younger
    stale = [Request(i, np.zeros(2), t_arrival=0.0) for i in range(4)]
    b.requeue_front(stale)
    assert [r.rid for r in b.cut()] == [0, 1, 2, 3, 100, 101, 102]
    # the one-request form still exists for single rejects
    b.submit(Request(200, np.zeros(2), t_arrival=0.0))
    b.requeue(Request(7, np.zeros(2), t_arrival=0.0))
    assert [r.rid for r in b.cut()] == [7, 200]


def test_loop_retries_stay_fifo_across_epoch_reject():
    """End-to-end: a commit that staleness-rejects a whole batch must serve
    the retried requests in their original submission order."""
    from repro.update import LiveIndex, journal as journal_lib

    corp = corpus_lib.make_corpus(3, 150, emb_dim=16, n_topics=5)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=5,
                           impl="xla", kmeans_iters=5)
    loop = PIRServeLoop(live, max_batch=8, deadline_ms=1e9)
    for rid in range(5):                    # all formed against epoch 0
        loop.submit(rid, corp.embeddings[rid])
    loop.submit_mutation(journal_lib.replace(3, b"bump",
                                             corp.embeddings[3]))
    loop.drain()
    retried = [r for r in loop.responses if r.retries == 1]
    assert [r.rid for r in retried] == [0, 1, 2, 3, 4]


@pytest.fixture(scope="module")
def system():
    corp = corpus_lib.make_corpus(0, 250, emb_dim=24, n_topics=8)
    sys = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                      n_clusters=8, impl="xla")
    return sys, corp


def test_serve_loop_end_to_end(system):
    sys, corp = system
    loop = PIRServeLoop(sys, max_batch=4, deadline_ms=1e9)
    for rid in range(6):
        loop.submit(rid, corp.embeddings[rid * 11])
        loop.tick()
    loop.drain()
    assert len(loop.responses) == 6
    # each response's top-1 must be the anchor doc (exact private retrieval)
    for r in loop.responses:
        top_ids = [d for d, _, _ in r.top]
        assert r.rid * 11 in top_ids
    # first four went out as one batch of 4
    assert loop.responses[0].batch_size == 4


def test_drain_preserves_configured_deadline(system):
    """drain() force-flushes the final partial batch WITHOUT zeroing the
    deadline — later traffic must still batch under the configured SLO."""
    sys, corp = system
    loop = PIRServeLoop(sys, max_batch=4, deadline_ms=77.0)
    loop.submit(0, corp.embeddings[0])
    loop.drain()
    assert len(loop.responses) == 1
    assert loop.batcher.deadline_ms == 77.0
    # the loop keeps batching afterwards: a fresh request is NOT cut early
    loop.submit(1, corp.embeddings[3])
    assert loop.tick() == 0


def test_per_batch_keys_are_distinct(system):
    """LWE secrets must come from one split stream, not wall-clock seeds:
    two equal-content batches in the same loop must encrypt differently."""
    sys, corp = system
    loop = PIRServeLoop(sys, max_batch=2, deadline_ms=1e9, seed=0)
    import repro.core.pipeline as pipeline_mod
    seen_keys = []
    # the sync loop routes through query_batch_async for component timing
    orig = pipeline_mod.PirRagSystem.query_batch_async

    def spy(self, embs, **kw):
        seen_keys.append(np.asarray(kw["key"]).tolist())
        return orig(self, embs, **kw)

    pipeline_mod.PirRagSystem.query_batch_async = spy
    try:
        for rid in range(4):
            loop.submit(rid, corp.embeddings[0])   # identical queries
            loop.tick()
    finally:
        pipeline_mod.PirRagSystem.query_batch_async = orig
    assert len(seen_keys) == 2
    assert seen_keys[0] != seen_keys[1]


def test_live_mode_interleaves_mutations_and_retries_stale():
    from repro.update import LiveIndex, journal as journal_lib

    corp = corpus_lib.make_corpus(1, 200, emb_dim=16, n_topics=6)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=6,
                           impl="xla", kmeans_iters=6)
    loop = PIRServeLoop(live, max_batch=4, deadline_ms=1e9)
    for rid in range(3):                   # formed against epoch 0
        loop.submit(rid, corp.embeddings[rid])
    loop.submit_mutation(journal_lib.replace(9, b"live-updated nine",
                                             corp.embeddings[9]))
    loop.drain()
    # the commit advanced the epoch, so all 3 were rejected once and retried
    assert live.epoch == 1
    assert loop.stale_retries == 3
    assert len(loop.responses) == 3
    assert all(r.epoch == 1 and r.retries == 1 for r in loop.responses)
    # fresh queries now see the mutated content
    loop.submit(50, corp.embeddings[9])
    loop.drain()
    assert [t for d, _, t in loop.responses[-1].top
            if d == 9] == [b"live-updated nine"]
