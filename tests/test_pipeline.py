"""PIR-RAG end-to-end: private retrieval returns the right documents."""
import jax
import numpy as np
import pytest

from repro.core import pipeline
from repro.data import corpus as corpus_lib


@pytest.fixture(scope="module")
def system_and_corpus():
    corp = corpus_lib.make_corpus(0, 300, emb_dim=32, n_topics=8)
    sys = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                      n_clusters=8, kmeans_iters=15,
                                      impl="xla", seed=1)
    return sys, corp


def test_query_returns_cluster_topk_exactly(system_and_corpus):
    """Private result == plaintext within-cluster brute force (no crypto loss)."""
    sys, corp = system_and_corpus
    q = corp.embeddings[17] + 0.01
    top, stats = sys.query(q, top_k=5, key=jax.random.PRNGKey(7))
    assert len(top) == 5
    # plaintext oracle: best cosine within the (client-chosen) cluster
    from repro.core import clustering
    import jax.numpy as jnp
    cl = int(clustering.assign_to_centroids(
        jnp.asarray(q, jnp.float32)[None], jnp.asarray(sys.centroids))[0])
    assert stats.cluster_index == cl
    member_ids = [i for j in range(sys.db.n)
                  for (i, _, _) in _cluster_docs(sys, j) if j == cl]
    got_ids = [t[0] for t in top]
    qn = q / np.linalg.norm(q)
    emb = corp.embeddings[member_ids]
    oracle = np.asarray(member_ids)[np.argsort(
        -(emb / np.linalg.norm(emb, axis=1, keepdims=True)) @ qn)][:5]
    # quantized embeddings may swap near-ties; demand ≥4/5 overlap and same top-1
    assert got_ids[0] == int(oracle[0])
    assert len(set(got_ids) & set(int(x) for x in oracle)) >= 4


def _cluster_docs(sys, j):
    from repro.core import chunking
    return chunking.deserialize_docs(sys.db.matrix[:, j], sys.db.emb_dim)


def test_retrieved_text_is_original(system_and_corpus):
    sys, corp = system_and_corpus
    top, _ = sys.query(corp.embeddings[5], top_k=3,
                       key=jax.random.PRNGKey(8))
    for doc_id, _, text in top:
        assert text == corp.texts[doc_id]


def test_comm_accounting(system_and_corpus):
    sys, _ = system_and_corpus
    _, stats = sys.query(np.ones(32, np.float32), top_k=2,
                         key=jax.random.PRNGKey(9))
    assert stats.uplink_bytes == sys.db.n * 4          # one u32 per cluster
    assert stats.downlink_bytes == sys.db.m * 2        # mod-switched u16 rows
    assert stats.downlink_bytes > stats.uplink_bytes   # paper's core trade-off


def test_batched_matches_sequential(system_and_corpus):
    sys, corp = system_and_corpus
    qs = corp.embeddings[[3, 50, 120]]
    batched = sys.query_batch(qs, top_k=4, seed=3)
    for q, res in zip(qs, batched):
        solo, _ = sys.query(q, top_k=4, key=jax.random.PRNGKey(11))
        assert [d for d, _, _ in res] == [d for d, _, _ in solo]


def test_build_seed_streams_are_independent():
    """One build seed, TWO independent fold_in streams (regression pin).

    kmeans++ seeding and LWE setup (the public matrix A's seed) must not
    share a PRNG stream: a shared key would let a clustering-knob change
    silently re-derive A — and with it every hint, query and cached client
    state.  Pins both stream values for seed 0/1 and asserts clustering
    knobs cannot move `a_seed`.
    """
    k_km, a_seed = pipeline._derive_build_streams(0)
    assert np.asarray(k_km).tolist() == [1797259609, 2579123966]
    assert a_seed == 1404501984
    assert pipeline._derive_build_streams(1)[1] == 879036028

    corp = corpus_lib.make_corpus(5, 150, emb_dim=16, n_topics=4)
    base = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                       n_clusters=4, impl="xla", seed=0)
    assert base.cfg.a_seed == 1404501984
    # changing cluster seeding inputs must leave key material untouched
    for kw in (dict(n_clusters=6), dict(kmeans_iters=3),
               dict(n_clusters=4, balance_factor=1.5)):
        other = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                            impl="xla", seed=0,
                                            **{"n_clusters": 4, **kw})
        assert other.cfg.a_seed == base.cfg.a_seed
        if other.cfg.n == base.cfg.n:      # A's shape is (n_clusters, k)
            assert np.array_equal(np.asarray(other.server.a_matrix),
                                  np.asarray(base.server.a_matrix))
    # ... and a different build seed moves BOTH streams
    moved = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                        n_clusters=4, impl="xla", seed=1)
    assert moved.cfg.a_seed == 879036028
    assert not np.array_equal(moved.centroids, base.centroids)
    # the kmeans stream is exactly the pinned fold_in stream
    from repro.core import clustering
    km = clustering.kmeans_fit(k_km, corp.embeddings.astype(np.float32),
                               k=4, iters=25,
                               n_blocks=clustering.BUILD_BLOCKS)
    assert np.array_equal(np.asarray(km.centroids), base.centroids)


def test_balanced_build_reduces_downlink():
    corp = corpus_lib.make_corpus(3, 200, emb_dim=16, n_topics=4)
    plain = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                        n_clusters=8, impl="xla", seed=0)
    balanced = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                           n_clusters=8, impl="xla", seed=0,
                                           balance_factor=1.3)
    assert balanced.db.m <= plain.db.m                 # beyond-paper win
    q = corp.embeddings[0]
    top, _ = balanced.query(q, top_k=3, key=jax.random.PRNGKey(1))
    assert top and top[0][1] > 0.5
