"""SchNet: graph/molecule modes, segment-sum message passing invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import schnet


def _graph_cfg(**kw):
    base = dict(name="s", n_interactions=2, d_hidden=16, n_rbf=8, cutoff=4.0,
                mode="graph", d_feat=12, n_out=5)
    base.update(kw)
    return schnet.SchNetConfig(**base)


def _rand_graph(seed, n=30, e=80, d_feat=12):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((n, d_feat)), jnp.float32),
            jnp.asarray(rng.integers(0, n, e), jnp.int32),
            jnp.asarray(rng.integers(0, n, e), jnp.int32),
            jnp.asarray(rng.uniform(0.1, 3.9, e), jnp.float32))


def test_graph_forward_shapes_and_finite():
    cfg = _graph_cfg()
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    feat, src, dst, dist = _rand_graph(0)
    out = schnet.apply_graph(params, feat, src, dst, dist, cfg)
    assert out.shape == (30, 5)
    assert np.isfinite(np.asarray(out)).all()


def test_message_passing_locality():
    """A node with no incoming edges is influenced only by its own features."""
    cfg = _graph_cfg(n_interactions=1)
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    feat, src, dst, dist = _rand_graph(1)
    # rewire: no edges point at node 0
    dst = jnp.where(dst == 0, 1, dst)
    out1 = schnet.apply_graph(params, feat, src, dst, dist, cfg)
    feat2 = feat.at[5].add(10.0)      # perturb some other node
    out2 = schnet.apply_graph(params, feat2, src, dst, dist, cfg)
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]),
                               atol=1e-5)
    assert np.abs(np.asarray(out1[5] - out2[5])).max() > 1e-3


def test_edges_beyond_cutoff_are_ignored():
    cfg = _graph_cfg(n_interactions=1, cutoff=2.0)
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    feat, src, dst, dist = _rand_graph(2)
    out_far = schnet.apply_graph(params, feat, src, dst,
                                 jnp.full_like(dist, 3.0), cfg)
    base = schnet.apply_graph(params, feat, src, dst,
                              jnp.full_like(dist, 5.0), cfg)
    np.testing.assert_allclose(np.asarray(out_far), np.asarray(base),
                               atol=1e-5)   # both beyond cutoff → no messages


def test_molecule_permutation_invariance():
    """Total energy is invariant to atom reordering."""
    cfg = schnet.SchNetConfig(name="m", n_interactions=2, d_hidden=16,
                              n_rbf=8, cutoff=6.0, mode="molecule", n_out=1,
                              n_species=10)
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.integers(1, 10, (2, 6)), jnp.int32)
    pos = jnp.asarray(rng.standard_normal((2, 6, 3)) * 2, jnp.float32)
    e1 = schnet.apply_molecule(params, z, pos, cfg)
    perm = rng.permutation(6)
    e2 = schnet.apply_molecule(params, z[:, perm], pos[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-2,
                               atol=1e-2)


def test_molecule_translation_invariance():
    cfg = schnet.SchNetConfig(name="m", n_interactions=1, d_hidden=16,
                              n_rbf=8, cutoff=6.0, mode="molecule",
                              n_species=10)
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    z = jnp.asarray(rng.integers(1, 10, (1, 5)), jnp.int32)
    pos = jnp.asarray(rng.standard_normal((1, 5, 3)), jnp.float32)
    e1 = schnet.apply_molecule(params, z, pos, cfg)
    e2 = schnet.apply_molecule(params, z, pos + 7.5, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-2,
                               atol=1e-2)


def test_losses_finite_and_trainable():
    cfg = _graph_cfg()
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    feat, src, dst, dist = _rand_graph(5)
    batch = {"node_feat": feat, "src": src, "dst": dst, "edge_dist": dist,
             "labels": jnp.asarray(np.random.default_rng(0).integers(0, 5, 30)),
             "label_mask": jnp.ones(30, bool)}
    loss, grads = jax.value_and_grad(
        lambda p: schnet.graph_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0
