"""Every assigned (arch × shape) cell runs as a REDUCED config on CPU.

This is the per-cell smoke matrix required by the assignment: instantiate a
small config of the same family, run one step (train/prefill/decode/serve/
retrieval as the shape dictates), assert shapes + finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps as steps_lib

CELLS = [(n, s) for n in sorted(cfgbase.all_archs())
         for s in cfgbase.get(n).shapes]


@pytest.mark.parametrize("arch_name,shape_name", CELLS,
                         ids=[f"{n}:{s}" for n, s in CELLS])
def test_cell_smoke(arch_name, shape_name):
    arch = cfgbase.get(arch_name)
    bundle = steps_lib.make_bundle(arch, shape_name, smoke=True)
    batch = steps_lib.materialize_inputs(arch, shape_name,
                                         jax.random.PRNGKey(0))
    if bundle.init_state is not None:
        state = bundle.init_state(jax.random.PRNGKey(1))
    else:
        state = jnp.zeros(bundle.state_spec.shape, jnp.uint8)
    out = jax.jit(bundle.fn)(state, batch)
    leaves = jax.tree.leaves(out)
    assert leaves
    for x in leaves:
        arr = np.asarray(x)
        if arr.dtype.kind in "fc":
            assert np.isfinite(arr).all(), (arch_name, shape_name)


@pytest.mark.parametrize("arch_name,shape_name", CELLS,
                         ids=[f"{n}:{s}" for n, s in CELLS])
def test_cell_specs_consistent(arch_name, shape_name):
    """Full-size input specs exist, have positive dims, right dtypes."""
    arch = cfgbase.get(arch_name)
    specs = steps_lib.input_specs_for(arch, shape_name, smoke=False)
    assert specs
    for name, s in specs.items():
        assert all(d > 0 for d in s.shape), (arch_name, shape_name, name)
        assert s.dtype in (jnp.int32, jnp.float32, jnp.bool_, jnp.uint8,
                           jnp.uint32), s.dtype
