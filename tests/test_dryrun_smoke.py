"""The dry-run machinery itself, exercised end-to-end in a subprocess.

Runs the fastest real cells (pir_serve + one recsys serve) on the actual
512-device production meshes and checks the emitted JSON artifacts.
"""
import json
import os
import subprocess
import sys


def _run(args, timeout=560):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"})
    return proc


def test_pir_serve_cell_both_meshes(tmp_path):
    proc = _run(["--arch", "pir_serve", "--shape", "online_b64",
                 "--mesh", "both", "--out", str(tmp_path)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for mesh, n_dev in [("pod", 256), ("multipod", 512)]:
        rec = json.load(open(
            tmp_path / f"pir_serve__online_b64__{mesh}.json"))
        assert rec["ok"], rec.get("error")
        assert rec["n_devices"] == n_dev
        # the zero-collective hot path claim, at production scale
        assert rec["hlo"]["collective_bytes_per_device"] == {}
        assert rec["memory"]["peak_per_device_bytes"] < 16 * 2**30
        # per-device flops × devices == 2·m·n·b exactly (row+batch sharding)
        total = sum(rec["hlo"]["dot_flops_per_device"].values()) * n_dev
        want = 2 * (2 * 1024 * 1024) * 4096 * 64
        assert abs(total - want) / want < 0.01


def test_recsys_serve_cell(tmp_path):
    proc = _run(["--arch", "dcn-v2", "--shape", "serve_p99",
                 "--mesh", "pod", "--out", str(tmp_path)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.load(open(tmp_path / "dcn-v2__serve_p99__pod.json"))
    assert rec["ok"], rec.get("error")
    assert rec["memory"]["peak_per_device_bytes"] < 16 * 2**30
    assert rec["compile_s"] > 0


def test_roofline_terms_from_record(tmp_path):
    _run(["--arch", "pir_serve", "--shape", "online_b512", "--mesh", "pod",
          "--out", str(tmp_path)])
    sys.path.insert(0, "src")
    from repro.launch import roofline
    rec = json.load(open(tmp_path / "pir_serve__online_b512__pod.json"))
    t = roofline.terms(rec)
    assert t["peak_used"] == "int8"
    assert t["collective_s"] == 0.0
    assert t["bottleneck"] in ("compute", "memory")
    assert 0 < t["roofline_frac"] <= 1.05
    # b=512 queries: 8·b int8-ops per DB byte ≫ 394/819 → MXU-bound
    assert t["compute_s"] > t["memory_s"]
