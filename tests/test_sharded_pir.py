"""Sharded-PIR serving: cross-mode equivalence under the 8-device harness.

Correctness for the sharded subsystem means "bit-identical decode across
1-device and N-device layouts": the legacy single-device answer path, the
batch-PIR bucketed path, and the shard_map'd row-sharded path must all
recover the same plaintext bytes and rank the same top-k documents on the
same corpus, key stream, and mutation sequence.  Every case here runs in a
subprocess with 8 fake CPU devices (tests/_mesh_harness.py).

The whole file carries the `slow` marker: tier-1 (`pytest -x -q`) skips it
via addopts, and CI runs it in a dedicated job step.
"""
import pytest

from _hypothesis_compat import given, settings, st
from _mesh_harness import run_sub

pytestmark = pytest.mark.slow


def test_cross_mode_plaintexts_and_topk_bit_identical():
    """Legacy 1-device, sharded 8-device, and batch mode agree bit-for-bit
    on recovered plaintext bytes and top-k rankings — same corpus, same key
    stream, same mutation sequence (the ISSUE 3 acceptance property)."""
    run_sub("""
from repro.core import pipeline, pir
from repro.data import corpus as corpus_lib
from repro.update import LiveIndex

corp = corpus_lib.make_corpus(0, 300, emb_dim=24, n_topics=8)
mesh = jax.make_mesh((8,), ("chunks",))
live1 = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=8,
                        impl="xla", kmeans_iters=8)
live8 = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=8,
                        impl="xla", kmeans_iters=8, mesh=mesh)
sys1, sys8 = live1.system, live8.system
sys1.enable_batch(kappa=4, seed=11)
sys8.enable_batch(kappa=4, seed=11)
assert sys8.server.mesh is not None and sys8.batch.server.mesh is not None

# prime the sharded bucket stack BEFORE mutating, so the commit exercises
# the in-place stack patch (not a lazy rebuild on the next answer)
warm = sys8.query(corp.embeddings[0], top_k=3, multi_probe=2,
                  key=jax.random.PRNGKey(1), mode="batch")
assert sys8.batch.server._stack is not None

# identical mutation sequence on both layouts
rng = np.random.default_rng(5)
for live in (live1, live8):
    live.replace(9, b"rewritten nine", corp.embeddings[9])
    live.delete(23)
    live.insert(10_000, b"brand new doc", corp.embeddings[50] + 0.01)
    live.replace(111, b"rewritten one-one-one", corp.embeddings[111])
    live.commit()
assert live1.epoch == live8.epoch == 1

# 1) hints identical after the patched commit
np.testing.assert_array_equal(np.asarray(sys1.hint), np.asarray(sys8.hint))

# 2) raw protocol: same key -> bit-identical answers and plaintext columns
client = pir.PIRClient(sys1.cfg, sys1.hint)
for trial, cl in enumerate([0, 3, 7]):
    qu, state = client.query(jax.random.PRNGKey(100 + trial), cl)
    a1 = np.asarray(sys1.server.answer(qu))
    a8 = np.asarray(sys8.server.answer(qu))
    np.testing.assert_array_equal(a1, a8)
    col1 = np.asarray(client.recover(jnp.asarray(a1), state))
    col8 = np.asarray(client.recover(jnp.asarray(a8), state))
    np.testing.assert_array_equal(col1, col8)
    np.testing.assert_array_equal(col1, sys1.db.matrix[:, cl])  # true bytes

# 3) batch mode: same key -> same per-cluster plaintext payloads as legacy
bp1, bp8 = sys1.batch, sys8.batch
key = jax.random.PRNGKey(77)
clusters = [1, 4, 6]
qs1, st1 = bp1.client.query(key, clusters)
qs8, st8 = bp8.client.query(key, clusters)
np.testing.assert_array_equal(np.asarray(qs1), np.asarray(qs8))
ans1 = [np.asarray(a) for a in bp1.server.answer_batch(qs1)]
ans8 = [np.asarray(a) for a in bp8.server.answer_batch(qs8)]
for a, b in zip(ans1, ans8):
    np.testing.assert_array_equal(a, b)
cols1 = bp1.client.recover([jnp.asarray(a) for a in ans1], st1)
cols8 = bp8.client.recover([jnp.asarray(a) for a in ans8], st8)
for cl in clusters:
    used = int(sys1.db.used_bytes[cl])
    np.testing.assert_array_equal(cols1[cl], cols8[cl])
    np.testing.assert_array_equal(cols1[cl][:used],
                                  sys1.db.matrix[:used, cl])  # = legacy bytes

# 4) end-to-end top-k rankings identical across all three modes
for trial in range(4):
    q = corp.embeddings[trial * 41] + 0.01
    key = jax.random.PRNGKey(500 + trial)
    top_legacy = sys1.query(q, top_k=6, multi_probe=3, key=key,
                            mode="legacy")[0]
    top_shard = sys8.query(q, top_k=6, multi_probe=3, key=key,
                           mode="legacy")[0]
    top_batch1 = sys1.query(q, top_k=6, multi_probe=3, key=key,
                            mode="batch")[0]
    top_batch8 = sys8.query(q, top_k=6, multi_probe=3, key=key,
                            mode="batch")[0]
    ids = [[d for d, _, _ in t]
           for t in (top_legacy, top_shard, top_batch1, top_batch8)]
    assert ids[0] == ids[1] == ids[2] == ids[3], ids
    texts = [[t for _, _, t in t_] for t_ in (top_legacy, top_shard,
                                              top_batch1, top_batch8)]
    assert texts[0] == texts[1] == texts[2] == texts[3]
    scores = [np.asarray([s for _, s, _ in t_]) for t_ in
              (top_legacy, top_shard, top_batch1, top_batch8)]
    np.testing.assert_array_equal(scores[0], scores[1])
    np.testing.assert_array_equal(scores[0], scores[2])
    np.testing.assert_array_equal(scores[0], scores[3])
print("OK cross-mode bit-identical")
""")


def test_sharded_answer_and_bucket_paths_have_no_collectives():
    """The compiled HLO of both sharded server GEMMs contains zero
    collective ops — the `pir_rules` zero-collective claim, executed."""
    run_sub("""
from repro.distributed import collectives
mesh = jax.make_mesh((8,), ("chunks",))
fn = collectives.row_shard_gemm(mesh, ("chunks",), impl="xla",
                                q_switch=1 << 16)
db = jax.device_put(jnp.zeros((512, 128), jnp.uint8),
                    NamedSharding(mesh, P(("chunks",), None)))
q = jax.device_put(jnp.zeros((128, 4), jnp.uint32),
                   NamedSharding(mesh, P()))
got = np.asarray(fn(db, q))
assert got.shape == (512, 4) and not got.any()
hlo = fn.lower(db, q).compile().as_text()
for coll in ["all-reduce", "all-gather", "all-to-all",
             "collective-permute", "reduce-scatter"]:
    assert coll not in hlo, coll

fnb = collectives.bucket_shard_gemm(mesh, ("chunks",))
spec = NamedSharding(mesh, P(("chunks",), None, None))
st = jax.device_put(jnp.zeros((16, 256, 32), jnp.uint8), spec)
qb = jax.device_put(jnp.zeros((16, 32, 3), jnp.uint32), spec)
hlo = fnb.lower(st, qb).compile().as_text()
for coll in ["all-reduce", "all-gather", "all-to-all",
             "collective-permute", "reduce-scatter"]:
    assert coll not in hlo, coll
print("OK zero-collective")
""")


def test_serve_loop_sharded_deadline_batching_and_stale_retry():
    """PIRServeLoop on a sharded system: max_batch cutting, stale-epoch
    rejection + retry across a live mutation commit, correct final results
    — all through the 8-device zero-collective answer path."""
    run_sub("""
from repro.data import corpus as corpus_lib
from repro.launch.serve import PIRServeLoop
from repro.update import LiveIndex, journal as journal_lib

corp = corpus_lib.make_corpus(1, 200, emb_dim=16, n_topics=6)
mesh = jax.make_mesh((8,), ("chunks",))
live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=6,
                       impl="xla", kmeans_iters=6, mesh=mesh)
assert live.system.server.mesh is not None
loop = PIRServeLoop(live, max_batch=4, deadline_ms=1e9)

# deadline batching: 4 requests cut as ONE sharded GEMM batch
for rid in range(4):
    loop.submit(rid, corp.embeddings[rid * 11])
assert loop.tick() == 4
assert all(r.batch_size == 4 for r in loop.responses)

# stale-epoch admission: queued requests straddle a mutation commit
for rid in range(10, 13):                  # formed against epoch 0
    loop.submit(rid, corp.embeddings[rid])
loop.submit_mutation(journal_lib.replace(9, b"live-updated nine",
                                         corp.embeddings[9]))
loop.drain()
assert live.epoch == 1
assert loop.stale_retries == 3, loop.stale_retries
assert len(loop.responses) == 7
assert all(r.epoch == 1 and r.retries == 1 for r in loop.responses[-3:])

# fresh query sees the mutated content through the sharded path
loop.submit(50, corp.embeddings[9])
loop.drain()
assert [t for d, _, t in loop.responses[-1].top
        if d == 9] == [b"live-updated nine"]
# exact private retrieval: each earlier response's anchor doc is in top-k
for r in loop.responses[:4]:
    assert r.rid * 11 in [d for d, _, _ in r.top]
print("OK sharded serve loop")
""")


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10),
       n_muts=st.integers(min_value=1, max_value=6))
def test_sharded_mutation_patch_equals_fresh_sharded_setup(seed, n_muts):
    """Property: any mutation batch patched on the SHARDED server leaves
    hint state (flat + per-bucket) bit-identical to a from-scratch sharded
    setup on the mutated database, and queries decode identically."""
    run_sub(f"""
from repro import batchpir
from repro.core import pir
from repro.data import corpus as corpus_lib
from repro.update import LiveIndex

SEED, N_MUTS = {seed}, {n_muts}
corp = corpus_lib.make_corpus(SEED, 160, emb_dim=16, n_topics=5)
mesh = jax.make_mesh((8,), ("chunks",))
live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=5,
                       impl="xla", kmeans_iters=5, mesh=mesh)
live.system.enable_batch(kappa=4, seed=11)

rng = np.random.default_rng(1000 + SEED)
alive = list(range(160))
for i in range(N_MUTS):
    kind = rng.integers(0, 3)
    if kind == 0 and len(alive) > 1:
        did = int(alive.pop(rng.integers(len(alive))))
        live.delete(did)
    elif kind == 1:
        did = int(alive[rng.integers(len(alive))])
        live.replace(did, f"mutated {{did}} rev{{i}}".encode(),
                     corp.embeddings[did] + rng.normal(0, 0.01, 16))
    else:
        live.insert(10_000 + i, f"inserted {{i}}".encode(),
                    corp.embeddings[rng.integers(160)] + 0.02)
live.commit()
sys8 = live.system

# flat hint: patched == from-scratch sharded setup on the mutated matrix
fresh_srv = pir.PIRServer(sys8.cfg, jnp.asarray(sys8.db.matrix), mesh=mesh)
np.testing.assert_array_equal(np.asarray(sys8.hint),
                              np.asarray(fresh_srv.setup()))

# bucket hints: patched == recomputed-from-scratch on the mutated sub-DBs
# (bucket row budgets only ever grow on the delta path, so the comparison
# is against setup() on the server's own state — the exactness invariant
# the single-device suite pins too)
bp = sys8.batch
for h_patched, h_fresh in zip(bp.server.hints, bp.server.setup()):
    np.testing.assert_array_equal(np.asarray(h_patched),
                                  np.asarray(h_fresh))
# and the sharded bucketed answer matches a freshly-built sharded system's
# recovered payloads for the same key (heights may differ; payloads can't)
fresh_bp = batchpir.build(sys8.db.matrix, sys8.db.used_bytes,
                          sys8.cfg.params, kappa=bp.kappa,
                          n_buckets=bp.partition.n_buckets, seed=bp.seed,
                          a_seed=sys8.cfg.a_seed, impl=sys8.cfg.impl,
                          mesh=mesh)
key_b = jax.random.PRNGKey(21)
probe = [0, 3]
qs_p, st_p = bp.client.query(key_b, probe)
qs_f, st_f = fresh_bp.client.query(key_b, probe)
cols_p = bp.client.recover(bp.server.answer_batch(qs_p), st_p)
cols_f = fresh_bp.client.recover(fresh_bp.server.answer_batch(qs_f), st_f)
for cl in probe:
    used = int(sys8.db.used_bytes[cl])
    np.testing.assert_array_equal(cols_p[cl][:used], cols_f[cl][:used])
    np.testing.assert_array_equal(cols_p[cl][:used],
                                  sys8.db.matrix[:used, cl])

# decode equality: same key on patched vs fresh sharded server
client = pir.PIRClient(sys8.cfg, sys8.hint)
qu, state = client.query(jax.random.PRNGKey(7), 2)
col_patched = np.asarray(client.recover(sys8.server.answer(qu), state))
col_fresh = np.asarray(client.recover(fresh_srv.answer(qu), state))
np.testing.assert_array_equal(col_patched, col_fresh)
np.testing.assert_array_equal(col_patched, sys8.db.matrix[:, 2])
print("OK property", SEED, N_MUTS)
""")


def test_row_sharded_update_columns_bitwise_vs_single_device():
    """PIRServer.update_columns on random data: the sharded delta, the
    post-update DB, and subsequent answers all match 1-device bitwise, with
    a row count that does NOT divide the shard count (padding path)."""
    run_sub("""
from repro.core import pir

rng = np.random.default_rng(0)
m, n = 516, 96            # m % 8 != 0 -> exercises row padding
db = rng.integers(0, 256, (m, n), dtype=np.uint8)
cfg = pir.make_config(m, n, impl="xla")
mesh = jax.make_mesh((8,), ("chunks",))
s1 = pir.PIRServer(cfg, jnp.asarray(db))
s8 = pir.PIRServer(cfg, jnp.asarray(db), mesh=mesh)
np.testing.assert_array_equal(np.asarray(s1.setup()),
                              np.asarray(s8.setup()))

cols = np.array([3, 17, 40])
new = rng.integers(0, 256, (m, 3), dtype=np.uint8)
d1 = np.asarray(s1.update_columns(jnp.asarray(cols), jnp.asarray(new)))
d8 = np.asarray(s8.update_columns(jnp.asarray(cols), jnp.asarray(new)))
np.testing.assert_array_equal(d1, d8)
np.testing.assert_array_equal(np.asarray(s1.db), np.asarray(s8.db)[:m])
assert not np.asarray(s8.db)[m:].any()       # padding rows stay zero

q = jnp.asarray(rng.integers(0, 2**32, (n, 5), dtype=np.uint32))
np.testing.assert_array_equal(np.asarray(s1.answer(q)),
                              np.asarray(s8.answer(q)))
print("OK sharded update bitwise")
""")
