"""LWE scheme correctness: bitwise homomorphic exactness + noise margins."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import lwe


def test_u32_matmul_is_exact_mod_2_32():
    """Foundation check: XLA u32 dot wraps exactly mod 2^32."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**32, (32, 48), dtype=np.uint32)
    b = rng.integers(0, 2**32, (48, 8), dtype=np.uint32)
    got = np.asarray(jnp.matmul(jnp.asarray(a), jnp.asarray(b)))
    ref = ((a.astype(np.uint64) @ b.astype(np.uint64)) & 0xFFFFFFFF)
    assert np.array_equal(got, ref.astype(np.uint32))


@pytest.mark.parametrize("n,p", [(64, 256), (1024, 256), (4096, 256)])
def test_decrypt_matvec_roundtrip(n, p):
    """Dec(D · Enc(onehot_i)) == D[:, i] exactly, for random u8 DBs."""
    params = lwe.LWEParams(p=p, q_switch=None)
    assert lwe.noise_budget_ok(params, n)
    m = 96
    key = jax.random.PRNGKey(1)
    k_db, k_s, k_e = jax.random.split(key, 3)
    db = jax.random.randint(k_db, (m, n), 0, p, dtype=jnp.int32).astype(jnp.uint8)
    a_mat = lwe.gen_public_matrix(3, n, params.k)
    s = lwe.keygen(k_s, params)
    idx = n // 3
    onehot = jnp.zeros((n,), jnp.uint32).at[idx].set(1)
    ct = lwe.encrypt_vector(k_e, s, a_mat, onehot, params.delta, params.sigma)

    ans = jnp.matmul(db.astype(jnp.uint32), ct)
    hint = jnp.matmul(db.astype(jnp.uint32), a_mat)
    rec = lwe.hint_strip(ans, hint, s)
    got = lwe.decode(rec, params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(db[:, idx], np.uint32))


def test_modulus_switched_roundtrip():
    params = lwe.LWEParams(p=256, q_switch=1 << 16)
    n, m = 2048, 128
    assert lwe.noise_budget_ok(params, n)
    key = jax.random.PRNGKey(2)
    k_db, k_s, k_e = jax.random.split(key, 3)
    db = jax.random.randint(k_db, (m, n), 0, 256, dtype=jnp.int32).astype(jnp.uint8)
    a_mat = lwe.gen_public_matrix(5, n, params.k)
    s = lwe.keygen(k_s, params)
    idx = 17
    onehot = jnp.zeros((n,), jnp.uint32).at[idx].set(1)
    ct = lwe.encrypt_vector(k_e, s, a_mat, onehot, params.delta, params.sigma)
    ans = jnp.matmul(db.astype(jnp.uint32), ct)
    hint = jnp.matmul(db.astype(jnp.uint32), a_mat)

    ans_sw = lwe.switch_modulus(ans, params.q_switch)
    assert ans_sw.dtype == jnp.uint16  # downlink halved
    got = lwe.decode_switched(ans_sw, hint, s, params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(db[:, idx], np.uint32))


@settings(max_examples=20, deadline=None)
@given(idx=st.integers(0, 255), seed=st.integers(0, 2**31 - 1))
def test_property_any_index_any_key(idx, seed):
    """Hypothesis: recovery is exact for arbitrary index / key / DB."""
    params = lwe.LWEParams(p=256, q_switch=None)
    n, m = 256, 32
    key = jax.random.PRNGKey(seed)
    k_db, k_s, k_e = jax.random.split(key, 3)
    db = jax.random.randint(k_db, (m, n), 0, 256, dtype=jnp.int32).astype(jnp.uint8)
    a_mat = lwe.gen_public_matrix(11, n, params.k)
    s = lwe.keygen(k_s, params)
    onehot = jnp.zeros((n,), jnp.uint32).at[idx].set(1)
    ct = lwe.encrypt_vector(k_e, s, a_mat, onehot, params.delta, params.sigma)
    ans = jnp.matmul(db.astype(jnp.uint32), ct)
    hint = jnp.matmul(db.astype(jnp.uint32), a_mat)
    got = lwe.decode(lwe.hint_strip(ans, hint, s), params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(db[:, idx], np.uint32))


def test_choose_params_shrinks_p_when_needed():
    small = lwe.choose_params(256)
    assert small.p == 256
    # gigantic inner dim forces a smaller plaintext modulus
    big = lwe.choose_params(1 << 34, q_switch=None)
    assert big.p < 256
    assert lwe.noise_budget_ok(big, 1 << 34)


def test_noise_budget_monotone():
    p = lwe.LWEParams()
    assert lwe.noise_bound(p, 1024) < lwe.noise_bound(p, 4096)


def test_query_is_pseudorandom_marginal():
    """Sanity (not a proof): ciphertext words should look ~uniform mod 2^32."""
    params = lwe.LWEParams()
    n = 4096
    a_mat = lwe.gen_public_matrix(9, n, params.k)
    s = lwe.keygen(jax.random.PRNGKey(3), params)
    onehot = jnp.zeros((n,), jnp.uint32).at[0].set(1)
    ct = lwe.encrypt_vector(jax.random.PRNGKey(4), s, a_mat, onehot,
                            params.delta, params.sigma)
    x = np.asarray(ct).astype(np.float64) / 2**32
    assert abs(x.mean() - 0.5) < 0.05
    assert abs(x.var() - 1 / 12) < 0.01
