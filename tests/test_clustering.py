"""K-means: recovery of planted clusters, balanced assignment invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import clustering


def _blob_data(seed=0, k=8, per=64, d=16, spread=0.05):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)) * 3.0
    labels = np.repeat(np.arange(k), per)
    x = centers[labels] + spread * rng.standard_normal((k * per, d))
    return x.astype(np.float32), labels, centers


def test_kmeans_recovers_planted_clusters():
    x, labels, _ = _blob_data()
    res = clustering.kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(x), k=8,
                                iters=30)
    assign = np.asarray(res.assignment)
    # planted clusters are well separated: every planted group must map to
    # a single k-means cluster (purity 1.0 up to label permutation)
    for g in range(8):
        vals = assign[labels == g]
        assert (vals == vals[0]).all()
    assert float(res.inertia) < 0.1


def test_inertia_decreases_with_iters():
    x, _, _ = _blob_data(spread=0.5)
    r1 = clustering.kmeans_fit(jax.random.PRNGKey(1), jnp.asarray(x), k=8,
                               iters=1)
    r20 = clustering.kmeans_fit(jax.random.PRNGKey(1), jnp.asarray(x), k=8,
                                iters=20)
    assert float(r20.inertia) <= float(r1.inertia) + 1e-6


def test_assign_to_centroids_matches_brute_force():
    x, _, _ = _blob_data(seed=3)
    cents = jnp.asarray(x[:5])
    got = np.asarray(clustering.assign_to_centroids(jnp.asarray(x), cents))
    d2 = ((x[:, None, :] - x[:5][None, :, :]) ** 2).sum(-1)
    np.testing.assert_array_equal(got, d2.argmin(1))


def test_balanced_assign_respects_cap_and_quality():
    x, labels, _ = _blob_data(k=4, per=100)
    res = clustering.kmeans_fit(jax.random.PRNGKey(2), jnp.asarray(x), k=4,
                                iters=20)
    cents = np.asarray(res.centroids)
    cap = 110
    out = clustering.balanced_assign(x, cents, cap)
    counts = np.bincount(out, minlength=4)
    assert counts.max() <= cap
    assert counts.sum() == len(x)
    # balanced assignment should still be mostly the nearest centroid here
    near = np.asarray(clustering.assign_to_centroids(jnp.asarray(x),
                                                     jnp.asarray(cents)))
    assert (out == near).mean() > 0.9


def test_balanced_assign_infeasible_cap_raises():
    x, _, _ = _blob_data(k=2, per=10)
    with pytest.raises(ValueError):
        clustering.balanced_assign(x, x[:2], cap=5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 12),
       slack=st.integers(0, 40))
def test_balanced_assign_cap_property(seed, k, slack):
    """Cap is respected and every doc lands somewhere, for any feasible cap."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(k, 160))
    x = rng.standard_normal((n, 8)).astype(np.float32)
    cents = rng.standard_normal((k, 8)).astype(np.float32)
    cap = -(-n // k) + slack                     # ceil(n/k) is always feasible
    out = clustering.balanced_assign(x, cents, cap)
    counts = np.bincount(out, minlength=k)
    assert counts.max() <= cap
    assert counts.sum() == n
    assert out.min() >= 0 and out.max() < k


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_balanced_assign_permutation_stable(seed):
    """The assignment is a function of the doc SET, not of input order.

    Shuffling the rows and un-shuffling the output must reproduce the
    original assignment: the greedy walk orders docs by their distances
    (continuous random data → no ties), never by input position.
    """
    rng = np.random.default_rng(seed)
    n, k = int(rng.integers(30, 120)), 6
    x = rng.standard_normal((n, 8)).astype(np.float32)
    cents = rng.standard_normal((k, 8)).astype(np.float32)
    cap = -(-n // k) + 2
    base = clustering.balanced_assign(x, cents, cap)
    perm = rng.permutation(n)
    shuffled = clustering.balanced_assign(x[perm], cents, cap)
    assert np.array_equal(shuffled, base[perm])


def test_balanced_build_bounds_downlink_bytes():
    """`max_cluster_bytes` — the PIR downlink driver — never exceeds the
    capped bound: a full cluster of cap docs at the longest text length."""
    from repro.core import chunking, pipeline
    from repro.data import corpus as corpus_lib
    corp = corpus_lib.make_corpus(7, 240, emb_dim=16, n_topics=6)
    bf, k = 1.25, 8
    sys_b = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                        n_clusters=k, impl="xla", seed=0,
                                        balance_factor=bf)
    cap = int(np.ceil(len(corp.texts) / k * bf))
    assert np.bincount(sys_b.assignment, minlength=k).max() <= cap
    bound = 4 + cap * chunking.record_bytes(
        corp.embeddings.shape[1], max(len(t) for t in corp.texts))
    assert int(sys_b.db.used_bytes.max()) <= bound
    # m (the per-query downlink row count) is the capped bound rounded up
    # to the chunk granule, so downlink_bytes is bounded too
    chunk = sys_b.db.chunk_size
    assert sys_b.db.m <= -(-bound // chunk) * chunk
    assert sys_b.cfg.downlink_bytes <= 2 * (-(-bound // chunk) * chunk)


def test_balanced_assign_d2_override_matches_internal():
    """The build-path d2= override reproduces the internal distance pass."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((80, 8)).astype(np.float32)
    cents = rng.standard_normal((5, 8)).astype(np.float32)
    d2 = ((x * x).sum(1, keepdims=True) - 2 * x @ cents.T
          + (cents * cents).sum(1)[None, :])
    a = clustering.balanced_assign(x, cents, cap=20)
    b = clustering.balanced_assign(x, cents, cap=20, d2=d2)
    # same distances in -> the greedy walk is deterministic -> same out
    assert np.array_equal(a, b)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 16),
       exact=st.sampled_from([True, False]))
def test_balanced_assign_matches_reference_walk(seed, k, exact):
    """The vectorized deferred-acceptance path ≡ the serial greedy walk.

    `balanced_assign` replaced the O(N·k) host loop with a masked-argmin
    deferred-acceptance round structure; both orderings are serial
    dictatorship under the same priority, so outputs must be EQUAL — not
    merely equally balanced — on any input, including exact caps
    (cap·k == n, every cluster filled to the brim) where rejection
    cascades are longest.
    """
    rng = np.random.default_rng(seed)
    n = int(k * rng.integers(4, 40))
    x = rng.standard_normal((n, 8)).astype(np.float32)
    cents = rng.standard_normal((k, 8)).astype(np.float32)
    cap = -(-n // k) + (0 if exact else int(rng.integers(1, 20)))
    fast = clustering.balanced_assign(x, cents, cap)
    ref = clustering._balanced_assign_walk(x, cents, cap)
    assert np.array_equal(fast, ref)


def test_empty_cluster_keeps_centroid():
    """k > n_distinct points: Lloyd must not NaN on empty clusters."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((10, 4)),
                    jnp.float32)
    res = clustering.kmeans_fit(jax.random.PRNGKey(0), x, k=16, iters=5)
    assert np.isfinite(np.asarray(res.centroids)).all()
