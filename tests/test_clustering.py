"""K-means: recovery of planted clusters, balanced assignment invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering


def _blob_data(seed=0, k=8, per=64, d=16, spread=0.05):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)) * 3.0
    labels = np.repeat(np.arange(k), per)
    x = centers[labels] + spread * rng.standard_normal((k * per, d))
    return x.astype(np.float32), labels, centers


def test_kmeans_recovers_planted_clusters():
    x, labels, _ = _blob_data()
    res = clustering.kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(x), k=8,
                                iters=30)
    assign = np.asarray(res.assignment)
    # planted clusters are well separated: every planted group must map to
    # a single k-means cluster (purity 1.0 up to label permutation)
    for g in range(8):
        vals = assign[labels == g]
        assert (vals == vals[0]).all()
    assert float(res.inertia) < 0.1


def test_inertia_decreases_with_iters():
    x, _, _ = _blob_data(spread=0.5)
    r1 = clustering.kmeans_fit(jax.random.PRNGKey(1), jnp.asarray(x), k=8,
                               iters=1)
    r20 = clustering.kmeans_fit(jax.random.PRNGKey(1), jnp.asarray(x), k=8,
                                iters=20)
    assert float(r20.inertia) <= float(r1.inertia) + 1e-6


def test_assign_to_centroids_matches_brute_force():
    x, _, _ = _blob_data(seed=3)
    cents = jnp.asarray(x[:5])
    got = np.asarray(clustering.assign_to_centroids(jnp.asarray(x), cents))
    d2 = ((x[:, None, :] - x[:5][None, :, :]) ** 2).sum(-1)
    np.testing.assert_array_equal(got, d2.argmin(1))


def test_balanced_assign_respects_cap_and_quality():
    x, labels, _ = _blob_data(k=4, per=100)
    res = clustering.kmeans_fit(jax.random.PRNGKey(2), jnp.asarray(x), k=4,
                                iters=20)
    cents = np.asarray(res.centroids)
    cap = 110
    out = clustering.balanced_assign(x, cents, cap)
    counts = np.bincount(out, minlength=4)
    assert counts.max() <= cap
    assert counts.sum() == len(x)
    # balanced assignment should still be mostly the nearest centroid here
    near = np.asarray(clustering.assign_to_centroids(jnp.asarray(x),
                                                     jnp.asarray(cents)))
    assert (out == near).mean() > 0.9


def test_balanced_assign_infeasible_cap_raises():
    x, _, _ = _blob_data(k=2, per=10)
    with pytest.raises(ValueError):
        clustering.balanced_assign(x, x[:2], cap=5)


def test_empty_cluster_keeps_centroid():
    """k > n_distinct points: Lloyd must not NaN on empty clusters."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((10, 4)),
                    jnp.float32)
    res = clustering.kmeans_fit(jax.random.PRNGKey(0), x, k=16, iters=5)
    assert np.isfinite(np.asarray(res.centroids)).all()
