"""Pipelined serving engine ≡ synchronous loop, bit-for-bit.

The contract under test (ISSUE 4): `PipelinedServeLoop` may move work in
time — async answer dispatch, deferred decode, shadow-epoch commits,
donated in-place patches — but every response (payload, epoch, retry
count, batch size) and the loop-level counters must be IDENTICAL to
`PIRServeLoop` over the same submit/mutation/tick/drain schedule.

Fast tests run a scripted interleaving in tier-1; the hypothesis property
(random interleavings) and the sharded-mesh variant are slow-marked and run
in CI's multi-device step.
"""
import copy

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _mesh_harness import run_sub

from repro.data import corpus as corpus_lib
from repro.serve import PIRServeLoop, PipelinedServeLoop
from repro.update import LiveIndex, journal as journal_lib

N_DOCS = 200


class FakeClock:
    """Deterministic monotone clock: batch cuts don't depend on wall time."""

    def __init__(self, step: float = 1e-4):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


_BASE: dict = {}


def _get_base():
    """Build the reference corpus + live index once per process.

    Not a fixture: the hypothesis property below must stay usable under the
    `_hypothesis_compat` shim, whose `given` wrapper presents a zero-arg
    signature (no fixture injection).  Each engine run gets a deepcopy, so
    the cached base is never mutated.
    """
    if not _BASE:
        corp = corpus_lib.make_corpus(1, N_DOCS, emb_dim=16, n_topics=6)
        live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=6,
                               impl="xla", kmeans_iters=6)
        live.system.enable_batch(kappa=4)
        _BASE["corp"], _BASE["live"] = corp, live
    return _BASE["corp"], _BASE["live"]


@pytest.fixture(scope="module")
def base_live():
    return _get_base()


def _signature(loop):
    return [(r.rid, r.epoch, r.retries, r.batch_size,
             tuple((d, t) for d, _, t in r.top)) for r in loop.responses]


def _drive_scripted(loop, corp, ops):
    """ops: list of ("submit", rid, emb_idx, top_k, multi_probe) |
    ("mutate", kind, doc_id, tag) | ("tick",) | ("drain",)."""
    for op in ops:
        if op[0] == "submit":
            _, rid, idx, top_k, mp = op
            loop.submit(rid, corp.embeddings[idx], top_k=top_k,
                        multi_probe=mp)
        elif op[0] == "mutate":
            _, kind, doc, tag = op
            if kind == "delete":
                loop.submit_mutation(journal_lib.delete(doc))
            else:
                mut = getattr(journal_lib, kind)
                loop.submit_mutation(mut(doc, f"{kind} {doc} {tag}".encode(),
                                         corp.embeddings[doc % N_DOCS]))
        elif op[0] == "tick":
            loop.tick()
        elif op[0] == "drain":
            loop.drain()
    loop.drain()


def _script_from_rng(rng, n_ops: int):
    """Random interleaving over live doc ids (insert/delete kept consistent)."""
    ops = []
    alive = set(range(N_DOCS))
    next_id = N_DOCS
    rid = 0
    for _ in range(n_ops):
        roll = rng.integers(0, 10)
        if roll < 6:
            ops.append(("submit", rid, int(rng.integers(0, N_DOCS)),
                        int(rng.integers(1, 6)),
                        int(rng.choice([1, 1, 2, 3]))))
            rid += 1
        elif roll < 8 and alive:
            kind = ["replace", "insert", "delete"][int(rng.integers(0, 3))]
            if kind == "insert":
                ops.append(("mutate", "insert", next_id, rid))
                alive.add(next_id)
                next_id += 1
            elif kind == "delete" and len(alive) > N_DOCS // 2:
                doc = int(sorted(alive)[int(rng.integers(0, len(alive)))])
                ops.append(("mutate", "delete", doc, rid))
                alive.discard(doc)
            else:
                doc = int(sorted(alive)[int(rng.integers(0, len(alive)))])
                ops.append(("mutate", "replace", doc, rid))
        elif roll == 8:
            ops.append(("tick",))
        else:
            ops.append(("drain",))
    return ops


def _compare_engines(corp, live_factory, ops, *, depth, donate=True,
                     max_batch=4):
    sync = PIRServeLoop(live_factory(), max_batch=max_batch,
                        deadline_ms=1e9, clock=FakeClock(), seed=0)
    _drive_scripted(sync, corp, ops)
    pipe = PipelinedServeLoop(live_factory(), max_batch=max_batch,
                              deadline_ms=1e9, clock=FakeClock(), seed=0,
                              depth=depth, donate=donate)
    _drive_scripted(pipe, corp, ops)
    assert _signature(sync) == _signature(pipe)
    assert sync.stale_retries == pipe.stale_retries
    assert sync.epoch == pipe.epoch
    assert pipe.inflight == 0
    return sync, pipe


def test_pipelined_matches_sync_scripted(base_live):
    """Deterministic interleaving: mutations, multi-probe, partial drains."""
    corp, base = base_live
    rng = np.random.default_rng(11)
    ops = _script_from_rng(rng, 60)
    for depth in (1, 3):
        _compare_engines(corp, lambda: copy.deepcopy(base), ops, depth=depth)


def test_pipelined_static_system(base_live):
    """No LiveIndex: pure pipelining over a static corpus still matches."""
    corp, base = base_live
    ops = [("submit", rid, rid % N_DOCS, 4, 1) for rid in range(9)]
    sys_factory = lambda: copy.deepcopy(base.system)  # noqa: E731
    sync = PIRServeLoop(sys_factory(), max_batch=4, deadline_ms=1e9,
                        clock=FakeClock(), seed=0)
    _drive_scripted(sync, corp, ops)
    pipe = PipelinedServeLoop(sys_factory(), max_batch=4, deadline_ms=1e9,
                              clock=FakeClock(), seed=0, depth=2)
    _drive_scripted(pipe, corp, ops)
    assert _signature(sync) == _signature(pipe)


def test_idle_ticks_retire_inflight_batches(base_live):
    """Regression: during a traffic lull, tick() must flush the pipeline —
    finished batches may not sit decoded-but-unreported behind `depth`."""
    corp, base = base_live
    loop = PipelinedServeLoop(copy.deepcopy(base), max_batch=4,
                              deadline_ms=1e9, clock=FakeClock(), seed=0,
                              depth=4)
    for rid in range(4):
        loop.submit(rid, corp.embeddings[rid])
    loop.tick()                         # dispatches one batch, depth not hit
    assert loop.inflight == 1 and not loop.responses
    assert loop.tick() == 0             # idle tick: nothing to dispatch...
    assert loop.inflight == 0 and len(loop.responses) == 4   # ...but retires


def test_batch_timing_parity_sync_vs_pipelined(base_live):
    """BatchTiming audit (ISSUE 7): both engines must stamp every response
    with a well-formed timing derived from their obs spans — same batch
    partition, monotone boundaries, positive components — even though the
    pipelined engine legitimately reports residual (near-zero overlapped)
    gemm time where the sync engine reports the full device wait."""
    corp, base = base_live
    ops = _script_from_rng(np.random.default_rng(17), 50)
    sync, pipe = _compare_engines(corp, lambda: copy.deepcopy(base), ops,
                                  depth=2)
    for loop in (sync, pipe):
        for r in loop.responses:
            t = r.timing
            assert t is not None, f"missing timing on rid {r.rid}"
            # FakeClock advances per read, so every span has positive width
            assert t.encode_s > 0 and t.gemm_s > 0 and t.decode_s > 0
            assert t.t_plan < r.t_done

    def partition(loop):
        """rids grouped by shared BatchTiming object (the batch identity)."""
        groups: dict = {}
        for r in loop.responses:
            groups.setdefault(id(r.timing), []).append(r.rid)
        return sorted(map(tuple, groups.values()))

    # the engines batch identically, so requests must SHARE timing structs
    # identically — a parity regression here means one engine fragmented
    # (or merged) a batch's timing without changing its responses
    assert partition(sync) == partition(pipe)


def test_donated_commits_stay_exact(base_live):
    """After donated shadow commits, server-side state is bit-identical to a
    from-scratch setup of the mutated corpus (the live-index invariant)."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    corp, base = base_live
    live = copy.deepcopy(base)
    loop = PipelinedServeLoop(live, max_batch=4, deadline_ms=1e9,
                              clock=FakeClock(), seed=0, depth=2,
                              donate=True)
    rng = np.random.default_rng(5)
    for rid in range(24):
        loop.submit(rid, corp.embeddings[rid % N_DOCS])
        if rid % 6 == 0:
            d = int(rng.integers(0, N_DOCS))
            loop.submit_mutation(journal_lib.replace(
                d, f"donated {d}@{rid}".encode(), corp.embeddings[d]))
        loop.tick()
    loop.drain()
    assert live.epoch >= 3
    sys = live.system
    # donated column scatters patched the device DB exactly (host mirror is
    # repacked independently), and the patched hint equals a fresh H = D·A
    np.testing.assert_array_equal(np.asarray(sys.server.db), sys.db.matrix)
    fresh = kops.hint_gemm(jnp.asarray(sys.db.matrix),
                           sys.server.a_matrix, impl="xla")
    assert jnp.array_equal(fresh, sys.hint)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**9))
def test_property_random_interleavings(seed):
    """Random submit/mutation/tick/drain interleavings: responses, epochs
    and retry counts identical at a random pipeline depth."""
    corp, base = _get_base()
    rng = np.random.default_rng(seed)
    ops = _script_from_rng(rng, int(rng.integers(20, 70)))
    depth = int(rng.integers(1, 5))
    _compare_engines(corp, lambda: copy.deepcopy(base), ops, depth=depth,
                     max_batch=int(rng.integers(2, 6)))


_MESH_BODY = """
from repro.data import corpus as corpus_lib
from repro.serve import PIRServeLoop, PipelinedServeLoop
from repro.update import LiveIndex, journal as journal_lib

class FakeClock:
    def __init__(self): self.t = 0.0
    def __call__(self): self.t += 1e-4; return self.t

mesh = jax.make_mesh((8,), ("chunks",))
corp = corpus_lib.make_corpus(1, 160, emb_dim=16, n_topics=6)

def build(m):
    return LiveIndex.build(corp.texts, corp.embeddings, n_clusters=6,
                           impl="xla", kmeans_iters=5, mesh=m)

def drive(loop):
    rng = np.random.default_rng(13)
    for rid in range(28):
        loop.submit(rid, corp.embeddings[rid % 160], top_k=4)
        if rid % 6 == 2:
            d = int(rng.integers(0, 160))
            loop.submit_mutation(journal_lib.replace(
                d, f"mesh {d}@{rid}".encode(), corp.embeddings[d]))
        loop.tick()
    loop.drain()
    return ([(r.rid, r.epoch, r.retries, r.batch_size,
              tuple((d, t) for d, _, t in r.top)) for r in loop.responses],
            loop.stale_retries, loop.epoch)

ref = drive(PIRServeLoop(build(None), max_batch=4, deadline_ms=1e9,
                         clock=FakeClock(), seed=0))
for donate in (False, True):
    got = drive(PipelinedServeLoop(build(mesh), max_batch=4, deadline_ms=1e9,
                                   clock=FakeClock(), seed=0, depth=2,
                                   donate=donate))
    assert got == ref, f"donate={donate} diverged from single-device sync"
print("MESH-OK")
"""


def test_keyed_lookup_mixed_batch_both_engines():
    """Keyed lookups flow through both engines alongside similarity queries
    in ONE tick's batch, stay bit-exact against the live table through a
    replace epoch, and the engines agree response-for-response."""
    rng = np.random.default_rng(6)
    table = rng.standard_normal((144, 8)).astype(np.float32)
    new_row = rng.standard_normal(8).astype(np.float32)
    asks = [rng.integers(0, 144, size=k).tolist() for k in (3, 9)]

    def drive(loop):
        layout = loop._serving_system().keyed
        for rid, ids in enumerate(asks):
            loop.submit_lookup(rid, ids)
        loop.submit(10, table[7], top_k=3)            # mixed-kind tick
        served = loop.tick(force=True)
        loop.drain()
        assert served >= 1
        loop.submit_mutation(journal_lib.replace(
            asks[0][0], layout.row_text(new_row), new_row))
        loop.submit_lookup(11, asks[0])               # re-fetch after commit
        loop.drain()
        return loop.responses

    sync = PIRServeLoop(
        LiveIndex.build_keyed(table, kappa=9, impl="xla", seed=0),
        max_batch=8, deadline_ms=1e9, clock=FakeClock(), seed=0)
    pipe = PipelinedServeLoop(
        LiveIndex.build_keyed(table, kappa=9, impl="xla", seed=0),
        max_batch=8, deadline_ms=1e9, clock=FakeClock(), seed=0, depth=2)
    rs, rp = drive(sync), drive(pipe)

    patched = table.copy()
    patched[asks[0][0]] = new_row
    for resp in (rs, rp):
        by_rid = {r.rid: r for r in resp}
        assert set(by_rid) == {0, 1, 10, 11}
        for rid, ids in enumerate(asks):
            np.testing.assert_array_equal(by_rid[rid].top, table[ids])
        assert by_rid[10].top and by_rid[10].epoch == 0
        assert by_rid[11].epoch == 1                  # post-commit epoch
        np.testing.assert_array_equal(by_rid[11].top, patched[asks[0]])
    # engines agree on everything, row payloads included
    assert [(r.rid, r.epoch, r.batch_size) for r in rs] == \
           [(r.rid, r.epoch, r.batch_size) for r in rp]
    for a, b in zip(rs, rp):
        if a.rid == 10:
            assert a.top == b.top
        else:
            np.testing.assert_array_equal(a.top, b.top)


@pytest.mark.slow
def test_pipelined_sharded_matches_single_device_sync():
    """8-fake-device mesh: pipelined sharded serving (shadow commits via the
    row-shard scatter, donated and not) ≡ the single-device sync loop."""
    out = run_sub(_MESH_BODY)
    assert "MESH-OK" in out
