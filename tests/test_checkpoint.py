"""Checkpointing + fault tolerance: atomicity, integrity, restart, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.launch.train import FaultTolerantTrainer, SimulatedFailure


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros(4, jnp.bfloat16)},
            "opt": [jnp.ones(3), (jnp.arange(5),)],
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_bitwise(tmp_path):
    s = _state()
    store.save(str(tmp_path), s, step=7)
    r = store.restore(str(tmp_path))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_roundtrip(tmp_path):
    x = {"w": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)}
    store.save(str(tmp_path), x, step=0)
    r = store.restore(str(tmp_path))
    assert r["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(r["w"], np.float32),
                                  np.asarray(x["w"], np.float32))


def test_corruption_detected(tmp_path):
    store.save(str(tmp_path), _state(), step=1)
    d = os.path.join(tmp_path, "step_00000001")
    victim = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(120)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="checksum"):
        store.restore(str(tmp_path))


def test_gc_keeps_last_k(tmp_path):
    for s in range(6):
        store.save(str(tmp_path), {"x": jnp.asarray(s)}, step=s, keep=3)
    assert store.steps(str(tmp_path)) == [3, 4, 5]


def test_tmp_dir_never_visible_as_checkpoint(tmp_path):
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert store.steps(str(tmp_path)) == []


def test_async_saver(tmp_path):
    saver = store.AsyncSaver()
    saver.save(str(tmp_path), _state(), step=2)
    saver.wait()
    assert store.latest_step(str(tmp_path)) == 2


# ---------------------------------------------------------------------------
# Trainer: failure injection → restart → bitwise continuation
# ---------------------------------------------------------------------------

def _toy_bundle():
    def init_state(key):
        return {"w": jnp.zeros((4,), jnp.float32),
                "n": jnp.zeros((), jnp.int32)}

    def step_fn(state, batch):
        w = state["w"] + batch["x"]
        return {"w": w, "n": state["n"] + 1}, {"loss": jnp.sum(w)}

    return step_fn, init_state


def _batch_at(step):
    return {"x": jnp.full((4,), float(step + 1), jnp.float32)}


def test_restart_bitwise_continuation(tmp_path):
    step_fn, init_state = _toy_bundle()
    ckpt = str(tmp_path / "ck")

    # uninterrupted reference
    t_ref = FaultTolerantTrainer(step_fn, init_state,
                                 ckpt_dir=str(tmp_path / "ref"),
                                 ckpt_every=4, log=lambda *_: None)
    ref_state, _ = t_ref.run(_batch_at, 10)

    # crash at step 6 (after ckpt at step 3+7? every=4 → saves at steps 3, 7)
    t1 = FaultTolerantTrainer(step_fn, init_state, ckpt_dir=ckpt,
                              ckpt_every=4, log=lambda *_: None)
    with pytest.raises(SimulatedFailure):
        t1.run(_batch_at, 10, fail_at=6)

    # restart: must resume from step 4 (ckpt at step index 3) and finish
    t2 = FaultTolerantTrainer(step_fn, init_state, ckpt_dir=ckpt,
                              ckpt_every=4, log=lambda *_: None)
    state, _ = t2.run(_batch_at, 10)
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.asarray(ref_state["w"]))
    assert int(state["n"]) == int(ref_state["n"]) == 10


def test_watchdog_flags_straggler(tmp_path):
    logs = []
    step_fn, init_state = _toy_bundle()
    t = FaultTolerantTrainer(step_fn, init_state, ckpt_dir=str(tmp_path),
                             ckpt_every=100, watchdog_factor=3.0,
                             log=logs.append)
    t.step_times = [0.01] * 10
    t._watchdog(11, 0.5)
    assert any("straggler" in line for line in logs)
