"""All assigned archs: smoke steps run on CPU; full param counts pinned.

Full configs are only ever touched through eval_shape (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps as steps_lib
from repro.models import nn

ALL = sorted(cfgbase.all_archs())


def _count_params_spec(spec) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(spec))


# one representative shape per family for the smoke step
_SMOKE_SHAPE = {"lm": "train_4k", "gnn": None, "recsys": "train_batch",
                "pir": "online_b64"}


@pytest.mark.parametrize("name", ALL)
def test_smoke_step_runs(name):
    arch = cfgbase.get(name)
    shapes = ([_SMOKE_SHAPE[arch.family]] if _SMOKE_SHAPE[arch.family]
              else list(arch.shapes))
    for shape_name in shapes:
        bundle = steps_lib.make_bundle(arch, shape_name, smoke=True)
        batch = steps_lib.materialize_inputs(arch, shape_name,
                                             jax.random.PRNGKey(0))
        if bundle.init_state is not None:
            state = bundle.init_state(jax.random.PRNGKey(1))
        else:  # pir: db is the state
            state = jnp.zeros(bundle.state_spec.shape, jnp.uint8
                              ).at[0, 0].set(3)
        out = jax.jit(bundle.fn)(state, batch)
        flat = jax.tree.leaves(out)
        assert flat, (name, shape_name)
        for x in flat:
            arr = np.asarray(x, np.float32) if x.dtype != jnp.uint16 \
                else np.asarray(x, np.int64)
            assert np.isfinite(arr).all(), (name, shape_name)


@pytest.mark.parametrize("name,shape", [
    (n, s) for n in ALL for s in cfgbase.get(n).shapes
    if cfgbase.get(n).family == "lm"])
def test_lm_all_shapes_smoke(name, shape):
    """Every LM shape kind (train/prefill/decode/long-decode) lowers + runs
    on the reduced config."""
    arch = cfgbase.get(name)
    bundle = steps_lib.make_bundle(arch, shape, smoke=True)
    batch = steps_lib.materialize_inputs(arch, shape, jax.random.PRNGKey(0))
    state = bundle.init_state(jax.random.PRNGKey(1))
    out = jax.jit(bundle.fn)(state, batch)
    leaves = jax.tree.leaves(out)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


EXPECTED_PARAMS = {
    # analytic totals from the assigned dims; phi4/qwen3 tie embeddings
    # (their nominal sizes), qwen2-7b is 7.62B real (untied head)
    "llama4-maverick-400b-a17b": 400e9,
    "kimi-k2-1t-a32b": 1.04e12,
    "phi4-mini-3.8b": 3.84e9,
    "qwen3-4b": 4.02e9,
    "qwen2-7b": 7.62e9,
}


@pytest.mark.parametrize("name", sorted(EXPECTED_PARAMS))
def test_full_param_counts(name):
    from repro.models import transformer as tf
    arch = cfgbase.get(name)
    spec = tf.param_spec(arch.model("train_4k"))
    n = _count_params_spec(spec)
    want = EXPECTED_PARAMS[name]
    assert abs(n - want) / want < 0.08, (name, n, want)


def test_moe_active_params():
    """a17b / a32b designations: active params from the flops helper."""
    arch = cfgbase.get("kimi-k2-1t-a32b")
    cfg = arch.model("train_4k")
    f = cfgbase.lm_flops_per_step(cfg, arch.shapes["train_4k"])
    # 6·N_active·tokens dominates: back out N_active
    tokens = 256 * 4096
    n_active = f / (6 * tokens)
    assert 28e9 < n_active < 40e9, n_active

    arch = cfgbase.get("llama4-maverick-400b-a17b")
    cfg = arch.model("train_4k")
    f = cfgbase.lm_flops_per_step(cfg, arch.shapes["train_4k"])
    n_active = f / (6 * tokens)
    assert 13e9 < n_active < 21e9, n_active


def test_recsys_full_table_sizes():
    from repro.models import recsys as rec
    cfg = cfgbase.get("dlrm-rm2").model("train_batch")
    spec = jax.eval_shape(lambda k: rec.init(k, cfg), jax.random.PRNGKey(0))
    assert spec["emb"]["table"].shape == (26_000_000, 64)
    cfg = cfgbase.get("xdeepfm").model("train_batch")
    spec = jax.eval_shape(lambda k: rec.init(k, cfg), jax.random.PRNGKey(0))
    assert spec["emb"]["table"].shape == (39_000_000, 10)


def test_registry_complete():
    assert len(ALL) == 11      # 10 assigned + pir_serve
    cells = sum(len(cfgbase.get(n).shapes) for n in ALL
                if cfgbase.get(n).family != "pir")
    assert cells == 40         # the assigned 40 cells


def test_state_axes_match_state_spec():
    """Axes trees must mirror state specs exactly for every full bundle."""
    for name in ALL:
        arch = cfgbase.get(name)
        for shape_name in arch.shapes:
            bundle = steps_lib.make_bundle(arch, shape_name, smoke=False)
            flat_s = jax.tree.leaves(bundle.state_spec)
            flat_a = jax.tree.leaves(bundle.state_axes,
                                     is_leaf=lambda v: isinstance(v, tuple))
            assert len(flat_s) == len(flat_a), (name, shape_name)
            for s, a in zip(flat_s, flat_a):
                assert s.ndim == len(a), (name, shape_name, s.shape, a)
