"""Keyed batch-PIR property hardening: private embedding-row lookups.

The recsys serving contract (`PirRagSystem.build_keyed` / `lookup`):
recovered rows are bit-identical to ``table[ids]`` for ANY id multiset —
Zipf-skewed, duplicated, empty — the wire view is independent of κ and of
which ids were asked, and cuckoo placement either succeeds or raises
`PlacementError` deterministically.  The e2e cases drive the unmodified
MIND `recsys.serve` on privately fetched rows, including through a live
mutation epoch.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.batchpir import KeyedLayout, PlacementError
from repro.core import pipeline


@functools.lru_cache(maxsize=None)
def _keyed_system(v=600, d=8, kappa=26, seed=0, **kw):
    """One shared keyed system per shape — crypto setup is the slow part."""
    rng = np.random.default_rng(seed + 17)
    table = rng.standard_normal((v, d)).astype(np.float32)
    sysm = pipeline.PirRagSystem.build_keyed(table, kappa=kappa, impl="xla",
                                             seed=seed, **kw)
    return sysm, table


def _zipf_ids(rng, n_rows, kappa, a=1.2):
    """DLRM-skew multiset: duplicates are the COMMON case, not an edge."""
    return ((rng.zipf(a, size=kappa) - 1) % n_rows).astype(np.int64)


# -- layout arithmetic (no crypto: full 1e3–1e5 vocab range) ----------------

@settings(max_examples=25, deadline=None)
@given(n_rows=st.integers(1_000, 100_000), dim=st.integers(1, 64),
       seed=st.integers(0, 10_000))
def test_layout_grouping_properties(n_rows, dim, seed):
    lay = KeyedLayout.build(n_rows, dim)
    assert lay.record_stride == 16 + 5 * dim
    assert lay.n_groups == -(-n_rows // lay.group_size)
    rng = np.random.default_rng(seed)
    ids = _zipf_ids(rng, n_rows, 26)
    for i in ids:
        g = lay.group_of(int(i))
        assert 0 <= g < lay.n_groups
        assert g == int(i) // lay.group_size
    gs = lay.groups_of(ids)
    assert gs == sorted(set(gs))                       # distinct + sorted
    assert set(gs) == {int(i) // lay.group_size for i in ids}
    for bad in (-1, n_rows):
        with pytest.raises(IndexError):
            lay.group_of(bad)


# -- bit-identity under skew ------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), kappa=st.integers(1, 26),
       zipf_a=st.sampled_from([1.1, 1.5, 2.5]))
def test_lookup_bit_identical_zipf(seed, kappa, zipf_a):
    """rows == table[ids] bitwise for Zipf multisets with duplicates."""
    sysm, table = _keyed_system()
    rng = np.random.default_rng(seed)
    ids = _zipf_ids(rng, len(table), kappa, a=zipf_a)
    rows, stats = sysm.lookup(ids, key=jax.random.PRNGKey(seed))
    assert rows.dtype == np.float32 and rows.shape == (kappa, table.shape[1])
    np.testing.assert_array_equal(rows, table[ids])
    assert stats.kappa == kappa
    assert stats.groups == len(set(int(i) // sysm.keyed.group_size
                                   for i in ids))


def test_lookup_edge_multisets():
    """Empty multiset and an all-duplicates multiset both decode exactly."""
    sysm, table = _keyed_system()
    empty, stats = sysm.lookup([], key=jax.random.PRNGKey(0))
    assert empty.shape == (0, table.shape[1]) and stats.kappa == 0
    ids = [41] * 26
    rows, _ = sysm.lookup(ids, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(rows, table[ids])


def test_lookup_batch_matches_sequential():
    """The engine's batched keyed path ≡ per-client sequential lookups."""
    sysm, table = _keyed_system()
    rng = np.random.default_rng(5)
    batch = [_zipf_ids(rng, len(table), int(k)) for k in (3, 8, 26)]
    outs = sysm.lookup_batch(batch, key=jax.random.PRNGKey(7))
    assert len(outs) == len(batch)
    for ids, rows in zip(batch, outs):
        assert rows.shape == (len(ids), table.shape[1])
        np.testing.assert_array_equal(rows, table[ids])


# -- wire-view independence -------------------------------------------------

def test_uplink_independent_of_kappa_and_ids():
    """The server always sees B same-width ciphertexts: message size can
    depend on neither κ, nor duplicate structure, nor the ids themselves."""
    sysm, table = _keyed_system()
    lay, bp = sysm.keyed, sysm.batch
    rng = np.random.default_rng(11)
    shapes = set()
    for kappa in (1, 2, 7, 13, 26):
        for draw in range(3):
            ids = _zipf_ids(rng, len(table), kappa)
            qs, _ = bp.client.query_rows(
                jax.random.PRNGKey(kappa * 100 + draw), lay, ids)
            shapes.add((qs.shape, qs.dtype.name, int(qs.size * 4)))
    assert len(shapes) == 1, shapes
    ((shape, _, up),) = shapes
    assert shape[0] == bp.partition.n_buckets       # dummies fill the gaps
    assert up == bp.server.uplink_bytes


def test_placement_deterministic_per_key():
    """Same (key, ids, walk_seed) → byte-identical queries; placement is a
    pure function, success or failure alike."""
    sysm, table = _keyed_system()
    lay, bp = sysm.keyed, sysm.batch
    rng = np.random.default_rng(23)
    for kappa in (4, 17, 26):
        ids = _zipf_ids(rng, len(table), kappa)
        q1, s1 = bp.client.query_rows(jax.random.PRNGKey(42), lay, ids)
        q2, s2 = bp.client.query_rows(jax.random.PRNGKey(42), lay, ids)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        assert s1.base.placement == s2.base.placement


def test_placement_failure_deterministic_and_fallback_exact():
    """> B distinct groups is structurally unplaceable: `query_rows` raises
    PlacementError every time, and the system-level `lookup` falls back to
    the legacy per-group path with bit-exact rows."""
    sysm, table = _keyed_system(v=640, d=8, kappa=4, n_buckets=6, seed=3)
    lay, bp = sysm.keyed, sysm.batch
    gs = lay.group_size
    ids = np.arange(7) * gs                      # 7 distinct groups > 6 buckets
    for attempt in range(2):
        with pytest.raises(PlacementError):
            bp.client.query_rows(jax.random.PRNGKey(attempt), lay, ids)
    rows, stats = sysm.lookup(ids, key=jax.random.PRNGKey(9))
    assert stats.mode == "legacy"
    np.testing.assert_array_equal(rows, table[ids])


# -- e2e: the unmodified MIND model on privately fetched rows ---------------

def _mind_batch(cfg, rng):
    hist = rng.integers(0, cfg.vocab_per_field, (2, cfg.hist_len))
    mask = np.ones((2, cfg.hist_len), bool)
    target = rng.integers(0, cfg.vocab_per_field, (2,))
    batch = {"hist": jnp.asarray(hist), "hist_mask": jnp.asarray(mask),
             "target": jnp.asarray(target)}
    ids = np.concatenate([hist.ravel(), target]).astype(np.int64)
    return batch, ids


def _serve_bits(params, batch, cfg):
    from repro.models import recsys
    return np.asarray(recsys.serve(params, batch, cfg)).view(np.uint32)


def test_mind_serve_parity_through_mutation_epoch():
    """serve() on PIR-fetched rows ≡ the public-table run, bit for bit —
    before AND after a live REPLACE epoch re-fetches patched rows."""
    from repro.configs.mind import SMOKE as cfg
    from repro.models import embedding, recsys
    from repro.update import LiveIndex

    rng = np.random.default_rng(2)
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    table = np.asarray(params["emb"]["table"], np.float32)
    live = LiveIndex.build_keyed(table, kappa=26, impl="xla", seed=0)
    batch, ids = _mind_batch(cfg, rng)

    def private_bits(pub_table):
        rows, _ = live.lookup(ids, epoch=live.epoch,
                              key=jax.random.PRNGKey(3 + live.epoch))
        np.testing.assert_array_equal(rows, pub_table[ids])
        priv = {"emb": embedding.table_from_rows(
                    len(pub_table), cfg.embed_dim, ids, rows),
                "bilinear": params["bilinear"]}
        return _serve_bits(priv, batch, cfg)

    pub = {"emb": {"table": jnp.asarray(table)}, "bilinear": params["bilinear"]}
    np.testing.assert_array_equal(private_bits(table),
                                  _serve_bits(pub, batch, cfg))

    # live epoch: replace two rows this request actually touches
    table2 = table.copy()
    for rid in (int(ids[0]), int(ids[-1])):
        table2[rid] = rng.standard_normal(cfg.embed_dim).astype(np.float32)
        live.replace_row(rid, table2[rid])
    patch = live.commit()
    assert patch is not None and not patch.is_full        # delta epoch
    pub2 = {"emb": {"table": jnp.asarray(table2)},
            "bilinear": params["bilinear"]}
    np.testing.assert_array_equal(private_bits(table2),
                                  _serve_bits(pub2, batch, cfg))
    # keyed dense-id guard: inserts must be rejected, not silently staged
    from repro.update import journal as journal_lib
    live.journal.append(journal_lib.insert(
        len(table2), b"x", np.zeros(cfg.embed_dim, np.float32)))
    with pytest.raises(ValueError, match="replace only"):
        live.commit()


@pytest.mark.slow
def test_lookup_bit_identical_large_vocab():
    """Vocab 1e5: the stride arithmetic and placement hold at DLRM scale."""
    sysm, table = _keyed_system(v=100_000, d=8, kappa=8, seed=1,
                                group_size=100)
    rng = np.random.default_rng(31)
    for seed in range(3):
        ids = _zipf_ids(np.random.default_rng(seed), len(table), 8)
        rows, _ = sysm.lookup(ids, key=jax.random.PRNGKey(seed))
        np.testing.assert_array_equal(rows, table[ids])
