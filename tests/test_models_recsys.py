"""RecSys models: forward shapes, oracles for CIN/EmbeddingBag, retrieval."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import embedding, recsys


def _cfg(kind, **kw):
    base = dict(
        dlrm=dict(name="dlrm", kind="dlrm", n_dense=4, n_sparse=5,
                  embed_dim=8, vocab_per_field=50,
                  bot_mlp=(4, 16, 8), top_mlp=(16, 8, 1)),
        dcn=dict(name="dcn", kind="dcn", n_dense=4, n_sparse=5, embed_dim=8,
                 vocab_per_field=50, top_mlp=(16, 8), n_cross_layers=2),
        xdeepfm=dict(name="xd", kind="xdeepfm", n_dense=0, n_sparse=6,
                     embed_dim=4, vocab_per_field=50, cin_layers=(8, 8),
                     dnn_mlp=(16,)),
        mind=dict(name="mind", kind="mind", n_dense=0, n_sparse=1,
                  embed_dim=8, vocab_per_field=100, n_interests=3,
                  capsule_iters=3, hist_len=10),
    )[kind]
    base.update(kw)
    return recsys.RecSysConfig(**base)


def _batch(cfg, B=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.kind == "mind":
        return {
            "hist": jnp.asarray(rng.integers(0, cfg.vocab_per_field,
                                             (B, cfg.hist_len))),
            "hist_mask": jnp.asarray(rng.random((B, cfg.hist_len)) < 0.8),
            "target": jnp.asarray(rng.integers(0, cfg.vocab_per_field, B)),
        }
    return {
        "dense": jnp.asarray(rng.standard_normal((B, cfg.n_dense)),
                             jnp.float32),
        "sparse": jnp.asarray(rng.integers(0, cfg.vocab_per_field,
                                           (B, cfg.n_sparse))),
        "label": jnp.asarray(rng.integers(0, 2, B)),
    }


@pytest.mark.parametrize("kind", ["dlrm", "dcn", "xdeepfm", "mind"])
def test_forward_loss_grads_finite(kind):
    cfg = _cfg(kind)
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: recsys.loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in jax.tree.leaves(grads))
    s = recsys.serve(params, batch, cfg)
    assert s.shape == (16,)
    if kind != "mind":
        assert (np.asarray(s) >= 0).all() and (np.asarray(s) <= 1).all()


@pytest.mark.parametrize("kind", ["dlrm", "dcn", "xdeepfm", "mind"])
def test_training_reduces_loss(kind):
    cfg = _cfg(kind)
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=32)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda p: recsys.loss(p, batch, cfg))(p)
        return l, jax.tree.map(lambda w, gw: w - 0.1 * gw.astype(w.dtype),
                               p, g)

    l0, params = step(params)
    for _ in range(20):
        l1, params = step(params)
    assert float(l1) < float(l0)


def test_cin_matches_naive_oracle():
    """xDeepFM CIN einsum == elementwise triple-loop definition."""
    cfg = _cfg("xdeepfm")
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B, F, d = 3, cfg.n_sparse, cfg.embed_dim
    x0 = rng.standard_normal((B, F, d)).astype(np.float32)
    w0 = np.asarray(params["cin"]["w0"], np.float32)       # (H, F, F)
    # naive: x1[b,h,k] = sum_ij w0[h,i,j] * x0[b,i,k]*x0[b,j,k]
    want = np.einsum("bik,bjk,hij->bhk", x0, x0, w0)
    z = jnp.einsum("bid,bjd->bijd", jnp.asarray(x0), jnp.asarray(x0))
    got = jnp.einsum("bijd,hij->bhd", z, jnp.asarray(w0))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_dcn_cross_identity():
    """With W=0,b=0 the cross layer is the identity (x_{l+1}=x_l)."""
    cfg = _cfg("dcn")
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    params["cross"]["c0"]["w"]["w"] = jnp.zeros_like(
        params["cross"]["c0"]["w"]["w"])
    params["cross"]["c0"]["w"]["b"] = jnp.zeros_like(
        params["cross"]["c0"]["w"]["b"])
    params["cross"]["c1"]["w"]["w"] = jnp.zeros_like(
        params["cross"]["c1"]["w"]["w"])
    params["cross"]["c1"]["w"]["b"] = jnp.zeros_like(
        params["cross"]["c1"]["w"]["b"])
    b = _batch(cfg, B=4)
    out = recsys.forward(params, b, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_mind_interests_shape_and_squash():
    cfg = _cfg("mind")
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, B=6)
    u = recsys.mind_interests(params, b["hist"], b["hist_mask"], cfg)
    assert u.shape == (6, 3, 8)
    norms = np.linalg.norm(np.asarray(u), axis=-1)
    assert (norms < 1.0 + 1e-5).all()       # squash bounds capsule norms


@pytest.mark.parametrize("kind", ["dlrm", "dcn", "xdeepfm", "mind"])
def test_retrieval_scores_batched(kind):
    cfg = _cfg(kind)
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, B=1)
    user = ({"hist": b["hist"][:1], "hist_mask": b["hist_mask"][:1]}
            if kind == "mind" else
            {"dense": b["dense"][0], "sparse": b["sparse"][0]})
    cands = jnp.arange(40)
    s = recsys.retrieval_score(params, user, cands, cfg)
    assert s.shape == (40,)
    assert np.isfinite(np.asarray(s)).all()
    if kind != "mind":
        # consistency: retrieval score for candidate c == forward with item=c
        sp = np.array(jnp.broadcast_to(b["sparse"][0], (40, cfg.n_sparse)))
        sp[:, 0] = np.arange(40)
        direct = recsys.forward(params, {"dense": jnp.broadcast_to(
            b["dense"][0], (40, cfg.n_dense)), "sparse": jnp.asarray(sp)},
            cfg)
        np.testing.assert_allclose(np.asarray(s), np.asarray(direct),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n_bags=st.integers(1, 8), mode=st.sampled_from(["sum", "mean", "max"]),
       seed=st.integers(0, 2**31 - 1))
def test_embedding_bag_ragged_matches_loop_oracle(n_bags, mode, seed):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((20, 4)).astype(np.float32)
    nnz = int(rng.integers(1, 30))
    idx = rng.integers(0, 20, nnz)
    seg = np.sort(rng.integers(0, n_bags, nnz))
    got = np.asarray(embedding.embedding_bag_ragged(
        jnp.asarray(table), jnp.asarray(idx), jnp.asarray(seg), n_bags,
        mode=mode))
    for b in range(n_bags):
        rows = table[idx[seg == b]]
        if len(rows) == 0:
            continue    # segment_sum yields 0 / -inf for empty; skip oracle
        want = {"sum": rows.sum(0), "mean": rows.mean(0),
                "max": rows.max(0)}[mode]
        np.testing.assert_allclose(got[b], want, rtol=1e-5, atol=1e-5)


def test_dense_bag_matches_ragged():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((30, 6)), jnp.float32)
    idx = rng.integers(0, 30, (4, 5))
    mask = rng.random((4, 5)) < 0.7
    mask[:, 0] = True
    got = embedding.embedding_bag(table, jnp.asarray(idx), jnp.asarray(mask),
                                  mode="sum", compute_dtype=jnp.float32)
    flat_idx = jnp.asarray(idx[mask])
    seg = jnp.asarray(np.repeat(np.arange(4), mask.sum(1)))
    want = embedding.embedding_bag_ragged(table, flat_idx, seg, 4, mode="sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_field_lookup_offsets():
    cfg = _cfg("dlrm")
    params = recsys.init(jax.random.PRNGKey(0), cfg)
    idx = jnp.asarray([[3, 7, 0, 1, 2]])
    out = embedding.field_lookup(params["emb"], idx, cfg.vocab_per_field,
                                 compute_dtype=jnp.float32)
    table = params["emb"]["table"]
    np.testing.assert_allclose(np.asarray(out[0, 1]),
                               np.asarray(table[1 * 50 + 7]), rtol=1e-6)
