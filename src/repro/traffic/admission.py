"""SLO-driven admission control over the serve-engine hooks.

The serving engines stay policy-free: they expose backlog observability
(`DeadlineBatcher.depth`, `oldest_age_ms`), a load-shedding primitive
(`shed_tail`), a commit gate (`PIRServeLoop.commit_gate`) and a pipeline
depth knob (`PipelinedServeLoop.set_depth`).  `AdmissionController` is the
policy that drives them, invoked by the `OpenLoopDriver` once per service
iteration:

shed        When the queue grows past `max_queue` the tail (youngest
            arrivals — the head is closest to its deadline and cheapest to
            save) is shed and reported to the driver, which records the
            requests as SLO misses.  This is what bounds p99 under
            overload: an open-loop arrival process at > sustainable qps
            grows the queue without bound, so *some* requests must fail —
            shedding makes them fail fast and keeps the served tail flat.

defer       Pending mutation commits are gated off while the queue holds
            more than `defer_queue` requests: a commit would bump the
            epoch and force every queued request through the stale-reject/
            retry path (plus a hint re-sync per client), exactly when the
            system can least afford it.  Freshness degrades — queued
            requests are answered at the pre-commit epoch — instead of
            latency.  Deferred commits apply on the first gated tick after
            the backlog clears (the engine re-checks the gate every tick).

depth       The pipeline depth tracks the backlog: ~1 batch queued needs
            depth 1 (lowest completion latency), a standing backlog earns
            a deeper pipeline (more device overlap, higher throughput) up
            to `max_depth`.  No-op on the synchronous engine.
"""
from __future__ import annotations

import math


class AdmissionController:
    """Shed / defer-commit / depth policy driven once per service iteration.

    Construct, `attach` to a serve loop, then call `step(now)` from the
    driving loop; it returns the requests shed this step (possibly empty)
    so the caller owns the SLO accounting.  `stats()` summarises what the
    controller did for the benchmark report.
    """

    def __init__(self, *, max_queue: int = 256, defer_queue: int | None = None,
                 min_depth: int = 1, max_depth: int = 4):
        assert max_queue >= 1 and min_depth >= 1 and max_depth >= min_depth
        self.max_queue = max_queue
        # defer commits strictly before shedding kicks in: holding an epoch
        # bump is free; dropping requests is not
        self.defer_queue = (max(1, max_queue // 2) if defer_queue is None
                            else defer_queue)
        self.min_depth = min_depth
        self.max_depth = max_depth
        self.loop = None
        self.shed_total = 0
        self.deferred_commits = 0
        self.allowed_commits = 0
        self.depth_trajectory: list[int] = []

    def attach(self, loop):
        """Install the commit gate on `loop` and start controlling it."""
        self.loop = loop
        loop.commit_gate = self._allow_commit
        return self

    def _allow_commit(self) -> bool:
        """Commit gate: hold epoch bumps while the queue is deep."""
        if self.loop.batcher.depth > self.defer_queue:
            self.deferred_commits += 1
            self.loop.obs.counter("admission.deferred_commits").inc()
            return False
        self.allowed_commits += 1
        return True

    def step(self, now: float) -> list:
        """One control decision; returns the requests shed (maybe empty)."""
        loop = self.loop
        assert loop is not None, "attach() a serve loop first"
        shed = []
        over = loop.batcher.depth - self.max_queue
        if over > 0:
            shed = loop.batcher.shed_tail(over)
            self.shed_total += len(shed)
            loop.obs.counter("admission.shed").inc(len(shed))
            loop.obs.instant("admission.shed", n=len(shed),
                             depth=loop.batcher.depth)
        if hasattr(loop, "set_depth"):
            want = max(self.min_depth, min(
                self.max_depth,
                math.ceil(loop.batcher.depth / loop.batcher.max_batch) or 1))
            if want != loop.depth:
                loop.set_depth(want)
                self.depth_trajectory.append(want)
                loop.obs.counter("admission.depth_changes").inc()
                loop.obs.instant("admission.depth", depth=want)
        return shed

    def stats(self) -> dict:
        """What the controller did, for the benchmark report."""
        return {
            "max_queue": self.max_queue,
            "defer_queue": self.defer_queue,
            "shed": self.shed_total,
            "deferred_commits": self.deferred_commits,
            "allowed_commits": self.allowed_commits,
            "depth_changes": len(self.depth_trajectory),
            "final_depth": (self.depth_trajectory[-1]
                            if self.depth_trajectory else None),
        }
