"""Traffic & hint-delivery subsystem: load generation, SLOs, admission.

Three layers, one per module:

`workload`   open-loop Poisson traffic (`OpenLoopDriver`) over long-lived
             `ClientSession`s with lazily synced hints — arrivals are
             scheduled up front and land on time regardless of backlog.
`slo`        per-request ground truth (`RequestRecord`) and the SLO fold
             (`summarize`): percentiles and deadline attainment over every
             offered request, with queue/encode/gemm/decode/hint-sync
             latency components.
`admission`  `AdmissionController` driving the engine's control hooks:
             shed the queue tail past `max_queue`, gate mutation commits
             under backlog (freshness degrades instead of latency), adapt
             pipeline depth to the standing backlog.

The hint-delivery cost model rides on `repro.update.epochs`: sessions
download compacted patch chains (`EpochLog.chain_since`), and every synced
byte is charged to the requesting client's SLO record.  benchmarks/
traffic_bench.py is the CLI; docs/traffic.md the narrative.
"""
from repro.traffic.admission import AdmissionController
from repro.traffic.slo import RequestRecord, summarize
from repro.traffic.workload import (ClientSession, OpenLoopDriver,
                                    TrafficResult, TrafficSpec,
                                    poisson_arrivals)

__all__ = ["AdmissionController", "ClientSession", "OpenLoopDriver",
           "RequestRecord", "TrafficResult", "TrafficSpec",
           "poisson_arrivals", "summarize"]
