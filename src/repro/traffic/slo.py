"""SLO accounting for open-loop PIR serving.

A `RequestRecord` is the per-request ground truth the `OpenLoopDriver`
assembles: arrival/completion timestamps against the driver's clock plus the
latency decomposition carried on each `Response` (`BatchTiming`) and the
hint-delivery cost the issuing session paid (chain bytes + modelled downlink
time).  `summarize` folds a run's records into the SLO summary the benchmark
emits — percentiles and deadline attainment are computed over every OFFERED
request, so a shed request counts as a miss rather than vanishing from the
denominator (the standard open-loop rule; closed-loop style "served-only"
percentiles would let the admission controller cheat by shedding).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs.registry import percentile

SHED = "shed"
SERVED = "served"
#: Terminal failure: the request exhausted its retry budget or deadline
#: (fleet.retry) — distinct from shed (admission dropped it before service).
FAILED = "failed"


@dataclasses.dataclass
class RequestRecord:
    """One request's life: arrival → (served | shed), with components.

    Times are seconds on the driver clock; component fields are milliseconds.
    `queue_ms` spans arrival → batch plan; `encode_ms`/`gemm_ms`/`decode_ms`
    come from the serving engine's `BatchTiming` (shared by the batch);
    `hint_sync_ms` is the modelled downlink time of the patch chain this
    request's session downloaded to form the query (0 for warm sessions);
    `generate_ms` is the generation completion stage (tokenize + prefill
    + decode, from `Response.rag`) — 0.0 on retrieval-only loops, and the
    component only appears in summaries when some record generated.
    """
    rid: int
    session: int
    t_arrival: float
    outcome: str = SERVED
    t_done: float | None = None
    epoch: int = 0
    retries: int = 0
    multi_probe: int = 1
    kind: str = "query"           # "query" (similarity) | "lookup" (keyed)
    queue_ms: float = 0.0
    encode_ms: float = 0.0
    gemm_ms: float = 0.0
    decode_ms: float = 0.0
    hint_sync_ms: float = 0.0
    hint_sync_bytes: int = 0
    generate_ms: float = 0.0

    @property
    def latency_ms(self) -> float:
        """End-to-end latency incl. hint sync; +inf unless actually served.

        A failed request HAS a completion timestamp (the tick that gave up
        on it) but no answer, so like a shed request it counts as an SLO
        miss rather than contributing a finite latency.
        """
        if self.t_done is None or self.outcome != SERVED:
            return float("inf")
        return (self.t_done - self.t_arrival) * 1e3 + self.hint_sync_ms


def _pct(values: np.ndarray, q: float) -> float:
    """Percentile that propagates +inf (shed requests) instead of NaN.

    Delegates to the repo's single rank rule (`repro.obs.registry
    .percentile`): the order statistic at rank ``ceil(q/100·n) − 1``.
    np.percentile interpolates, which turns a single inf into NaN for
    everything above the last finite sample; the order statistic doesn't —
    pinned by tests/test_traffic.py.  Kept as a named wrapper so the SLO
    fold and the metrics registry provably share one convention.
    """
    return percentile(values, q)


def summarize(records: list[RequestRecord], *, deadline_ms: float,
              wall_s: float) -> dict:
    """Fold a run's records into the SLO summary dict the bench emits.

    Attainment = fraction of OFFERED requests whose end-to-end latency
    (queue + service + hint sync) beat `deadline_ms`; shed and failed
    requests have infinite latency and therefore count against attainment
    and p99.  served + shed + failed == offered — every offered request
    lands in exactly one bucket (the fleet invariant the chaos tests pin).
    Component means are over served requests only (a shed request never
    entered the pipeline, a failed one never completed it).
    """
    served = [r for r in records if r.outcome == SERVED]
    lat = np.array([r.latency_ms for r in records], np.float64)
    out = {
        "offered": len(records),
        "served": len(served),
        "shed": sum(r.outcome == SHED for r in records),
        "failed": sum(r.outcome == FAILED for r in records),
        "wall_s": round(wall_s, 4),
        "offered_qps": round(len(records) / wall_s, 2) if wall_s else 0.0,
        "served_qps": round(len(served) / wall_s, 2) if wall_s else 0.0,
        "deadline_ms": deadline_ms,
        "attainment": (round(float(np.mean(lat <= deadline_ms)), 4)
                       if records else 1.0),
        "p50_ms": round(_pct(lat, 50), 3),
        "p99_ms": round(_pct(lat, 99), 3),
        "retries": sum(r.retries for r in served),
        "hint_sync_bytes": sum(r.hint_sync_bytes for r in served),
    }
    comp = {}
    names = ["queue_ms", "encode_ms", "gemm_ms", "decode_ms",
             "hint_sync_ms"]
    # generate_ms appears ONLY when the run generated: query-only specs
    # keep byte-identical summaries to the pre-RAG component set (the
    # stream-preservation regression tests/test_traffic.py pins).
    if any(r.generate_ms for r in records):
        names.append("generate_ms")
    for name in names:
        vals = np.array([getattr(r, name) for r in served], np.float64)
        comp[name] = {"mean": round(float(vals.mean()), 3) if served else 0.0,
                      "p99": round(_pct(vals, 99), 3)}
    out["components"] = comp
    # Per-kind breakdown (similarity queries vs keyed lookups): the mixed
    # recsys workload needs each kind's attainment separately — a flat
    # aggregate would let one kind's tail hide behind the other's volume.
    kinds: dict[str, dict] = {}
    for kind in sorted({r.kind for r in records}):
        sub = [r for r in records if r.kind == kind]
        sub_served = [r for r in sub if r.outcome == SERVED]
        klat = np.array([r.latency_ms for r in sub], np.float64)
        kinds[kind] = {
            "offered": len(sub),
            "served": len(sub_served),
            "attainment": round(float(np.mean(klat <= deadline_ms)), 4),
            "p50_ms": round(_pct(klat, 50), 3),
            "p99_ms": round(_pct(klat, 99), 3),
        }
    out["kinds"] = kinds
    return out
