"""Open-loop traffic generation over the PIR serve loops.

Open loop means arrivals are a property of the WORLD, not of the server:
request times are drawn up front from a Poisson process at `qps` and each
request is submitted at its scheduled instant whether or not the engine has
kept up.  (A closed-loop driver — next request only after the previous
response — self-throttles under overload and hides exactly the tail
behaviour this subsystem exists to measure.)

`ClientSession` models a long-lived client: it holds the epoch of its cached
hint and only pays for hint delivery when it has to — either proactively
when it falls more than `staleness_tolerance` epochs behind the published
head, or reactively when the engine stale-rejects its query.  Both paths
download the epoch log's minimal compacted chain (`EpochLog.chain_since`),
and both charge the exact wire bytes plus a modelled downlink time to the
request's SLO record, so "cheap hint delivery" is measured in the same
budget as serving latency.

`OpenLoopDriver` owns the run: it merges query and mutation arrivals into
one schedule, services the engine while waiting between events (tick +
admission-controller step + response absorption), and assembles the
per-request `RequestRecord`s that `slo.summarize` folds into the benchmark
report.  The driver takes its clock from the serve loop, so the FakeClock
the engine tests use drives deterministic end-to-end traffic tests too.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.fleet.retry import RetryPolicy
from repro.traffic import slo
from repro.traffic.slo import RequestRecord


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Shape of an open-loop run.

    `probe_mix` gives (multi_probe, weight) pairs for the single/multi-probe
    request mix; `staleness_tolerance` is how many epochs behind a session
    lets its cached hint drift before proactively syncing (0 = always
    fresh); `downlink_gbps` converts synced chain bytes into the
    `hint_sync_ms` latency component.
    """
    qps: float = 50.0
    duration_s: float = 2.0
    n_sessions: int = 8
    probe_mix: tuple[tuple[int, float], ...] = ((1, 0.75), (4, 0.25))
    top_k: int = 5
    staleness_tolerance: int = 0
    mutation_qps: float = 0.0
    downlink_gbps: float = 1.0
    seed: int = 0
    # Keyed-lookup share of the arrival process: each query arrival is a
    # keyed embedding lookup with probability `lookup_mix` (needs a
    # build_keyed system on the loop).  Lookups draw `lookup_kappa` row ids
    # from a Zipf(`lookup_zipf_a`) popularity law folded onto the table —
    # the DLRM-style skew where a few hot ids dominate and requests repeat
    # them freely.
    lookup_mix: float = 0.0
    lookup_kappa: int = 8
    lookup_zipf_a: float = 1.2
    # Retry posture: when `max_retries` is set the driver installs a
    # `fleet.retry.RetryPolicy` on the loop (budget + optional backoff +
    # deadline), so requests that keep losing the stale-sync race — or
    # whose answers a fault plan keeps dropping — end as terminal FAILED
    # records instead of looping forever.  None keeps the loop's own
    # policy (the engine default).
    max_retries: int | None = None
    retry_backoff_ms: float = 0.0
    retry_deadline_ms: float | None = None


def poisson_arrivals(rng: np.random.Generator, qps: float,
                     duration_s: float) -> np.ndarray:
    """Sorted arrival times (s) of a Poisson process at rate `qps`.

    Exponential interarrivals drawn up front — the open-loop schedule is
    fixed before the run starts and never reacts to service progress.
    """
    if qps <= 0 or duration_s <= 0:
        return np.empty(0, np.float64)
    n = max(int(qps * duration_s * 2), 16)     # overdraw, then truncate
    t = np.cumsum(rng.exponential(1.0 / qps, size=n))
    while t[-1] < duration_s:                  # rare: overdraw fell short
        t = np.concatenate([t, t[-1] + np.cumsum(
            rng.exponential(1.0 / qps, size=n))])
    return t[t < duration_s]


class ClientSession:
    """A long-lived client: cached-hint epoch + hint-delivery accounting.

    ``give_ups`` counts requests this session abandoned after the engine's
    retry budget ran out (its reactive stale-sync loop is BOUNDED: each
    lost sync race charges the request's retry budget, and exhaustion is a
    terminal failed request, not another sync).  ``resyncs`` counts
    corrupt-chain recoveries, each charged as one full hint download.
    """

    def __init__(self, sid: int, epoch: int = 0):
        self.sid = sid
        self.epoch = epoch
        self.bytes_downloaded = 0
        self.syncs = 0
        self.resyncs = 0
        self.give_ups = 0
        self.n_requests = 0

    def sync_to(self, log, until: int | None = None) -> int:
        """Download the minimal chain to `until` (default head); rtn bytes.

        Downloads go through `EpochLog.download_chain` (the wire copy), so
        an injected corruption lands here too: a checksum mismatch charges
        the wasted chain bytes PLUS one full re-sync (`EpochLog.
        full_fetch`) — the session's accounting matches what a real
        `HintCache.sync` would pay.
        """
        goal = log.epoch if until is None else until
        if goal <= self.epoch:
            return 0
        chain = (log.download_chain(self.epoch, goal)
                 if hasattr(log, "download_chain")
                 else log.chain_since(self.epoch, goal))
        nbytes = sum(p.wire_bytes for p in chain)
        if not all(p.verify() for p in chain):
            assert log.full_fetch is not None, "corrupt chain, no fallback"
            nbytes += log.full_fetch(self.epoch).wire_bytes
            self.resyncs += 1
        self.epoch = goal
        self.bytes_downloaded += nbytes
        self.syncs += 1
        return nbytes


@dataclasses.dataclass
class TrafficResult:
    """Everything a run produced: records + engine/controller counters."""
    records: list[RequestRecord]
    wall_s: float
    spec: TrafficSpec
    stale_retries: int = 0
    commits: int = 0
    controller: dict | None = None
    session_sync_bytes: int = 0
    failed: int = 0
    session_resyncs: int = 0

    def summary(self, deadline_ms: float) -> dict:
        """SLO summary dict (see slo.summarize) plus run-level counters."""
        out = slo.summarize(self.records, deadline_ms=deadline_ms,
                            wall_s=self.wall_s)
        out["target_qps"] = self.spec.qps
        out["stale_retries"] = self.stale_retries
        out["commits"] = self.commits
        out["session_sync_bytes"] = self.session_sync_bytes
        out["session_resyncs"] = self.session_resyncs
        if self.controller is not None:
            out["admission"] = self.controller
        return out


class OpenLoopDriver:
    """Drive a serve loop with an open-loop schedule; collect SLO records.

    `queries`: (n, d) pool of query embeddings sampled per request.
    `mutator`: optional callable(rng) -> journal record, invoked at each
    mutation arrival (requires the loop to wrap a LiveIndex).
    `controller`: optional AdmissionController, attached on construction
    and stepped once per service iteration.
    """

    def __init__(self, loop, queries: np.ndarray, spec: TrafficSpec, *,
                 mutator=None, controller=None):
        self.loop = loop
        self.queries = np.asarray(queries)
        self.spec = spec
        self.mutator = mutator
        self.controller = controller
        if controller is not None:
            controller.attach(loop)
        if spec.max_retries is not None:
            loop.retry = RetryPolicy(max_retries=spec.max_retries,
                                     backoff_base_ms=spec.retry_backoff_ms,
                                     deadline_ms=spec.retry_deadline_ms,
                                     seed=spec.seed)
        self.clock = loop.clock
        self.rng = np.random.default_rng(spec.seed)
        self.sessions = [ClientSession(i, epoch=loop.epoch)
                         for i in range(spec.n_sessions)]
        self.records: dict[int, RequestRecord] = {}
        # rid -> (session, epoch at submit) for completion-time accounting
        self._pending: dict[int, tuple[ClientSession, int]] = {}
        # responses already on the loop (warmup runs) are not ours to absorb
        self._n_seen = len(loop.responses)
        self._probes = np.array([p for p, _ in spec.probe_mix])
        w = np.array([w for _, w in spec.probe_mix], np.float64)
        self._probe_w = w / w.sum()

    # -- schedule -------------------------------------------------------------

    def _schedule(self) -> list[tuple[float, str]]:
        """Merged (time, kind) events: 'q' = query arrival, 'm' = mutation.

        Queries and mutations draw from INDEPENDENT seeded streams, so the
        mutation schedule is identical across runs that differ only in
        query rate — a load sweep compares points against the same commit
        pressure.
        """
        ev = [(float(t), "q") for t in poisson_arrivals(
            np.random.default_rng([self.spec.seed, 1]),
            self.spec.qps, self.spec.duration_s)]
        if self.mutator is not None and self.spec.mutation_qps > 0:
            ev += [(float(t), "m") for t in poisson_arrivals(
                np.random.default_rng([self.spec.seed, 2]),
                self.spec.mutation_qps, self.spec.duration_s)]
        return sorted(ev)

    # -- per-iteration service ------------------------------------------------

    def _service(self):
        """One service iteration: control, tick, absorb new responses."""
        if self.controller is not None:
            for req in self.controller.step(self.clock()):
                rec = self.records.get(req.rid)
                if rec is not None:          # pre-warm traffic isn't ours
                    rec.outcome = slo.SHED
                self._pending.pop(req.rid, None)
        self.loop.tick()
        self._absorb()

    def _absorb(self):
        """Fold newly retired responses into their records and sessions."""
        resp = self.loop.responses
        while self._n_seen < len(resp):
            r = resp[self._n_seen]
            self._n_seen += 1
            rec = self.records.get(r.rid)
            if rec is None:                  # not ours (pre-warm traffic)
                continue
            sess, submit_epoch = self._pending.pop(r.rid)
            rec.t_done = r.t_done
            rec.epoch = r.epoch
            rec.retries = r.retries
            if getattr(r, "failed", False):
                # terminal: the engine gave up after the retry budget —
                # the session abandons the request (no hint sync charged;
                # it never got an answer to decode)
                rec.outcome = slo.FAILED
                sess.give_ups += 1
                sess.n_requests += 1
                continue
            if r.retries and r.epoch > submit_epoch:
                # the engine stale-rejected this query: the client synced
                # its hint to the serving epoch and re-encrypted — charge
                # the exact chain bytes for that reactive sync
                nbytes = sess.sync_to(self.loop.live.epochs,
                                      max(sess.epoch, r.epoch))
                rec.hint_sync_bytes += nbytes
                rec.hint_sync_ms += self._downlink_ms(nbytes)
                self._count_sync(nbytes, reactive=True)
            if r.timing is not None:
                rec.queue_ms = (r.timing.t_plan - r.t_arrival) * 1e3
                rec.encode_ms = r.timing.encode_s * 1e3
                rec.gemm_ms = r.timing.gemm_s * 1e3
                rec.decode_ms = r.timing.decode_s * 1e3
            rag = getattr(r, "rag", None)
            if rag is not None:
                # the generation completion stage (loops with generator=);
                # r.t_done already sits at the end of generation, so
                # latency_ms and attainment cover the full RAG answer
                rec.generate_ms = (rag.tokenize_s + rag.prefill_s
                                   + rag.generate_s) * 1e3
            sess.n_requests += 1

    def _downlink_ms(self, nbytes: int) -> float:
        """Modelled time to ship `nbytes` over the spec'd downlink."""
        return nbytes * 8 / (self.spec.downlink_gbps * 1e9) * 1e3

    def _count_sync(self, nbytes: int, *, reactive: bool):
        """Charge one hint sync to the loop's metrics registry."""
        kind = "reactive" if reactive else "proactive"
        obs = self.loop.obs
        obs.counter(f"traffic.hint_sync_bytes.{kind}").inc(nbytes)
        obs.counter(f"traffic.hint_syncs.{kind}").inc()

    # -- arrivals -------------------------------------------------------------

    def _pick_session(self) -> tuple[ClientSession, int, float]:
        """Draw the issuing session and charge any proactive hint sync."""
        sess = self.sessions[int(self.rng.integers(len(self.sessions)))]
        sync_bytes, sync_ms = 0, 0.0
        live = self.loop.live
        if live is not None:
            behind = self.loop.epoch - sess.epoch
            if behind > self.spec.staleness_tolerance:
                sync_bytes = sess.sync_to(live.epochs)
                sync_ms = self._downlink_ms(sync_bytes)
                self._count_sync(sync_bytes, reactive=False)
        return sess, sync_bytes, sync_ms

    def _submit_arrival(self, rid: int):
        """One arrival: a keyed lookup with probability `lookup_mix`,
        otherwise a similarity query (the decision rides the run stream, so
        the mix is reproducible per seed)."""
        if (self.spec.lookup_mix > 0
                and self.rng.random() < self.spec.lookup_mix):
            self._submit_lookup(rid)
        else:
            self._submit_query(rid)

    def _submit_query(self, rid: int):
        """One query arrival: pick a session, maybe sync, submit."""
        sess, sync_bytes, sync_ms = self._pick_session()
        live = self.loop.live
        emb = self.queries[int(self.rng.integers(len(self.queries)))]
        mp = int(self.rng.choice(self._probes, p=self._probe_w))
        rec = RequestRecord(rid, sess.sid, t_arrival=self.clock(),
                            multi_probe=mp, hint_sync_bytes=sync_bytes,
                            hint_sync_ms=sync_ms)
        self.records[rid] = rec
        self._pending[rid] = (sess, sess.epoch)
        self.loop.submit(rid, emb, top_k=self.spec.top_k, multi_probe=mp,
                         epoch=sess.epoch if live is not None else None)

    def _submit_lookup(self, rid: int):
        """One keyed-lookup arrival: Zipf-skewed id multiset → submit_lookup.

        Ids come from a Zipf popularity law folded onto [0, V): hot ids
        repeat within a single request exactly as DLRM sparse features do
        (the keyed client dedups them to groups on the wire, so the
        multiset costs the same as its distinct set).
        """
        layout = getattr(self.loop._serving_system(), "keyed", None)
        assert layout is not None, "lookup_mix needs a build_keyed system"
        sess, sync_bytes, sync_ms = self._pick_session()
        live = self.loop.live
        ids = ((self.rng.zipf(self.spec.lookup_zipf_a,
                              size=self.spec.lookup_kappa) - 1)
               % layout.n_rows).astype(np.int64)
        rec = RequestRecord(rid, sess.sid, t_arrival=self.clock(),
                            kind="lookup", hint_sync_bytes=sync_bytes,
                            hint_sync_ms=sync_ms)
        self.records[rid] = rec
        self._pending[rid] = (sess, sess.epoch)
        self.loop.submit_lookup(rid, ids,
                                epoch=sess.epoch if live is not None
                                else None)

    # -- the run --------------------------------------------------------------

    def run(self) -> TrafficResult:
        """Execute the schedule; returns the assembled TrafficResult."""
        events = self._schedule()
        epoch0 = self.loop.epoch
        retries0 = self.loop.stale_retries
        t0 = self.clock()
        rid = 0
        i = 0
        while i < len(events):
            # submit every arrival that is due NOW — arrivals land on time
            # regardless of backlog (open loop) — then service once; when
            # the engine is slower than the arrival process this alternation
            # is what grows the queue and exercises the admission policy
            now = self.clock() - t0
            while i < len(events) and events[i][0] <= now:
                t_ev, kind = events[i]
                i += 1
                if kind == "q":
                    self._submit_arrival(rid)
                    rid += 1
                else:
                    self.loop.submit_mutation(self.mutator(self.rng))
            if i < len(events):
                self._service()
        self.loop.drain()
        if self.controller is not None:      # account post-drain state
            self.controller.step(self.clock())
        self._absorb()
        wall = self.clock() - t0
        recs = [self.records[i] for i in sorted(self.records)]
        return TrafficResult(
            records=recs, wall_s=wall, spec=self.spec,
            stale_retries=self.loop.stale_retries - retries0,
            commits=self.loop.epoch - epoch0,
            controller=(self.controller.stats()
                        if self.controller is not None else None),
            session_sync_bytes=sum(s.bytes_downloaded
                                   for s in self.sessions),
            failed=sum(r.outcome == slo.FAILED for r in recs),
            session_resyncs=sum(s.resyncs for s in self.sessions))
