"""Fault-tolerant fleet serving: faults, retries, replicas, recovery.

Failure model and degradation contract live in docs/fleet.md.  The light
leaves — `faults` (the injection plan/injector) and `retry` (the bounded
retry/backoff policy) — import eagerly: they depend only on numpy and are
what the serve/update/traffic layers import at module scope.  The heavy
modules — `replica` (replica groups + FleetServeLoop, pulls in the whole
serve stack) and `recovery` (journal replay) — resolve lazily via PEP 562
so ``from repro.fleet.faults import ...`` inside `update.live` never
re-enters the serve engine mid-import.
"""
from __future__ import annotations

from repro.fleet.faults import (ALL_SITES, FaultEvent, FaultInjector,
                                FaultPlan, InjectedCommitFault, NO_FAULTS,
                                SITE_ANSWER_DELAY, SITE_ANSWER_DROP,
                                SITE_CHAIN_CORRUPT, SITE_COMMIT_FAIL,
                                SITE_SHARD_LOSS)
from repro.fleet.retry import DEFAULT_POLICY, RetryPolicy

_LAZY = {
    "ReplicaGroup": ("repro.fleet.replica", "ReplicaGroup"),
    "ShardHost": ("repro.fleet.replica", "ShardHost"),
    "FleetServeLoop": ("repro.fleet.replica", "FleetServeLoop"),
    "ReplayReport": ("repro.fleet.recovery", "ReplayReport"),
    "epoch_batches": ("repro.fleet.recovery", "epoch_batches"),
    "replay_into": ("repro.fleet.recovery", "replay_into"),
    "readmit": ("repro.fleet.recovery", "readmit"),
}

__all__ = [
    "ALL_SITES", "FaultEvent", "FaultInjector", "FaultPlan",
    "InjectedCommitFault", "NO_FAULTS", "SITE_ANSWER_DELAY",
    "SITE_ANSWER_DROP", "SITE_CHAIN_CORRUPT", "SITE_COMMIT_FAIL",
    "SITE_SHARD_LOSS", "DEFAULT_POLICY", "RetryPolicy", *_LAZY,
]


def __getattr__(name: str):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod_name), attr)
