"""Bounded retry / timeout / backoff policy shared across the serving fleet.

Every retrying path in the system — the serve engines' stale-reject requeue,
fault-injected answer drops, failed staged commits, and the traffic driver's
reactive hint syncs — used to retry UNBOUNDEDLY: under heavy epoch churn a
request could ping-pong between the queue head and the stale-reject path
forever, and a lost answer was simply re-queued with no terminal outcome.
`RetryPolicy` is the one shared budget that closes those loops:

budget      ``max_retries`` bounds how many times a single request may be
            re-admitted.  Exhausting the budget produces a TERMINAL
            ``failed`` response (never silence), which `traffic.slo` folds
            into the run summary so served + shed + failed == offered.

deadline    ``deadline_ms`` (optional) fails a request at retry time once
            its age exceeds the deadline — retrying work that can no longer
            meet its SLO only steals capacity from requests that still can.

backoff     ``backoff_ms(rid, attempt)`` is deterministic exponential
            backoff with seeded jitter: base · factor^(attempt−1), capped,
            plus a jitter drawn from ``default_rng([seed, rid, attempt])``
            — a pure function of (policy, request, attempt), so retry
            schedules are bit-reproducible across runs and across the
            sync/pipelined engines.  The default base of 0 keeps the
            historical immediate-requeue behaviour (and its bit-identical
            response stream); fault-tolerant deployments raise it so a
            struggling shard is not hammered by synchronized retries.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-request retry budget + deadline + deterministic backoff.

    ``max_retries`` is the number of RE-admissions allowed (a request served
    on first admission has retries=0); ``backoff_base_ms=0`` (default)
    means immediate requeue — bit-identical to the pre-fleet engines.
    ``deadline_ms=None`` disables age-based failing.  ``seed`` keys the
    jitter stream; two policies with equal fields produce identical
    schedules.
    """
    max_retries: int = 32
    backoff_base_ms: float = 0.0
    backoff_factor: float = 2.0
    backoff_cap_ms: float = 1000.0
    jitter_frac: float = 0.1
    deadline_ms: float | None = None
    seed: int = 0

    def __post_init__(self):
        assert self.max_retries >= 0, self.max_retries
        assert self.backoff_base_ms >= 0 and self.backoff_cap_ms >= 0
        assert self.backoff_factor >= 1.0, self.backoff_factor
        assert 0.0 <= self.jitter_frac <= 1.0, self.jitter_frac

    def exhausted(self, retries: int) -> bool:
        """True once `retries` re-admissions have used up the budget."""
        return retries > self.max_retries

    def past_deadline(self, t_arrival: float, now: float) -> bool:
        """True when the request's age exceeds ``deadline_ms`` (if set)."""
        if self.deadline_ms is None:
            return False
        return (now - t_arrival) * 1e3 > self.deadline_ms

    def backoff_ms(self, rid: int, attempt: int) -> float:
        """Deterministic backoff before re-admission number `attempt` (≥1).

        base · factor^(attempt−1), capped at ``backoff_cap_ms``, plus a
        seeded jitter in [0, jitter_frac·delay) drawn from
        ``default_rng([seed, rid, attempt])`` — reproducible per
        (policy, request, attempt) with no shared RNG state, so concurrent
        retries desynchronize without breaking determinism.
        """
        if self.backoff_base_ms <= 0:
            return 0.0
        delay = min(self.backoff_base_ms * self.backoff_factor ** (attempt - 1),
                    self.backoff_cap_ms)
        if self.jitter_frac > 0:
            u = float(np.random.default_rng(
                [self.seed, int(rid) & 0x7FFFFFFF, attempt]).random())
            delay += delay * self.jitter_frac * u
        return delay

    def backoff_s(self, rid: int, attempt: int) -> float:
        """`backoff_ms` in seconds (the serve-loop clock unit)."""
        return self.backoff_ms(rid, attempt) * 1e-3


#: The engines' default: generous budget, zero backoff — behaviourally
#: identical to the historical unbounded requeue for every workload whose
#: requests see fewer than 32 epoch bumps while queued, but with a hard
#: floor under pathological churn.
DEFAULT_POLICY = RetryPolicy()
