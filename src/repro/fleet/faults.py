"""Deterministic fault injection for the serving fleet.

A `FaultPlan` is a SEEDED, fully enumerated schedule of fault events, each
bound to a named SITE — a specific guarded point in the serving stack — and
an invocation index at that site.  The `FaultInjector` compiled from a plan
counts site invocations and fires the matching events; with an empty plan
every guard is a pure counter increment, so the no-fault path makes exactly
the same clock reads and dispatches as an uninjected run (the bit-identity
regression in tests/test_fleet.py pins this).

Sites (each module guards its own; the literals below are the canonical
vocabulary — this module depends only on numpy, so guarded modules import
it eagerly without cycles, while the heavier fleet modules stay lazy behind
``repro.fleet.__getattr__``):

``collectives.row_shard.loss``  (`distributed.collectives.row_shard_health_check`)
    Fired once per fleet tick; payload ``device`` + ``down_ticks`` takes
    that device out of the replica placement for a window, killing every
    row-shard replica cell placed on it.
``serve.answer.drop``  (`serve.engine` tick, post-admission)
    The cut batch's answer is lost before dispatch: every request in it is
    charged one retry against the engine's `RetryPolicy` and re-queued
    with backoff (or terminally failed).
``serve.answer.delay``  (`serve.engine` tick, post-admission)
    The cut batch is held for ``delay_s`` of loop-clock time before
    becoming dispatchable again (no retry charged — the answer is late,
    not lost).
``update.commit.stage``  (`update.live.LiveIndex.stage`)
    The staged commit raises `InjectedCommitFault` mid-stage; the engines
    catch it, leave the journal's pending batch intact, and retry the
    commit with backoff on a later tick (PR 6 closed the donation window,
    so a dropped `StagedEpoch` leaves the live epoch serving untouched).
``update.hint.chain``  (`update.epochs.EpochLog.download_chain`)
    One patch of the downloaded chain is bit-flipped in transit (the log's
    own copy is untouched); the client detects the checksum mismatch at
    decode time and performs one deterministic full re-sync.

Every event is identified by (site, nth invocation), so a plan is exact
under FakeClock virtual time AND under the real clock — fault timing is a
function of the control flow, not of wall time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: Canonical site names (mirrored as literals at each guarded call site).
SITE_SHARD_LOSS = "collectives.row_shard.loss"
SITE_ANSWER_DROP = "serve.answer.drop"
SITE_ANSWER_DELAY = "serve.answer.delay"
SITE_COMMIT_FAIL = "update.commit.stage"
SITE_CHAIN_CORRUPT = "update.hint.chain"

ALL_SITES = (SITE_SHARD_LOSS, SITE_ANSWER_DROP, SITE_ANSWER_DELAY,
             SITE_COMMIT_FAIL, SITE_CHAIN_CORRUPT)


class InjectedCommitFault(RuntimeError):
    """A staged commit failed by injection; the mutation batch is retryable."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire at the `at`-th invocation of `site` (0-based).

    Payload fields are site-specific: ``device``/``down_ticks`` for shard
    loss, ``delay_s`` for answer delays; the rest ignore them.
    """
    site: str
    at: int
    device: int = 0
    down_ticks: int = 0
    delay_s: float = 0.0

    def __post_init__(self):
        assert self.site in ALL_SITES, self.site
        assert self.at >= 0 and self.down_ticks >= 0 and self.delay_s >= 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, enumerable fault schedule (empty = no faults).

    Plans are data: the chaos property tests draw seeded random plans,
    shrink them, and replay them exactly; benches pin literal plans so the
    measured degradation is attributable to a known fault.
    """
    events: tuple[FaultEvent, ...] = ()

    @classmethod
    def none(cls) -> "FaultPlan":
        """The empty plan: every guard is a counter increment, nothing fires."""
        return cls(())

    @classmethod
    def single_shard_loss(cls, *, at_tick: int, device: int,
                          down_ticks: int) -> "FaultPlan":
        """One device lost for a window — the bench's headline scenario."""
        return cls((FaultEvent(SITE_SHARD_LOSS, at=at_tick, device=device,
                               down_ticks=down_ticks),))

    @classmethod
    def random(cls, seed: int, *, n_events: int, horizon: int,
               n_devices: int, max_down_ticks: int = 12,
               max_delay_s: float = 0.02,
               sites: tuple[str, ...] = ALL_SITES) -> "FaultPlan":
        """A seeded random plan: `n_events` faults over `horizon` invocations.

        Deterministic per (seed, shape): the chaos tests sweep seeds and
        assert the same invariants under every drawn schedule.
        """
        rng = np.random.default_rng([seed, 0xFA])
        events = []
        for _ in range(n_events):
            site = sites[int(rng.integers(len(sites)))]
            ev = FaultEvent(
                site, at=int(rng.integers(horizon)),
                device=int(rng.integers(n_devices)),
                down_ticks=int(rng.integers(1, max_down_ticks + 1)),
                delay_s=float(rng.uniform(0.0, max_delay_s)))
            events.append(ev)
        return cls(tuple(events))

    def compile(self) -> "FaultInjector":
        """An injector with fresh invocation counters for this plan."""
        return FaultInjector(self)


class FaultInjector:
    """Counts site invocations and fires the plan's matching events.

    One injector per run: counters are mutable state, so two runs that
    should see identical faults must each `compile()` the plan afresh.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._count: dict[str, int] = {}
        self._by_site: dict[str, dict[int, list[FaultEvent]]] = {}
        for ev in plan.events:
            self._by_site.setdefault(ev.site, {}).setdefault(
                ev.at, []).append(ev)
        self.fired: list[FaultEvent] = []

    def fire(self, site: str) -> list[FaultEvent]:
        """Advance `site`'s invocation counter; return the events due NOW.

        Returns an empty list almost always — the hot-path cost of an armed
        injector is one dict lookup and one integer increment.
        """
        n = self._count.get(site, 0)
        self._count[site] = n + 1
        due = self._by_site.get(site, {}).get(n, [])
        if due:
            self.fired.extend(due)
        return due

    def invocations(self, site: str) -> int:
        """How many times `site` has been guarded so far this run."""
        return self._count.get(site, 0)


#: Compiled empty plan, shareable: it has no per-run counter state that
#: matters (nothing ever fires).
NO_FAULTS = None
