"""Journal-replay re-admission: a lost shard host rejoins the fleet.

The mutation journal is the durable recovery story (`repro.update.journal`):
every committed epoch is an ordered batch of journal records, and
`LiveIndex` commits are DETERMINISTIC — staging the same mutation batch on
the same epoch-e state publishes a bit-identical epoch e+1 (hint, DB,
patch; property-tested in tests/test_fleet.py).  So a host that lost state
(or merely fell behind while its device was down) catches up by replaying
the surviving authority's committed records, epoch by epoch, through its
OWN `LiveIndex.commit` path:

    for (epoch, batch) in epoch_batches(authority_journal, since=me.epoch):
        me.journal.append(*batch); me.commit()   # reproduces epoch exactly

Replaying through the journal (rather than copying arrays) keeps the
recovered host's journal, epoch log and hint-patch chain COMPLETE — after
re-admission it is indistinguishable from a host that never failed, and can
itself become the replay source for the next failure.

Injected commit faults never target replays: `replay_into` disarms the
host's fault hook for the duration (`update.commit.stage` guards foreground
commits; recovery is the path that must not fail).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass(frozen=True)
class ReplayReport:
    """One re-admission's accounting (the `fleet.recovery` observable)."""
    from_epoch: int      # host's epoch before replay
    to_epoch: int        # head epoch reached
    epochs: int          # commits replayed
    mutations: int       # journal records replayed
    wall_s: float        # replay wall-clock (the bench's recovery time)


def epoch_batches(journal, since_epoch: int) -> list[tuple[int, list]]:
    """Committed (epoch, mutation batch) groups after `since_epoch`.

    Groups `journal.committed_records()` by the epoch each record joined,
    in epoch order — exactly the commit batches the authority folded, so
    replaying them reproduces the same epoch boundaries (and therefore the
    same patches) on the recovering host.
    """
    groups: dict[int, list] = {}
    for epoch, mut in journal.committed_records():
        if epoch > since_epoch:
            groups.setdefault(epoch, []).append(mut)
    return [(e, groups[e]) for e in sorted(groups)]


def replay_into(live, batches: list[tuple[int, list]], *,
                obs=None) -> int:
    """Replay epoch batches through `live.commit()`; returns epochs applied.

    Asserts the epoch numbering lines up after every commit — a drifted
    replay would otherwise silently produce a host at the right epoch with
    the wrong state.  The host's fault hook is disarmed for the duration
    (injected commit faults target foreground commits, not recovery).
    """
    n_muts = 0
    faults, live.faults = live.faults, None
    try:
        for epoch, batch in batches:
            assert live.epoch == epoch - 1, (live.epoch, epoch)
            for mut in batch:
                live.journal.append(mut)
            live.commit()
            assert live.epoch == epoch, (live.epoch, epoch)
            n_muts += len(batch)
    finally:
        live.faults = faults
    if obs is not None and batches:
        obs.counter("fleet.replayed_epochs").inc(len(batches))
        obs.counter("fleet.replayed_mutations").inc(n_muts)
    return len(batches)


def readmit(live, source_journal, *, obs=None) -> ReplayReport:
    """Re-admit `live` by replaying `source_journal` past its epoch.

    Returns the `ReplayReport`; after this the host's epoch, hint, DB and
    epoch log match the source's bit-for-bit (commit determinism), so it
    re-enters rotation as a full failover target.
    """
    t0 = time.perf_counter()
    from_epoch = live.epoch
    batches = epoch_batches(source_journal, from_epoch)
    epochs = replay_into(live, batches, obs=obs)
    report = ReplayReport(
        from_epoch=from_epoch, to_epoch=live.epoch, epochs=epochs,
        mutations=sum(len(b) for _, b in batches),
        wall_s=time.perf_counter() - t0)
    if obs is not None:
        obs.counter("fleet.recovery").inc()
    return report
