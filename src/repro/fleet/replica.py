"""Row-shard replica groups: placement, health, failover, failback.

A `ReplicaGroup` holds R complete ranks of the live index (R=2 by default),
each rank's row shards placed on a DISJOINT device set
(`launch.mesh.make_replica_meshes`: rank r of shard s sits on device
r·S + s), so one lost device takes out exactly one rank's shard cell and
never its sibling.  Rank 0 is the home authority: it takes every commit,
and followers trail it by at most ``sync_lag`` epochs, catching up by
replaying the authority's journal (`fleet.recovery`) — deterministic
commits make the follower's state bit-identical to the authority's at every
epoch boundary.

Health is a per-device heartbeat state machine in TICK COUNTS (no clock
reads — fleet timing must not perturb the serve loop's virtual clock):

    healthy ── miss a beat ──▶ suspect ── `heartbeat_timeout` misses ──▶ down
       ▲                                                                  │
       └── recovering ◀── device beats again / journal replay ◀───────────┘

When the authority rank goes DOWN the group FAILS OVER: the lowest
available rank becomes authority at its own (possibly stale, ≤ sync_lag
behind) epoch — answers degrade to bounded staleness instead of erroring,
with the exact epoch gap stamped on every response (`Response.staleness`).
The new authority catches the remaining lag up at ``catchup_per_tick``
epochs per tick and only then accepts fresh commits.  When rank 0's device
returns it is RE-ADMITTED by journal replay (bit-identical to never having
failed) and the group fails back.  If no rank is available the group
reports a total outage and the serve loop queues instead of answering.

`FleetServeLoop` wraps `PipelinedServeLoop` with the group: same batching,
admission and pipelining, plus the per-tick health step, authority
tracking, commit gating during catch-up, and staleness accounting.  With
no faults injected the group never changes state and the response stream
is BIT-IDENTICAL to a plain `PipelinedServeLoop` on the same index
(regression-asserted in tests/test_fleet.py).
"""
from __future__ import annotations

import copy
import dataclasses
from collections import deque

import numpy as np

from repro.distributed import collectives
from repro.fleet import recovery
from repro.fleet.retry import DEFAULT_POLICY
from repro.serve.engine import PipelinedServeLoop
from repro.serve.epochs import ShadowCommitter
from repro.update.live import LiveIndex

#: Health states (registered telemetry enums in repro.obs.scrub).
HEALTHY = "healthy"
SUSPECT = "suspect"
DOWN = "down"
RECOVERING = "recovering"


@dataclasses.dataclass
class ShardHost:
    """One replica rank: a full copy of the live index + its history."""
    rank: int
    live: LiveIndex
    readmissions: int = 0


class ReplicaGroup:
    """R replica ranks over disjoint device rows, with failover/failback.

    ``ranks`` must start bit-identical (same seeded build, or deepcopies);
    ``heartbeat_timeout`` is missed beats before a device counts as DOWN
    (the detection delay), ``sync_lag`` the follower freshness bound, and
    ``catchup_per_tick`` the failover catch-up rate.  Journals model
    durable per-host storage: they survive the host's device being down,
    which is what makes journal-replay recovery possible.
    """

    def __init__(self, ranks: list[LiveIndex], *, n_shards: int = 4,
                 heartbeat_timeout: int = 2, sync_lag: int = 2,
                 catchup_per_tick: int = 1, faults=None, obs=None):
        assert ranks, "a replica group needs at least one rank"
        self.hosts = [ShardHost(rank=r, live=live)
                      for r, live in enumerate(ranks)]
        self.n_replicas = len(ranks)
        self.n_shards = n_shards
        self.n_devices = self.n_replicas * n_shards
        # (R, S) logical device grid: rank-major, matching the disjoint
        # per-rank meshes of launch.mesh.make_replica_meshes
        self.placement = np.arange(self.n_devices).reshape(
            self.n_replicas, n_shards)
        self.heartbeat_timeout = heartbeat_timeout
        self.sync_lag = sync_lag
        self.catchup_per_tick = max(1, catchup_per_tick)
        self.faults = faults
        self.obs = obs
        self.authority_rank = 0
        self.outage = False
        self.ticks = 0
        self.failovers = 0
        self.failbacks = 0
        # failover latency in ticks: last injected loss vs the failover it
        # triggered (benchmarks convert via the measured tick duration)
        self.last_loss_tick = -1
        self.last_failover_tick = -1
        self.replay_reports: list[recovery.ReplayReport] = []
        self._last_beat = {d: 0 for d in range(self.n_devices)}
        self._down_until = {d: 0 for d in range(self.n_devices)}

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, texts, embeddings, *, n_replicas: int = 2,
              n_shards: int = 4, meshes=None, group_kwargs: dict | None = None,
              **build_kwargs) -> "ReplicaGroup":
        """Build rank 0 and replicate it R ways (identical by construction).

        ``meshes`` (from `launch.mesh.make_replica_meshes`) builds each
        rank THROUGH its own disjoint sub-mesh — the 8-fake-device path;
        without meshes, ranks are deepcopies of one seeded build (exactly
        what a deterministic rebuild on another host produces, minus the
        wall-clock).  Remaining kwargs forward to `LiveIndex.build`.
        """
        if meshes is not None:
            assert len(meshes) == n_replicas, (len(meshes), n_replicas)
            ranks = [LiveIndex.build(texts, embeddings, mesh=m,
                                     **build_kwargs) for m in meshes]
        else:
            first = LiveIndex.build(texts, embeddings, **build_kwargs)
            ranks = [first] + [copy.deepcopy(first)
                               for _ in range(n_replicas - 1)]
        return cls(ranks, n_shards=n_shards, **(group_kwargs or {}))

    @classmethod
    def from_live(cls, live: LiveIndex, *, n_replicas: int = 2,
                  **kwargs) -> "ReplicaGroup":
        """Wrap an existing LiveIndex as rank 0, deepcopying the followers."""
        ranks = [live] + [copy.deepcopy(live) for _ in range(n_replicas - 1)]
        return cls(ranks, **kwargs)

    def attach(self, *, obs, faults):
        """Adopt the serve loop's obs handle and arm the fault injector.

        The commit-fail and chain-corruption sites follow the AUTHORITY'S
        live index (foreground commits and client downloads go there);
        follower replays never see injected faults.
        """
        self.obs = obs
        self.faults = faults
        if faults is not None:
            live = self.authority.live
            live.faults = faults
            live.epochs.faults = faults

    # -- introspection -------------------------------------------------------

    @property
    def authority(self) -> ShardHost:
        """The rank currently taking commits and serving answers."""
        return self.hosts[self.authority_rank]

    def head_epoch(self) -> int:
        """The most advanced epoch any rank has published (the fleet head)."""
        return max(h.live.epoch for h in self.hosts)

    @property
    def catching_up(self) -> bool:
        """True while the authority trails the fleet head (post-failover)."""
        return self.authority.live.epoch < self.head_epoch()

    def device_state(self, dev: int) -> str:
        """healthy | suspect | down for one logical device."""
        missed = self.ticks - self._last_beat[dev]
        if missed >= self.heartbeat_timeout:
            return DOWN
        return SUSPECT if missed > 0 else HEALTHY

    def rank_state(self, rank: int) -> str:
        """Aggregate health of one replica rank (worst device + lag)."""
        states = [self.device_state(int(d)) for d in self.placement[rank]]
        if DOWN in states:
            return DOWN
        if SUSPECT in states:
            return SUSPECT
        if self.hosts[rank].live.epoch < self.head_epoch():
            return RECOVERING
        return HEALTHY

    def rank_available(self, rank: int) -> bool:
        """True when every device of `rank`'s row answers heartbeats."""
        return all(self.device_state(int(d)) != DOWN
                   for d in self.placement[rank])

    # -- the health tick -----------------------------------------------------

    def tick(self):
        """One fleet health step: faults → heartbeats → authority → sync.

        Pure counter arithmetic — NO clock reads, spans or instants on the
        un-faulted path, so wrapping a serve loop in a fleet changes
        nothing about its virtual-time behaviour until a fault fires.
        """
        self.ticks += 1
        t = self.ticks
        for dev, down_ticks in collectives.row_shard_health_check(
                self.faults, self.n_devices):
            self._down_until[dev] = max(self._down_until[dev], t + down_ticks)
            self.last_loss_tick = t
            if self.obs is not None:
                self.obs.counter("fleet.shard_loss").inc()
        for dev in range(self.n_devices):
            if self._down_until[dev] <= t:
                self._last_beat[dev] = t

        if not self.rank_available(self.authority_rank):
            target = next((r for r in range(self.n_replicas)
                           if self.rank_available(r)), None)
            if target is None:
                if not self.outage and self.obs is not None:
                    self.obs.counter("fleet.outages").inc()
                self.outage = True
            else:
                self.outage = False
                self._set_authority(target, reason="failover")
        else:
            self.outage = False
            if self.authority_rank != 0 and self.rank_available(0):
                self._readmit(0)
                self._set_authority(0, reason="failback")

        self._catch_up()
        self._sync_followers()

    def _set_authority(self, target: int, *, reason: str):
        """Move the write/serve authority (and the armed fault sites)."""
        old = self.authority.live
        new = self.hosts[target].live
        if self.faults is not None:
            old.faults = None
            old.epochs.faults = None
            new.faults = self.faults
            new.epochs.faults = self.faults
        self.authority_rank = target
        if reason == "failover":
            self.failovers += 1
            self.last_failover_tick = self.ticks
        else:
            self.failbacks += 1
        if self.obs is not None:
            self.obs.counter(f"fleet.{reason}").inc()

    def _readmit(self, rank: int):
        """Journal-replay a returned rank back to the head (fleet.recovery)."""
        host = self.hosts[rank]
        report = recovery.readmit(host.live, self.authority.live.journal,
                                  obs=self.obs)
        host.readmissions += 1
        self.replay_reports.append(report)

    def _catch_up(self):
        """Advance a stale authority toward the head, bounded per tick.

        The replay source is whichever rank holds the longest journal (the
        pre-failover authority's journal survives on durable storage even
        while its device is down).  Serving continues at the authority's
        epoch throughout — bounded staleness, not downtime.
        """
        auth = self.authority.live
        src = max(self.hosts, key=lambda h: h.live.epoch).live
        if auth.epoch >= src.epoch:
            return
        batches = recovery.epoch_batches(src.journal, auth.epoch)
        recovery.replay_into(auth, batches[:self.catchup_per_tick],
                             obs=self.obs)

    def _sync_followers(self):
        """Keep available followers within `sync_lag` of the authority."""
        auth = self.authority.live
        for r, host in enumerate(self.hosts):
            if r == self.authority_rank or not self.rank_available(r):
                continue
            behind = auth.epoch - host.live.epoch
            if behind > self.sync_lag:
                batches = recovery.epoch_batches(auth.journal,
                                                 host.live.epoch)
                recovery.replay_into(host.live,
                                     batches[:behind - self.sync_lag])


class FleetServeLoop(PipelinedServeLoop):
    """The pipelined engine over a replica group: serving that survives.

    Identical batching/admission/pipelining; each tick additionally runs
    the group's health step, follows the authority pointer (rebinding the
    shadow committer on failover/failback), gates commits while the
    authority is catching up, and stamps `Response.staleness` with the
    exact epoch gap when answers are served behind the fleet head.  During
    a total outage the loop queues instead of answering (requests age and
    either shed, fail, or serve after recovery).
    """

    def __init__(self, group: ReplicaGroup, *, depth: int = 2,
                 donate: bool = True, retry=DEFAULT_POLICY, faults=None,
                 **kwargs):
        self._donate = donate
        self._stale_fifo: deque = deque()
        super().__init__(group.authority.live, depth=depth, donate=donate,
                         retry=retry, faults=faults, **kwargs)
        self.group = group
        group.attach(obs=self.obs, faults=faults)

    def _follow_authority(self):
        """Rebind live/system/shadow to the group's current authority."""
        live = self.group.authority.live
        if self.live is not live:
            self.live = live
            self.system = live.system
            self._shadow = ShadowCommitter(live, donate=self._donate)
            live.set_obs(self.obs)

    def _commit_mutations(self):
        # A catching-up (or absent) authority takes no fresh commits:
        # freshness degrades within the staleness bound instead of forking
        # epoch history across ranks.
        if self.group.outage or self.group.catching_up:
            return None
        return super()._commit_mutations()

    def _plan_group(self, system, kind, reqs, kq):
        # Exact staleness is a dispatch-time fact: how far the serving
        # authority trailed the fleet head when this batch was encoded
        # (0 except during failover catch-up).  Batches retire FIFO, so a
        # deque pairs each gap with its `_record` call.
        self._stale_fifo.append(
            self.group.head_epoch() - self.group.authority.live.epoch)
        return super()._plan_group(system, kind, reqs, kq)

    def _record(self, reqs, results, epoch, t_done, timing, staleness=0):
        # Staleness rides INTO the record call (the engine's single append
        # point stamps it on each Response) rather than being patched onto
        # the responses list afterwards: a generation group may defer its
        # append to a later tick, so "the last len(reqs) responses" is not
        # guaranteed to be this batch anymore.
        staleness = self._stale_fifo.popleft() if self._stale_fifo else 0
        if staleness > 0:
            self.obs.counter("fleet.stale_served").inc(len(reqs))
            self.obs.histogram("fleet.staleness",
                               bounds=(1, 2, 4, 8, 16)).record(staleness)
        super()._record(reqs, results, epoch, t_done, timing, staleness)

    def tick(self, force: bool = False) -> int:
        self.group.tick()
        self._follow_authority()
        if self.group.outage:
            # no rank can answer: requests keep queueing (and completed
            # batches keep retiring) until a device returns
            self._tick_no += 1
            self._retire(0)
            return 0
        return super().tick(force)
