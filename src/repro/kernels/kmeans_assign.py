"""Pallas TPU kernel: fused pairwise-distance + argmin (k-means assignment).

The offline clustering hot loop: every Lloyd iteration assigns N embeddings
to K centroids.  Unfused, XLA materializes the (N, K) distance matrix in HBM
(N=5.7M docs × K=4096 f32 = 93 GB per iteration).  This kernel fuses
  d²(x,c) = |x|² − 2·x·c + |c|²   →   running (min, argmin)
so the (bn, bk) score tile lives only in VMEM; HBM traffic is X once per
K-tile sweep + C once — the same blocking logic as the PIR GEMM, reused for
the paper's *other* offline stage.

Grid (i, k): i over N tiles (parallel), k over K tiles (arbitrary,
running-min accumulation in the output refs).  Tie-break: strict `<` keeps
the earliest centroid index, matching jnp.argmin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(x_ref, c_ref, best_d_ref, best_i_ref, *, bk: int):
    k = pl.program_id(1)
    x = x_ref[...]                                  # (bn, d) f32
    c = c_ref[...]                                  # (bk, d) f32
    x2 = jnp.sum(x * x, axis=1, keepdims=True)      # (bn, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]            # (1, bk)
    scores = x2 - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + c2    # (bn, bk)

    local_i = jnp.argmin(scores, axis=1)            # (bn,)
    local_d = jnp.min(scores, axis=1)
    global_i = (k * bk + local_i).astype(jnp.int32)

    @pl.when(k == 0)
    def _init():
        best_d_ref[...] = local_d[:, None]
        best_i_ref[...] = global_i[:, None]

    @pl.when(k > 0)
    def _update():
        prev_d = best_d_ref[..., 0]
        better = local_d < prev_d                   # strict: earliest wins
        best_d_ref[...] = jnp.where(better, local_d, prev_d)[:, None]
        best_i_ref[...] = jnp.where(better, global_i,
                                    best_i_ref[..., 0])[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def kmeans_assign_pallas(x: jax.Array, c: jax.Array, *, bn: int = 256,
                         bk: int = 512, interpret: bool = False):
    """x: (N, d) f32; c: (K, d) f32 → (assign (N,) i32, min_d2 (N,) f32).

    N % bn == 0 and K % bk == 0 (ops.py pads; padded centroids are +inf'd
    by the wrapper so they never win)."""
    n, d = x.shape
    k_total, d2 = c.shape
    assert d == d2 and n % bn == 0 and k_total % bk == 0

    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary"))
        except Exception:  # pragma: no cover
            pass

    best_d, best_i = pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=(n // bn, k_total // bk),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, k: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(x, c)
    return best_i[:, 0], best_d[:, 0]
