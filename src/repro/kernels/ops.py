"""Public jit'd wrappers around the modular-GEMM kernels.

``modmatmul`` is the single entry point used by the PIR protocol (online
answer, offline hint GEMM) and by the Tiptoe-style baseline (private scoring).
It handles shape padding, implementation dispatch and matvec convenience:

  impl="pallas"  — the Pallas TPU kernel (interpret=True off-TPU, for tests)
  impl="xla"     — the exact uint32 XLA matmul (production CPU path; oracle)
  impl="auto"    — pallas on TPU, xla elsewhere
"""
from __future__ import annotations

import collections
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.modmatmul import modmatmul_pallas
# Zero-overhead profiler regions: a no-op context unless
# repro.obs.trace.enable_kernel_annotations(True) is in effect.
from repro.obs.trace import kernel_annotation

U32 = jnp.uint32


#: jit'd oracle: fuses the u8→u32 widening into the GEMM instead of
#: materializing a 4× DB copy per call (measured ~40× on large matvecs).
_modmatmul_ref_jit = jax.jit(ref.modmatmul_ref)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


class _LayoutCache:
    """Identity-keyed memo for device-resident kernel layouts.

    The Pallas path pads (and thereby re-uploads) its whole database operand
    on every call; in the serving hot loop the database is the SAME array
    object tick after tick, so the padded layout is cached and reused until a
    commit swaps the array.  Keys carry ``id()`` plus shape/block, and every
    entry pins the source array(s) so an entry can only be returned while its
    key identity still refers to the array it was built from (a recycled
    ``id()`` after GC can never alias: the pinned source keeps the id alive).
    Bounded FIFO so retired epochs' layouts fall out on their own.  The
    capacity stays small because only the live epoch's layout (plus, on the
    serving path, at most one in-flight predecessor and the transient
    delta-GEMM operands of a commit) can ever hit again — anything older is
    a full-size padded copy pinning dead memory.
    """

    def __init__(self, capacity: int = 4):
        self.capacity = capacity
        self._slots: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple, srcs: tuple, build: Callable[[], jax.Array]
            ) -> jax.Array:
        ent = self._slots.get(key)
        if ent is not None and all(a is b for a, b in zip(ent[0], srcs)):
            self.hits += 1
            self._slots.move_to_end(key)
            return ent[1]
        self.misses += 1
        val = build()
        self._slots[key] = (srcs, val)
        if len(self._slots) > self.capacity:
            self._slots.popitem(last=False)
        return val

    def clear(self):
        self._slots.clear()
        self.hits = 0
        self.misses = 0


_db_pad_cache = _LayoutCache()
_bucket_stack_cache = _LayoutCache()


# ---------------------------------------------------------------------------
# In-place column patching (epoch commits)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_cols_donated(db, cols, new_cols):
    return db.at[:, cols].set(new_cols)


@jax.jit
def _scatter_cols(db, cols, new_cols):
    return db.at[:, cols].set(new_cols)


def scatter_columns(db: jax.Array, cols: jax.Array, new_cols: jax.Array, *,
                    donate: bool = False) -> jax.Array:
    """db with columns ``cols`` replaced by ``new_cols`` (fresh array).

    donate=True donates the input buffer to XLA so the scatter writes the
    touched columns in place instead of copying the whole (m, n) database
    per epoch commit.  The caller must guarantee no OTHER pending Python-side
    use of ``db`` exists (already-dispatched computations are safe — the
    runtime keeps their operand buffers alive); the shadow-epoch committer is
    the only donating caller.
    """
    fn = _scatter_cols_donated if donate else _scatter_cols
    return fn(db, cols, new_cols)


@functools.partial(jax.jit, donate_argnums=(0,))
def _add_into(delta, hint):
    return hint + delta


def add_delta(hint: jax.Array, delta: jax.Array) -> jax.Array:
    """hint + delta (exact mod 2^32) writing into ``delta``'s buffer.

    The hint delta ΔH is transient — it exists only to be folded into the
    hint — so donating IT (never the hint, which client-side snapshots may
    still reference) lets every epoch commit reuse the ΔH allocation for the
    patched hint instead of allocating a third (m, k) u32 array.
    """
    return _add_into(delta, hint)


def modmatmul(db: jax.Array, q: jax.Array, *, impl: str = "auto",
              block: tuple[int, int, int] = (256, 512, 128)) -> jax.Array:
    """Exact (db @ q) mod 2^32.

    db: (m, n) uint8 (entries < plaintext modulus p ≤ 256).
    q:  (n,) or (n, b) uint32.
    Returns uint32 of shape (m,) or (m, b).
    """
    if db.dtype != jnp.uint8:
        raise TypeError(f"db must be uint8, got {db.dtype}")
    if q.dtype != U32:
        raise TypeError(f"q must be uint32, got {q.dtype}")

    was_vec = q.ndim == 1
    q2 = q[:, None] if was_vec else q

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    if impl == "xla":
        with kernel_annotation("pirrag.modmatmul.xla"):
            out = _modmatmul_ref_jit(db, q2)
    elif impl == "pallas":
        bm, bn, bb = block
        m, n = db.shape
        # Hot-loop reuse: the serving DB is the same array object across
        # ticks, so its padded device layout is cached instead of re-padded
        # (and re-uploaded) per call.  Queries change every call — pad inline.
        dbp = _db_pad_cache.get((id(db), db.shape, bm, bn), (db,),
                                lambda: _pad_to(_pad_to(db, 0, bm), 1, bn))
        qp = _pad_to(_pad_to(q2, 0, bn), 1, bb)
        interpret = jax.default_backend() != "tpu"
        with kernel_annotation("pirrag.modmatmul.pallas"):
            out = modmatmul_pallas(dbp, qp, bm=bm, bn=bn, bb=bb,
                                   interpret=interpret)
        out = out[:m, :q2.shape[1]]
    else:
        raise ValueError(f"unknown impl {impl!r}")

    return out[:, 0] if was_vec else out


def hint_gemm(db: jax.Array, a_mat: jax.Array, *, impl: str = "auto",
              block: tuple[int, int, int] = (256, 512, 128)) -> jax.Array:
    """Offline hint H = D · A (mod 2^32); same kernel, many query columns."""
    return modmatmul(db, a_mat, impl=impl, block=block)


def delta_gemm(new_cols: jax.Array, old_cols: jax.Array, a_j: jax.Array, *,
               impl: str = "auto") -> jax.Array:
    """Sparse hint delta ΔH = (new − old)·A_J, exact mod 2^32.

    The live-index hot path (PIRServer.update_columns).  The difference
    ΔD isn't u8-representable (entries ∈ [−255, 255] wrap to u32), so:

      xla    — ONE u32 GEMM on the wrapped difference (ref path accepts
               u32; halves the work vs subtracting two products)
      pallas — two u8 limb GEMMs on the MXU, subtracted afterwards (the
               MXU kernel needs u8 inputs, and on TPU the GEMMs are cheap)

    new_cols/old_cols: (m, J) uint8.  a_j: (J, k) uint32.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        with kernel_annotation("pirrag.delta_gemm.xla"):
            diff = new_cols.astype(U32) - old_cols.astype(U32)
            return ref.modmatmul_ref(diff, a_j)
    with kernel_annotation("pirrag.delta_gemm.pallas"):
        return (modmatmul(new_cols, a_j, impl=impl)
                - modmatmul(old_cols, a_j, impl=impl))


@jax.jit
def _matvec_u32(d: jax.Array, q: jax.Array) -> jax.Array:
    """u8 × u32 2-D product — the one u32 GEMM shape XLA-CPU executes fast.

    q may be (W,) or (W, C); every output column is an exact mod-2^32 dot.
    """
    return jnp.matmul(d.astype(U32), q)


def stack_buckets(dbs: Sequence[jax.Array], n_shards: int = 1,
                  order: Sequence[int] | None = None) -> jax.Array:
    """Zero-pad bucket sub-DBs to a common height and stack: (B', m', W).

    The bucket count pads up to a multiple of ``n_shards`` with all-zero
    buckets (their answers are zero and are never sliced out), so the stack
    divides evenly over a mesh for the sharded batch-PIR path.

    ``order`` — a permutation of the padded bucket axis, e.g. from
    `distributed.collectives.balanced_bucket_order` — reorders the stack so
    skewed bucket heights pack evenly across devices.  Callers must route
    queries and answers through the same permutation (queries reorder, the
    answer slices index via the inverse); every bucket's GEMM is complete
    on its own leading-axis slice, so the reordered layout is bit-identical
    to the sequential one.
    """
    m_pad = max(d.shape[0] for d in dbs)
    b_pad = (-len(dbs)) % n_shards
    padded = [jnp.pad(d, ((0, m_pad - d.shape[0]), (0, 0))) for d in dbs]
    if b_pad:
        zero = jnp.zeros((m_pad, dbs[0].shape[1]), jnp.uint8)
        padded += [zero] * b_pad
    if order is not None:
        assert len(order) == len(padded), (len(order), len(padded))
        padded = [padded[int(b)] for b in order]
    return jnp.stack(padded)


def bucketed_modmatmul_sharded(stack: jax.Array, qs: jax.Array, mesh,
                               mesh_axes: tuple[str, ...]) -> jax.Array:
    """Bucket-sharded batch-PIR GEMM: buckets spread across the mesh.

    stack: (B', m', W) uint8 from `stack_buckets` (B' a multiple of the
    mesh's shard count); qs: (B', W, C) uint32.  Both shard on the bucket
    axis — each device answers its own whole buckets, zero collectives —
    and the result (B', m', C) uint32 is bit-identical to the per-bucket
    loop (exact mod-2^32 arithmetic either way).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import collectives
    spec = NamedSharding(mesh, P(tuple(mesh_axes), None, None))
    fn = collectives.bucket_shard_gemm(mesh, tuple(mesh_axes))
    return fn(jax.device_put(stack, spec), jax.device_put(qs, spec))


def bucketed_modmatmul(dbs: Sequence[jax.Array], qs: jax.Array, *,
                       impl: str = "auto",
                       block: tuple[int, int, int] = (256, 512, 128)
                       ) -> list[jax.Array]:
    """Per-bucket exact (D_b @ q_b) mod 2^32 — the batch-PIR server op.

    dbs: B uint8 sub-DBs (m_b, W) sharing one padded width W (rows may
         differ per bucket: each bucket is row-truncated to its tallest
         member cluster).
    qs:  (B, W) or (B, W, C) uint32 — one query (or C stacked client
         queries) per bucket.
    Returns a list of B uint32 arrays, (m_b,) or (m_b, C).

    This is ONE public entry point, not B ad-hoc dispatches, but the two
    implementations deliberately diverge in execution shape:

      pallas — buckets are row-padded to a shared height, stacked, and the
               limb-decomposed MXU kernel is vmapped over the bucket axis:
               one fused dispatch whose grid covers every bucket (the
               MXU-shaped form the TPU wants).  The stacked layout is
               cached on the sub-DB identities, so hot-loop serving calls
               skip the restack until a commit swaps a bucket.
      xla    — a loop of 2-D (m_b, W) @ (W, C) products.  Measured on CPU,
               XLA's 2-D u32 matmul is ~15× faster per MAC than any 3-D
               batched dot_general form (which lowers to a naive loop
               nest), so the "one big dispatch" shape would be a large
               pessimization here — but all C client columns of a bucket
               DO share one 2-D call (bitwise equal to per-column matvecs:
               each output column is the same exact mod-2^32 dot).  The
               loop reuses one traced callee, so compile cost is O(1) in B.
    """
    if qs.dtype != U32:
        raise TypeError(f"qs must be uint32, got {qs.dtype}")
    n_b = len(dbs)
    if qs.shape[0] != n_b:
        raise ValueError(f"{n_b} buckets but qs has leading dim {qs.shape[0]}")
    was_vec = qs.ndim == 2
    q3 = qs[:, :, None] if was_vec else qs
    width = q3.shape[1]
    for d in dbs:
        if d.dtype != jnp.uint8:
            raise TypeError(f"bucket sub-DBs must be uint8, got {d.dtype}")
        if d.shape[1] != width:
            raise ValueError(f"bucket width {d.shape[1]} != query width {width}")

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    if impl == "xla":
        # one (m_b, W) @ (W, C) call per bucket — C stacked client columns
        # share the dispatch, each output column the same exact u32 dot as
        # the old per-column matvec loop (parity-tested bitwise)
        with kernel_annotation("pirrag.bucketed_modmatmul.xla"):
            out = [_matvec_u32(d, q3[b]) for b, d in enumerate(dbs)]
    elif impl == "pallas":
        bm, bn, bb = block
        m_pad = max(d.shape[0] for d in dbs)
        m_pad += (-m_pad) % bm
        stack = _bucket_stack_cache.get(
            (tuple(id(d) for d in dbs),
             tuple(d.shape for d in dbs), bm, bn),
            tuple(dbs),
            lambda: jnp.stack([_pad_to(jnp.pad(d, ((0, m_pad - d.shape[0]),
                                                   (0, 0))), 1, bn)
                               for d in dbs]))
        qp = _pad_to(_pad_to(q3, 1, bn), 2, bb)
        interpret = jax.default_backend() != "tpu"
        with kernel_annotation("pirrag.bucketed_modmatmul.pallas"):
            full = jax.vmap(lambda d, q: modmatmul_pallas(
                d, q, bm=bm, bn=bn, bb=bb, interpret=interpret))(stack, qp)
        out = [full[b, :d.shape[0], :q3.shape[2]] for b, d in enumerate(dbs)]
    else:
        raise ValueError(f"unknown impl {impl!r}")

    return [o[:, 0] for o in out] if was_vec else out


def kmeans_assign(x: jax.Array, c: jax.Array, *, impl: str = "auto",
                  block: tuple[int, int] = (256, 512)):
    """Fused nearest-centroid assignment: (assign (N,) i32, min_d2 (N,)).

    x: (N, d) f32 points; c: (k, d) f32 centroids.  impl="pallas" fuses
    distance + argmin on the MXU without materializing the (N, k) distance
    matrix in HBM; "xla" is the unfused oracle (identical results).

    This is the assignment kernel of the offline build's K-means: the
    block-canonical Lloyd core (`core.clustering._block_stats`) calls it
    per corpus block, both on the host path and inside the `shard_map`'d
    sharded build (`collectives.corpus_shard_kmeans` /
    `row_shard_assign`), so the same fused kernel serves every layout —
    one call sees only its (rows_local/blocks, d) slice either way.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        with kernel_annotation("pirrag.kmeans_assign.xla"):
            return ref.kmeans_assign_ref(x, c)
    from repro.kernels.kmeans_assign import kmeans_assign_pallas
    bn, bk = block
    n, k = x.shape[0], c.shape[0]
    xp = _pad_to(x, 0, bn)
    cp = _pad_to(c, 0, bk)
    if cp.shape[0] != k:
        # padded centroids must never win the argmin
        pad = cp.shape[0] - k
        cp = cp.at[k:].set(jnp.full((pad, c.shape[1]), 1e30, c.dtype))
    interpret = jax.default_backend() != "tpu"
    with kernel_annotation("pirrag.kmeans_assign.pallas"):
        assign, d2 = kmeans_assign_pallas(xp, cp, bn=bn, bk=bk,
                                          interpret=interpret)
    return assign[:n], d2[:n]
