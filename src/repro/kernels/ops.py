"""Public jit'd wrappers around the modular-GEMM kernels.

``modmatmul`` is the single entry point used by the PIR protocol (online
answer, offline hint GEMM) and by the Tiptoe-style baseline (private scoring).
It handles shape padding, implementation dispatch and matvec convenience:

  impl="pallas"  — the Pallas TPU kernel (interpret=True off-TPU, for tests)
  impl="xla"     — the exact uint32 XLA matmul (production CPU path; oracle)
  impl="auto"    — pallas on TPU, xla elsewhere
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.modmatmul import modmatmul_pallas

U32 = jnp.uint32


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def modmatmul(db: jax.Array, q: jax.Array, *, impl: str = "auto",
              block: tuple[int, int, int] = (256, 512, 128)) -> jax.Array:
    """Exact (db @ q) mod 2^32.

    db: (m, n) uint8 (entries < plaintext modulus p ≤ 256).
    q:  (n,) or (n, b) uint32.
    Returns uint32 of shape (m,) or (m, b).
    """
    if db.dtype != jnp.uint8:
        raise TypeError(f"db must be uint8, got {db.dtype}")
    if q.dtype != U32:
        raise TypeError(f"q must be uint32, got {q.dtype}")

    was_vec = q.ndim == 1
    q2 = q[:, None] if was_vec else q

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    if impl == "xla":
        out = ref.modmatmul_ref(db, q2)
    elif impl == "pallas":
        bm, bn, bb = block
        m, n = db.shape
        dbp = _pad_to(_pad_to(db, 0, bm), 1, bn)
        qp = _pad_to(_pad_to(q2, 0, bn), 1, bb)
        interpret = jax.default_backend() != "tpu"
        out = modmatmul_pallas(dbp, qp, bm=bm, bn=bn, bb=bb,
                               interpret=interpret)
        out = out[:m, :q2.shape[1]]
    else:
        raise ValueError(f"unknown impl {impl!r}")

    return out[:, 0] if was_vec else out


def hint_gemm(db: jax.Array, a_mat: jax.Array, *, impl: str = "auto",
              block: tuple[int, int, int] = (256, 512, 128)) -> jax.Array:
    """Offline hint H = D · A (mod 2^32); same kernel, many query columns."""
    return modmatmul(db, a_mat, impl=impl, block=block)


def delta_gemm(new_cols: jax.Array, old_cols: jax.Array, a_j: jax.Array, *,
               impl: str = "auto") -> jax.Array:
    """Sparse hint delta ΔH = (new − old)·A_J, exact mod 2^32.

    The live-index hot path (PIRServer.update_columns).  The difference
    ΔD isn't u8-representable (entries ∈ [−255, 255] wrap to u32), so:

      xla    — ONE u32 GEMM on the wrapped difference (ref path accepts
               u32; halves the work vs subtracting two products)
      pallas — two u8 limb GEMMs on the MXU, subtracted afterwards (the
               MXU kernel needs u8 inputs, and on TPU the GEMMs are cheap)

    new_cols/old_cols: (m, J) uint8.  a_j: (J, k) uint32.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        diff = new_cols.astype(U32) - old_cols.astype(U32)
        return ref.modmatmul_ref(diff, a_j)
    return (modmatmul(new_cols, a_j, impl=impl)
            - modmatmul(old_cols, a_j, impl=impl))


def kmeans_assign(x: jax.Array, c: jax.Array, *, impl: str = "auto",
                  block: tuple[int, int] = (256, 512)):
    """Fused nearest-centroid assignment: (assign (N,) i32, min_d2 (N,))."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return ref.kmeans_assign_ref(x, c)
    from repro.kernels.kmeans_assign import kmeans_assign_pallas
    bn, bk = block
    n, k = x.shape[0], c.shape[0]
    xp = _pad_to(x, 0, bn)
    cp = _pad_to(c, 0, bk)
    if cp.shape[0] != k:
        # padded centroids must never win the argmin
        pad = cp.shape[0] - k
        cp = cp.at[k:].set(jnp.full((pad, c.shape[1]), 1e30, c.dtype))
    interpret = jax.default_backend() != "tpu"
    assign, d2 = kmeans_assign_pallas(xp, cp, bn=bn, bk=bk,
                                      interpret=interpret)
    return assign[:n], d2[:n]
