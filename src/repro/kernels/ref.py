"""Pure-jnp oracles for the modular-GEMM kernels.

XLA integer arithmetic is modular (wraparound), so a plain uint32 matmul *is*
the exact mod-2^32 product — verified bitwise against uint64 numpy in tests.
These oracles are also the production CPU path (`impl="xla"` in ops.py).
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32


def modmatmul_ref(db: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact (db @ q) mod 2^32.

    db: (m, n) unsigned integer (u8/u16/u32) plaintext database.
    q:  (n,) or (n, b) uint32 ciphertext queries.
    returns uint32 (m,) or (m, b).
    """
    return jnp.matmul(db.astype(U32), q.astype(U32))


def modmatvec_ref(db: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    return modmatmul_ref(db, q)


def kmeans_assign_ref(x: jnp.ndarray, c: jnp.ndarray):
    """Unfused oracle for the k-means assignment kernel."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d2 = x2 - 2.0 * (x @ c.T) + c2
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
