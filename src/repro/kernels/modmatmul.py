"""Pallas TPU kernel: exact mod-2^32 GEMM between a u8 database and u32 queries.

This is the PIR-RAG server hot loop (`ans = D · qu mod 2^32`) and, with more
query columns, the offline hint GEMM (`H = D · A`).  The TPU has no scalar
u32 multiply path worth using — the MXU is an int8×int8→int32 systolic array —
so we adapt the computation instead of porting it:

  * The DB entry fits one 8-bit limb (plaintext modulus p ≤ 256).
  * Each u32 query word is split into 4 × 8-bit limbs:
        qu = Σ_l limb_l · 2^(8l)
    ⇒   D·qu mod 2^32 = Σ_l (D · limb_l) << 8l        (mod 2^32)
  * int32 accumulator overflow *wraps*, which is exactly mod-2^32 arithmetic —
    bits ≥ 32 are discarded by definition, so no carry tracking is needed.
  * Unsigned 8-bit limbs exceed int8 range; the MXU path is kept via the
    zero-point identity with X_u = X_s + 128·J, Y_u = Y_s + 128·J:
        X_u @ Y_u = X_s@Y_s + 128·rowsum(X_s)⊕ + 128·colsum(Y_s)⊕ + 128²·n
    where the rank-1 corrections are cheap VPU work.
  * The 4 limb GEMMs are fused into ONE MXU call by stacking limbs along the
    output-column axis: (bm,bn)@(bn,4·bb), then combined with shifts.

Blocking: D streams HBM→VMEM in (bm, bn) u8 tiles; queries are small and
VMEM-resident per (j,k) block; the u32 accumulator is the output block itself,
revisited across the contraction grid axis.  Default tile (256, 512, 128) ⇒
~1.2 MiB VMEM working set, MXU-aligned (multiples of 32×128 int8 tiling).

Arithmetic intensity of the online op is 4·b int8-MACs per DB byte: HBM-bound
for small query batches (SimplePIR's "PIR at memory bandwidth" reappears on
TPU), compute-bound for b ≳ 60.

Validated bitwise (integer exact, not allclose) against ref.modmatmul_ref in
interpret mode — see tests/test_kernels_modmatmul.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are an optional nicety; interpret mode ignores them
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

U32 = jnp.uint32
I32 = jnp.int32
I8 = jnp.int8
N_LIMBS = 4
_ZP = 128  # zero point for u8 → i8


def _kernel(d_ref, q_ref, o_ref, *, bn: int):
    """Grid (i, j, k) = (m-tile, b-tile, n-tile); k is the contraction axis."""
    k = pl.program_id(2)

    # ---- load & center the DB tile: u8 → i8 around zero-point 128 ----------
    d_u = d_ref[...].astype(I32)                      # (bm, bn) in [0, 256)
    d_s = (d_u - _ZP).astype(I8)                      # [-128, 128)

    # ---- split the u32 query tile into 4 stacked 8-bit limbs ---------------
    q_u32 = q_ref[...]                                # (bn, bb) u32
    bb = q_u32.shape[1]
    limbs = [((q_u32 >> jnp.uint32(8 * l)) & jnp.uint32(0xFF)).astype(I32)
             for l in range(N_LIMBS)]
    q_u = jnp.concatenate(limbs, axis=1)              # (bn, 4*bb) in [0,256)
    q_s = (q_u - _ZP).astype(I8)

    # ---- one MXU int8 GEMM for all four limbs -------------------------------
    prod = jax.lax.dot_general(
        d_s, q_s, (((1,), (0,)), ((), ())), preferred_element_type=I32)

    # ---- zero-point corrections (rank-1, VPU) --------------------------------
    rs_d = jnp.sum(d_s.astype(I32), axis=1, keepdims=True)     # (bm, 1)
    cs_q = jnp.sum(q_s.astype(I32), axis=0, keepdims=True)     # (1, 4*bb)
    full = prod + _ZP * (rs_d + cs_q) + (_ZP * _ZP) * bn       # int32, wraps ok

    # ---- recombine limbs with shifts, mod 2^32 -------------------------------
    full = full.astype(U32).reshape(full.shape[0], N_LIMBS, bb)
    acc = full[:, 0, :]
    for l in range(1, N_LIMBS):
        acc = acc + (full[:, l, :] << jnp.uint32(8 * l))

    # ---- accumulate over contraction grid axis ------------------------------
    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = o_ref[...] + acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bb", "interpret"))
def modmatmul_pallas(db: jax.Array, q: jax.Array, *, bm: int = 256,
                     bn: int = 512, bb: int = 128,
                     interpret: bool = False) -> jax.Array:
    """(db @ q) mod 2^32 via the limb-decomposed MXU kernel.

    db: (m, n) uint8 — m, n must be multiples of (bm, bn) (ops.py pads).
    q:  (n, b) uint32 — b must be a multiple of bb.
    returns (m, b) uint32, bitwise equal to ref.modmatmul_ref.
    """
    m, n = db.shape
    n2, b = q.shape
    assert n == n2, (db.shape, q.shape)
    assert m % bm == 0 and n % bn == 0 and b % bb == 0, (db.shape, q.shape,
                                                         (bm, bn, bb))
    grid = (m // bm, b // bb, n // bn)

    kwargs = {}
    if pltpu is not None and not interpret:
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:  # pragma: no cover - older API name
            pass

    return pl.pallas_call(
        functools.partial(_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bb), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, b), U32),
        interpret=interpret,
        **kwargs,
    )(db, q)
