"""Minimal pure-JAX NN substrate (no flax): params are nested dicts.

Every init_* has a sibling *_axes helper producing the same-structure tree of
logical dimension names used by distributed/sharding.py to derive
PartitionSpecs — parameters never embed device placement themselves.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, *, compute_dtype=jnp.bfloat16):
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, ids, *, compute_dtype=jnp.bfloat16):
    # cast BEFORE the gather: the sharded lookup's mask+psum intermediates
    # (and their backward scatter-add) then move bf16, not f32 — measured
    # 2× on the dominant activation buffers at train_4k scale
    return jnp.take(p["table"].astype(compute_dtype), ids, axis=0)


def mlp_init(key, sizes: list[int], *, bias: bool = True, dtype=jnp.float32):
    """Plain ReLU MLP used by the recsys/gnn heads."""
    keys = jax.random.split(key, len(sizes) - 1)
    return {f"l{i}": dense_init(k, sizes[i], sizes[i + 1], bias=bias,
                                dtype=dtype)
            for i, k in enumerate(keys)}


def mlp(p, x, *, act=jax.nn.relu, final_act: bool = False,
        compute_dtype=jnp.bfloat16):
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x, compute_dtype=compute_dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def mlp_axes(sizes: list[int], *, bias: bool = True, row: str | None = None,
             col: str | None = None):
    out = {}
    for i in range(len(sizes) - 1):
        # never shard narrow dims (e.g. a final logit column of width 1)
        c = col if sizes[i + 1] >= 128 else None
        r = row if sizes[i] >= 128 else None
        ax = {"w": (r, c)}
        if bias:
            ax["b"] = (c,)
        out[f"l{i}"] = ax
    return out


def softplus_shifted(x):
    """SchNet's shifted softplus: ln(0.5 e^x + 0.5)."""
    return jax.nn.softplus(x) - math.log(2.0)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))
