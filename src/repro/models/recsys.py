"""RecSys architectures: DLRM (dot), DCN-v2 (cross), xDeepFM (CIN), MIND
(multi-interest capsules).

All four share the stacked embedding-table substrate (models/embedding.py:
jnp.take + segment ops — JAX's EmbeddingBag).  Tables are the dominant state
(n_fields × 10⁶ rows) and row-shard over the "model" mesh axis.

``retrieval_score`` implements the `retrieval_cand` shape: score ONE user
context against 10⁶ candidate items as a batched computation over the
candidate axis (no loop) — for MIND this is the two-tower max-over-interests
dot; for the ranking models the item field varies while user features
broadcast.  This is also where PIR-RAG composes with recsys: candidate
embeddings can be clustered and privately fetched (examples/private_recsys.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import embedding, nn


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str                        # dlrm | dcn | xdeepfm | mind
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_per_field: int = 1_000_000
    bot_mlp: tuple = ()              # dlrm (includes input width)
    top_mlp: tuple = ()              # dlrm/dcn (excludes input width)
    n_cross_layers: int = 0          # dcn
    cin_layers: tuple = ()           # xdeepfm feature-map widths
    dnn_mlp: tuple = ()              # xdeepfm deep branch (excludes input)
    n_interests: int = 0             # mind
    capsule_iters: int = 0
    hist_len: int = 50
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16


# ---------------------------------------------------------------------------
# Init / axes
# ---------------------------------------------------------------------------

def init(key, cfg: RecSysConfig):
    ke, k1, k2, k3, k4 = jax.random.split(key, 5)
    d, F = cfg.embed_dim, cfg.n_sparse
    p: dict = {"emb": embedding.table_init(ke, F, cfg.vocab_per_field, d,
                                           cfg.param_dtype)}
    if cfg.kind == "dlrm":
        p["bot"] = nn.mlp_init(k1, list(cfg.bot_mlp), dtype=cfg.param_dtype)
        n_int = (F + 1) * F // 2 + F + 1        # pairwise dots incl. self grp
        n_feat = cfg.bot_mlp[-1] + (F + 1) * F // 2
        p["top"] = nn.mlp_init(k2, [n_feat] + list(cfg.top_mlp),
                               dtype=cfg.param_dtype)
    elif cfg.kind == "dcn":
        d_in = cfg.n_dense + F * d
        p["cross"] = {
            f"c{i}": {"w": nn.dense_init(jax.random.fold_in(k1, i), d_in,
                                         d_in, bias=True,
                                         dtype=cfg.param_dtype)}
            for i in range(cfg.n_cross_layers)}
        p["deep"] = nn.mlp_init(k2, [d_in] + list(cfg.top_mlp),
                                dtype=cfg.param_dtype)
        p["final"] = nn.dense_init(k3, d_in + cfg.top_mlp[-1], 1, bias=True,
                                   dtype=cfg.param_dtype)
    elif cfg.kind == "xdeepfm":
        hs = [F] + list(cfg.cin_layers)
        p["cin"] = {f"w{i}": (jax.random.normal(
            jax.random.fold_in(k1, i), (hs[i + 1], hs[i], F))
            * (1.0 / jnp.sqrt(hs[i] * F))).astype(cfg.param_dtype)
            for i in range(len(cfg.cin_layers))}
        p["cin_out"] = nn.dense_init(k2, sum(cfg.cin_layers), 1, bias=True,
                                     dtype=cfg.param_dtype)
        p["dnn"] = nn.mlp_init(k3, [F * d] + list(cfg.dnn_mlp) + [1],
                               dtype=cfg.param_dtype)
        p["linear"] = embedding.table_init(k4, F, cfg.vocab_per_field, 1,
                                           cfg.param_dtype)
    elif cfg.kind == "mind":
        p["bilinear"] = nn.dense_init(k1, d, d, dtype=cfg.param_dtype)
    else:
        raise ValueError(cfg.kind)
    return p


def param_axes(cfg: RecSysConfig):
    ax: dict = {"emb": embedding.table_axes()}
    if cfg.kind == "dlrm":
        ax["bot"] = nn.mlp_axes(list(cfg.bot_mlp))
        ax["top"] = nn.mlp_axes([0] + list(cfg.top_mlp))
    elif cfg.kind == "dcn":
        ax["cross"] = {f"c{i}": {"w": {"w": (None, None), "b": (None,)}}
                       for i in range(cfg.n_cross_layers)}
        ax["deep"] = nn.mlp_axes([0] + list(cfg.top_mlp), col="d_ff")
        ax["final"] = {"w": (None, None), "b": (None,)}
    elif cfg.kind == "xdeepfm":
        ax["cin"] = {f"w{i}": (None, None, None)
                     for i in range(len(cfg.cin_layers))}
        ax["cin_out"] = {"w": (None, None), "b": (None,)}
        ax["dnn"] = nn.mlp_axes([0] + list(cfg.dnn_mlp) + [1], col="d_ff")
        ax["linear"] = embedding.table_axes()
    elif cfg.kind == "mind":
        ax["bilinear"] = {"w": (None, None)}
    return ax


# ---------------------------------------------------------------------------
# Forward passes (ranking models → logit (B,))
# ---------------------------------------------------------------------------

def _dlrm_forward(p, dense_x, sparse_idx, cfg):
    cd = cfg.compute_dtype
    bot = nn.mlp(p["bot"], dense_x.astype(cd), final_act=True,
                 compute_dtype=cd)                          # (B, d)
    emb = embedding.field_lookup(p["emb"], sparse_idx, cfg.vocab_per_field,
                                 compute_dtype=cd)          # (B, F, d)
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)     # (B, F+1, d)
    sims = jnp.einsum("bfd,bgd->bfg", z, z)                 # (B, F+1, F+1)
    iu, ju = jnp.triu_indices(z.shape[1], k=1)
    tri = sims[:, iu, ju]                                   # (B, (F+1)F/2)
    feat = jnp.concatenate([bot, tri], axis=1)
    return nn.mlp(p["top"], feat, compute_dtype=cd)[:, 0]


def _dcn_forward(p, dense_x, sparse_idx, cfg):
    cd = cfg.compute_dtype
    emb = embedding.field_lookup(p["emb"], sparse_idx, cfg.vocab_per_field,
                                 compute_dtype=cd)
    x0 = jnp.concatenate([dense_x.astype(cd),
                          emb.reshape(emb.shape[0], -1)], axis=1)
    x = x0
    for i in range(cfg.n_cross_layers):
        w = p["cross"][f"c{i}"]["w"]
        x = x0 * nn.dense(w, x, compute_dtype=cd) + x       # DCN-v2 full-rank
    deep = nn.mlp(p["deep"], x0, final_act=True, compute_dtype=cd)
    out = nn.dense(p["final"], jnp.concatenate([x, deep], axis=1),
                   compute_dtype=cd)
    return out[:, 0]


def _xdeepfm_forward(p, dense_x, sparse_idx, cfg):
    del dense_x
    cd = cfg.compute_dtype
    x0 = embedding.field_lookup(p["emb"], sparse_idx, cfg.vocab_per_field,
                                compute_dtype=cd)           # (B, F, d)
    xk = x0
    pools = []
    for i in range(len(cfg.cin_layers)):
        z = jnp.einsum("bid,bjd->bijd", xk, x0)             # outer product
        xk = jnp.einsum("bijd,hij->bhd", z,
                        p["cin"][f"w{i}"].astype(cd))       # compress
        pools.append(jnp.sum(xk, axis=-1))                  # (B, H_i)
    cin_logit = nn.dense(p["cin_out"], jnp.concatenate(pools, axis=1),
                         compute_dtype=cd)[:, 0]
    dnn_logit = nn.mlp(p["dnn"], x0.reshape(x0.shape[0], -1),
                       compute_dtype=cd)[:, 0]
    lin = embedding.field_lookup(p["linear"], sparse_idx,
                                 cfg.vocab_per_field, compute_dtype=cd)
    return cin_logit + dnn_logit + jnp.sum(lin[..., 0], axis=1)


def _squash(x, axis=-1):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(p, hist_idx, hist_mask, cfg: RecSysConfig):
    """B2I dynamic routing: history (B, L) → interest capsules (B, K, d)."""
    cd = cfg.compute_dtype
    e = jnp.take(p["emb"]["table"], hist_idx, axis=0).astype(cd)  # (B, L, d)
    eS = nn.dense(p["bilinear"], e, compute_dtype=cd)             # (B, L, d)
    m = hist_mask.astype(jnp.float32)
    B, L, d = e.shape
    b = jnp.zeros((B, L, cfg.n_interests), jnp.float32)
    u = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b, axis=-1) * m[..., None]
        z = jnp.einsum("blk,bld->bkd", w.astype(cd), eS)
        u = _squash(z.astype(jnp.float32))
        b = b + jnp.einsum("bkd,bld->blk", u,
                           eS.astype(jnp.float32))
    return u                                                # (B, K, d) f32


def _mind_train_scores(p, batch, cfg):
    u = mind_interests(p, batch["hist"], batch["hist_mask"], cfg)
    tgt = jnp.take(p["emb"]["table"], batch["target"], axis=0)    # (B, d)
    att = jax.nn.softmax(
        jnp.einsum("bkd,bd->bk", u, tgt.astype(jnp.float32)) * 2.0, axis=-1)
    user = jnp.einsum("bk,bkd->bd", att, u)                 # label-aware
    return user @ tgt.astype(jnp.float32).T                 # (B, B) in-batch


# ---------------------------------------------------------------------------
# Uniform entry points
# ---------------------------------------------------------------------------

_FWD = {"dlrm": _dlrm_forward, "dcn": _dcn_forward, "xdeepfm": _xdeepfm_forward}


def forward(params, batch, cfg: RecSysConfig):
    if cfg.kind == "mind":
        raise ValueError("mind uses mind_interests / loss directly")
    return _FWD[cfg.kind](params, batch.get("dense"), batch["sparse"], cfg)


def loss(params, batch, cfg: RecSysConfig):
    """BCE for ranking models; in-batch sampled softmax for MIND."""
    if cfg.kind == "mind":
        scores = _mind_train_scores(params, batch, cfg)     # (B, B)
        labels = jnp.arange(scores.shape[0])
        logz = jax.nn.logsumexp(scores, axis=-1)
        gold = jnp.take_along_axis(scores, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)
    logit = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jax.nn.softplus(logit) - y * logit)     # stable BCE


def serve(params, batch, cfg: RecSysConfig):
    """Online/bulk scoring → probability (B,)."""
    if cfg.kind == "mind":
        u = mind_interests(params, batch["hist"], batch["hist_mask"], cfg)
        tgt = jnp.take(params["emb"]["table"], batch["target"], axis=0)
        return jnp.max(jnp.einsum("bkd,bd->bk", u,
                                  tgt.astype(jnp.float32)), axis=-1)
    return jax.nn.sigmoid(forward(params, batch, cfg).astype(jnp.float32))


def retrieval_score(params, user_batch, candidate_ids, cfg: RecSysConfig):
    """One user context × NC candidates → (NC,) scores, fully batched."""
    cd = cfg.compute_dtype
    nc = candidate_ids.shape[0]
    if cfg.kind == "mind":
        u = mind_interests(params, user_batch["hist"],
                           user_batch["hist_mask"], cfg)    # (1, K, d)
        cand = jnp.take(params["emb"]["table"], candidate_ids, axis=0)
        cand = logical(cand, "candidates", None)
        s = jnp.einsum("kd,nd->nk", u[0].astype(cd), cand.astype(cd))
        return jnp.max(s, axis=-1).astype(jnp.float32)
    # ranking models: item field = field 0 varies, user context broadcasts
    sparse = jnp.broadcast_to(user_batch["sparse"], (nc, cfg.n_sparse))
    sparse = sparse.at[:, 0].set(candidate_ids)
    sparse = logical(sparse, "candidates", None)
    dense = (jnp.broadcast_to(user_batch["dense"], (nc, cfg.n_dense))
             if cfg.n_dense else None)
    return _FWD[cfg.kind](params, dense, sparse, cfg).astype(jnp.float32)
