"""SchNet (Schütt et al., arXiv:1706.08566): continuous-filter convolutions.

Two input regimes cover the four assigned shapes:
  * "molecule": batched small molecules — atom numbers + 3D positions; dense
    all-pairs cfconv within the cutoff; energy regression (sum over atoms).
  * "graph": generic graphs (citation/products) — node features + an edge
    list with a per-edge scalar playing the distance role; message passing is
    ``gather → filter-modulate → segment_sum`` (the JAX-native SpMM-equivalent
    — BCOO has no role here); node classification head.

The paper's PIR technique is inapplicable to message passing (see DESIGN.md
§Arch-applicability); SchNet runs *without* it but with full dry-run coverage.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    mode: str = "graph"              # "graph" | "molecule"
    d_feat: int = 0                  # graph mode input feature width
    n_out: int = 1                   # classes (graph) / energy dim (molecule)
    n_species: int = 100
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16


def rbf_expand(d: jax.Array, cfg: SchNetConfig) -> jax.Array:
    """Gaussian radial basis on [0, cutoff], γ from center spacing."""
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf, dtype=jnp.float32)
    gamma = 1.0 / (mu[1] - mu[0]) ** 2
    x = d.astype(jnp.float32)[..., None] - mu
    return jnp.exp(-gamma * x * x).astype(cfg.compute_dtype)


def cosine_cutoff(d: jax.Array, cutoff: float) -> jax.Array:
    c = 0.5 * (jnp.cos(jnp.pi * d / cutoff) + 1.0)
    return jnp.where(d < cutoff, c, 0.0)


def init(key, cfg: SchNetConfig):
    k_in, k_int, k_head = jax.random.split(key, 3)
    d = cfg.d_hidden
    if cfg.mode == "molecule":
        inp = nn.embed_init(k_in, cfg.n_species, d, cfg.param_dtype)
    else:
        inp = nn.dense_init(k_in, cfg.d_feat, d, bias=True,
                            dtype=cfg.param_dtype)
    inters = {}
    for t in range(cfg.n_interactions):
        k = jax.random.fold_in(k_int, t)
        k1, k2, k3, k4 = jax.random.split(k, 4)
        inters[f"int{t}"] = {
            "w_atom": nn.dense_init(k1, d, d, dtype=cfg.param_dtype),
            "filter": nn.mlp_init(k2, [cfg.n_rbf, d, d],
                                  dtype=cfg.param_dtype),
            "out1": nn.dense_init(k3, d, d, bias=True, dtype=cfg.param_dtype),
            "out2": nn.dense_init(k4, d, d, bias=True, dtype=cfg.param_dtype),
        }
    head = nn.mlp_init(k_head, [d, d // 2, cfg.n_out], dtype=cfg.param_dtype)
    return {"input": inp, "interactions": inters, "head": head}


def param_axes(cfg: SchNetConfig):
    def dax(bias=False):
        return {"w": (None, None), **({"b": (None,)} if bias else {})}
    inter = {
        "w_atom": dax(), "filter": nn.mlp_axes([cfg.n_rbf, cfg.d_hidden,
                                                cfg.d_hidden]),
        "out1": dax(True), "out2": dax(True),
    }
    return {
        "input": ({"table": (None, None)} if cfg.mode == "molecule"
                  else dax(True)),
        "interactions": {f"int{t}": inter
                         for t in range(cfg.n_interactions)},
        "head": nn.mlp_axes([cfg.d_hidden, cfg.d_hidden // 2, cfg.n_out]),
    }


def _interaction_graph(p, x, rbf, w_cut, src, dst, n_nodes, cfg):
    cd = cfg.compute_dtype
    h = nn.dense(p["w_atom"], x, compute_dtype=cd)
    filt = nn.mlp(p["filter"], rbf, act=nn.softplus_shifted, final_act=True,
                  compute_dtype=cd)                       # (E, d)
    msg = h[src] * filt * w_cut[:, None].astype(cd)       # gather + modulate
    msg = logical(msg, "edges", None)
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    v = nn.dense(p["out2"], nn.softplus_shifted(
        nn.dense(p["out1"], agg, compute_dtype=cd)), compute_dtype=cd)
    return x + v


def apply_graph(params, node_feat, src, dst, edge_dist, cfg: SchNetConfig):
    """node_feat (N, d_feat); src/dst (E,) int32; edge_dist (E,) → (N, n_out)."""
    n_nodes = node_feat.shape[0]
    cd = cfg.compute_dtype
    x = nn.dense(params["input"], node_feat.astype(cd), compute_dtype=cd)
    x = logical(x, "nodes", None)
    rbf = rbf_expand(edge_dist, cfg)
    w_cut = cosine_cutoff(edge_dist.astype(jnp.float32), cfg.cutoff)
    for t in range(cfg.n_interactions):
        x = _interaction_graph(params["interactions"][f"int{t}"], x, rbf,
                               w_cut, src, dst, n_nodes, cfg)
    return nn.mlp(params["head"], x, act=nn.softplus_shifted,
                  compute_dtype=cd).astype(jnp.float32)


def apply_molecule(params, z, pos, cfg: SchNetConfig):
    """z (B, A) atom numbers (0 = padding); pos (B, A, 3) → energy (B, n_out)."""
    cd = cfg.compute_dtype
    B, A = z.shape
    x = nn.embed(params["input"], z, compute_dtype=cd)     # (B, A, d)
    diff = pos[:, :, None, :] - pos[:, None, :, :]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)  # (B, A, A)
    amask = (z > 0)
    pair = (amask[:, :, None] & amask[:, None, :]
            & ~jnp.eye(A, dtype=bool)[None])
    w_cut = cosine_cutoff(dist, cfg.cutoff) * pair.astype(jnp.float32)
    rbf = rbf_expand(dist, cfg)                             # (B, A, A, n_rbf)
    for t in range(cfg.n_interactions):
        p = params["interactions"][f"int{t}"]
        h = nn.dense(p["w_atom"], x, compute_dtype=cd)      # (B, A, d)
        filt = nn.mlp(p["filter"], rbf, act=nn.softplus_shifted,
                      final_act=True, compute_dtype=cd)     # (B, A, A, d)
        msg = h[:, None, :, :] * filt * w_cut[..., None].astype(cd)
        agg = jnp.sum(msg, axis=2)                          # Σ_j → (B, A, d)
        v = nn.dense(p["out2"], nn.softplus_shifted(
            nn.dense(p["out1"], agg, compute_dtype=cd)), compute_dtype=cd)
        x = x + v
    per_atom = nn.mlp(params["head"], x, act=nn.softplus_shifted,
                      compute_dtype=cd)                     # (B, A, n_out)
    per_atom = per_atom * amask[..., None].astype(cd)
    return jnp.sum(per_atom, axis=1).astype(jnp.float32)    # (B, n_out)


def graph_loss(params, batch, cfg: SchNetConfig):
    """Node classification CE on `label_mask` nodes."""
    out = apply_graph(params, batch["node_feat"], batch["src"], batch["dst"],
                      batch["edge_dist"], cfg)
    logz = jax.nn.logsumexp(out, axis=-1)
    gold = jnp.take_along_axis(out, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch["label_mask"].astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def molecule_loss(params, batch, cfg: SchNetConfig):
    pred = apply_molecule(params, batch["z"], batch["pos"], cfg)
    err = pred[:, 0] - batch["energy"]
    return jnp.mean(err * err)
