"""Embedding lookup / EmbeddingBag built from jnp.take + segment_sum.

JAX has no native nn.EmbeddingBag and no CSR sparse — the ragged
gather-reduce is implemented here as part of the system (see kernel taxonomy
§RecSys).  Tables are stored stacked (n_fields·vocab, dim) and row-sharded
over the "model" mesh axis (`emb_rows`); a shard_map lookup with masked psum
lives in distributed/collectives.py for the explicit model-parallel path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical


def table_init(key, n_fields: int, vocab: int, dim: int, dtype=jnp.float32):
    t = jax.random.normal(key, (n_fields * vocab, dim)) / jnp.sqrt(dim)
    return {"table": t.astype(dtype)}


def table_axes():
    return {"table": ("emb_rows", None)}


def table_from_rows(n_rows: int, dim: int, flat_ids, rows,
                    dtype=jnp.float32):
    """Rebuild a sparse stacked table from privately fetched rows.

    The private-serving bridge: a client that PIR-fetched exactly the rows
    its request touches (core.pipeline.PirRagSystem.lookup over the flat
    stacked-id space) scatters them into an otherwise-zero table of the
    full (n_rows, dim) shape, so `recsys.forward`/`serve` run UNMODIFIED
    on params holding only the client's own rows.  Outputs are bitwise
    equal to the public-table run whenever every id the batch touches was
    fetched — duplicate ids scatter identical rows, so repeats are
    harmless.  Returns the ``{"table": ...}`` params leaf `table_init`
    produces.
    """
    t = jnp.zeros((n_rows, dim), dtype)
    flat_ids = jnp.asarray(flat_ids).reshape(-1)
    rows = jnp.asarray(rows, dtype).reshape(-1, dim)
    if flat_ids.shape[0]:
        t = t.at[flat_ids].set(rows)
    return {"table": t}


def field_lookup(p, idx: jax.Array, vocab: int,
                 *, compute_dtype=jnp.bfloat16) -> jax.Array:
    """idx: (B, n_fields) per-field ids → (B, n_fields, dim)."""
    n_fields = idx.shape[-1]
    flat = idx + (jnp.arange(n_fields, dtype=idx.dtype) * vocab)[None, :]
    out = jnp.take(p["table"], flat, axis=0).astype(compute_dtype)
    return logical(out, "batch", "fields", None)


def embedding_bag(table: jax.Array, idx: jax.Array, mask: jax.Array,
                  *, mode: str = "mean",
                  compute_dtype=jnp.bfloat16) -> jax.Array:
    """Dense-batch bag: idx (B, L), mask (B, L) → (B, dim)."""
    e = jnp.take(table, idx, axis=0).astype(compute_dtype)
    m = mask.astype(compute_dtype)[..., None]
    s = jnp.sum(e * m, axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    if mode == "max":
        neg = jnp.finfo(compute_dtype).min
        return jnp.max(jnp.where(m > 0, e, neg), axis=1)
    raise ValueError(mode)


def embedding_bag_ragged(table: jax.Array, indices: jax.Array,
                         segment_ids: jax.Array, n_bags: int,
                         *, mode: str = "sum") -> jax.Array:
    """Ragged bag: flat indices + segment ids → (n_bags, dim)."""
    e = jnp.take(table, indices, axis=0)
    if mode == "sum":
        return jax.ops.segment_sum(e, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(e, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(indices, e.dtype), segment_ids,
                                num_segments=n_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(e, segment_ids, num_segments=n_bags)
    raise ValueError(mode)
