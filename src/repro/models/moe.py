"""Mixture-of-Experts FFN: top-k routing with sort + capacity dispatch.

GShard-style dense dispatch tensors are O(T·E·C) — intractable at assigned
scales (Kimi-K2: 1M tokens × 384 experts).  We instead sort token→expert
assignments and scatter into an (E, C, d) buffer (MegaBlocks-without-kernels),
so dispatch cost is O(T·k·d) + one sort, and expert compute is a dense batched
GEMM whose FLOPs match the *active* parameter count (6·N_active·D shows up
cleanly in the roofline).

Sharding: experts over the "model" mesh axis, capacity over "data" — the
scatter/gather across those boundaries is XLA's all-to-all, i.e. the standard
EP token shuffle.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden width
    n_shared: int = 0            # always-on shared experts
    capacity_factor: float = 1.25
    every: int = 1               # MoE layer every `every` layers


def moe_init(key, d_model: int, moe: MoEConfig, dtype=jnp.float32):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, f = moe.n_experts, moe.d_ff
    s_in, s_out = 1 / math.sqrt(d_model), 1 / math.sqrt(f)
    p = {
        "router": {"w": (s_in * jax.random.normal(kr, (d_model, E))
                         ).astype(dtype)},
        "w_gate": (s_in * jax.random.normal(kg, (E, d_model, f))).astype(dtype),
        "w_up": (s_in * jax.random.normal(ku, (E, d_model, f))).astype(dtype),
        "w_down": (s_out * jax.random.normal(kd, (E, f, d_model))).astype(dtype),
    }
    if moe.n_shared:
        fs = f * moe.n_shared
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": nn.dense_init(k1, d_model, fs, dtype=dtype),
            "w_up": nn.dense_init(k2, d_model, fs, dtype=dtype),
            "w_down": nn.dense_init(k3, fs, d_model, dtype=dtype),
        }
    return p


def moe_param_axes(moe: MoEConfig):
    ax = {
        "router": {"w": ("fsdp", "experts")},
        "w_gate": ("experts", "fsdp", None),
        "w_up": ("experts", "fsdp", None),
        "w_down": ("experts", None, "fsdp"),
    }
    if moe.n_shared:
        ax["shared"] = {
            "w_gate": {"w": ("fsdp", "d_ff")},
            "w_up": {"w": ("fsdp", "d_ff")},
            "w_down": {"w": ("d_ff", "fsdp")},
        }
    return ax


def capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = int(math.ceil(n_tokens * moe.top_k * moe.capacity_factor
                      / moe.n_experts))
    return max(8, ((c + 7) // 8) * 8)


def moe_apply(p, x: jax.Array, moe: MoEConfig, *,
              compute_dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_load_balance_loss).

    Dispatch is GROUPED by data shard: tokens reshape to (G, T/G, d) with
    G = the mesh extent of the "batch" logical axis (1 on a single device),
    and routing/sort/scatter are vmapped per group.  Each data shard then
    sorts only its own tokens — a global argsort over B·S·k assignments
    otherwise forces XLA into a distributed sort + full-activation gathers
    (measured: 26 TB/device of all-reduce on kimi-k2 train_4k).  The (G, E,
    C, d) buffer carries data sharding on G and EP sharding on E; the
    scatter across those axes is the standard MoE all-to-all shuffle.
    """
    from repro.distributed.sharding import axis_size

    B, S, d = x.shape
    T = B * S
    E, k = moe.n_experts, moe.top_k
    G = math.gcd(axis_size("batch"), T)
    T_loc = T // G
    C = capacity(T_loc, moe)
    xg = x.reshape(G, T_loc, d)
    xg = logical(xg, "batch", None, None)

    router_w = p["router"]["w"].astype(jnp.float32)

    def dispatch(x_loc):
        """One group's routing + sort + capacity scatter (runs vmapped)."""
        gate_logits = x_loc.astype(jnp.float32) @ router_w      # (T_loc, E)
        probs = jax.nn.softmax(gate_logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        flat_e = top_e.reshape(-1)                              # (T_loc·k,)
        flat_t = jnp.arange(T_loc * k, dtype=jnp.int32) // k
        flat_w = top_w.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(flat_e, length=E)
        offsets = jnp.cumsum(counts) - counts
        pos = (jnp.arange(T_loc * k, dtype=jnp.int32)
               - offsets[se].astype(jnp.int32))
        keep = pos < C
        vals = (x_loc[st].astype(compute_dtype)
                * keep[:, None].astype(compute_dtype))
        buf = jnp.zeros((E, C, d), compute_dtype)
        buf = buf.at[se, pos].add(vals, mode="drop")
        return buf, (se, st, sw, pos, keep, counts, probs)

    buf, (se, st, sw, pos, keep, counts, probs) = jax.vmap(dispatch)(xg)
    buf = logical(buf, "batch", "experts", "expert_cap", None)  # EP a2a here

    # --- expert compute: batched SwiGLU (E sharded over model) ---------------
    wg = p["w_gate"].astype(compute_dtype)
    wu = p["w_up"].astype(compute_dtype)
    wd = p["w_down"].astype(compute_dtype)
    h = jnp.einsum("gecd,edf->gecf", buf, wg)
    u = jnp.einsum("gecd,edf->gecf", buf, wu)
    a = jax.nn.silu(h) * u
    out = jnp.einsum("gecf,efd->gecd", a, wd)
    out = logical(out, "batch", "experts", "expert_cap", None)

    # --- combine per group: gather back, weight, scatter over tokens ---------
    def combine(out_g, se_g, st_g, sw_g, pos_g, keep_g):
        pos_c = jnp.minimum(pos_g, C - 1)
        back = out_g[se_g, pos_c] * (keep_g.astype(compute_dtype)
                                     * sw_g.astype(compute_dtype))[:, None]
        return jax.ops.segment_sum(back, st_g, num_segments=T_loc)

    y = jax.vmap(combine)(out, se, st, sw, pos, keep)           # (G, T_loc, d)
    y = logical(y, "batch", None, None).reshape(T, d)

    # --- shared experts (always-on) -------------------------------------------
    if moe.n_shared:
        sh = p["shared"]
        xf = x.reshape(T, d)
        g = nn.dense(sh["w_gate"], xf, compute_dtype=compute_dtype)
        uu = nn.dense(sh["w_up"], xf, compute_dtype=compute_dtype)
        y = y + nn.dense(sh["w_down"], jax.nn.silu(g) * uu,
                         compute_dtype=compute_dtype)

    # --- load-balance aux loss (Switch): E · Σ_i f_i · P_i -------------------
    f_frac = jnp.sum(counts, axis=0).astype(jnp.float32) / jnp.maximum(
        1, T * k)
    p_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_frac * p_mean)

    return y.reshape(B, S, d).astype(x.dtype), aux
