"""Decoder-only transformer LM covering all five assigned LM architectures.

One configurable implementation: GQA attention (+optional QK-norm, QKV bias),
RoPE, SwiGLU dense FFN, optional MoE layers (interleaved every
``moe.every``), scan-over-layers (compile time O(1 layer)), blockwise
online-softmax attention (flash-style memory, pure JAX so multi-pod dry-runs
lower on any backend), KV-cache prefill/decode, chunked cross-entropy.

Param dtype fp32 by default with bf16 compute; big-MoE configs train with
Adafactor (see optim/) so the 1T-param Kimi-K2 state fits the v5e fleet.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import nn
from repro.models.moe import MoEConfig, moe_apply, moe_init, moe_param_axes


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 500_000.0
    moe: MoEConfig | None = None
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    ce_chunk: int = 512          # sequence chunk for cross-entropy
    n_microbatch: int = 1        # gradient-accumulation microbatches

    @property
    def block_size(self) -> int:
        return self.moe.every if self.moe is not None else 1

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_size == 0
        return self.n_layers // self.block_size

    def sub_is_moe(self, i: int) -> bool:
        return self.moe is not None and i == self.block_size - 1


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: LMConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": nn.dense_init(kq, d, H * hd, bias=cfg.qkv_bias,
                            dtype=cfg.param_dtype),
        "wk": nn.dense_init(kk, d, KV * hd, bias=cfg.qkv_bias,
                            dtype=cfg.param_dtype),
        "wv": nn.dense_init(kv, d, KV * hd, bias=cfg.qkv_bias,
                            dtype=cfg.param_dtype),
        "wo": nn.dense_init(ko, H * hd, d, dtype=cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(hd, cfg.param_dtype)
        p["k_norm"] = nn.rmsnorm_init(hd, cfg.param_dtype)
    return p


def _dense_ffn_init(key, cfg: LMConfig):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": nn.dense_init(kg, cfg.d_model, cfg.d_ff,
                                dtype=cfg.param_dtype),
        "w_up": nn.dense_init(ku, cfg.d_model, cfg.d_ff,
                              dtype=cfg.param_dtype),
        "w_down": nn.dense_init(kd, cfg.d_ff, cfg.d_model,
                                dtype=cfg.param_dtype),
    }


def _block_init(key, cfg: LMConfig):
    blk = {}
    for i in range(cfg.block_size):
        ka, kf = jax.random.split(jax.random.fold_in(key, i))
        sub = {
            "ln1": nn.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "attn": _attn_init(ka, cfg),
            "ln2": nn.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        }
        if cfg.sub_is_moe(i):
            sub["moe"] = moe_init(kf, cfg.d_model, cfg.moe, cfg.param_dtype)
        else:
            sub["ffn"] = _dense_ffn_init(kf, cfg)
        blk[f"sub{i}"] = sub
    return blk


def init(key, cfg: LMConfig):
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.n_blocks)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(block_keys)
    params = {
        "embed": nn.embed_init(ke, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "blocks": blocks,
        "final_norm": nn.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = nn.dense_init(kh, cfg.d_model, cfg.vocab,
                                       dtype=cfg.param_dtype)
    return params


def param_spec(cfg: LMConfig):
    """Full-size ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))


def param_axes(cfg: LMConfig):
    """Logical dim names per parameter (leading None = scan-stacked blocks)."""
    def attn_ax():
        ax = {"wq": {"w": ("fsdp", "heads")},
              "wk": {"w": ("fsdp", "kv_heads")},
              "wv": {"w": ("fsdp", "kv_heads")},
              "wo": {"w": ("heads", "fsdp")}}
        if cfg.qkv_bias:
            ax["wq"]["b"] = ("heads",)
            ax["wk"]["b"] = ("kv_heads",)
            ax["wv"]["b"] = ("kv_heads",)
        if cfg.qk_norm:
            ax["q_norm"] = {"g": (None,)}
            ax["k_norm"] = {"g": (None,)}
        return ax

    blk = {}
    for i in range(cfg.block_size):
        sub = {"ln1": {"g": (None,)}, "attn": attn_ax(),
               "ln2": {"g": (None,)}}
        if cfg.sub_is_moe(i):
            sub["moe"] = moe_param_axes(cfg.moe)
        else:
            sub["ffn"] = {"w_gate": {"w": ("fsdp", "d_ff")},
                          "w_up": {"w": ("fsdp", "d_ff")},
                          "w_down": {"w": ("d_ff", "fsdp")}}
        blk[f"sub{i}"] = sub
    # prepend the scan-stacked block axis
    blk = jax.tree.map(lambda t: (None,) + t, blk,
                       is_leaf=lambda v: isinstance(v, tuple))
    out = {
        # vocab → model only: sharding the d dim too (over data) collides
        # with batch-sharded ids in the gather, forcing XLA to materialize
        # full-batch f32 intermediates (measured +24 GiB/device)
        "embed": {"table": ("vocab", None)},
        "blocks": blk,
        "final_norm": {"g": (None,)},
    }
    if not cfg.tie_embeddings:
        out["head"] = {"w": (None, "vocab")}
    return out


# ---------------------------------------------------------------------------
# RoPE + attention
# ---------------------------------------------------------------------------

def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _qkv(p, x, cfg: LMConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.compute_dtype
    q = nn.dense(p["wq"], x, compute_dtype=cd).reshape(B, S, H, hd)
    k = nn.dense(p["wk"], x, compute_dtype=cd).reshape(B, S, KV, hd)
    v = nn.dense(p["wv"], x, compute_dtype=cd).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q)
        k = nn.rmsnorm(p["k_norm"], k)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, cfg: LMConfig, *, causal: bool = True):
    """Online-softmax (flash-style) attention in pure JAX.

    q: (B, Sq, H, hd), k/v: (B, Skv, KV, hd).  Outer python loop over q
    chunks (static count), inner lax.scan over only the kv chunks a causal
    chunk can see — memory O(chunk²), FLOPs ≈ causal-optimal.

    GQA is handled by repeating K/V to the full H heads PER CHUNK and using
    flat-H einsums.  The alternative — grouped (B,S,KV,G,hd) einsums — makes
    GSPMD replicate the whole attention over the model axis whenever H is
    not divisible by it (28/24/40 heads on a 16-wide axis): measured 16×
    redundant FLOPs + "involuntary full rematerialization" warnings.  With
    flat H the head axis shards (with ≤14% padding) and K/V repetition stays
    chunk-local.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    cq, ckv = min(cfg.attn_chunk_q, Sq), min(cfg.attn_chunk_kv, Skv)
    nq = (Sq + cq - 1) // cq
    scale = 1.0 / math.sqrt(hd)
    # No explicit head-sharding constraint: with flat-H einsums GSPMD
    # propagates the wq output sharding naturally; forcing P(..., heads)
    # here measured 2× extra all-gather on the MoE TP path (llama4 train:
    # 1397 → 643 GiB/device without it).
    qg = q

    # pad K/V to the chunk grid — dynamic_slice CLAMPS out-of-bounds starts,
    # which would silently re-read shifted keys on a ragged final chunk
    pad_kv = (-Skv) % ckv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    def one_q_chunk(qc, k, v, q_lo, cq_i, nkv):  # noqa: D401
        """One q-chunk's online softmax over its visible kv chunks.

        jax.checkpoint'd: without it the inner scan saves its (m, l, acc)
        carries for backward across ALL q chunks simultaneously (the python
        loop is dataflow-parallel), costing O(Sq/cq · Skv/ckv · chunk²) HBM —
        measured 20+ GiB/device at train_4k scale.  Remat recomputes the
        inner scan during the chunk's backward instead.
        """

        def step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * ckv, ckv, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * ckv, ckv, axis=1)
            # GQA → flat H per chunk (see docstring)
            kc = jnp.repeat(kc, G, axis=2)                  # (B, ckv, H, hd)
            vc = jnp.repeat(vc, G, axis=2)
            # matmuls stay in the input dtype (bf16 wire/HBM in the model,
            # f32-exact in unit tests); accumulation is always f32
            s = jnp.einsum("bqhd,bthd->bqht", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            kpos = ki * ckv + jnp.arange(ckv)
            valid = kpos < Skv                              # padded keys
            if causal:
                qpos = q_lo + jnp.arange(cq_i)
                mask = (qpos[:, None] >= kpos[None, :]) & valid[None, :]
                s = jnp.where(mask[:, None, :][None], s, -1e30)
            else:
                s = jnp.where(valid[None, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            prob = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(prob, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqht,bthd->bqhd", prob.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, cq_i, H), -1e30, jnp.float32)
        l0 = jnp.zeros((B, cq_i, H), jnp.float32)
        a0 = jnp.zeros((B, cq_i, H, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out

    chunk_fn = jax.checkpoint(
        one_q_chunk, policy=jax.checkpoint_policies.nothing_saveable,
        static_argnums=(3, 4, 5))

    outs = []
    for qi in range(nq):
        q_lo = qi * cq
        q_hi = min(Sq, q_lo + cq)
        qc = qg[:, q_lo:q_hi]
        cq_i = q_hi - q_lo
        kv_hi = min(Skv, q_hi) if causal else Skv
        nkv = max(1, (kv_hi + ckv - 1) // ckv)
        outs.append(chunk_fn(qc, k, v, q_lo, cq_i, nkv))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, cfg: LMConfig):
    """Single-token attention over a (possibly seq-sharded) KV cache.

    q: (B, 1, H, hd); caches: (B, T, KV, hd); lengths: (B,) valid length.
    Masked full-width softmax — O(T) work, and XLA partitions the reduction
    when the cache's T axis is sharded (split-S / flash-decoding layout).
    """
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    tpos = jnp.arange(T)
    mask = tpos[None, :] < lengths[:, None]                  # (B, T)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blocks / forward
# ---------------------------------------------------------------------------

def _sublayer(sub, x, cfg: LMConfig, i: int, positions):
    h = nn.rmsnorm(sub["ln1"], x)
    h = logical(h, "batch", None, None)       # SP: all-gather at entry
    q, k, v = _qkv(sub["attn"], h, cfg, positions)
    o = blockwise_attention(q, k, v, cfg)
    o = nn.dense(sub["attn"]["wo"], o.reshape(*o.shape[:2], -1),
                 compute_dtype=cfg.compute_dtype)
    x = x + o.astype(x.dtype)
    x = logical(x, "batch", "seq", None)      # SP: reduce-scatter at exit

    h = nn.rmsnorm(sub["ln2"], x)
    h = logical(h, "batch", None, None)
    if cfg.sub_is_moe(i):
        y, aux = moe_apply(sub["moe"], h, cfg.moe,
                           compute_dtype=cfg.compute_dtype)
    else:
        f = sub["ffn"]
        g = nn.dense(f["w_gate"], h, compute_dtype=cfg.compute_dtype)
        u = nn.dense(f["w_up"], h, compute_dtype=cfg.compute_dtype)
        y = nn.dense(f["w_down"], jax.nn.silu(g) * u,
                     compute_dtype=cfg.compute_dtype)
        aux = jnp.zeros((), jnp.float32)
    x = x + y.astype(x.dtype)
    x = logical(x, "batch", "seq", None)
    return x, aux


def _block(blk, x, cfg: LMConfig, positions):
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.block_size):
        x, aux = _sublayer(blk[f"sub{i}"], x, cfg, i, positions)
        aux_total = aux_total + aux
    return x, aux_total


def forward(params, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array,
                                                               jax.Array]:
    """tokens (B, S) → final hidden states (B, S, d) + total aux loss."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = nn.embed(params["embed"], tokens, compute_dtype=cfg.compute_dtype)
    x = logical(x, "batch", "seq", None)

    def blk_fn(x, blk):
        return _block(blk, x, cfg, positions)

    if cfg.remat:
        blk_fn = jax.checkpoint(blk_fn,
                                policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(carry, blk):
        x, aux = carry
        x, a = blk_fn(x, blk)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = nn.rmsnorm(params["final_norm"], x)
    return x, aux


def logits_from_hidden(params, x, cfg: LMConfig):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cfg.compute_dtype)
        return (x.astype(cfg.compute_dtype) @ w.T).astype(jnp.float32)
    return nn.dense(params["head"], x, compute_dtype=cfg.compute_dtype
                    ).astype(jnp.float32)


def chunked_xent(params, x, labels, cfg: LMConfig):
    """Cross-entropy without materializing (B, S, V) logits: scan S chunks.

    The per-chunk loss is checkpointed — otherwise the scan saves every
    chunk's (B, c, V) logits for backward and the chunking saves nothing
    (measured +2.3 GiB/device/chunk at train_4k scale).
    """
    B, S, d = x.shape
    c = min(cfg.ce_chunk, S)
    assert S % c == 0
    # gather a seq-sharded (SP) residual stream before chunking S
    x = logical(x, "batch", None, None)
    xc = x.reshape(B, S // c, c, d).swapaxes(0, 1)       # (n, B, c, d)
    lc = labels.reshape(B, S // c, c).swapaxes(0, 1)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_loss(xi, li):
        logits = logits_from_hidden(params, xi, cfg)     # (B, c, V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def step(tot, xl):
        return tot + chunk_loss(*xl), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)


def lm_loss(params, batch, cfg: LMConfig, *, aux_coef: float = 0.01):
    """batch: {"tokens": (B, S), "labels": (B, S)} → scalar loss."""
    x, aux = forward(params, batch["tokens"], cfg)
    ce = chunked_xent(params, x, batch["labels"], cfg)
    return ce + aux_coef * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV cache: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    def one():
        return {
            "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
        }
    blocks = {f"sub{i}": one() for i in range(cfg.block_size)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_blocks,) + a.shape), blocks)


def cache_spec(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))


def cache_axes(cfg: LMConfig):
    one = {"k": (None, "batch", "cache_seq", "kv_heads", None),
           "v": (None, "batch", "cache_seq", "kv_heads", None)}
    return {f"sub{i}": one for i in range(cfg.block_size)}


def prefill(params, tokens, cache, cfg: LMConfig, *, last_pos=None):
    """Run the prompt through the model, filling the cache; return logits of
    the last position (B, V) + new cache.

    ``last_pos`` (optional, (B,) int32) gathers each row's logits at its
    OWN last real token instead of column S-1 — the ragged-prompt path
    (right-padded batches from `rag.prompt.pack_batch` pass lengths-1).
    Padding columns still write the cache; decode masks them out by
    attending only to `lengths` positions."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = nn.embed(params["embed"], tokens, compute_dtype=cfg.compute_dtype)

    def blk_fn(x, blk_and_cache):
        blk, cb = blk_and_cache
        new_cb = {}
        for i in range(cfg.block_size):
            sub = blk[f"sub{i}"]
            h = nn.rmsnorm(sub["ln1"], x)
            q, k, v = _qkv(sub["attn"], h, cfg, positions)
            new_cb[f"sub{i}"] = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cb[f"sub{i}"]["k"], k.astype(cb[f"sub{i}"]["k"].dtype),
                    0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cb[f"sub{i}"]["v"], v.astype(cb[f"sub{i}"]["v"].dtype),
                    0, axis=1),
            }
            o = blockwise_attention(q, k, v, cfg)
            o = nn.dense(sub["attn"]["wo"], o.reshape(B, S, -1),
                         compute_dtype=cfg.compute_dtype)
            x = x + o.astype(x.dtype)
            h = nn.rmsnorm(sub["ln2"], x)
            if cfg.sub_is_moe(i):
                y, _ = moe_apply(sub["moe"], h, cfg.moe,
                                 compute_dtype=cfg.compute_dtype)
            else:
                f = sub["ffn"]
                g = nn.dense(f["w_gate"], h, compute_dtype=cfg.compute_dtype)
                u = nn.dense(f["w_up"], h, compute_dtype=cfg.compute_dtype)
                y = nn.dense(f["w_down"], jax.nn.silu(g) * u,
                             compute_dtype=cfg.compute_dtype)
            x = x + y.astype(x.dtype)
        return x, new_cb

    def scan_body(x, xs):
        blk, cb = xs
        x, new_cb = blk_fn(x, (blk, cb))
        return x, new_cb

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = nn.rmsnorm(params["final_norm"], x)
    if last_pos is None:
        xl = x[:, -1:]
    else:
        idx = jnp.asarray(last_pos, jnp.int32).reshape(B, 1, 1)
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (B, 1, x.shape[-1])), axis=1)
    logits = logits_from_hidden(params, xl, cfg)[:, 0]
    return logits, new_cache


def decode_step(params, cache, tokens, lengths, cfg: LMConfig):
    """One decode step.  tokens: (B,) new ids; lengths: (B,) current context
    length (the new token is written at index `lengths`)."""
    B = tokens.shape[0]
    positions = lengths[:, None].astype(jnp.int32)            # (B, 1)
    x = nn.embed(params["embed"], tokens[:, None],
                 compute_dtype=cfg.compute_dtype)
    barange = jnp.arange(B)

    def blk_fn(x, xs):
        blk, cb = xs
        new_cb = {}
        for i in range(cfg.block_size):
            sub = blk[f"sub{i}"]
            h = nn.rmsnorm(sub["ln1"], x)
            q, k, v = _qkv(sub["attn"], h, cfg, positions)
            kc = cb[f"sub{i}"]["k"].at[barange, lengths].set(
                k[:, 0].astype(cb[f"sub{i}"]["k"].dtype))
            vc = cb[f"sub{i}"]["v"].at[barange, lengths].set(
                v[:, 0].astype(cb[f"sub{i}"]["v"].dtype))
            kc = logical(kc, "batch", "cache_seq", "kv_heads", None)
            vc = logical(vc, "batch", "cache_seq", "kv_heads", None)
            new_cb[f"sub{i}"] = {"k": kc, "v": vc}
            o = decode_attention(q, kc, vc, lengths + 1, cfg)
            o = nn.dense(sub["attn"]["wo"], o.reshape(B, 1, -1),
                         compute_dtype=cfg.compute_dtype)
            x = x + o.astype(x.dtype)
            h = nn.rmsnorm(sub["ln2"], x)
            if cfg.sub_is_moe(i):
                y, _ = moe_apply(sub["moe"], h, cfg.moe,
                                 compute_dtype=cfg.compute_dtype)
            else:
                f = sub["ffn"]
                g = nn.dense(f["w_gate"], h, compute_dtype=cfg.compute_dtype)
                u = nn.dense(f["w_up"], h, compute_dtype=cfg.compute_dtype)
                y = nn.dense(f["w_down"], jax.nn.silu(g) * u,
                             compute_dtype=cfg.compute_dtype)
            x = x + y.astype(x.dtype)
        return x, new_cb

    x, new_cache = jax.lax.scan(blk_fn, x, (params["blocks"], cache))
    x = nn.rmsnorm(params["final_norm"], x)
    logits = logits_from_hidden(params, x, cfg)[:, 0]         # (B, V)
    return logits, new_cache
