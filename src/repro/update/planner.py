"""Delta planner: mutation batch → touched clusters or a full-rebuild trigger.

The planner resolves a mutation batch against the current doc→cluster map,
computes the new contents of every touched cluster, and runs the
column-capacity accounting that decides between the two publish paths:

  delta epoch   — every touched column still fits in m rows and the
                  projected pad fraction stays under the threshold; the
                  live index re-packs only those columns and ships a
                  sparse HintPatch.
  full rebuild  — an insert overflows a column (m must grow) or deletes
                  have degraded pad_fraction past `max_pad_fraction`
                  (the m×n matrix is mostly padding, so downlink and
                  server GEMM cost are being wasted); re-cluster, re-pack
                  and re-hint from scratch, shipping a full-hint patch.

Inserts are assigned to their nearest PUBLIC centroid — the same rule the
client uses to route queries, so freshly inserted documents are reachable
by the very next query without re-clustering.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core import chunking
from repro.update import journal as journal_lib


@dataclasses.dataclass(frozen=True)
class UpdatePlan:
    """Resolved effect of one mutation batch."""
    touched: tuple[int, ...]                 # sorted touched cluster ids
    docs_by_cluster: dict[int, list[chunking.DocTriple]]  # new full contents
    new_docs: dict[int, tuple[bytes, np.ndarray]]         # id → (text, emb)
    new_cluster_of: dict[int, int]           # id → cluster after the batch
    full_rebuild: bool
    reason: str | None                       # overflow | pad-degradation
    projected_pad_fraction: float


def nearest_centroid(emb: np.ndarray, centroids: np.ndarray) -> int:
    d2 = ((centroids - emb[None, :]) ** 2).sum(axis=1)
    return int(np.argmin(d2))


def plan_updates(mutations: Sequence[journal_lib.Mutation], *,
                 docs: Mapping[int, tuple[bytes, np.ndarray]],
                 cluster_of: Mapping[int, int],
                 centroids: np.ndarray,
                 m: int,
                 used_bytes: Mapping[int, int],
                 n_clusters: int,
                 emb_dim: int,
                 max_pad_fraction: float = 0.95,
                 assign_fn: Callable[[int, np.ndarray], int] | None = None
                 ) -> UpdatePlan:
    """Resolve `mutations` in order and account column capacity.

    ``assign_fn(doc_id, emb) -> cluster`` overrides the nearest-centroid
    placement rule for inserts/replaces.  Keyed (embedding-table) systems
    pass the id→group map here: their column membership is a public
    function of the ID, so a replaced row must stay in its id-derived
    group — re-routing it by embedding similarity would silently break the
    client's fixed-stride decode arithmetic.
    """
    new_docs = dict(docs)
    new_cluster_of = dict(cluster_of)
    touched: set[int] = set()

    for mut in mutations:
        if mut.kind == journal_lib.DELETE:
            if mut.doc_id not in new_docs:
                raise KeyError(f"delete of unknown doc_id {mut.doc_id}")
            del new_docs[mut.doc_id]
            touched.add(new_cluster_of.pop(mut.doc_id))
            continue
        if mut.kind == journal_lib.INSERT and mut.doc_id in new_docs:
            raise KeyError(f"insert of existing doc_id {mut.doc_id}")
        if mut.kind == journal_lib.REPLACE and mut.doc_id not in new_docs:
            raise KeyError(f"replace of unknown doc_id {mut.doc_id}")
        emb = np.asarray(mut.emb, np.float32)
        if emb.shape != (emb_dim,):
            raise ValueError(f"embedding dim {emb.shape} != ({emb_dim},)")
        old_cluster = new_cluster_of.get(mut.doc_id)
        if old_cluster is not None:
            touched.add(old_cluster)       # replace may move the doc
        cl = (nearest_centroid(emb, centroids) if assign_fn is None
              else int(assign_fn(mut.doc_id, emb)))
        new_docs[mut.doc_id] = (mut.text, emb)
        new_cluster_of[mut.doc_id] = cl
        touched.add(cl)

    # New contents of every touched cluster (canonical doc_id order comes
    # from pack_column; membership from the post-batch cluster map).
    docs_by_cluster: dict[int, list[chunking.DocTriple]] = {
        j: [] for j in touched}
    for doc_id, cl in new_cluster_of.items():
        if cl in docs_by_cluster:
            text, emb = new_docs[doc_id]
            docs_by_cluster[cl].append((doc_id, emb, text))

    # Capacity accounting: per-column payload vs the m-row budget.
    full_rebuild, reason = False, None
    new_used = dict(used_bytes)
    for j in touched:
        need = chunking.column_payload_bytes(
            emb_dim, [len(t) for _, _, t in docs_by_cluster[j]])
        new_used[j] = need
        if need > m:
            full_rebuild, reason = True, "overflow"
    pad = 1.0 - sum(new_used.values()) / float(m * n_clusters)
    if not full_rebuild and pad > max_pad_fraction:
        full_rebuild, reason = True, "pad-degradation"

    return UpdatePlan(touched=tuple(sorted(touched)),
                      docs_by_cluster=docs_by_cluster,
                      new_docs=new_docs,
                      new_cluster_of=new_cluster_of,
                      full_rebuild=full_rebuild,
                      reason=reason,
                      projected_pad_fraction=pad)
