"""Versioned hint epochs: the client-visible side of the live index.

Every committed mutation batch publishes a new epoch e → e+1 together with a
`HintPatch` that transforms a cached epoch-e hint into the epoch-(e+1) hint
*bit-exactly*.  Two patch kinds:

delta patch (the common case)
    Carries the raw DB column delta `ΔD[:,J]` (int16, entries ∈ [−255, 255])
    truncated to the first `r` rows that can differ (max used length of the
    touched columns).  The client recomputes `ΔH = ΔD·A[J,:]` locally — A is
    public and seed-derived, so it never travels.  Wire size is
    `16 + 4·|J| + 2·r·|J|` bytes vs `4·m·k` for the full hint: the download
    ratio is ≈ |J|·r / (2·k·m), e.g. 5% of 4096 clusters at k=1024 ⇒ ~10⁻²
    of a re-download even before row truncation.

full patch (rebuild epochs)
    Published when the planner triggers a full rebuild (column overflow or
    pad-fraction degradation); carries the fresh hint and the new PIRConfig
    (m and LWE params may change).  Costs `hint_bytes`, same as bootstrap.

All arithmetic is uint32 wraparound (mod 2^32), matching the server's
`PIRServer.update_columns` path, so `patch(H)` equals `server.setup()` on
the rebuilt DB bit-for-bit.

Patch CHAINS (the hint-delivery layer): patches compose.  Two consecutive
delta patches merge into one spanning patch whose delta is
`D_final − D_initial` over the union of their touched columns — still
int16 (both endpoints are u8 databases), and strictly no larger than the
two patches side by side (overlapping columns dedupe).  `EpochLog` built
with ``compact_every=C`` folds every aligned run of C patches into one
compacted segment at publish time, so a client K epochs behind downloads
O(K/C) segments plus a short raw tail instead of K patches — and never
the full `m·k·4`-byte hint unless a rebuild epoch intervened (a full
patch subsumes everything before it).  `chain_since`/`chain_bytes` give
the minimal chain and its exact downlink cost; `HintCache.sync` applies
either representation with bit-identical results (property-tested in
tests/test_hint_chains.py).

Publication timing: under the pipelined serving engine a commit is staged
into shadow buffers first (`LiveIndex.stage`) and `EpochLog.publish`
happens inside the pointer swap (`LiveIndex.publish`) — i.e. the epoch
counter, the server buffers and the patch log all advance at the same
instant, which is what lets `check_fresh` remain a plain equality test
with no read locks: a query either sees the old epoch everywhere or the
new epoch everywhere.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core import lwe, pir

U32 = jnp.uint32

_PATCH_HEADER_BYTES = 16   # from_epoch u32 | to_epoch u32 | n_cols u32 | nrows u32


class StaleEpochError(RuntimeError):
    """A query/patch was formed against an epoch the server has moved past."""

    def __init__(self, have: int, want: int):
        super().__init__(f"stale epoch {have}; server is at {want}")
        self.have = have
        self.want = want


class CorruptPatchError(RuntimeError):
    """A downloaded patch failed its integrity checksum and the log offers
    no full-hint fallback (`EpochLog.full_fetch`) to re-sync from."""


@dataclasses.dataclass(frozen=True)
class HintPatch:
    """Transforms the epoch-`from_epoch` hint into the `to_epoch` hint.

    ``crc`` is the wire-integrity checksum over the patch's payload and
    epoch span, computed at publish time (`sealed`); a client verifies it
    at decode time so a corrupt or truncated download is detected instead
    of silently patching the cached hint into garbage.  Unsealed patches
    (``crc=None`` — intermediate compositions, hand-built test patches)
    verify trivially: the checksum protects the DELIVERY path, not
    in-process arithmetic.
    """
    from_epoch: int
    to_epoch: int
    cols: np.ndarray | None = None        # (J,) int64 touched cluster ids
    delta: np.ndarray | None = None       # (r, J) int16: D_new − D_old rows <r
    full_hint: np.ndarray | None = None   # (m, k) u32 — rebuild epochs only
    cfg: pir.PIRConfig | None = None      # new config on rebuild epochs
    crc: int | None = None                # payload checksum (None = unsealed)

    @property
    def is_full(self) -> bool:
        """True for rebuild epochs: the patch carries a whole (m, k) hint."""
        return self.full_hint is not None

    def payload_crc(self) -> int:
        """CRC-32 over the epoch span and payload arrays (wire contents)."""
        hdr = np.asarray([self.from_epoch, self.to_epoch], np.uint32)
        acc = zlib.crc32(hdr.tobytes())
        if self.is_full:
            acc = zlib.crc32(np.ascontiguousarray(self.full_hint).tobytes(),
                             acc)
        else:
            acc = zlib.crc32(np.ascontiguousarray(self.cols).tobytes(), acc)
            acc = zlib.crc32(np.ascontiguousarray(self.delta).tobytes(), acc)
        return acc

    def sealed(self) -> "HintPatch":
        """This patch with its checksum stamped (idempotent)."""
        return dataclasses.replace(self, crc=self.payload_crc())

    def verify(self) -> bool:
        """True iff the payload matches the stamped checksum (or unsealed)."""
        return self.crc is None or self.crc == self.payload_crc()

    @property
    def wire_bytes(self) -> int:
        """Downlink cost of shipping this patch (cf. PIRConfig.hint_bytes)."""
        if self.is_full:
            return _PATCH_HEADER_BYTES + self.full_hint.size * 4
        return (_PATCH_HEADER_BYTES + 4 * len(self.cols)
                + 2 * self.delta.size)

    def apply(self, hint: jnp.ndarray, a_mat: jnp.ndarray) -> jnp.ndarray:
        """hint → patched hint (exact mod 2^32; bit-identical to a rebuild).

        a_mat: the client's seed-derived public matrix A (n, k) u32.
        """
        if self.is_full:
            return jnp.asarray(self.full_hint, U32)
        r = self.delta.shape[0]
        # int16 → int32 → u32 wraps negatives to their mod-2^32 residues,
        # so the u32 GEMM below is the exact ring product ΔD·A[J,:].
        d_u32 = jnp.asarray(self.delta.astype(np.int32)).astype(U32)
        a_j = jnp.asarray(a_mat)[jnp.asarray(self.cols)].astype(U32)
        return hint.at[:r].add(jnp.matmul(d_u32, a_j))


def compose_patches(a: HintPatch, b: HintPatch) -> HintPatch:
    """Merge consecutive patches into ONE spanning a.from_epoch→b.to_epoch.

    Exact in every case (all arithmetic lands on the same mod-2^32 residues
    a client applying the two patches in sequence would reach):

      delta ∘ delta — the spanning delta is `D_final − D_initial` over the
          union of touched columns: per-column int32 sum of the two deltas,
          which provably fits int16 again (both endpoints are u8 databases),
          row-truncated to the taller of the two.
      anything ∘ full — the later full patch subsumes the earlier patch.
      full ∘ delta — the delta is folded into the carried hint via the
          public matrix A (seed-derived from the full patch's cfg), i.e.
          exactly `HintPatch.apply` on the server side.
    """
    assert a.to_epoch == b.from_epoch, (a.to_epoch, b.from_epoch)
    if b.is_full:
        # crc is span-dependent: the widened composition must re-seal
        return dataclasses.replace(b, from_epoch=a.from_epoch, crc=None)
    if a.is_full:
        assert a.cfg is not None, "full patch needs cfg to absorb deltas"
        a_mat = lwe.gen_public_matrix(a.cfg.a_seed, a.cfg.n, a.cfg.params.k)
        hint = np.asarray(b.apply(jnp.asarray(a.full_hint, U32), a_mat))
        return HintPatch(from_epoch=a.from_epoch, to_epoch=b.to_epoch,
                         full_hint=hint, cfg=a.cfg)
    cols = np.union1d(a.cols, b.cols)
    r = max(a.delta.shape[0], b.delta.shape[0])
    acc = np.zeros((r, len(cols)), np.int32)
    acc[:a.delta.shape[0], np.searchsorted(cols, a.cols)] += a.delta
    acc[:b.delta.shape[0], np.searchsorted(cols, b.cols)] += b.delta
    return HintPatch(from_epoch=a.from_epoch, to_epoch=b.to_epoch,
                     cols=cols, delta=acc.astype(np.int16))


def compact_chain(patches: list[HintPatch]) -> HintPatch:
    """Fold a consecutive patch run into one spanning patch (left fold)."""
    assert patches, "cannot compact an empty chain"
    out = patches[0]
    for p in patches[1:]:
        out = compose_patches(out, p)
    return out


class EpochLog:
    """Server-side publication log: monotone epochs + their patches.

    ``compact_every=C`` turns on periodic compaction: every time the head
    reaches a multiple of C, the just-completed aligned run of C patches is
    folded into one segment.  `chain_since` then hands a catching-up client
    the minimal chain — a short raw prefix up to the next C-boundary,
    whole segments across the middle, and the raw tail — instead of one
    patch per missed epoch.  Raw patches are kept (clients can be stranded
    at any epoch, including mid-segment); `stored_bytes` accounts the
    server-side cost of keeping both representations.
    """

    def __init__(self, compact_every: int | None = None):
        assert compact_every is None or compact_every >= 2, compact_every
        self.epoch = 0
        self.compact_every = compact_every
        self._patches: list[HintPatch] = []
        self._segments: dict[int, HintPatch] = {}   # from_epoch → segment
        # Optional observability handle (repro.obs.Obs); LiveIndex threads
        # its own through so compaction events land in the serving trace.
        self.obs = None
        # Fault-injection hook (repro.fleet.faults.FaultInjector): when set,
        # `download_chain` guards the "update.hint.chain" site and corrupts
        # one patch of the served copy when the plan says so.
        self.faults = None
        # Full re-sync fallback: callable(from_epoch) -> sealed full
        # HintPatch to the head.  LiveIndex wires this to its serving hint
        # so a client that detects a corrupt chain can recover with one
        # deterministic full download instead of a wrong hint.
        self.full_fetch = None

    def publish(self, patch: HintPatch) -> int:
        """Append the next epoch's patch; returns the new head epoch.

        Patches are SEALED here (integrity checksum stamped) — publication
        is the wire boundary, so everything `chain_since`/`download_chain`
        hands out is client-verifiable.  With compaction enabled, a head
        landing on a ``compact_every`` boundary folds the completed run
        into its segment here — publish time, not sync time — so every
        client downloading that span shares one precomputed segment.
        """
        assert patch.from_epoch == self.epoch, (patch.from_epoch, self.epoch)
        assert patch.to_epoch == self.epoch + 1
        self._patches.append(patch.sealed())
        self.epoch = patch.to_epoch
        c = self.compact_every
        if c and self.epoch % c == 0:
            lo = self.epoch - c
            seg = compact_chain(self._patches[lo:self.epoch]).sealed()
            self._segments[lo] = seg
            if self.obs is not None:
                self.obs.counter("epoch.compactions").inc()
                self.obs.instant("epoch.compact", from_epoch=lo,
                                 to_epoch=self.epoch,
                                 segment_bytes=seg.wire_bytes)
        if self.obs is not None:
            self.obs.gauge("epoch.stored_bytes").set(self.stored_bytes)
        return self.epoch

    def patches_since(self, epoch: int) -> list[HintPatch]:
        """The RAW patch chain a client at `epoch` needs to reach the head.

        A full patch in the chain subsumes everything before it, so only the
        suffix from the last full patch onward is returned.  `chain_since`
        is the compaction-aware variant every client-facing path uses.
        """
        if not 0 <= epoch <= self.epoch:
            raise StaleEpochError(epoch, self.epoch)
        return _subsume_full(self._patches[epoch:])

    def chain_since(self, epoch: int,
                    until: int | None = None) -> list[HintPatch]:
        """The MINIMAL patch chain from `epoch` to `until` (default: head).

        Greedy walk preferring compacted segments: at each epoch take the
        segment starting there if one exists and does not overshoot the
        target, else the raw patch.  A full patch anywhere in the chain
        (rebuild epoch, or a segment that absorbed one) drops everything
        before it.
        """
        goal = self.epoch if until is None else until
        if not 0 <= epoch <= goal <= self.epoch:
            raise StaleEpochError(epoch, self.epoch)
        chain: list[HintPatch] = []
        e = epoch
        while e < goal:
            p = self._segments.get(e)
            if p is None or p.to_epoch > goal:
                p = self._patches[e]
            chain.append(p)
            e = p.to_epoch
        return _subsume_full(chain)

    def chain_bytes(self, epoch: int, until: int | None = None) -> int:
        """Exact downlink bytes of `chain_since(epoch, until)` (0 if fresh)."""
        return sum(p.wire_bytes for p in self.chain_since(epoch, until))

    def download_chain(self, epoch: int,
                       until: int | None = None) -> list[HintPatch]:
        """`chain_since`, as seen over the WIRE (the fault-injectable copy).

        Every client-side sync path downloads through here.  With a fault
        injector armed, the "update.hint.chain" site can corrupt one patch
        of the returned list — a bit flip on a COPY, the log's own storage
        is untouched — which the client's `HintPatch.verify` catches at
        decode time.  Unarmed, this IS `chain_since` (same objects, no
        copies), so the no-fault path stays allocation- and bit-identical.
        """
        chain = self.chain_since(epoch, until)
        if self.faults is not None and chain:
            due = self.faults.fire("update.hint.chain")
            if due:
                i = due[0].device % len(chain)
                chain = list(chain)
                chain[i] = _tampered(chain[i])
                if self.obs is not None:
                    self.obs.counter("fleet.chain_corruptions").inc()
        return chain

    @property
    def stored_bytes(self) -> int:
        """Server-side storage: raw patches plus compacted segments."""
        return (sum(p.wire_bytes for p in self._patches)
                + sum(p.wire_bytes for p in self._segments.values()))

    def check_fresh(self, epoch: int):
        """Raise StaleEpochError unless `epoch` is the published head."""
        if epoch != self.epoch:
            raise StaleEpochError(epoch, self.epoch)


def _tampered(patch: HintPatch) -> HintPatch:
    """A transit-corrupted copy of `patch`: one payload bit flipped, the
    stamped crc kept — exactly what `HintPatch.verify` must catch."""
    if patch.is_full:
        full = np.array(patch.full_hint, copy=True)
        full.flat[0] ^= 1
        return dataclasses.replace(patch, full_hint=full)
    delta = np.array(patch.delta, copy=True)
    delta.flat[0] ^= 1
    return dataclasses.replace(patch, delta=delta)


def _subsume_full(chain: list[HintPatch]) -> list[HintPatch]:
    """Suffix of `chain` from its last full patch onward (whole chain if
    none): a full patch carries the complete hint, so nothing before it
    needs to travel."""
    for i in range(len(chain) - 1, -1, -1):
        if chain[i].is_full:
            return chain[i:]
    return chain


class HintCache:
    """Client-side cached hint with patch-based freshness tracking.

    Accounts every byte the client downloads (`bytes_downloaded`) so the
    freshness cost can be compared against re-fetching `cfg.hint_bytes`.
    """

    def __init__(self, hint: jnp.ndarray, cfg: pir.PIRConfig, epoch: int = 0):
        self.hint = jnp.asarray(hint, U32)
        self.cfg = cfg
        self.epoch = epoch
        self.bytes_downloaded = cfg.hint_bytes      # bootstrap download
        self.resyncs = 0          # corrupt-chain recoveries (full downloads)
        self._a_mat = lwe.gen_public_matrix(cfg.a_seed, cfg.n, cfg.params.k)

    def apply(self, patch: HintPatch):
        """Patch the cached (m, k) u32 hint one epoch forward (exact)."""
        if patch.from_epoch != self.epoch:
            raise StaleEpochError(self.epoch, patch.from_epoch)
        if patch.is_full and patch.cfg is not None and patch.cfg != self.cfg:
            self.cfg = patch.cfg
            self._a_mat = lwe.gen_public_matrix(
                self.cfg.a_seed, self.cfg.n, self.cfg.params.k)
        self.hint = patch.apply(self.hint, self._a_mat)
        self.epoch = patch.to_epoch
        self.bytes_downloaded += patch.wire_bytes

    def sync(self, log: EpochLog) -> int:
        """Catch up to the log head; returns bytes downloaded for the sync.

        Downloads the MINIMAL chain (`EpochLog.download_chain`): compacted
        segments where the log has them, raw patches elsewhere.  Applying
        the chain is bit-identical to applying every raw patch — and to a
        fresh full-hint download (tests/test_hint_chains.py).

        Every patch is checksum-verified BEFORE it touches the cached
        hint; a corrupt or truncated download triggers one deterministic
        full re-sync (the wasted chain bytes AND the full download are
        both charged to `bytes_downloaded` — corruption costs downlink,
        never correctness).
        """
        before = self.bytes_downloaded
        chain = (log.download_chain(self.epoch)
                 if hasattr(log, "download_chain")
                 else log.chain_since(self.epoch))
        if not all(p.verify() for p in chain):
            self.bytes_downloaded += sum(p.wire_bytes for p in chain)
            self.resyncs += 1
            if log.obs is not None:
                log.obs.counter("fleet.full_resyncs").inc()
            if getattr(log, "full_fetch", None) is None:
                raise CorruptPatchError(
                    f"corrupt patch chain from epoch {self.epoch} and no "
                    "full-hint fallback on the log")
            full = log.full_fetch(self.epoch)
            assert full.is_full and full.verify(), "fallback must be clean"
            self.apply(full)
            return self.bytes_downloaded - before
        for patch in chain:
            if patch.from_epoch != self.epoch and patch.is_full:
                self.epoch = patch.from_epoch   # full patch subsumes the gap
            self.apply(patch)
        return self.bytes_downloaded - before

    def client(self) -> pir.PIRClient:
        """A PIRClient snapshotting this cache's current cfg + hint."""
        return pir.PIRClient(self.cfg, self.hint)
