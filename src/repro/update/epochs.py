"""Versioned hint epochs: the client-visible side of the live index.

Every committed mutation batch publishes a new epoch e → e+1 together with a
`HintPatch` that transforms a cached epoch-e hint into the epoch-(e+1) hint
*bit-exactly*.  Two patch kinds:

delta patch (the common case)
    Carries the raw DB column delta `ΔD[:,J]` (int16, entries ∈ [−255, 255])
    truncated to the first `r` rows that can differ (max used length of the
    touched columns).  The client recomputes `ΔH = ΔD·A[J,:]` locally — A is
    public and seed-derived, so it never travels.  Wire size is
    `16 + 4·|J| + 2·r·|J|` bytes vs `4·m·k` for the full hint: the download
    ratio is ≈ |J|·r / (2·k·m), e.g. 5% of 4096 clusters at k=1024 ⇒ ~10⁻²
    of a re-download even before row truncation.

full patch (rebuild epochs)
    Published when the planner triggers a full rebuild (column overflow or
    pad-fraction degradation); carries the fresh hint and the new PIRConfig
    (m and LWE params may change).  Costs `hint_bytes`, same as bootstrap.

All arithmetic is uint32 wraparound (mod 2^32), matching the server's
`PIRServer.update_columns` path, so `patch(H)` equals `server.setup()` on
the rebuilt DB bit-for-bit.

Publication timing: under the pipelined serving engine a commit is staged
into shadow buffers first (`LiveIndex.stage`) and `EpochLog.publish`
happens inside the pointer swap (`LiveIndex.publish`) — i.e. the epoch
counter, the server buffers and the patch log all advance at the same
instant, which is what lets `check_fresh` remain a plain equality test
with no read locks: a query either sees the old epoch everywhere or the
new epoch everywhere.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import lwe, pir

U32 = jnp.uint32

_PATCH_HEADER_BYTES = 16   # from_epoch u32 | to_epoch u32 | n_cols u32 | nrows u32


class StaleEpochError(RuntimeError):
    """A query/patch was formed against an epoch the server has moved past."""

    def __init__(self, have: int, want: int):
        super().__init__(f"stale epoch {have}; server is at {want}")
        self.have = have
        self.want = want


@dataclasses.dataclass(frozen=True)
class HintPatch:
    """Transforms the epoch-`from_epoch` hint into the `to_epoch` hint."""
    from_epoch: int
    to_epoch: int
    cols: np.ndarray | None = None        # (J,) int64 touched cluster ids
    delta: np.ndarray | None = None       # (r, J) int16: D_new − D_old rows <r
    full_hint: np.ndarray | None = None   # (m, k) u32 — rebuild epochs only
    cfg: pir.PIRConfig | None = None      # new config on rebuild epochs

    @property
    def is_full(self) -> bool:
        """True for rebuild epochs: the patch carries a whole (m, k) hint."""
        return self.full_hint is not None

    @property
    def wire_bytes(self) -> int:
        """Downlink cost of shipping this patch (cf. PIRConfig.hint_bytes)."""
        if self.is_full:
            return _PATCH_HEADER_BYTES + self.full_hint.size * 4
        return (_PATCH_HEADER_BYTES + 4 * len(self.cols)
                + 2 * self.delta.size)

    def apply(self, hint: jnp.ndarray, a_mat: jnp.ndarray) -> jnp.ndarray:
        """hint → patched hint (exact mod 2^32; bit-identical to a rebuild).

        a_mat: the client's seed-derived public matrix A (n, k) u32.
        """
        if self.is_full:
            return jnp.asarray(self.full_hint, U32)
        r = self.delta.shape[0]
        # int16 → int32 → u32 wraps negatives to their mod-2^32 residues,
        # so the u32 GEMM below is the exact ring product ΔD·A[J,:].
        d_u32 = jnp.asarray(self.delta.astype(np.int32)).astype(U32)
        a_j = jnp.asarray(a_mat)[jnp.asarray(self.cols)].astype(U32)
        return hint.at[:r].add(jnp.matmul(d_u32, a_j))


class EpochLog:
    """Server-side publication log: monotone epochs + their patches."""

    def __init__(self):
        self.epoch = 0
        self._patches: list[HintPatch] = []

    def publish(self, patch: HintPatch) -> int:
        """Append the next epoch's patch; returns the new head epoch."""
        assert patch.from_epoch == self.epoch, (patch.from_epoch, self.epoch)
        assert patch.to_epoch == self.epoch + 1
        self._patches.append(patch)
        self.epoch = patch.to_epoch
        return self.epoch

    def patches_since(self, epoch: int) -> list[HintPatch]:
        """The patch chain a client at `epoch` needs to reach the head.

        A full patch in the chain subsumes everything before it, so only the
        suffix from the last full patch onward is returned.
        """
        if not 0 <= epoch <= self.epoch:
            raise StaleEpochError(epoch, self.epoch)
        chain = self._patches[epoch:]
        for i in range(len(chain) - 1, -1, -1):
            if chain[i].is_full:
                return chain[i:]
        return chain

    def check_fresh(self, epoch: int):
        """Raise StaleEpochError unless `epoch` is the published head."""
        if epoch != self.epoch:
            raise StaleEpochError(epoch, self.epoch)


class HintCache:
    """Client-side cached hint with patch-based freshness tracking.

    Accounts every byte the client downloads (`bytes_downloaded`) so the
    freshness cost can be compared against re-fetching `cfg.hint_bytes`.
    """

    def __init__(self, hint: jnp.ndarray, cfg: pir.PIRConfig, epoch: int = 0):
        self.hint = jnp.asarray(hint, U32)
        self.cfg = cfg
        self.epoch = epoch
        self.bytes_downloaded = cfg.hint_bytes      # bootstrap download
        self._a_mat = lwe.gen_public_matrix(cfg.a_seed, cfg.n, cfg.params.k)

    def apply(self, patch: HintPatch):
        """Patch the cached (m, k) u32 hint one epoch forward (exact)."""
        if patch.from_epoch != self.epoch:
            raise StaleEpochError(self.epoch, patch.from_epoch)
        if patch.is_full and patch.cfg is not None and patch.cfg != self.cfg:
            self.cfg = patch.cfg
            self._a_mat = lwe.gen_public_matrix(
                self.cfg.a_seed, self.cfg.n, self.cfg.params.k)
        self.hint = patch.apply(self.hint, self._a_mat)
        self.epoch = patch.to_epoch
        self.bytes_downloaded += patch.wire_bytes

    def sync(self, log: EpochLog) -> int:
        """Catch up to the log head; returns bytes downloaded for the sync."""
        before = self.bytes_downloaded
        for patch in log.patches_since(self.epoch):
            if patch.from_epoch != self.epoch and patch.is_full:
                self.epoch = patch.from_epoch   # full patch subsumes the gap
            self.apply(patch)
        return self.bytes_downloaded - before

    def client(self) -> pir.PIRClient:
        """A PIRClient snapshotting this cache's current cfg + hint."""
        return pir.PIRClient(self.cfg, self.hint)
