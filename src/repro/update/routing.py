"""Cluster → bucket routing for live-index deltas into batch-PIR state.

The live index patches the flat system hint with ΔH = ΔD[:,J]·A[J,:].  When
batch-PIR is enabled the same mutated columns ALSO live as replicas inside
up to three cuckoo buckets each, every bucket carrying its own hint
H_b = D_b·A_b.  This module is the thin bridge: given the re-packed columns
of a committed mutation batch, route each touched cluster to its owning
buckets and let `BatchPIRServer.update_columns` apply the exact per-bucket
sub-DB swap + sparse hint patch (or a single-bucket rebuild on row-budget
overflow).

Kept in `update/` rather than `batchpir/` because the *decision* of when a
delta flows is epoch/commit logic: `LiveIndex` calls here once per commit,
after the flat-system patch, so both hint families advance in the same
epoch and stay bit-identical to a from-scratch setup of the mutated DB.
"""
from __future__ import annotations

import numpy as np


def touched_buckets(partition, cols) -> list[int]:
    """Sorted bucket ids owning a replica of any touched cluster."""
    out: set[int] = set()
    for j in cols:
        out.update(partition.buckets_of(int(j)))
    return sorted(out)


def patch_batch_hints(system, cols: np.ndarray, new_cols: np.ndarray,
                      new_used: dict[int, int]) -> list:
    """Propagate one committed mutation batch into the batch-PIR subsystem.

    No-op (empty list) when batch-PIR isn't enabled.  Otherwise returns the
    per-bucket `BucketUpdate` records (delta-patched or rebuilt).
    """
    staged = stage_batch_hints(system, cols, new_cols, new_used)
    return staged.publish() if staged is not None else []


def stage_batch_hints(system, cols: np.ndarray, new_cols: np.ndarray,
                      new_used: dict[int, int], *, donate: bool = False):
    """Shadow-commit variant: compute the bucket patches, defer the swap.

    Returns the `StagedBucketPatch` (or None when batch-PIR is off); the
    live-index publish step calls its `.publish()` inside the same pointer
    swap that flips the flat DB/hint, so both hint families advance
    atomically from the serving path's point of view.
    """
    bp = getattr(system, "batch", None)
    if bp is None:
        return None
    return bp.server.stage_update_columns(np.asarray(cols),
                                          np.asarray(new_cols),
                                          new_used, donate=donate)


def rebuild_batch(old_system, new_system) -> None:
    """Full-rebuild epochs re-bucketize: cluster contents and column
    geometry may all have changed, so the subsystem is rebuilt on the fresh
    system with the SAME (kappa, n_buckets, seed) knobs the old one used."""
    bp = getattr(old_system, "batch", None)
    if bp is None:
        return
    new_system.enable_batch(kappa=bp.kappa,
                            n_buckets=bp.partition.n_buckets,
                            seed=bp.seed)
