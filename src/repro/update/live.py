"""LiveIndex: streaming corpus mutations over a PirRagSystem.

Mutations accumulate in the journal; `commit()` folds the pending batch into
one published epoch:

    plan      — planner resolves the batch, accounts column capacity
    repack    — `chunking.rebuild_columns` re-serializes only touched columns
    delta     — `PIRServer.update_columns` swaps the columns in-place and
                returns ΔH = ΔD[:,J]·A[J,:] via the modmatmul kernel path
    publish   — EpochLog gains a HintPatch; clients `HintCache.sync()` to
                patch their cached hint instead of re-downloading it

When the planner trips a full-rebuild trigger (insert overflowing the m-row
budget, or pad_fraction degrading past the threshold after deletes), the
epoch is published as a full-hint patch over a freshly re-clustered system.

Exactness invariant (tested): after any mutation sequence, the incrementally
patched hint — server-side AND client-side — is bit-identical to
`server.setup()` on the rebuilt database.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import chunking, pipeline
from repro.fleet.faults import InjectedCommitFault
from repro.kernels import ops
from repro.obs import DEFAULT_SIZE_BUCKETS, Obs
from repro.update import journal as journal_lib
from repro.update import planner, routing
from repro.update.epochs import EpochLog, HintPatch

U32 = jnp.uint32


@dataclasses.dataclass
class CommitStats:
    epoch: int
    n_mutations: int
    touched_clusters: int
    full_rebuild: bool
    reason: str | None
    seconds: float
    patch_bytes: int


@dataclasses.dataclass
class StagedEpoch:
    """One commit's shadow buffers: computed while the live epoch serves.

    `LiveIndex.stage()` plans the mutation batch, re-packs the touched
    columns and DISPATCHES every device-side patch (ΔH GEMMs, column
    scatters, bucket patches) against shadow copies — the serving path's
    pointers are untouched, so queries keep being planned, answered and
    decoded at the old epoch throughout.  `LiveIndex.publish()` then flips
    the pointers and advances the epoch log: the stale-reject window is the
    swap, not the patch computation.
    """
    patch: HintPatch
    plan: planner.UpdatePlan
    n_mutations: int
    t0: float
    _apply: Callable[[], None]


class LiveIndex:
    """A PirRagSystem that accepts insert/delete/replace without downtime."""

    def __init__(self, system: pipeline.PirRagSystem,
                 texts, embeddings, *,
                 doc_ids=None,
                 max_pad_fraction: float = 0.95,
                 compact_every: int | None = None,
                 rebuild_kwargs: dict | None = None):
        assert system.assignment is not None, "build system via PirRagSystem.build"
        assert system.db.used_bytes is not None
        self.system = system
        self.journal = journal_lib.MutationJournal()
        # compact_every=C chains patches with periodic compaction: a client
        # K epochs behind downloads O(K/C) precomputed segments, not K
        # patches (the hint-delivery layer; see update.epochs.EpochLog)
        self.epochs = EpochLog(compact_every=compact_every)
        self.max_pad_fraction = max_pad_fraction
        self._rebuild_kwargs = dict(rebuild_kwargs or {})
        # _commit_full supplies the then-current id set itself
        self._rebuild_kwargs.pop("doc_ids", None)
        if system.keyed is None:
            self._rebuild_kwargs.setdefault("n_clusters", system.db.n)
        # Full rebuilds re-run the ENTIRE offline build; a sharded system
        # must rebuild through the same sharded path (mesh-parallel K-means,
        # per-shard packing) rather than fall back to a host-side build that
        # would then reshard — at scale the rebuild epoch is exactly where
        # the single-host path stops fitting.
        self._rebuild_kwargs.setdefault("mesh", system.mesh)
        self._rebuild_kwargs.setdefault("mesh_axes", system.mesh_axes)
        self.commits: list[CommitStats] = []
        # Observability handle: a serve loop replaces this with its own via
        # set_obs() so commit spans land in the SAME trace as serve ticks.
        self.obs = Obs(trace=False)
        self.epochs.obs = self.obs
        # Fault-injection hook (repro.fleet.faults.FaultInjector): `stage`
        # guards the "update.commit.stage" site — an injected failure
        # raises BEFORE any shadow state is computed, so the pending
        # journal batch stays intact and the serving epoch never moves.
        # Recovery replay (repro.fleet.recovery) clears it around replays.
        self.faults = None
        # A client that detects a corrupt patch chain recovers by fetching
        # the CURRENT full hint (one deterministic full re-sync).
        self.epochs.full_fetch = self._full_patch

        ids = (np.arange(len(texts)) if doc_ids is None
               else np.asarray(doc_ids))
        embs = np.asarray(embeddings, np.float32)
        self._docs = {int(i): (texts[p], embs[p])
                      for p, i in enumerate(ids)}
        self._cluster_of = {int(i): int(system.assignment[p])
                            for p, i in enumerate(ids)}
        self._used = {j: int(system.db.used_bytes[j])
                      for j in range(system.db.n)}

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, texts, embeddings, *, n_clusters: int,
              max_pad_fraction: float = 0.95, doc_ids=None,
              compact_every: int | None = None,
              **build_kwargs) -> "LiveIndex":
        """Offline-build a PirRagSystem and wrap it as a live index.

        texts: N byte strings; embeddings: (N, d) f32; extra kwargs
        (incl. ``mesh=`` for a sharded build) forward to
        `PirRagSystem.build` AND are replayed on every full rebuild, so a
        sharded index rebuilds through the sharded path.
        ``compact_every=C`` enables periodic hint-patch compaction in the
        epoch log (the many-epoch hint-delivery path).
        """
        system = pipeline.PirRagSystem.build(
            texts, embeddings, n_clusters=n_clusters, doc_ids=doc_ids,
            **build_kwargs)
        return cls(system, texts, embeddings, doc_ids=doc_ids,
                   max_pad_fraction=max_pad_fraction,
                   compact_every=compact_every,
                   rebuild_kwargs=dict(n_clusters=n_clusters, **build_kwargs))

    @classmethod
    def build_keyed(cls, table, *, max_pad_fraction: float = 0.95,
                    compact_every: int | None = None,
                    **build_kwargs) -> "LiveIndex":
        """Offline-build a KEYED system (embedding table) as a live index.

        table: (V, d) f32; extra kwargs forward to
        `PirRagSystem.build_keyed` and are replayed on full rebuilds.  Row i
        is doc i with the id-derived group assignment, so `replace(i, ...)`
        streams fresh embedding rows through the standard delta-epoch path
        (replaced records keep the fixed keyed width, so a replace can
        never overflow a column).  Keyed mutations are REPLACE-only: the
        table's id space must stay dense 0..V-1 for the client's stride
        arithmetic, so inserts/deletes trip the planner's keyed guard.
        """
        table = np.ascontiguousarray(table, np.float32)
        system = pipeline.PirRagSystem.build_keyed(table, **build_kwargs)
        layout = system.keyed
        texts = [layout.row_text(table[i]) for i in range(layout.n_rows)]
        return cls(system, texts, table,
                   max_pad_fraction=max_pad_fraction,
                   compact_every=compact_every,
                   rebuild_kwargs=dict(build_kwargs))

    def set_obs(self, obs: Obs) -> None:
        """Adopt `obs` (a serve loop's handle) for commit/compaction events."""
        self.obs = obs
        self.epochs.obs = obs

    # -- introspection -------------------------------------------------------

    @property
    def epoch(self) -> int:
        """The published epoch number (0 before any commit)."""
        return self.epochs.epoch

    @property
    def n_docs(self) -> int:
        """Documents in the PUBLISHED epoch (pending mutations excluded)."""
        return len(self._docs)

    def pad_fraction(self) -> float:
        """Current zero-padding share of the (m, n) matrix (rebuild gauge)."""
        db = self.system.db
        return 1.0 - sum(self._used.values()) / float(db.m * db.n)

    def doc_ids(self) -> list[int]:
        """Sorted external doc ids of the published epoch."""
        return sorted(self._docs)

    # -- mutation intake -----------------------------------------------------

    def insert(self, doc_id: int, text: bytes, emb: np.ndarray):
        """Journal an insert (emb: (d,) f32); visible at the next commit."""
        self.journal.append(journal_lib.insert(doc_id, text, emb))

    def delete(self, doc_id: int):
        """Journal a delete; visible at the next commit."""
        self.journal.append(journal_lib.delete(doc_id))

    def replace(self, doc_id: int, text: bytes, emb: np.ndarray):
        """Journal a replace (emb: (d,) f32); visible at the next commit."""
        self.journal.append(journal_lib.replace(doc_id, text, emb))

    def replace_row(self, row_id: int, row: np.ndarray):
        """Journal a KEYED row replace; the record payload is the row itself.

        Keyed records carry the row's raw f32 bytes as their text payload
        (`KeyedLayout.row_text`), so callers hand over just the new row and
        the (fixed-width) record stays in the id-derived group — the next
        commit ships it as an ordinary delta epoch.
        """
        layout = self.system.keyed
        if layout is None:
            raise ValueError("replace_row needs a keyed (build_keyed) index")
        row = np.asarray(row, np.float32)
        self.journal.append(journal_lib.replace(
            row_id, layout.row_text(row), row))

    # -- commit --------------------------------------------------------------

    def commit(self, *, donate: bool = False) -> HintPatch | None:
        """Fold all pending mutations into one published epoch.

        Equivalent to ``publish(stage())`` — the synchronous path runs the
        two halves back-to-back.  ``donate=True`` (engine-only) patches the
        server-side DB buffers in place instead of copying them per epoch;
        see `PIRServer.stage_update` for the aliasing contract.
        """
        staged = self.stage(donate=donate)
        return self.publish(staged) if staged is not None else None

    def stage(self, *, donate: bool = False) -> StagedEpoch | None:
        """Compute one commit's shadow state without publishing it.

        Everything device-side is dispatched (JAX async) against fresh —
        or, with ``donate=True``, in-place aliased — buffers; nothing the
        serving path reads has moved when this returns.  Returns None when
        no mutations are pending.
        """
        muts = self.journal.pending()
        if not muts:
            return None
        if self.faults is not None and self.faults.fire("update.commit.stage"):
            raise InjectedCommitFault(
                f"injected stage failure at epoch {self.epochs.epoch} "
                f"({len(muts)} pending mutations, batch retryable)")
        t0 = time.perf_counter()
        db = self.system.db
        keyed = self.system.keyed
        if keyed is not None:
            # The client decodes by id arithmetic over a dense 0..V-1 space;
            # inserts/deletes would punch holes in it.  Replaced rows must
            # also STAY in their id-derived group, so the planner routes by
            # the public layout, not by embedding similarity.
            for m_ in muts:
                if m_.kind != journal_lib.REPLACE:
                    raise ValueError(
                        f"keyed index supports replace only, got {m_.kind} "
                        f"for doc {m_.doc_id}")
        assign_fn = (None if keyed is None
                     else (lambda i, e: keyed.group_of(i)))
        with self.obs.span("commit.stage", mutations=len(muts)) as sp:
            plan = planner.plan_updates(
                muts, docs=self._docs, cluster_of=self._cluster_of,
                centroids=self.system.centroids, m=db.m,
                used_bytes=self._used, n_clusters=db.n, emb_dim=db.emb_dim,
                max_pad_fraction=self.max_pad_fraction,
                assign_fn=assign_fn)
            sp.set(kind="full" if plan.full_rebuild else "delta",
                   touched=len(plan.touched))
            if plan.full_rebuild:
                with self.obs.span("commit.rebuild", docs=len(plan.new_docs)):
                    patch, apply = self._stage_full(plan)
            else:
                patch, apply = self._stage_delta(plan, donate=donate)
        return StagedEpoch(patch=patch, plan=plan, n_mutations=len(muts),
                           t0=t0, _apply=apply)

    def publish(self, staged: StagedEpoch) -> HintPatch:
        """Flip the staged pointers and advance the epoch: the swap instant.

        Queries planned before this call keep decoding against their
        snapshot of the old epoch; queries planned after it are formed —
        and admitted — at the new one.
        """
        plan, patch = staged.plan, staged.patch
        with self.obs.span("commit.publish",
                           kind="full" if plan.full_rebuild else "delta",
                           epoch=self.epochs.epoch + 1):
            staged._apply()
            self.epochs.publish(patch)
        self.journal.mark_committed(self.epochs.epoch)
        self._docs = plan.new_docs
        self._cluster_of = plan.new_cluster_of
        self.obs.counter("commit.epochs").inc()
        self.obs.counter("commit.mutations").inc(staged.n_mutations)
        if plan.full_rebuild:
            self.obs.counter("commit.full_rebuilds").inc()
        self.obs.histogram("commit.patch_bytes",
                           bounds=DEFAULT_SIZE_BUCKETS).record(
                               patch.wire_bytes)
        self.commits.append(CommitStats(
            epoch=self.epochs.epoch, n_mutations=staged.n_mutations,
            touched_clusters=len(plan.touched),
            full_rebuild=plan.full_rebuild, reason=plan.reason,
            seconds=time.perf_counter() - staged.t0,
            patch_bytes=patch.wire_bytes))
        return patch

    def _stage_delta(self, plan: planner.UpdatePlan, *, donate: bool
                     ) -> tuple[HintPatch, Callable[[], None]]:
        db, system = self.system.db, self.system
        cols, new_cols, used = chunking.rebuild_columns(
            db.m, plan.docs_by_cluster)

        # Row truncation for the patch: beyond the max used length of the
        # old and new touched columns both sides are zero padding, so ΔD
        # there is identically zero and need not travel.
        old_used = max(self._used[int(j)] for j in cols)
        r = max(old_used, max(used.values()))
        old_rows = np.asarray(system.server.db[:, jnp.asarray(cols)])[:r]
        delta = (new_cols[:r].astype(np.int16)
                 - old_rows.astype(np.int16))           # entries ∈ [−255, 255]

        cols_j, new_cols_j = jnp.asarray(cols), jnp.asarray(new_cols)
        delta_h = system.server.stage_delta(cols_j, new_cols_j)
        # The donating column scatter is DEFERRED to publish(): an exception
        # later in this stage tail, or a caller dropping the StagedEpoch
        # unpublished, must leave server.db serving the old epoch — never
        # pointing at a consumed buffer.  Without donation the scatter is a
        # fresh buffer, so it overlaps here in the (shadowable) stage phase.
        new_db_arr = (None if donate
                      else system.server.stage_scatter(cols_j, new_cols_j))
        # u32 wraparound: exact.  ΔH is transient, so the add donates ITS
        # buffer; the old hint array survives for in-flight decode snapshots.
        new_hint = (ops.add_delta(system.hint, delta_h)
                    if system.mesh is None else system.hint + delta_h)
        # Batch-PIR replicas (if enabled) take the same exact delta, routed
        # to each touched cluster's owning buckets.
        staged_batch = routing.stage_batch_hints(system, cols, new_cols,
                                                 used, donate=donate)

        def apply():
            system.server.db = (
                system.server.stage_scatter(cols_j, new_cols_j, donate=True)
                if donate else new_db_arr)
            system.hint = new_hint
            if staged_batch is not None:
                staged_batch.publish()
            # Mirror the host-side ChunkedDB view (tests/tools read
            # db.matrix).  Patched in place: copying the full (m, n) matrix
            # per commit would make host cost O(DB) and swamp the O(m·|J|)
            # delta path at scale.
            db.matrix[:, cols] = new_cols
            for j in cols:
                db.cluster_sizes[j] = len(plan.docs_by_cluster[int(j)])
                self._used[int(j)] = used[int(j)]
                db.used_bytes[j] = used[int(j)]
            self.system.db = dataclasses.replace(
                db, n_docs=len(plan.new_docs),
                pad_fraction=1.0 - sum(self._used.values())
                / float(db.m * db.n))

        return HintPatch(from_epoch=self.epochs.epoch,
                         to_epoch=self.epochs.epoch + 1,
                         cols=np.asarray(cols), delta=delta), apply

    def _stage_full(self, plan: planner.UpdatePlan
                    ) -> tuple[HintPatch, Callable[[], None]]:
        """Overflow / pad-degradation: re-cluster, re-pack, re-hint.

        Naturally shadowed: the rebuilt system is a fresh object graph, so
        the whole build (clustering, packing, hint GEMM, re-bucketing)
        happens while the old system keeps serving; publish is one pointer
        swap.
        """
        ids = sorted(plan.new_docs)
        texts = [plan.new_docs[i][0] for i in ids]
        embs = np.stack([plan.new_docs[i][1] for i in ids])
        if self.system.keyed is not None:
            # Keyed rebuild: the id space is dense (replace-only), so the
            # doc set IS the table — rebuild through the keyed path with
            # the same layout/bucket knobs.
            assert ids == list(range(len(ids))), "keyed id space not dense"
            lay, bp = self.system.keyed, self.system.batch
            kw = {k: v for k, v in self._rebuild_kwargs.items()
                  if k not in ("group_size", "kappa", "n_buckets",
                               "batch_seed")}
            new_system = pipeline.PirRagSystem.build_keyed(
                embs, group_size=lay.group_size, kappa=bp.kappa,
                n_buckets=bp.partition.n_buckets, batch_seed=bp.seed, **kw)
            plan.new_cluster_of.clear()
            plan.new_cluster_of.update(
                {i: int(new_system.assignment[p])
                 for p, i in enumerate(ids)})

            def apply_keyed():
                self.system = new_system
                self._used = {j: int(new_system.db.used_bytes[j])
                              for j in range(new_system.db.n)}

            return HintPatch(from_epoch=self.epochs.epoch,
                             to_epoch=self.epochs.epoch + 1,
                             full_hint=np.asarray(new_system.hint),
                             cfg=new_system.cfg), apply_keyed
        new_system = pipeline.PirRagSystem.build(
            texts, embs, doc_ids=ids, **self._rebuild_kwargs)
        routing.rebuild_batch(self.system, new_system)
        # Rebuild re-clusters, so the plan's incremental cluster map is stale.
        plan.new_cluster_of.clear()
        plan.new_cluster_of.update(
            {i: int(new_system.assignment[p]) for p, i in enumerate(ids)})

        def apply():
            self.system = new_system
            self._used = {j: int(new_system.db.used_bytes[j])
                          for j in range(new_system.db.n)}

        return HintPatch(from_epoch=self.epochs.epoch,
                         to_epoch=self.epochs.epoch + 1,
                         full_hint=np.asarray(new_system.hint),
                         cfg=new_system.cfg), apply

    def _full_patch(self, from_epoch: int) -> HintPatch:
        """A sealed full-hint patch `from_epoch` → head (corrupt-chain
        fallback: costs `cfg.hint_bytes`, same as bootstrap)."""
        return HintPatch(from_epoch=from_epoch, to_epoch=self.epochs.epoch,
                         full_hint=np.asarray(self.system.hint),
                         cfg=self.system.cfg).sealed()

    # -- epoch-checked queries ----------------------------------------------

    def check_epoch(self, epoch: int):
        """Raise StaleEpochError unless `epoch` is the published head."""
        self.epochs.check_fresh(epoch)

    def query(self, query_emb: np.ndarray, *, epoch: int, **kwargs):
        """Epoch-checked private query (kwargs forwarded to the system).

        A query formed against a stale cached hint would decode garbage, so
        the server rejects it up front; the client syncs its HintCache and
        retries.
        """
        self.check_epoch(epoch)
        return self.system.query(query_emb, **kwargs)

    def query_batch(self, query_embs: np.ndarray, *, epoch: int, **kwargs):
        """Epoch-checked batched query ((B, d) f32; kwargs to the system)."""
        self.check_epoch(epoch)
        return self.system.query_batch(query_embs, **kwargs)

    def lookup(self, ids, *, epoch: int, **kwargs):
        """Epoch-checked keyed row lookup (kwargs to `PirRagSystem.lookup`)."""
        self.check_epoch(epoch)
        return self.system.lookup(ids, **kwargs)

    def lookup_batch(self, ids_batch, *, epoch: int, **kwargs):
        """Epoch-checked batched keyed lookup (kwargs to the system)."""
        self.check_epoch(epoch)
        return self.system.lookup_batch(ids_batch, **kwargs)
