"""LiveIndex: streaming corpus mutations over a PirRagSystem.

Mutations accumulate in the journal; `commit()` folds the pending batch into
one published epoch:

    plan      — planner resolves the batch, accounts column capacity
    repack    — `chunking.rebuild_columns` re-serializes only touched columns
    delta     — `PIRServer.update_columns` swaps the columns in-place and
                returns ΔH = ΔD[:,J]·A[J,:] via the modmatmul kernel path
    publish   — EpochLog gains a HintPatch; clients `HintCache.sync()` to
                patch their cached hint instead of re-downloading it

When the planner trips a full-rebuild trigger (insert overflowing the m-row
budget, or pad_fraction degrading past the threshold after deletes), the
epoch is published as a full-hint patch over a freshly re-clustered system.

Exactness invariant (tested): after any mutation sequence, the incrementally
patched hint — server-side AND client-side — is bit-identical to
`server.setup()` on the rebuilt database.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import chunking, pipeline
from repro.update import journal as journal_lib
from repro.update import planner, routing
from repro.update.epochs import EpochLog, HintPatch

U32 = jnp.uint32


@dataclasses.dataclass
class CommitStats:
    epoch: int
    n_mutations: int
    touched_clusters: int
    full_rebuild: bool
    reason: str | None
    seconds: float
    patch_bytes: int


class LiveIndex:
    """A PirRagSystem that accepts insert/delete/replace without downtime."""

    def __init__(self, system: pipeline.PirRagSystem,
                 texts, embeddings, *,
                 doc_ids=None,
                 max_pad_fraction: float = 0.95,
                 rebuild_kwargs: dict | None = None):
        assert system.assignment is not None, "build system via PirRagSystem.build"
        assert system.db.used_bytes is not None
        self.system = system
        self.journal = journal_lib.MutationJournal()
        self.epochs = EpochLog()
        self.max_pad_fraction = max_pad_fraction
        self._rebuild_kwargs = dict(rebuild_kwargs or {})
        # _commit_full supplies the then-current id set itself
        self._rebuild_kwargs.pop("doc_ids", None)
        self._rebuild_kwargs.setdefault("n_clusters", system.db.n)
        self.commits: list[CommitStats] = []

        ids = (np.arange(len(texts)) if doc_ids is None
               else np.asarray(doc_ids))
        embs = np.asarray(embeddings, np.float32)
        self._docs = {int(i): (texts[p], embs[p])
                      for p, i in enumerate(ids)}
        self._cluster_of = {int(i): int(system.assignment[p])
                            for p, i in enumerate(ids)}
        self._used = {j: int(system.db.used_bytes[j])
                      for j in range(system.db.n)}

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, texts, embeddings, *, n_clusters: int,
              max_pad_fraction: float = 0.95, doc_ids=None,
              **build_kwargs) -> "LiveIndex":
        system = pipeline.PirRagSystem.build(
            texts, embeddings, n_clusters=n_clusters, doc_ids=doc_ids,
            **build_kwargs)
        return cls(system, texts, embeddings, doc_ids=doc_ids,
                   max_pad_fraction=max_pad_fraction,
                   rebuild_kwargs=dict(n_clusters=n_clusters, **build_kwargs))

    # -- introspection -------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.epochs.epoch

    @property
    def n_docs(self) -> int:
        return len(self._docs)

    def pad_fraction(self) -> float:
        db = self.system.db
        return 1.0 - sum(self._used.values()) / float(db.m * db.n)

    def doc_ids(self) -> list[int]:
        return sorted(self._docs)

    # -- mutation intake -----------------------------------------------------

    def insert(self, doc_id: int, text: bytes, emb: np.ndarray):
        self.journal.append(journal_lib.insert(doc_id, text, emb))

    def delete(self, doc_id: int):
        self.journal.append(journal_lib.delete(doc_id))

    def replace(self, doc_id: int, text: bytes, emb: np.ndarray):
        self.journal.append(journal_lib.replace(doc_id, text, emb))

    # -- commit --------------------------------------------------------------

    def commit(self) -> HintPatch | None:
        """Fold all pending mutations into one published epoch."""
        muts = self.journal.pending()
        if not muts:
            return None
        t0 = time.perf_counter()
        db = self.system.db
        plan = planner.plan_updates(
            muts, docs=self._docs, cluster_of=self._cluster_of,
            centroids=self.system.centroids, m=db.m,
            used_bytes=self._used, n_clusters=db.n, emb_dim=db.emb_dim,
            max_pad_fraction=self.max_pad_fraction)
        if plan.full_rebuild:
            patch = self._commit_full(plan)
        else:
            patch = self._commit_delta(plan)
        self.epochs.publish(patch)
        self.journal.mark_committed(self.epochs.epoch)
        self._docs = plan.new_docs
        self._cluster_of = plan.new_cluster_of
        self.commits.append(CommitStats(
            epoch=self.epochs.epoch, n_mutations=len(muts),
            touched_clusters=len(plan.touched),
            full_rebuild=plan.full_rebuild, reason=plan.reason,
            seconds=time.perf_counter() - t0,
            patch_bytes=patch.wire_bytes))
        return patch

    def _commit_delta(self, plan: planner.UpdatePlan) -> HintPatch:
        db, system = self.system.db, self.system
        cols, new_cols, used = chunking.rebuild_columns(
            db.m, plan.docs_by_cluster)

        # Row truncation for the patch: beyond the max used length of the
        # old and new touched columns both sides are zero padding, so ΔD
        # there is identically zero and need not travel.
        old_used = max(self._used[int(j)] for j in cols)
        r = max(old_used, max(used.values()))
        old_rows = np.asarray(system.server.db[:, jnp.asarray(cols)])[:r]
        delta = (new_cols[:r].astype(np.int16)
                 - old_rows.astype(np.int16))           # entries ∈ [−255, 255]

        delta_h = system.server.update_columns(jnp.asarray(cols),
                                               jnp.asarray(new_cols))
        system.hint = system.hint + delta_h             # u32 wraparound: exact
        # Batch-PIR replicas (if enabled) take the same exact delta, routed
        # to each touched cluster's owning buckets.
        routing.patch_batch_hints(system, cols, new_cols, used)

        # Mirror the host-side ChunkedDB view (tests/tools read db.matrix).
        # Patched in place: copying the full (m, n) matrix per commit would
        # make host cost O(DB) and swamp the O(m·|J|) delta path at scale.
        db.matrix[:, cols] = new_cols
        for j in cols:
            db.cluster_sizes[j] = len(plan.docs_by_cluster[int(j)])
            self._used[int(j)] = used[int(j)]
            db.used_bytes[j] = used[int(j)]
        self.system.db = dataclasses.replace(
            db, n_docs=len(plan.new_docs),
            pad_fraction=1.0 - sum(self._used.values()) / float(db.m * db.n))
        return HintPatch(from_epoch=self.epochs.epoch,
                         to_epoch=self.epochs.epoch + 1,
                         cols=np.asarray(cols), delta=delta)

    def _commit_full(self, plan: planner.UpdatePlan) -> HintPatch:
        """Overflow / pad-degradation: re-cluster, re-pack, re-hint."""
        ids = sorted(plan.new_docs)
        texts = [plan.new_docs[i][0] for i in ids]
        embs = np.stack([plan.new_docs[i][1] for i in ids])
        new_system = pipeline.PirRagSystem.build(
            texts, embs, doc_ids=ids, **self._rebuild_kwargs)
        routing.rebuild_batch(self.system, new_system)
        self.system = new_system
        # Rebuild re-clusters, so the plan's incremental cluster map is stale.
        plan.new_cluster_of.clear()
        plan.new_cluster_of.update(
            {i: int(new_system.assignment[p]) for p, i in enumerate(ids)})
        self._used = {j: int(new_system.db.used_bytes[j])
                      for j in range(new_system.db.n)}
        return HintPatch(from_epoch=self.epochs.epoch,
                         to_epoch=self.epochs.epoch + 1,
                         full_hint=np.asarray(new_system.hint),
                         cfg=new_system.cfg)

    # -- epoch-checked queries ----------------------------------------------

    def check_epoch(self, epoch: int):
        """Raise StaleEpochError unless `epoch` is the published head."""
        self.epochs.check_fresh(epoch)

    def query(self, query_emb: np.ndarray, *, epoch: int, **kwargs):
        """Epoch-checked private query (kwargs forwarded to the system).

        A query formed against a stale cached hint would decode garbage, so
        the server rejects it up front; the client syncs its HintCache and
        retries.
        """
        self.check_epoch(epoch)
        return self.system.query(query_emb, **kwargs)

    def query_batch(self, query_embs: np.ndarray, *, epoch: int, **kwargs):
        self.check_epoch(epoch)
        return self.system.query_batch(query_embs, **kwargs)
