"""Live-index subsystem: streaming corpus mutations over a PirRagSystem.

The paper's offline/online split assumes a frozen corpus; this package makes
the index *live*.  Because the PIR hint `H = D·A` is linear in the database,
a mutation batch touching clusters J yields an exact sparse patch
`ΔH = ΔD[:,J]·A[J,:]` — a small GEMM instead of a full offline rebuild, and
a tiny versioned download (`HintPatch`) instead of a fresh m×k hint.

Layering:

    journal.py — durable append-only mutation log (insert/delete/replace)
    planner.py — mutations → touched clusters + overflow / pad-degradation
                 full-rebuild triggers (column-capacity accounting)
    epochs.py  — versioned HintPatch wire format, patch composition and
                 periodic compaction (EpochLog segments), client HintCache
    routing.py — cluster→bucket routing of deltas into batch-PIR's
                 per-bucket replica hints (no-op when batch-PIR is off)
    live.py    — LiveIndex: orchestrates plan → column rebuild → delta GEMM
                 → epoch publish, with bit-exactness vs a from-scratch setup
"""
from repro.update.epochs import (EpochLog, HintCache, HintPatch,
                                 StaleEpochError, compact_chain,
                                 compose_patches)
from repro.update.journal import Mutation, MutationJournal
from repro.update.live import LiveIndex
from repro.update.planner import UpdatePlan, plan_updates
from repro.update.routing import patch_batch_hints, touched_buckets

__all__ = [
    "EpochLog", "HintCache", "HintPatch", "StaleEpochError",
    "compact_chain", "compose_patches",
    "Mutation", "MutationJournal", "LiveIndex", "UpdatePlan", "plan_updates",
    "patch_batch_hints", "touched_buckets",
]
