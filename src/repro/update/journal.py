"""Append-only mutation journal for the live index.

Wire format (little-endian), one record per mutation:

    [kind : u8][doc_id : u32][emb_dim : u32][text_len : u32]
    [emb : f32 × emb_dim][text : u8 × text_len]

kind ∈ {1=insert, 2=delete, 3=replace}; delete records carry emb_dim =
text_len = 0.  The journal is the recovery story: replaying it over the
last full-rebuild snapshot reconstructs the current epoch's document set,
so delta epochs never need their own durable snapshots.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

INSERT = "insert"
DELETE = "delete"
REPLACE = "replace"

_KIND_CODE = {INSERT: 1, DELETE: 2, REPLACE: 3}
_CODE_KIND = {v: k for k, v in _KIND_CODE.items()}


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One streaming corpus mutation, keyed by external doc_id."""
    kind: str                      # insert | delete | replace
    doc_id: int
    text: bytes | None = None      # None for delete
    emb: np.ndarray | None = None  # (d,) f32; None for delete

    def __post_init__(self):
        if self.kind not in _KIND_CODE:
            raise ValueError(f"unknown mutation kind {self.kind!r}")
        if self.kind == DELETE:
            assert self.text is None and self.emb is None
        else:
            assert self.text is not None and self.emb is not None

    def to_bytes(self) -> bytes:
        emb = (np.asarray(self.emb, np.float32) if self.emb is not None
               else np.zeros(0, np.float32))
        text = self.text if self.text is not None else b""
        hdr = (np.uint8(_KIND_CODE[self.kind]).tobytes()
               + np.uint32(self.doc_id).tobytes()
               + np.uint32(emb.size).tobytes()
               + np.uint32(len(text)).tobytes())
        return hdr + emb.tobytes() + text


def insert(doc_id: int, text: bytes, emb: np.ndarray) -> Mutation:
    return Mutation(INSERT, doc_id, text, np.asarray(emb, np.float32))


def delete(doc_id: int) -> Mutation:
    return Mutation(DELETE, doc_id)


def replace(doc_id: int, text: bytes, emb: np.ndarray) -> Mutation:
    return Mutation(REPLACE, doc_id, text, np.asarray(emb, np.float32))


def _parse_one(buf: bytes, ofs: int) -> tuple[Mutation, int]:
    kind = _CODE_KIND[int(np.frombuffer(buf[ofs:ofs + 1], np.uint8)[0])]
    doc_id = int(np.frombuffer(buf[ofs + 1:ofs + 5], np.uint32)[0])
    d = int(np.frombuffer(buf[ofs + 5:ofs + 9], np.uint32)[0])
    tlen = int(np.frombuffer(buf[ofs + 9:ofs + 13], np.uint32)[0])
    ofs += 13
    emb = np.frombuffer(buf[ofs:ofs + 4 * d], np.float32).copy() if d else None
    ofs += 4 * d
    text = buf[ofs:ofs + tlen] if kind != DELETE else None
    ofs += tlen
    return Mutation(kind, doc_id, text, emb), ofs


class MutationJournal:
    """Append-only log with a committed/pending watermark.

    `append` adds pending mutations; `mark_committed(epoch)` moves the
    watermark once LiveIndex publishes the epoch that folded them in.
    """

    def __init__(self):
        self._log: list[Mutation] = []
        self._committed = 0            # prefix length already in an epoch
        self._epoch_of: list[int] = [] # per committed record: epoch it joined

    def append(self, mut: Mutation):
        self._log.append(mut)

    def pending(self) -> list[Mutation]:
        return self._log[self._committed:]

    def mark_committed(self, epoch: int):
        n_new = len(self._log) - self._committed
        self._epoch_of.extend([epoch] * n_new)
        self._committed = len(self._log)

    def committed_records(self) -> Iterator[tuple[int, Mutation]]:
        """(epoch, mutation) pairs for the committed prefix, in log order."""
        return zip(self._epoch_of, self._log[:self._committed])

    def __len__(self) -> int:
        return len(self._log)

    def to_bytes(self) -> bytes:
        """Serialize the full log in the documented wire format."""
        return b"".join(m.to_bytes() for m in self._log)

    @classmethod
    def from_bytes(cls, buf: bytes) -> "MutationJournal":
        j = cls()
        ofs = 0
        while ofs < len(buf):
            mut, ofs = _parse_one(buf, ofs)
            j.append(mut)
        return j


def replay(base: dict[int, tuple[bytes, np.ndarray]],
           mutations: Sequence[Mutation]
           ) -> dict[int, tuple[bytes, np.ndarray]]:
    """Apply a mutation sequence to a doc_id → (text, emb) snapshot."""
    docs = dict(base)
    for m in mutations:
        if m.kind == DELETE:
            docs.pop(m.doc_id, None)
        else:
            docs[m.doc_id] = (m.text, np.asarray(m.emb, np.float32))
    return docs
