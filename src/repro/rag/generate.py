"""Batched prefill + KV-cache decode behind the serve engines.

`Generator` owns a transformer LM (`repro.models.transformer`) plus the
packing policy (`rag.prompt.PromptSpec`) and exposes the three stage
methods the engines wrap in obs spans:

    pack     ranked docs  → (B, S) token grid + lengths      host
    prefill  token grid   → KV cache + first-token logits    device
    decode   step loop    → (B, max_new_tokens) int32 ids    device

Decoding is FIXED LENGTH (`max_new_tokens`, no early EOS stop) so shapes
are static and output is a dense (B, N) grid — the determinism contract
the serve equivalence tests pin.  Greedy (temperature=0.0, the default)
takes argmax; seeded sampling derives one key per (seed, rid, step) with
`jax.random.fold_in`, so a request's sampled continuation depends only on
its rid and the generator seed — NOT on which batch or engine served it.

Caches and compute run in float32: generation must be bit-identical
between the sync and pipelined engines, and bf16 accumulation order is
the classic source of spurious diffs.  Models here are tiny (the bench /
serve configs), so f32 costs nothing that matters.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.rag import prompt as prompt_lib
from repro.rag.prompt import PackedPrompt, PromptSpec


@dataclasses.dataclass
class GenState:
    """Device state between prefill and the decode loop.

    `logits` are the next-token logits at each row's last prompt token;
    `lengths` are true prompt lengths (cache write cursor starts there).
    """
    cache: dict
    logits: jax.Array        # (B, V) f32
    lengths: jax.Array       # (B,) int32


class Generator:
    """Prompt-conditioned fixed-length generation over a KV cache.

    Construct with model params + config (``cfg.vocab`` must cover the
    byte vocabulary, ``rag.prompt.VOCAB``); `tiny()` builds the small
    self-contained model the benches, CLI and tests use.  One instance
    is safe to share across engines — per-(batch-size) jitted prefill
    and step functions are cached on the instance.
    """

    def __init__(self, params, cfg: tf.LMConfig, *,
                 spec: PromptSpec | None = None, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0):
        assert cfg.vocab >= prompt_lib.VOCAB, (
            f"vocab {cfg.vocab} < byte vocabulary {prompt_lib.VOCAB}")
        assert max_new_tokens >= 1
        self.params = params
        self.cfg = cfg
        self.spec = spec if spec is not None else PromptSpec()
        self.max_new_tokens = int(max_new_tokens)
        self.temperature = float(temperature)
        self.seed = int(seed)
        self._root_key = jax.random.PRNGKey(seed)
        self._prefill_jit: dict[int, object] = {}   # batch size → fn
        self._step_jit: dict[int, object] = {}

    @classmethod
    def tiny(cls, *, seed: int = 0, context_budget: int = 96,
             max_new_tokens: int = 8, temperature: float = 0.0,
             d_model: int = 64, n_layers: int = 2, d_ff: int = 128
             ) -> "Generator":
        """A small deterministic generator for benches/CLI/tests.

        2 layers, d_model 64, byte vocab, f32 compute — big enough that
        prefill/decode exercise the real KV-cache path, small enough to
        compile and run inside a CI tick.  ``d_model``/``n_layers``/
        ``d_ff`` scale the model up for benches that need generation to
        be real device work (d_model must stay divisible by 4: four
        heads of d_model/4).
        """
        assert d_model % 4 == 0, d_model
        cfg = tf.LMConfig(
            name="rag-tiny", n_layers=n_layers, d_model=d_model, n_heads=4,
            n_kv_heads=2, head_dim=d_model // 4, d_ff=d_ff,
            vocab=prompt_lib.VOCAB,
            attn_chunk_q=64, attn_chunk_kv=64, remat=False,
            compute_dtype=jnp.float32)
        params = tf.init(jax.random.PRNGKey(seed), cfg)
        return cls(params, cfg, spec=PromptSpec(context_budget),
                   max_new_tokens=max_new_tokens, temperature=temperature,
                   seed=seed)

    # -- stage 1: host-side tokenize + pack ----------------------------------

    def pack(self, doc_lists) -> tuple[np.ndarray, np.ndarray,
                                       list[PackedPrompt]]:
        """Ranked rerank triples per request → (B, S) grid + lengths.

        `doc_lists[i]` is request i's ranked `(doc_id, score, text)`
        list; only the text bytes enter the prompt (rank order is the
        retrieval order, already deterministic across engines).
        """
        prompts = [prompt_lib.pack_docs([t for _, _, t in docs], self.spec)
                   for docs in doc_lists]
        grid, lengths = prompt_lib.pack_batch(prompts, self.spec)
        return grid, lengths, prompts

    # -- stage 2: prefill -----------------------------------------------------

    def _prefill_fn(self, batch: int):
        if batch not in self._prefill_jit:
            cfg, S, new = self.cfg, self.spec.context_budget, \
                self.max_new_tokens

            @jax.jit
            def fn(params, toks, lengths):
                cache = tf.init_cache(cfg, batch, S + new,
                                      dtype=jnp.float32)
                return tf.prefill(params, toks, cache, cfg,
                                  last_pos=lengths - 1)
            self._prefill_jit[batch] = fn
        return self._prefill_jit[batch]

    def prefill(self, tokens: np.ndarray, lengths: np.ndarray) -> GenState:
        """Run the packed prompts through the model, filling the cache."""
        B = tokens.shape[0]
        lengths = jnp.asarray(lengths, jnp.int32)
        logits, cache = self._prefill_fn(B)(
            self.params, jnp.asarray(tokens), lengths)
        return GenState(cache=cache, logits=logits, lengths=lengths)

    # -- stage 3: the decode loop --------------------------------------------

    def _step_fn(self, batch: int):
        if batch not in self._step_jit:
            cfg = self.cfg

            @jax.jit
            def fn(params, cache, toks, lengths):
                return tf.decode_step(params, cache, toks, lengths, cfg)
            self._step_jit[batch] = fn
        return self._step_jit[batch]

    def _pick(self, logits: jax.Array, rids: jax.Array,
              step: int) -> jax.Array:
        """logits (B, V) → next ids (B,) int32 (greedy or seeded sample)."""
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def one(rid, lg):
            k = jax.random.fold_in(jax.random.fold_in(self._root_key, rid),
                                   step)
            return jax.random.categorical(k, lg / self.temperature)
        return jax.vmap(one)(rids, logits).astype(jnp.int32)

    def decode_async(self, state: GenState, rids) -> jax.Array:
        """Dispatch the whole step loop; return the (B, N) ids UNBLOCKED.

        Every step is enqueued on the device stream and nothing waits:
        the pipelined engine calls this at its retire stage and blocks a
        tick later, so the decode chain's device time runs concurrently
        with the NEXT batch's host-side retrieval (encode + recover) —
        the overlap `benchmarks/rag_bench.py` measures.  Values are
        identical to `decode` (deferring the block changes nothing).
        """
        B = int(state.logits.shape[0])
        rids_arr = jnp.asarray(list(rids), jnp.int32)
        step_fn = self._step_fn(B)
        cache, lengths = state.cache, state.lengths
        cur = self._pick(state.logits, rids_arr, 0)
        out = [cur]
        for step in range(1, self.max_new_tokens):
            logits, cache = step_fn(self.params, cache, cur,
                                    lengths + (step - 1))
            cur = self._pick(logits, rids_arr, step)
            out.append(cur)
        return jnp.stack(out, axis=1)

    def decode(self, state: GenState, rids) -> np.ndarray:
        """Greedy/sampled step loop → (B, max_new_tokens) int32 ids.

        ``decode_async`` + one block: the synchronous engine's posture
        (and the convenience path for tests).
        """
        return np.asarray(jax.block_until_ready(
            self.decode_async(state, rids)))

    # -- convenience ----------------------------------------------------------

    def generate(self, doc_lists, rids) -> np.ndarray:
        """pack → prefill → decode in one call (tests/benches)."""
        grid, lengths, _ = self.pack(doc_lists)
        return self.decode(self.prefill(grid, lengths), rids)

    def generate_nocache(self, doc_lists, rids) -> np.ndarray:
        """Cache-free reference: re-run full `forward` every step.

        O(N·S²) — exists so tests can pin the KV-cache loop against an
        independently-computed token sequence.  Greedy only.
        """
        assert self.temperature <= 0.0, "reference path is greedy-only"
        del rids
        grid, lengths, _ = self.pack(doc_lists)
        toks = np.array(grid)
        lens = np.array(lengths).copy()
        B = toks.shape[0]
        out = np.zeros((B, self.max_new_tokens), np.int32)

        @functools.partial(jax.jit, static_argnums=())
        def fwd(params, t):
            x, _ = tf.forward(params, t, self.cfg)
            return tf.logits_from_hidden(params, x, self.cfg)

        for step in range(self.max_new_tokens):
            full = np.asarray(fwd(self.params, jnp.asarray(toks)))
            for b in range(B):
                nxt = int(np.argmax(full[b, lens[b] - 1]))
                out[b, step] = nxt
                if lens[b] < toks.shape[1]:
                    toks[b, lens[b]] = nxt
                else:
                    toks = np.pad(toks, ((0, 0), (0, 1)),
                                  constant_values=prompt_lib.PAD)
                    toks[b, lens[b]] = nxt
                lens[b] += 1
        return out
