"""RAG generation stage: retrieved payloads → packed prompts → tokens.

`prompt` owns the deterministic byte-level tokenizer and context-budgeted
doc packing; `generate` owns the batched prefill + KV-cache decode loop
the serve engines run as their generation completion stage (see
docs/rag.md and `repro.serve.engine`).
"""
from repro.rag.generate import Generator, GenState
from repro.rag.prompt import (BOS, GEN, PAD, SEP, VOCAB, PackedPrompt,
                              PromptSpec, decode_tokens, encode_bytes,
                              pack_batch, pack_docs)

__all__ = [
    "Generator", "GenState", "PromptSpec", "PackedPrompt",
    "pack_docs", "pack_batch", "encode_bytes", "decode_tokens",
    "PAD", "BOS", "SEP", "GEN", "VOCAB",
]
