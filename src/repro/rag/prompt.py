"""Deterministic prompt construction for the RAG generation stage.

The retrieval side of the system hands back ranked plaintext documents
(`rerank.rerank` triples); this module turns them into the (B, S) int32
token grid the transformer prefill consumes.  Everything here is pure
python/NumPy and bit-deterministic: the SAME ranked texts and the SAME
`PromptSpec` always produce the SAME tokens, which is what lets the serve
engines promise generated tokens identical across sync/pipelined/fleet.

Wire format of one packed prompt (see docs/rag.md):

    [BOS] doc₀ [SEP] doc₁ [SEP] … docₖ [SEP] [GEN]

Tokens 0–255 are raw byte values of the document text; ids ≥ 256 are the
specials below.  Documents are packed greedily in RANK order, whole-doc
include-or-drop (a document is never split mid-record); a document that
does not fit is dropped and packing CONTINUES with later (shorter) ranks.
`PackedPrompt` carries exact truncation accounting: ``packed_bytes +
dropped_bytes`` always equals the total payload bytes offered.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

#: Byte-level vocabulary: ids 0..255 are raw bytes, then the specials.
PAD = 256      #: right-padding of the (B, S) batch grid
BOS = 257      #: start of prompt
SEP = 258      #: end of one packed document
GEN = 259      #: generation trigger — always the last prompt token
#: Minimum `LMConfig.vocab` a generator model needs (256 bytes + specials).
VOCAB = 260


@dataclasses.dataclass(frozen=True)
class PromptSpec:
    """Packing policy: `context_budget` is the HARD prompt-length cap.

    The packed token sequence (BOS + docs/SEPs + GEN) never exceeds
    `context_budget` tokens; the (B, S) batch grid is padded to exactly
    S = context_budget so prefill shapes are static per batch size.
    """
    context_budget: int = 160

    def __post_init__(self):
        assert self.context_budget >= 2, "need room for [BOS][GEN]"


@dataclasses.dataclass(frozen=True)
class PackedPrompt:
    """One request's packed prompt + exact truncation accounting.

    `tokens` is a (L,) int32 array, L ≤ spec.context_budget;
    `packed_bytes + dropped_bytes` == total payload bytes offered.
    """
    tokens: np.ndarray
    n_docs: int            #: documents packed into the prompt
    n_docs_dropped: int    #: documents dropped (over budget)
    packed_bytes: int      #: payload bytes that made it in
    dropped_bytes: int     #: payload bytes truncated away

    @property
    def length(self) -> int:
        """Prompt length in tokens (before batch padding)."""
        return int(self.tokens.shape[0])


def encode_bytes(text: bytes) -> np.ndarray:
    """Byte string → (len,) int32 token ids (identity byte tokenizer)."""
    return np.frombuffer(bytes(text), dtype=np.uint8).astype(np.int32)


def decode_tokens(tokens) -> bytes:
    """Token ids → byte string, dropping specials (debug/test helper)."""
    t = np.asarray(tokens).ravel()
    return bytes(int(v) for v in t if 0 <= v < 256)


def pack_docs(texts: Sequence[bytes], spec: PromptSpec) -> PackedPrompt:
    """Greedy rank-order packing of whole documents into one prompt.

    Each document costs ``len(text) + 1`` tokens (its trailing SEP); BOS
    and the terminal GEN cost one each.  A document that would blow the
    budget is dropped whole — packing continues, so a long rank-2 doc
    does not shadow a short rank-3 doc that still fits.  Deterministic:
    no RNG, no clock, order == input order.
    """
    budget = spec.context_budget
    parts = [np.array([BOS], np.int32)]
    used = 1                          # BOS; 1 more reserved for GEN below
    n_in = n_drop = b_in = b_drop = 0
    for text in texts:
        text = bytes(text)
        cost = len(text) + 1          # doc bytes + its SEP
        if used + cost + 1 <= budget:  # +1: the terminal GEN must still fit
            parts.append(encode_bytes(text))
            parts.append(np.array([SEP], np.int32))
            used += cost
            n_in += 1
            b_in += len(text)
        else:
            n_drop += 1
            b_drop += len(text)
    parts.append(np.array([GEN], np.int32))
    tokens = np.concatenate(parts)
    assert tokens.shape[0] <= budget, (tokens.shape[0], budget)
    return PackedPrompt(tokens=tokens, n_docs=n_in, n_docs_dropped=n_drop,
                        packed_bytes=b_in, dropped_bytes=b_drop)


def pack_batch(prompts: Sequence[PackedPrompt], spec: PromptSpec
               ) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad packed prompts into the (B, S) grid prefill consumes.

    S is always exactly `spec.context_budget` (static shapes → one
    prefill compile per batch size); returns (tokens (B, S) int32,
    lengths (B,) int32) where lengths are the true prompt lengths and
    everything beyond is PAD.
    """
    assert prompts, "empty batch"
    S = spec.context_budget
    B = len(prompts)
    grid = np.full((B, S), PAD, np.int32)
    lengths = np.zeros((B,), np.int32)
    for i, p in enumerate(prompts):
        L = p.length
        assert L <= S
        grid[i, :L] = p.tokens
        lengths[i] = L
    return grid, lengths
