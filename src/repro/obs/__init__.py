"""Privacy-safe observability: tracing + metrics spanning the whole stack.

One cross-cutting layer (ISSUE 7), three modules:

`scrub`     the privacy audit boundary — a typed allowlist (numbers,
            registered enums) every span attribute and metric value passes
            through at record time; arrays/bytes/free strings raise
            `PrivacyViolation`, so exports are metadata-only BY
            CONSTRUCTION (sizes, timings, epochs, shard/request ids —
            never query vectors, one-hots, probe patterns or plaintexts).
`registry`  `MetricsRegistry`: counters, gauges, fixed-bucket histograms —
            deterministic (no clock reads), associatively mergeable across
            shards, sharing ONE percentile rank rule with `traffic.slo`.
`trace`     `Tracer`/`Span` nested spans with explicit parent ids,
            Chrome-trace/Perfetto export, and the zero-overhead-when-
            disabled `kernel_annotation` hook `repro.kernels.ops` wears.

`Obs` bundles a tracer and a registry behind one handle the serving stack
threads through itself: the serve engines open tick/plan/gemm/complete
spans (and derive `BatchTiming` from their boundaries), `LiveIndex` opens
stage/publish/rebuild spans, `EpochLog` emits compaction events,
`AdmissionController` emits shed/defer/depth events, and `OpenLoopDriver`
charges per-session hint-sync byte counters.  Built with ``trace=False``
(the engines' default) spans are timestamped but not retained — the same
timeline, none of the memory.  `launch.serve --trace out.json --metrics`
is the CLI surface; docs/observability.md the narrative.
"""
from __future__ import annotations

import time

from repro.obs.registry import (DEFAULT_MS_BUCKETS, DEFAULT_SIZE_BUCKETS,
                                Counter, Gauge, Histogram, MetricsRegistry,
                                percentile)
from repro.obs.scrub import PrivacyViolation, register_enum, scrub
from repro.obs.trace import (Span, Tracer, enable_kernel_annotations,
                             kernel_annotation, kernel_annotations_enabled,
                             span_coverage, validate_chrome_trace)

__all__ = [
    "Obs", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_MS_BUCKETS", "DEFAULT_SIZE_BUCKETS", "percentile",
    "PrivacyViolation", "register_enum", "scrub",
    "Span", "Tracer", "span_coverage", "validate_chrome_trace",
    "enable_kernel_annotations", "kernel_annotation",
    "kernel_annotations_enabled",
]


class Obs:
    """One tracer + one metrics registry, threaded through the hot path.

    ``clock`` must match the instrumented component's clock (the serve
    loops pass theirs in), so virtual-time tests stay deterministic.
    ``trace=False`` keeps span TIMING (the engines build `BatchTiming`
    from span boundaries either way) but retains no spans — the default
    serving configuration, within the <2% instrumentation budget.
    """

    def __init__(self, *, clock=time.perf_counter, trace: bool = False):
        self.tracer = Tracer(clock=clock, keep=trace)
        self.metrics = MetricsRegistry()

    def span(self, name: str, **attrs) -> Span:
        """Open a nested span (context manager); attrs are scrubbed."""
        return self.tracer.span(name, **attrs)

    def instant(self, name: str, **attrs) -> None:
        """Record a point-in-time event (no-op when tracing is off)."""
        self.tracer.instant(name, **attrs)

    def counter(self, name: str) -> Counter:
        """The registry counter `name` (created on first use)."""
        return self.metrics.counter(name)

    def gauge(self, name: str) -> Gauge:
        """The registry gauge `name` (created on first use)."""
        return self.metrics.gauge(name)

    def histogram(self, name: str, bounds=DEFAULT_MS_BUCKETS) -> Histogram:
        """The registry histogram `name` (bounds fix on first creation)."""
        return self.metrics.histogram(name, bounds)

    def export_chrome(self, path: str) -> dict:
        """Write the Chrome-trace JSON to `path`; returns the dict."""
        return self.tracer.export_chrome(path)

    def metrics_dict(self) -> dict:
        """Deterministic export of every metric (see MetricsRegistry)."""
        return self.metrics.to_dict()
