"""Metrics: counters, gauges, fixed-bucket histograms — mergeable, exact.

Design constraints (ISSUE 7):

deterministic   Nothing here reads a clock or draws randomness: a metric's
                state is a pure function of the `record`/`inc`/`set` calls
                made against it, so FakeClock-driven tests see identical
                registries run after run.

mergeable       Every metric type has an ASSOCIATIVE merge (counters sum,
                gauges take the max, histograms add bucket counts), so
                per-shard registries fold into a fleet view in any
                grouping order — `merge(merge(a, b), c)` equals
                `merge(a, merge(b, c))` exactly (property-tested).

privacy-safe    Every recorded value passes the `scrub` allowlist first:
                a histogram can hold latencies and byte counts, never an
                embedding or a plaintext.

one rank rule   `percentile` is THE quantile convention for the repo: the
                order statistic at rank ``ceil(q/100·n) − 1``, propagating
                +inf (shed requests) instead of interpolating it into NaN.
                `traffic.slo.summarize` and `Histogram.percentile` both
                call into it, so the SLO fold and the metrics registry
                cannot drift apart.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.obs.scrub import scrub

#: Default latency buckets (milliseconds): sub-ms to multi-second tail.
DEFAULT_MS_BUCKETS = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0,
                      200.0, 500.0, 1000.0, 2000.0, 5000.0)

#: Default size buckets (counts/bytes as powers of two-ish).
DEFAULT_SIZE_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                        512.0, 2048.0, 8192.0, 65536.0, 1048576.0)


def _rank(n: int, q: float) -> int:
    """Order-statistic rank for the q-th percentile of n samples."""
    return max(min(n - 1, math.ceil(q / 100.0 * n) - 1), 0)


def percentile(values, q: float) -> float:
    """Exact order-statistic percentile, propagating +inf; 0.0 when empty.

    np.percentile interpolates, which turns a single +inf sample into NaN
    for every quantile above the last finite one; the order statistic keeps
    it +inf — exactly the "shed requests dominate the tail" semantics the
    SLO fold pins.  This function is the single rank rule shared by
    `traffic.slo` and `Histogram.percentile`.
    """
    arr = np.asarray(values, np.float64)
    if arr.size == 0:
        return 0.0
    return float(np.sort(arr)[_rank(arr.size, q)])


@dataclasses.dataclass
class Counter:
    """Monotone counter; cross-shard merge is the sum."""
    name: str
    value: float = 0

    def inc(self, n=1) -> None:
        """Add `n` (scrubbed number) to the counter."""
        self.value += scrub(n, where=self.name)

    def to_value(self):
        """Exported form: the plain count."""
        return self.value


@dataclasses.dataclass
class Gauge:
    """Level gauge (queue depth, pipeline depth); merge takes the max.

    Merging last-write-wins across shards is not associative without
    timestamps, so the cross-shard semantics here are explicitly
    "worst level anywhere": ``value`` merges by max, and ``hi`` tracks the
    local peak so a single-shard registry still exposes its own worst case.
    """
    name: str
    value: float | None = None
    hi: float | None = None

    def set(self, v) -> None:
        """Set the current level (scrubbed number); updates the peak."""
        v = scrub(v, where=self.name)
        self.value = v
        self.hi = v if self.hi is None else max(self.hi, v)

    def to_value(self):
        """Exported form: {value, hi} (None when never set)."""
        return {"value": self.value, "hi": self.hi}


class Histogram:
    """Fixed-bucket histogram with exact inf accounting.

    ``bounds`` are ascending upper edges; bucket i counts values
    ``<= bounds[i]`` (first matching edge), with one overflow bucket for
    values above the last edge.  +inf recordings are tracked separately
    (``n_inf``) so `percentile` can propagate them exactly: a rank landing
    in the inf tail returns +inf, one landing in finite overflow returns
    the largest finite value seen (never a made-up edge).  Merging two
    histograms requires identical bounds and is plain vector addition.
    """

    def __init__(self, name: str, bounds=DEFAULT_MS_BUCKETS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        assert all(a < b for a, b in zip(self.bounds, self.bounds[1:])), \
            "histogram bounds must be strictly ascending"
        self.counts = [0] * (len(self.bounds) + 1)   # [+overflow]
        self.n = 0
        self.n_inf = 0
        self.sum = 0.0          # finite mass only
        self.min: float | None = None                # finite extrema
        self.max: float | None = None

    def record(self, v) -> None:
        """Record one value (scrubbed number; +inf allowed, NaN is not)."""
        v = float(scrub(v, where=self.name))
        assert not math.isnan(v), f"NaN recorded into {self.name}"
        self.n += 1
        if math.isinf(v):
            self.n_inf += 1
            self.counts[-1] += 1
            return
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        idx = int(np.searchsorted(self.bounds, v, side="left"))
        self.counts[idx] += 1

    def percentile(self, q: float) -> float:
        """Bucketed percentile under the shared order-statistic rank rule.

        Returns the upper edge of the bucket the rank lands in; +inf when
        the rank falls inside the recorded-inf tail (same propagation as
        the exact `percentile`); the largest finite recorded value when it
        lands in finite overflow; 0.0 when empty.
        """
        if self.n == 0:
            return 0.0
        k = _rank(self.n, q)
        if k >= self.n - self.n_inf:
            return float("inf")
        seen = 0
        for i, c in enumerate(self.counts[:-1]):
            seen += c
            if k < seen:
                return self.bounds[i]
        return self.max if self.max is not None else float("inf")

    def merge_from(self, other: "Histogram") -> None:
        """Fold `other` (same name and bounds) into this histogram."""
        assert self.bounds == other.bounds, (self.name, "bucket mismatch")
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.n += other.n
        self.n_inf += other.n_inf
        self.sum += other.sum
        for attr in ("min", "max"):
            mine, theirs = getattr(self, attr), getattr(other, attr)
            pick = min if attr == "min" else max
            setattr(self, attr, theirs if mine is None else
                    (mine if theirs is None else pick(mine, theirs)))

    def to_value(self):
        """Exported form: bounds, counts, n/sum/extrema, p50/p99."""
        return {
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "n": self.n, "n_inf": self.n_inf,
            "sum": round(self.sum, 6),
            "min": self.min, "max": self.max,
            "p50": self.percentile(50), "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named counters/gauges/histograms with an associative shard merge.

    `counter`/`gauge`/`histogram` create-or-return by name (a histogram's
    bounds are fixed by its first creation).  `merge` builds a NEW registry
    folding both operands — a pure, associative operation, so per-shard
    registries reduce in any tree shape to the identical fleet view.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def counter(self, name: str) -> Counter:
        """The counter `name`, created on first use."""
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        """The gauge `name`, created on first use."""
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, bounds=DEFAULT_MS_BUCKETS) -> Histogram:
        """The histogram `name`; `bounds` only applies on first creation."""
        return self._get(name, Histogram, lambda: Histogram(name, bounds))

    def _get(self, name, cls, build):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = build()
        assert isinstance(m, cls), f"{name} already registered as {type(m)}"
        return m

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """A new registry = self ⊕ other (associative, operands untouched)."""
        out = MetricsRegistry()
        for src in (self, other):
            for name, m in src._metrics.items():
                if isinstance(m, Counter):
                    out.counter(name).value += m.value
                elif isinstance(m, Gauge):
                    g = out.gauge(name)
                    for attr in ("value", "hi"):
                        mine, theirs = getattr(g, attr), getattr(m, attr)
                        setattr(g, attr, theirs if mine is None else
                                (mine if theirs is None
                                 else max(mine, theirs)))
                else:
                    out.histogram(name, m.bounds).merge_from(m)
        return out

    def to_dict(self) -> dict:
        """Deterministic (name-sorted) export of every metric's value."""
        return {name: self._metrics[name].to_value()
                for name in sorted(self._metrics)}
