"""Nested spans with explicit parent ids + Chrome-trace/Perfetto export.

`Tracer.span(name, **attrs)` opens a span as a context manager; spans nest
by stack discipline, each one carrying a sequential id and its parent's id,
so the exported tree is DETERMINISTIC under a FakeClock — same schedule,
same ids, same nesting, byte-identical export.  A tracer built with
``keep=False`` still timestamps every span (the serving engines derive
`BatchTiming` from span boundaries, traced or not) but retains nothing:
the per-span cost collapses to two clock reads, which is what keeps default
serving within the <2% instrumentation budget.

Every attribute value passes the `scrub` privacy gate at record time —
see `repro.obs.scrub` — so an export can be shipped off-box without a
redaction pass.

The export is the Chrome Trace Event Format (the JSON both
``chrome://tracing`` and https://ui.perfetto.dev load directly): complete
events (``ph: "X"``) for spans, instant events (``ph: "i"``) for
point-in-time markers, timestamps in microseconds.  `validate_chrome_trace`
structurally checks an export (the CI gate re-checks the privacy allowlist
on every ``args`` value too — `scripts/check_trace.py`).

Kernel regions: `kernel_annotation(name)` returns a
`jax.profiler.TraceAnnotation` context only while
`enable_kernel_annotations(True)` is in effect, and a shared no-op context
otherwise — the hot kernel wrappers in `repro.kernels.ops` wear it with
zero overhead when disabled (one global-bool check, no profiler import).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Callable

from repro.obs.scrub import scrub


@dataclasses.dataclass
class Span:
    """One timed region: name, id, parent id, [t0, t1), scrubbed attrs."""
    name: str
    sid: int
    parent: int | None
    t0: float
    t1: float | None = None
    attrs: dict = dataclasses.field(default_factory=dict)
    _tracer: "Tracer | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def dur(self) -> float:
        """Span duration in seconds (0.0 while still open)."""
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def set(self, **attrs) -> "Span":
        """Attach scrubbed attributes (numbers / registered enums only)."""
        for k, v in attrs.items():
            self.attrs[k] = scrub(v, where=f"{self.name}.{k}")
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        tracer = self._tracer
        assert tracer is not None, "span already closed"
        self.t1 = tracer.clock()
        self._tracer = None
        tracer._close(self)


class Tracer:
    """Span factory + store; ``keep=False`` times spans without retaining.

    ``clock`` must be the same clock the instrumented component uses (the
    serve loops pass theirs through), so FakeClock tests stay deterministic
    and `BatchTiming` derived from span boundaries matches the engine's
    own timeline.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter, *,
                 keep: bool = True):
        self.clock = clock
        self.keep = keep
        self.spans: list[Span] = []      # finished spans, completion order
        self.instants: list[Span] = []   # zero-duration markers
        self._stack: list[int] = []      # open span ids (nesting)
        self._next_sid = 0

    def span(self, name: str, **attrs) -> Span:
        """Open a nested span (use as a context manager)."""
        sid, self._next_sid = self._next_sid, self._next_sid + 1
        sp = Span(name=name, sid=sid,
                  parent=self._stack[-1] if self._stack else None,
                  t0=self.clock(), _tracer=self)
        if attrs:
            sp.set(**attrs)
        self._stack.append(sid)
        return sp

    def _close(self, sp: Span) -> None:
        # stack discipline normally makes sp the top; be defensive about
        # exception paths that unwound an inner span out of order
        if self._stack and self._stack[-1] == sp.sid:
            self._stack.pop()
        else:                                       # pragma: no cover
            self._stack = [s for s in self._stack if s != sp.sid]
        if self.keep:
            self.spans.append(sp)

    def instant(self, name: str, **attrs) -> None:
        """Record a point-in-time event (dropped when ``keep=False``)."""
        if not self.keep:
            return
        sid, self._next_sid = self._next_sid, self._next_sid + 1
        sp = Span(name=name, sid=sid,
                  parent=self._stack[-1] if self._stack else None,
                  t0=self.clock())
        sp.t1 = sp.t0
        if attrs:
            sp.set(**attrs)
        self.instants.append(sp)

    # -- export ---------------------------------------------------------------

    def to_chrome(self) -> dict:
        """The trace as a Chrome Trace Event Format dict (ts/dur in µs)."""
        events = []
        for sp in self.spans:
            events.append({
                "name": sp.name, "ph": "X", "pid": 0, "tid": 0,
                "ts": round(sp.t0 * 1e6, 3),
                "dur": round(max(sp.dur, 0.0) * 1e6, 3),
                "args": {"sid": sp.sid,
                         "parent": -1 if sp.parent is None else sp.parent,
                         **sp.attrs},
            })
        for sp in self.instants:
            events.append({
                "name": sp.name, "ph": "i", "s": "t", "pid": 0, "tid": 0,
                "ts": round(sp.t0 * 1e6, 3),
                "args": {"sid": sp.sid,
                         "parent": -1 if sp.parent is None else sp.parent,
                         **sp.attrs},
            })
        events.sort(key=lambda e: (e["ts"], e["args"]["sid"]))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> dict:
        """Write the Chrome-trace JSON to `path`; returns the dict."""
        obj = self.to_chrome()
        with open(path, "w") as f:
            json.dump(obj, f, indent=1)
        return obj


def span_coverage(spans: list[Span], *, roots_only: bool = True) -> float:
    """Fraction of [first start, last end] covered by the spans' union.

    With ``roots_only`` (the default) only parentless spans count — the
    engine's tick/drain roots — so nested spans can't double-cover.  This
    is the acceptance metric for "spans cover ≥95% of wall time": the gap
    is exactly the time the instrumented component was NOT inside any root
    span.
    """
    closed = [s for s in spans if s.t1 is not None
              and (s.parent is None or not roots_only)]
    if not closed:
        return 0.0
    t_lo = min(s.t0 for s in closed)
    t_hi = max(s.t1 for s in closed)
    if t_hi <= t_lo:
        return 1.0
    covered, cur_lo, cur_hi = 0.0, None, None
    for s in sorted(closed, key=lambda s: s.t0):
        if cur_hi is None or s.t0 > cur_hi:
            covered += 0.0 if cur_hi is None else cur_hi - cur_lo
            cur_lo, cur_hi = s.t0, s.t1
        else:
            cur_hi = max(cur_hi, s.t1)
    covered += cur_hi - cur_lo
    return covered / (t_hi - t_lo)


def validate_chrome_trace(obj) -> list[str]:
    """Structural check of a Chrome-trace export; returns error strings.

    The CI gate (`scripts/check_trace.py`) layers the checked-in JSON
    schema and the privacy allowlist re-scan on top of this.
    """
    errs: list[str] = []
    if not isinstance(obj, dict):
        return ["top level must be an object"]
    ev = obj.get("traceEvents")
    if not isinstance(ev, list):
        return ["traceEvents must be a list"]
    for i, e in enumerate(ev):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        for key, typ in (("name", str), ("ph", str)):
            if not isinstance(e.get(key), typ):
                errs.append(f"event {i}: bad {key!r}")
        if not isinstance(e.get("ts"), (int, float)):
            errs.append(f"event {i}: bad 'ts'")
        if e.get("ph") == "X" and not isinstance(e.get("dur"), (int, float)):
            errs.append(f"event {i}: complete event missing 'dur'")
        if e.get("ph") not in ("X", "i", "M"):
            errs.append(f"event {i}: unknown phase {e.get('ph')!r}")
    return errs


# -- kernel-region annotations (zero overhead when disabled) -----------------

_KERNEL_ANNOTATIONS = False
_NULL_CTX = contextlib.nullcontext()


def enable_kernel_annotations(on: bool = True) -> None:
    """Toggle `jax.profiler.TraceAnnotation` wrapping of kernel regions.

    Off (the default), `kernel_annotation` returns a shared no-op context:
    the hot path pays one global-bool check and nothing else.  On, kernel
    dispatches in `repro.kernels.ops` appear as named regions in JAX
    profiler traces (TensorBoard / Perfetto).
    """
    global _KERNEL_ANNOTATIONS
    _KERNEL_ANNOTATIONS = bool(on)


def kernel_annotations_enabled() -> bool:
    """Whether kernel-region profiler annotations are currently on."""
    return _KERNEL_ANNOTATIONS


def kernel_annotation(name: str):
    """Context manager naming a kernel region (no-op unless enabled)."""
    if not _KERNEL_ANNOTATIONS:
        return _NULL_CTX
    from jax.profiler import TraceAnnotation
    return TraceAnnotation(name)
