"""The privacy audit boundary: a typed allowlist for telemetry values.

PIR's whole point is that the server learns nothing about queries, so the
observability layer must be *provably metadata-only*: sizes, timings,
epochs, shard ids — never query vectors, LWE ciphertexts, selection
one-hots, bucket probe patterns, or decoded plaintexts.  Rather than audit
every export after the fact, `scrub` enforces the property at RECORD time:
every span attribute and every metric value passes through it, and anything
outside the allowlist raises `PrivacyViolation` immediately (in the test
suite and in production alike — a trace export is safe to ship off-box by
construction, not by review).

Allowed:
  * ``bool`` / ``int`` / ``float`` and their NumPy scalar equivalents
    (coerced to plain Python so no array machinery leaks into exports) —
    this covers timings, byte counts, epochs, shard/request/session ids;
  * ``str`` values that are REGISTERED enum members (`register_enum`) —
    engine names, outcome labels, commit kinds.  Free-form strings are
    rejected: a string that did not come from a code-side vocabulary could
    carry a decoded document fragment.

Rejected (always): ``bytes``/``bytearray``, ``np.ndarray`` and any other
array type (jax.Array included via the catch-all), containers, ``None``,
and arbitrary objects.  There is deliberately no escape hatch.
"""
from __future__ import annotations

import numbers

import numpy as np


class PrivacyViolation(TypeError):
    """A telemetry value fell outside the metadata-only allowlist."""

    def __init__(self, value, where: str = ""):
        loc = f" at {where!r}" if where else ""
        super().__init__(
            f"obs value of type {type(value).__name__}{loc} is not "
            "allowlisted telemetry (numbers, registered enums only) — "
            "array/bytes/str payloads could carry query-derived data")


#: Registered enum vocabulary: the only strings telemetry may carry.
_ENUM_VOCAB: set[str] = set()


def register_enum(*values: str) -> None:
    """Admit code-side enum strings (engine names, outcomes) to telemetry.

    Call at import time with literal values; registering data-derived
    strings would defeat the gate, so callers must only pass constants.
    """
    for v in values:
        assert isinstance(v, str), v
        _ENUM_VOCAB.add(v)


# The repo-wide vocabulary.  Everything here is a code literal; none of
# these can encode a query, a probe pattern, or a plaintext.
register_enum(
    "sync", "pipelined",            # serve engines
    "served", "shed", "failed",     # request outcomes (traffic.slo)
    "delta", "full",                # commit / hint-patch kinds
    "xla", "pallas", "auto",        # kernel impl dispatch
    "query", "lookup",              # request kinds (serve/traffic)
    "healthy", "suspect", "down",   # fleet device/replica health states
    "recovering",                   # (repro.fleet.replica)
)


def scrub(value, *, where: str = ""):
    """Pass `value` through the telemetry allowlist or raise.

    Returns the value coerced to a plain Python ``bool``/``int``/``float``
    (or the registered enum ``str``).  ``where`` names the metric/attr for
    the error message only — it never changes the decision.
    """
    # bool first: it subclasses int and should stay a bool in exports
    if isinstance(value, (bool, np.bool_)):
        return bool(value)
    if isinstance(value, (int, np.integer)):
        return int(value)
    if isinstance(value, (float, np.floating)):
        return float(value)
    if isinstance(value, str):
        if value in _ENUM_VOCAB:
            return value
        raise PrivacyViolation(value, where)
    if isinstance(value, numbers.Number) and not isinstance(value, complex):
        return float(value)
    raise PrivacyViolation(value, where)
