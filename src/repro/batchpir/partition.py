"""Cuckoo bucketization of the cluster space (batch-PIR layer 1).

The cluster axis of the chunk-transposed DB is split into B buckets using
3-way cuckoo hashing (the probabilistic-batch-code construction from the
batch-PIR literature):

  server side — every cluster j gets THREE candidate buckets derived from a
    public seed, and its column is replicated into each candidate's sub-DB.
    Candidates come from a balanced template (each bucket receives the same
    number of replicas ±1), so all bucket widths equal ~3n/B and the shared
    kernel width pads minimally.

  client side — a client that wants κ clusters cuckoo-places them, each
    into exactly ONE of its three candidates with at most one cluster per
    bucket, via random-walk eviction.  A placement failure (walk cycles)
    retries with a fresh walk seed; only a structurally infeasible probe
    set (Hall violation, probability ≪ 1e-4 for κ ≤ B/3) raises
    PlacementError, which callers treat as "fall back to the legacy path".

Everything here is deterministic given (seed, walk seed): server and client
derive identical candidate tables independently, so only the seed is ever
communicated.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


class PlacementError(RuntimeError):
    """The probe set admits no one-cluster-per-bucket cuckoo placement."""

    def __init__(self, clusters, n_buckets: int, retries: int):
        super().__init__(
            f"cannot place {len(clusters)} probe clusters into "
            f"{n_buckets} buckets after {retries} walk retries")
        self.clusters = tuple(clusters)
        self.retries = retries


def _balanced_candidates(n_clusters: int, n_buckets: int,
                         rng: np.random.Generator) -> np.ndarray:
    """(n, 3) distinct candidate buckets per cluster, bucket loads ±1.

    Greedy least-loaded with a random tiebreak: each cluster takes the three
    least-loaded buckets, which keeps every load within 1 of the others
    (inductively: the three minima are always raised first), while the
    tiebreak shuffle makes the triples pseudorandom — what the cuckoo walk
    needs for placement to succeed with overwhelming probability.
    """
    cand = np.zeros((n_clusters, 3), np.int64)
    loads = np.zeros(n_buckets, np.int64)
    for j in range(n_clusters):
        order = np.lexsort((rng.random(n_buckets), loads))
        cand[j] = np.sort(order[:3])
        loads[cand[j]] += 1
    return cand


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class KeyedLayout:
    """Keyed grouping of a fixed-width embedding table (recsys serving).

    The document path clusters by embedding similarity; a recsys embedding
    lookup is KEYED — the client knows row ids, not contents — so the
    "clusters" are just contiguous id ranges: row i lives in group
    ``i // group_size`` at within-group position ``i % group_size``.
    Both sides derive the mapping from (n_rows, group_size) alone; nothing
    about the table contents leaks into the layout.

    Row codec: each row serializes through the standard chunking record
    with ``text`` = the row's raw little-endian f32 bytes, so every record
    has the same width (16-byte header + d quantized bytes + 4d payload
    bytes) and a group's column decodes by fixed-stride arithmetic.  The
    f32 payload round-trips bit-exactly — the u8-quantized emb field only
    feeds the (inert, for keyed systems) legacy re-rank path.
    """
    n_rows: int                 # V: embedding table rows
    dim: int                    # d: embedding width (f32 lanes)
    group_size: int             # rows per group; last group may be short

    @classmethod
    def build(cls, n_rows: int, dim: int,
              group_size: int | None = None) -> "KeyedLayout":
        """Size the grouping; default group_size ≈ √V balances the column
        height (group_size·record bytes) against the group count (the PIR
        query width), the same m×n tradeoff the document build makes."""
        if n_rows < 1 or dim < 1:
            raise ValueError(f"need n_rows, dim >= 1, got {n_rows}, {dim}")
        if group_size is None:
            group_size = max(1, math.isqrt(n_rows))
        if group_size < 1:
            raise ValueError(f"group_size must be >= 1, got {group_size}")
        return cls(n_rows=n_rows, dim=dim, group_size=group_size)

    @property
    def n_groups(self) -> int:
        """Number of id groups = PIR clusters of the keyed DB."""
        return -(-self.n_rows // self.group_size)

    @property
    def record_stride(self) -> int:
        """Fixed serialized bytes per row record: header + emb_q + raw f32."""
        return 16 + 5 * self.dim

    def group_of(self, row_id: int) -> int:
        """The group (cluster) holding table row ``row_id``."""
        if not 0 <= row_id < self.n_rows:
            raise IndexError(f"row id {row_id} outside table "
                             f"[0, {self.n_rows})")
        return row_id // self.group_size

    def groups_of(self, ids) -> list[int]:
        """Distinct, sorted groups covering an id multiset — the probe set
        handed to cuckoo placement (duplicates fan back out at decode)."""
        return sorted({self.group_of(int(i)) for i in ids})

    def row_text(self, row: np.ndarray) -> bytes:
        """A row's record payload: its raw little-endian f32 bytes."""
        return np.ascontiguousarray(row, dtype="<f4").tobytes()

    def decode_row(self, col: np.ndarray, row_id: int) -> np.ndarray:
        """Extract row ``row_id`` from its group's decrypted column bytes.

        Fixed-stride: group g packs rows [g·gs, min((g+1)·gs, V)) in
        ascending id order (the canonical `chunking.pack_column` order), so
        the record sits at ``4 + (row_id % gs)·record_stride``.  The id
        header is verified; on mismatch (a corrupt or foreign column) the
        records are scanned before giving up.
        """
        g = self.group_of(row_id)
        stride = self.record_stride
        start = 4 + (row_id - g * self.group_size) * stride
        buf = np.asarray(col, np.uint8)
        rec = buf[start:start + stride]
        if (len(rec) == stride
                and int(np.frombuffer(rec[:4].tobytes(), np.uint32)[0])
                == row_id):
            return np.frombuffer(
                rec[16 + self.dim:].tobytes(), "<f4").copy()
        n_docs = int(np.frombuffer(buf[:4].tobytes(), np.uint32)[0])
        for p in range(n_docs):
            rec = buf[4 + p * stride:4 + (p + 1) * stride]
            if (len(rec) == stride
                    and int(np.frombuffer(rec[:4].tobytes(), np.uint32)[0])
                    == row_id):
                return np.frombuffer(
                    rec[16 + self.dim:].tobytes(), "<f4").copy()
        raise KeyError(f"row {row_id} not found in group {g}'s column")


@dataclasses.dataclass(frozen=True)
class CuckooPartition:
    """Public cluster → candidate-bucket mapping plus placement logic."""
    n_clusters: int
    n_buckets: int
    seed: int
    candidates: np.ndarray              # (n, 3) int64, distinct per row
    members: tuple[np.ndarray, ...]     # per bucket: sorted member clusters
    width: int                          # shared sub-DB width (power of two)

    @classmethod
    def build(cls, n_clusters: int, n_buckets: int, seed: int
              ) -> "CuckooPartition":
        """Draw the public 3-way candidate map from `seed` (balanced, so
        every bucket width is ≈ 3n/B, padded to a shared power of two)."""
        if n_buckets < 3:
            raise ValueError("3-way cuckoo needs at least 3 buckets")
        rng = np.random.default_rng([0x5C0B, seed, n_clusters, n_buckets])
        cand = _balanced_candidates(n_clusters, n_buckets, rng)
        members = tuple(np.sort(np.nonzero((cand == b).any(axis=1))[0])
                        for b in range(n_buckets))
        width = _next_pow2(max(1, max(len(m) for m in members)))
        return cls(n_clusters=n_clusters, n_buckets=n_buckets, seed=seed,
                   candidates=cand, members=members, width=width)

    def position(self, bucket: int, cluster: int) -> int:
        """Local column index of `cluster` inside `bucket`'s sub-DB."""
        mem = self.members[bucket]
        pos = int(np.searchsorted(mem, cluster))
        if pos >= len(mem) or mem[pos] != cluster:
            raise KeyError(f"cluster {cluster} not in bucket {bucket}")
        return pos

    def buckets_of(self, cluster: int) -> tuple[int, int, int]:
        """The three candidate buckets holding `cluster`'s replicas."""
        return tuple(int(b) for b in self.candidates[cluster])

    def place(self, clusters, *, walk_seed: int = 0, retries: int = 16
              ) -> dict[int, int]:
        """Cuckoo-place distinct probe clusters; returns {bucket: cluster}.

        Random-walk eviction: a cluster whose candidates are all occupied
        kicks out one occupant (uniformly) and the evictee re-places.  A
        walk that exceeds its step budget restarts with the next walk seed;
        after `retries` restarts the probe set is declared unplaceable.
        """
        clusters = [int(c) for c in clusters]
        if len(set(clusters)) != len(clusters):
            raise ValueError("probe clusters must be distinct")
        if len(clusters) > self.n_buckets:
            raise PlacementError(clusters, self.n_buckets, 0)
        max_steps = 16 * max(1, len(clusters))
        for r in range(retries):
            rng = np.random.default_rng(
                [0xC0C0, self.seed, walk_seed, r])
            occ: dict[int, int] = {}
            failed = False
            for c in clusters:
                item = c
                for _ in range(max_steps):
                    cand = self.candidates[item]
                    free = [int(b) for b in cand if b not in occ]
                    if free:
                        occ[free[int(rng.integers(len(free)))]] = item
                        break
                    b = int(cand[int(rng.integers(3))])
                    item, occ[b] = occ[b], item
                else:
                    failed = True
                    break
            if not failed:
                return occ
        raise PlacementError(clusters, self.n_buckets, retries)
