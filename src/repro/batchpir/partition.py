"""Cuckoo bucketization of the cluster space (batch-PIR layer 1).

The cluster axis of the chunk-transposed DB is split into B buckets using
3-way cuckoo hashing (the probabilistic-batch-code construction from the
batch-PIR literature):

  server side — every cluster j gets THREE candidate buckets derived from a
    public seed, and its column is replicated into each candidate's sub-DB.
    Candidates come from a balanced template (each bucket receives the same
    number of replicas ±1), so all bucket widths equal ~3n/B and the shared
    kernel width pads minimally.

  client side — a client that wants κ clusters cuckoo-places them, each
    into exactly ONE of its three candidates with at most one cluster per
    bucket, via random-walk eviction.  A placement failure (walk cycles)
    retries with a fresh walk seed; only a structurally infeasible probe
    set (Hall violation, probability ≪ 1e-4 for κ ≤ B/3) raises
    PlacementError, which callers treat as "fall back to the legacy path".

Everything here is deterministic given (seed, walk seed): server and client
derive identical candidate tables independently, so only the seed is ever
communicated.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class PlacementError(RuntimeError):
    """The probe set admits no one-cluster-per-bucket cuckoo placement."""

    def __init__(self, clusters, n_buckets: int, retries: int):
        super().__init__(
            f"cannot place {len(clusters)} probe clusters into "
            f"{n_buckets} buckets after {retries} walk retries")
        self.clusters = tuple(clusters)
        self.retries = retries


def _balanced_candidates(n_clusters: int, n_buckets: int,
                         rng: np.random.Generator) -> np.ndarray:
    """(n, 3) distinct candidate buckets per cluster, bucket loads ±1.

    Greedy least-loaded with a random tiebreak: each cluster takes the three
    least-loaded buckets, which keeps every load within 1 of the others
    (inductively: the three minima are always raised first), while the
    tiebreak shuffle makes the triples pseudorandom — what the cuckoo walk
    needs for placement to succeed with overwhelming probability.
    """
    cand = np.zeros((n_clusters, 3), np.int64)
    loads = np.zeros(n_buckets, np.int64)
    for j in range(n_clusters):
        order = np.lexsort((rng.random(n_buckets), loads))
        cand[j] = np.sort(order[:3])
        loads[cand[j]] += 1
    return cand


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class CuckooPartition:
    """Public cluster → candidate-bucket mapping plus placement logic."""
    n_clusters: int
    n_buckets: int
    seed: int
    candidates: np.ndarray              # (n, 3) int64, distinct per row
    members: tuple[np.ndarray, ...]     # per bucket: sorted member clusters
    width: int                          # shared sub-DB width (power of two)

    @classmethod
    def build(cls, n_clusters: int, n_buckets: int, seed: int
              ) -> "CuckooPartition":
        """Draw the public 3-way candidate map from `seed` (balanced, so
        every bucket width is ≈ 3n/B, padded to a shared power of two)."""
        if n_buckets < 3:
            raise ValueError("3-way cuckoo needs at least 3 buckets")
        rng = np.random.default_rng([0x5C0B, seed, n_clusters, n_buckets])
        cand = _balanced_candidates(n_clusters, n_buckets, rng)
        members = tuple(np.sort(np.nonzero((cand == b).any(axis=1))[0])
                        for b in range(n_buckets))
        width = _next_pow2(max(1, max(len(m) for m in members)))
        return cls(n_clusters=n_clusters, n_buckets=n_buckets, seed=seed,
                   candidates=cand, members=members, width=width)

    def position(self, bucket: int, cluster: int) -> int:
        """Local column index of `cluster` inside `bucket`'s sub-DB."""
        mem = self.members[bucket]
        pos = int(np.searchsorted(mem, cluster))
        if pos >= len(mem) or mem[pos] != cluster:
            raise KeyError(f"cluster {cluster} not in bucket {bucket}")
        return pos

    def buckets_of(self, cluster: int) -> tuple[int, int, int]:
        """The three candidate buckets holding `cluster`'s replicas."""
        return tuple(int(b) for b in self.candidates[cluster])

    def place(self, clusters, *, walk_seed: int = 0, retries: int = 16
              ) -> dict[int, int]:
        """Cuckoo-place distinct probe clusters; returns {bucket: cluster}.

        Random-walk eviction: a cluster whose candidates are all occupied
        kicks out one occupant (uniformly) and the evictee re-places.  A
        walk that exceeds its step budget restarts with the next walk seed;
        after `retries` restarts the probe set is declared unplaceable.
        """
        clusters = [int(c) for c in clusters]
        if len(set(clusters)) != len(clusters):
            raise ValueError("probe clusters must be distinct")
        if len(clusters) > self.n_buckets:
            raise PlacementError(clusters, self.n_buckets, 0)
        max_steps = 16 * max(1, len(clusters))
        for r in range(retries):
            rng = np.random.default_rng(
                [0xC0C0, self.seed, walk_seed, r])
            occ: dict[int, int] = {}
            failed = False
            for c in clusters:
                item = c
                for _ in range(max_steps):
                    cand = self.candidates[item]
                    free = [int(b) for b in cand if b not in occ]
                    if free:
                        occ[free[int(rng.integers(len(free)))]] = item
                        break
                    b = int(cand[int(rng.integers(3))])
                    item, occ[b] = occ[b], item
                else:
                    failed = True
                    break
            if not failed:
                return occ
        raise PlacementError(clusters, self.n_buckets, retries)
