"""Batch-PIR client: placement, per-bucket encryption, decode.

One batched query is exactly B ciphertexts — one per bucket, always:

  placed buckets  — an LWE-encrypted one-hot selecting the member column
                    where the wanted cluster's replica lives;
  empty buckets   — a DUMMY: an encryption of the all-zero message under a
                    fresh secret.

Under LWE both are pseudorandom uint32 vectors, so the server's view is κ-
and pattern-independent: it learns neither how many probes the client
packed nor which buckets carry them.  Dummy answers are discarded without
decryption.

Secrets are per-bucket per-query, folded from one caller key; decoding per
bucket is the standard SimplePIR recover against that bucket's hint H_b.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.batchpir.partition import CuckooPartition
from repro.batchpir.server import BatchPIRServer
from repro.core import lwe, pir

U32 = jnp.uint32


@dataclasses.dataclass
class BatchQueryState:
    """Client-side secrets + placement for one batched query (never sent)."""
    placement: dict[int, int]            # bucket → cluster (real queries)
    secrets: list[jax.Array]             # per bucket LWE secret


@dataclasses.dataclass
class BatchAccounting:
    uplink_bytes: int
    downlink_bytes: int
    hint_bytes: int
    n_buckets: int
    placed: int


class BatchPIRClient:
    """Forms batched queries and decodes per-bucket answers."""

    def __init__(self, partition: CuckooPartition,
                 cfgs: list[pir.PIRConfig], hints: list[jax.Array]):
        self.partition = partition
        self.cfgs = cfgs
        self.hints = hints                 # shared refs; patched by epochs
        self._a_mats = [lwe.gen_public_matrix(c.a_seed, c.n, c.params.k)
                        for c in cfgs]

    @classmethod
    def from_server(cls, server: BatchPIRServer) -> "BatchPIRClient":
        """Client sharing the server's partition/configs/hint list refs
        (the in-process stand-in for the one-time hint download)."""
        if not server.hints:
            server.install_hints()
        return cls(server.partition, server.cfgs, server.hints)

    # -- query formulation ---------------------------------------------------

    def query(self, key: jax.Array, clusters, *, walk_seed: int = 0
              ) -> tuple[jax.Array, BatchQueryState]:
        """Encrypt probes for `clusters` (distinct) → ((B, W) u32, state).

        Raises PlacementError if the probe set is structurally unplaceable
        (callers fall back to the legacy multi-query path).
        """
        part = self.partition
        placement = part.place(clusters, walk_seed=walk_seed)
        qs, secrets = [], []
        for b in range(part.n_buckets):
            cfg = self.cfgs[b]
            k_sec, k_err = jax.random.split(jax.random.fold_in(key, b))
            s = lwe.keygen(k_sec, cfg.params)
            msg = jnp.zeros((cfg.n,), U32)
            if b in placement:
                msg = msg.at[part.position(b, placement[b])].set(1)
            qs.append(lwe.encrypt_vector(k_err, s, self._a_mats[b], msg,
                                         cfg.params.delta, cfg.params.sigma))
            secrets.append(s)
        return jnp.stack(qs), BatchQueryState(placement=placement,
                                              secrets=secrets)

    # -- decode --------------------------------------------------------------

    def recover(self, answers: list[jax.Array], state: BatchQueryState, *,
                hints: list[jax.Array] | None = None,
                cfgs: list[pir.PIRConfig] | None = None
                ) -> dict[int, np.ndarray]:
        """Decode REAL buckets only → {cluster: column bytes (m_b,) u8}.

        ``hints``/``cfgs`` override the live per-bucket state with a
        plan-time snapshot: the pipelined engine decodes in-flight batches
        AFTER an epoch commit may have patched `self.hints` in place, so it
        passes the lists it captured when the query was formed.
        """
        hints = self.hints if hints is None else hints
        cfgs = self.cfgs if cfgs is None else cfgs
        out: dict[int, np.ndarray] = {}
        for b, cluster in state.placement.items():
            p = cfgs[b].params
            s = state.secrets[b]
            if p.q_switch is not None:
                vals = lwe.decode_switched(answers[b], hints[b], s, p)
            else:
                vals = lwe.decode(lwe.hint_strip(answers[b], hints[b],
                                                 s), p)
            out[cluster] = np.asarray(vals.astype(jnp.uint8))
        return out

    # -- accounting ----------------------------------------------------------

    def accounting(self, state: BatchQueryState) -> BatchAccounting:
        """Exact per-bucket wire costs of one batched query (summed)."""
        return BatchAccounting(
            uplink_bytes=sum(c.uplink_bytes for c in self.cfgs),
            downlink_bytes=sum(c.downlink_bytes for c in self.cfgs),
            hint_bytes=sum(c.hint_bytes for c in self.cfgs),
            n_buckets=self.partition.n_buckets,
            placed=len(state.placement))
