"""Batch-PIR client: placement, per-bucket encryption, decode.

One batched query is exactly B ciphertexts — one per bucket, always:

  placed buckets  — an LWE-encrypted one-hot selecting the member column
                    where the wanted cluster's replica lives;
  empty buckets   — a DUMMY: an encryption of the all-zero message under a
                    fresh secret.

Under LWE both are pseudorandom uint32 vectors, so the server's view is κ-
and pattern-independent: it learns neither how many probes the client
packed nor which buckets carry them.  Dummy answers are discarded without
decryption.

Secrets are per-bucket per-query, folded from one caller key; decoding per
bucket is the standard SimplePIR recover against that bucket's hint H_b.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.batchpir.partition import CuckooPartition, KeyedLayout
from repro.batchpir.server import BatchPIRServer
from repro.core import lwe, pir

U32 = jnp.uint32


@dataclasses.dataclass
class BatchQueryState:
    """Client-side secrets + placement for one batched query (never sent)."""
    placement: dict[int, int]            # bucket → cluster (real queries)
    secrets: list[jax.Array]             # per bucket LWE secret


@dataclasses.dataclass
class KeyedQueryState:
    """Client-side state of one keyed row lookup (never sent).

    ``ids`` preserves the requested multiset and its order — recovery
    returns one row per requested id, duplicates included — while the
    wrapped `BatchQueryState` carries the placement of the DISTINCT id
    groups that actually went on the wire.
    """
    ids: tuple[int, ...]                 # requested row ids, caller order
    layout: KeyedLayout
    base: BatchQueryState


@dataclasses.dataclass
class BatchAccounting:
    uplink_bytes: int
    downlink_bytes: int
    hint_bytes: int
    n_buckets: int
    placed: int


class BatchPIRClient:
    """Forms batched queries and decodes per-bucket answers."""

    def __init__(self, partition: CuckooPartition,
                 cfgs: list[pir.PIRConfig], hints: list[jax.Array]):
        self.partition = partition
        self.cfgs = cfgs
        self.hints = hints                 # shared refs; patched by epochs
        self._a_mats = [lwe.gen_public_matrix(c.a_seed, c.n, c.params.k)
                        for c in cfgs]

    @classmethod
    def from_server(cls, server: BatchPIRServer) -> "BatchPIRClient":
        """Client sharing the server's partition/configs/hint list refs
        (the in-process stand-in for the one-time hint download)."""
        if not server.hints:
            server.install_hints()
        return cls(server.partition, server.cfgs, server.hints)

    # -- query formulation ---------------------------------------------------

    def query(self, key: jax.Array, clusters, *, walk_seed: int = 0
              ) -> tuple[jax.Array, BatchQueryState]:
        """Encrypt probes for `clusters` (distinct) → ((B, W) u32, state).

        Raises PlacementError if the probe set is structurally unplaceable
        (callers fall back to the legacy multi-query path).
        """
        part = self.partition
        placement = part.place(clusters, walk_seed=walk_seed)
        qs, secrets = [], []
        for b in range(part.n_buckets):
            cfg = self.cfgs[b]
            k_sec, k_err = jax.random.split(jax.random.fold_in(key, b))
            s = lwe.keygen(k_sec, cfg.params)
            msg = jnp.zeros((cfg.n,), U32)
            if b in placement:
                msg = msg.at[part.position(b, placement[b])].set(1)
            qs.append(lwe.encrypt_vector(k_err, s, self._a_mats[b], msg,
                                         cfg.params.delta, cfg.params.sigma))
            secrets.append(s)
        return jnp.stack(qs), BatchQueryState(placement=placement,
                                              secrets=secrets)

    def query_rows(self, key: jax.Array, layout: KeyedLayout, ids, *,
                   walk_seed: int = 0
                   ) -> tuple[jax.Array, KeyedQueryState]:
        """Encrypt a keyed lookup for table rows `ids` → ((B, W) u32, state).

        ``ids`` is a MULTISET (duplicates fine — a DLRM request repeats hot
        ids freely): it dedups to distinct id groups before cuckoo
        placement, and `recover_rows` fans shared group columns back out to
        every requesting id.  The wire view is the document path's: always
        B ciphertexts of the shared width, independent of κ, of duplicate
        structure, and of which ids were asked.  Raises PlacementError when
        the distinct-group set is structurally unplaceable.
        """
        ids = [int(i) for i in ids]
        groups = layout.groups_of(ids)       # validates every id's range
        qs, base = self.query(key, groups, walk_seed=walk_seed)
        return qs, KeyedQueryState(ids=tuple(ids), layout=layout, base=base)

    # -- decode --------------------------------------------------------------

    def recover(self, answers: list[jax.Array], state: BatchQueryState, *,
                hints: list[jax.Array] | None = None,
                cfgs: list[pir.PIRConfig] | None = None
                ) -> dict[int, np.ndarray]:
        """Decode REAL buckets only → {cluster: column bytes (m_b,) u8}.

        ``hints``/``cfgs`` override the live per-bucket state with a
        plan-time snapshot: the pipelined engine decodes in-flight batches
        AFTER an epoch commit may have patched `self.hints` in place, so it
        passes the lists it captured when the query was formed.
        """
        hints = self.hints if hints is None else hints
        cfgs = self.cfgs if cfgs is None else cfgs
        out: dict[int, np.ndarray] = {}
        for b, cluster in state.placement.items():
            p = cfgs[b].params
            s = state.secrets[b]
            if p.q_switch is not None:
                vals = lwe.decode_switched(answers[b], hints[b], s, p)
            else:
                vals = lwe.decode(lwe.hint_strip(answers[b], hints[b],
                                                 s), p)
            out[cluster] = np.asarray(vals.astype(jnp.uint8))
        return out

    def recover_rows(self, answers: list[jax.Array],
                     state: KeyedQueryState, *,
                     hints: list[jax.Array] | None = None,
                     cfgs: list[pir.PIRConfig] | None = None) -> np.ndarray:
        """Decode a keyed lookup → (κ, d) f32, bit-identical to table[ids].

        Decodes each placed group's column once, then extracts every
        requested row by fixed-stride arithmetic (`KeyedLayout.decode_row`)
        — duplicate ids repeat their row, in the caller's original order.
        ``hints``/``cfgs`` are the same plan-time epoch snapshots `recover`
        takes.
        """
        cols = self.recover(answers, state.base, hints=hints, cfgs=cfgs)
        layout = state.layout
        rows = [layout.decode_row(cols[layout.group_of(i)], i)
                for i in state.ids]
        if not rows:
            return np.zeros((0, layout.dim), np.float32)
        return np.stack(rows)

    # -- accounting ----------------------------------------------------------

    def accounting(self, state: BatchQueryState) -> BatchAccounting:
        """Exact per-bucket wire costs of one batched query (summed)."""
        return BatchAccounting(
            uplink_bytes=sum(c.uplink_bytes for c in self.cfgs),
            downlink_bytes=sum(c.downlink_bytes for c in self.cfgs),
            hint_bytes=sum(c.hint_bytes for c in self.cfgs),
            n_buckets=self.partition.n_buckets,
            placed=len(state.placement))
