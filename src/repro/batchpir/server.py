"""Batch-PIR server: per-bucket sub-DBs, hints, and the one-pass answer.

Bucket b's sub-DB D_b holds a replica of every member cluster's column,
row-truncated to the tallest member payload (rounded to the kernel row
granule) — the global DB pads every column to the single largest cluster,
so bucket-local truncation converts that padding into compute and downlink
savings.  Columns beyond the member count are zero up to the partition's
shared power-of-two width, so every bucket presents the same query width to
the kernel.

Per bucket there is an independent LWE instance: public matrix A_b from a
bucket-specific seed and hint H_b = D_b·A_b.  A batched query is one
uint32 vector per bucket; the answer is ONE `ops.bucketed_modmatmul`
call — a streamed pass over the bucketed DB whose cost does not depend on
how many probes κ the (hidden) placement carried.

Live-index deltas route here through `update/routing.py`: a mutation that
re-packs cluster columns J patches the owning buckets' sub-DBs and hints
with the same exact sparse GEMM as `PIRServer.update_columns`, so the
patched H_b stays bit-identical to `setup()` on the mutated sub-DB.  A
payload that outgrows its bucket's row budget triggers a single-bucket
rebuild (re-truncate + re-hint), never a full-system one.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.batchpir.partition import CuckooPartition
from repro.core import lwe, pir
from repro.kernels import ops

U32 = jnp.uint32

_ROW_GRANULE = 128          # bucket heights round up to this (kernel tiling)


def _bucket_a_seed(a_seed: int, bucket: int) -> int:
    """Distinct public-matrix seed per bucket, derived from the global one."""
    return a_seed * 1_000_003 + bucket


@functools.partial(jax.jit, static_argnames=("q_switch",))
def _switch_jit(x: jax.Array, q_switch: int) -> jax.Array:
    """One fused dispatch per bucket for the downlink modulus switch."""
    return lwe.switch_modulus(x, q_switch)


def _round_rows(used: int) -> int:
    return max(_ROW_GRANULE, ((used + _ROW_GRANULE - 1) // _ROW_GRANULE)
               * _ROW_GRANULE)


@dataclasses.dataclass
class BucketUpdate:
    """One bucket's reaction to a live-index mutation batch."""
    bucket: int
    rebuilt: bool               # True: overflow forced a bucket re-hint
    cols: np.ndarray            # local column positions patched (delta only)


@dataclasses.dataclass
class StagedBucketPatch:
    """Shadow half of a bucketed mutation commit: computed, not yet live.

    `stage_update_columns` builds every patched sub-DB, hint and config as
    fresh buffers (the delta GEMMs are already dispatched — JAX async —
    but nothing the serving path reads has moved); `publish()` is the
    pointer swap.  In-flight answers keep decoding against the plan-time
    snapshots they captured, so the stale window is the swap instant.
    """
    updates: list[BucketUpdate]
    _apply: "callable"
    published: bool = False

    def publish(self) -> list[BucketUpdate]:
        """Flip every staged bucket pointer; returns the per-bucket log."""
        assert not self.published, "StagedBucketPatch published twice"
        self._apply()
        self.published = True
        return self.updates


class BatchPIRServer:
    """Holds the bucketed replica DBs and answers batched queries."""

    def __init__(self, matrix: np.ndarray, used_bytes: np.ndarray,
                 partition: CuckooPartition, params: lwe.LWEParams, *,
                 a_seed: int = 7, impl: str = "auto",
                 mesh=None, mesh_axes: tuple[str, ...] | None = None):
        n = partition.n_clusters
        assert matrix.shape[1] == n, (matrix.shape, n)
        self.partition = partition
        self.impl = impl
        self.a_seed = a_seed
        self.mesh = mesh
        self.mesh_axes: tuple[str, ...] | None = None
        self.n_shards = 1
        self._stack: jax.Array | None = None   # sharded bucket stack cache
        self._order: np.ndarray | None = None  # height-aware stack permutation
        self._slot: np.ndarray | None = None   # bucket → stack slot (inverse)
        if mesh is not None:
            from repro.core import clustering
            self.mesh_axes, self.n_shards = clustering.resolve_mesh_axes(
                mesh, mesh_axes)
        if not lwe.noise_budget_ok(params, partition.width):
            params = lwe.choose_params(partition.width,
                                       q_switch=params.q_switch)
        self.params = params
        self.cfgs: list[pir.PIRConfig] = []
        self.sub_dbs: list[jax.Array] = []
        self._a_mats: list[jax.Array | None] = []
        used = np.asarray(used_bytes)
        for b in range(partition.n_buckets):
            mem = partition.members[b]
            # granule-rounded, but never taller than the source matrix
            # (m need not be a multiple of the granule)
            rows = min(_round_rows(int(used[mem].max()) if len(mem) else 1),
                       matrix.shape[0])
            sub = np.zeros((rows, partition.width), np.uint8)
            if len(mem):
                sub[:, :len(mem)] = matrix[:rows, mem]
            # sharded servers answer from the mesh-resident stack, so the
            # per-bucket views stay host-side (read for deltas/restacks
            # only) — otherwise device 0 would hold a second full DB copy
            self.sub_dbs.append(sub if mesh is not None
                                else jnp.asarray(sub))
            self.cfgs.append(pir.PIRConfig(
                m=rows, n=partition.width, params=self.params,
                a_seed=_bucket_a_seed(a_seed, b), impl=impl))
            self._a_mats.append(None)
        self.hints: list[jax.Array] = []

    # -- public matrices / hints --------------------------------------------

    def a_matrix(self, bucket: int) -> jax.Array:
        """Bucket b's public LWE matrix A_b: (W, k) u32, seed-derived."""
        if self._a_mats[bucket] is None:
            cfg = self.cfgs[bucket]
            self._a_mats[bucket] = lwe.gen_public_matrix(
                cfg.a_seed, cfg.n, cfg.params.k)
        return self._a_mats[bucket]

    def setup(self) -> list[jax.Array]:
        """Recompute every bucket hint H_b = D_b·A_b from the current DBs."""
        return [ops.hint_gemm(self.sub_dbs[b], self.a_matrix(b),
                              impl=self.impl)
                for b in range(self.partition.n_buckets)]

    def install_hints(self) -> int:
        """One-time offline hint build; returns total hint bytes."""
        self.hints = [jax.block_until_ready(h) for h in self.setup()]
        return self.hint_bytes

    @property
    def hint_bytes(self) -> int:
        """One-time hint downlink: Σ_b 4·m_b·k bytes across buckets."""
        return sum(cfg.hint_bytes for cfg in self.cfgs)

    @property
    def downlink_bytes(self) -> int:
        """Response bytes of one batched query (all buckets answer)."""
        return sum(cfg.downlink_bytes for cfg in self.cfgs)

    @property
    def uplink_bytes(self) -> int:
        """Query bytes of one batched query: B ciphertexts of 4·W bytes."""
        return sum(cfg.uplink_bytes for cfg in self.cfgs)

    @property
    def stored_bytes(self) -> int:
        """Total bucketed-DB bytes = what one batched answer streams."""
        return sum(int(d.shape[0]) * int(d.shape[1]) for d in self.sub_dbs)

    # -- online --------------------------------------------------------------

    def answer_batch(self, qs: jax.Array) -> list[jax.Array]:
        """qs: (B, W) or (B, W, C) uint32 → per-bucket (switched) answers.

        On a sharded server the buckets spread over the mesh (each device
        owns whole buckets, zero collectives); the stacked sub-DB layout is
        cached across calls and invalidated by column updates/rebuilds.
        """
        if self.mesh is not None:
            raw = self._answer_batch_sharded(qs)
        else:
            raw = ops.bucketed_modmatmul(self.sub_dbs, qs, impl=self.impl)
        if self.params.q_switch is not None:
            raw = [_switch_jit(a, self.params.q_switch) for a in raw]
        return raw

    @property
    def _stack_sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self.mesh, P(self.mesh_axes, None, None))

    def _answer_batch_sharded(self, qs: jax.Array) -> list[jax.Array]:
        if self._stack is None:
            # height-aware bucket→device packing: skewed bucket heights
            # otherwise park the tall (real-work) buckets on a few devices
            # while the rest multiply zero padding.  The LPT permutation is
            # cached with the stack and both invalidate together.
            from repro.distributed import collectives
            self._order = collectives.balanced_bucket_order(
                [d.shape[0] for d in self.sub_dbs], self.n_shards)
            self._slot = np.empty_like(self._order)
            self._slot[self._order] = np.arange(len(self._order))
            self._stack = jax.device_put(
                ops.stack_buckets(self.sub_dbs, self.n_shards,
                                  order=self._order),
                self._stack_sharding)
        was_vec = qs.ndim == 2
        q3 = qs[:, :, None] if was_vec else qs
        b_pad = self._stack.shape[0] - q3.shape[0]
        if b_pad:
            q3 = jnp.pad(q3, ((0, b_pad), (0, 0), (0, 0)))
        # queries travel with their buckets; answers index back through the
        # inverse permutation, so the reorder is invisible to callers
        q3 = q3[jnp.asarray(self._order)]
        full = ops.bucketed_modmatmul_sharded(self._stack, q3, self.mesh,
                                              self.mesh_axes)
        out = [full[int(self._slot[b]), :d.shape[0], :]
               for b, d in enumerate(self.sub_dbs)]
        return [o[:, 0] for o in out] if was_vec else out

    # -- live-index deltas ---------------------------------------------------

    def update_columns(self, cols: np.ndarray, new_cols: np.ndarray,
                       new_used: dict[int, int], *, donate: bool = False
                       ) -> list[BucketUpdate]:
        """Patch every bucket owning a touched cluster; exact mod 2^32.

        cols: (J,) global cluster ids (already re-packed), new_cols:
        (m, J) u8 at the GLOBAL row height, new_used: payload bytes per
        touched cluster.  Buckets whose row budget still fits every new
        payload take a sparse ΔH_b = ΔD_b[:,P]·A_b[P,:] patch (bit-identical
        to a from-scratch hint, as in `PIRServer.update_columns`); a bucket
        that overflows is rebuilt and re-hinted alone.
        """
        return self.stage_update_columns(cols, new_cols, new_used,
                                         donate=donate).publish()

    def stage_update_columns(self, cols: np.ndarray, new_cols: np.ndarray,
                             new_used: dict[int, int], *,
                             donate: bool = False) -> StagedBucketPatch:
        """Compute every bucket's patch WITHOUT publishing (shadow commit).

        All ΔH_b GEMMs and sub-DB scatters are dispatched against the
        current epoch's buffers; the returned patch's `publish()` swaps the
        pointers.  ``donate=True`` donates each touched sub-DB buffer into
        its scatter (in-place column write instead of a full copy); the
        donating scatters are deferred to `publish()` so an abandoned or
        aborted staged patch never strands `sub_dbs` on consumed buffers —
        legal because, as in the serving engine, no new dispatch touches
        the old buffers between stage and publish.
        """
        cols = np.asarray(cols)
        part = self.partition
        by_bucket: dict[int, list[int]] = {}
        for idx, j in enumerate(cols):
            for b in part.buckets_of(int(j)):
                by_bucket.setdefault(b, []).append(idx)
        updates: list[BucketUpdate] = []
        new_sub_dbs: dict[int, object] = {}
        deferred_scatters: list[tuple[int, jax.Array, jax.Array]] = []
        host_writes: list[tuple[int, np.ndarray, np.ndarray]] = []
        new_hints: dict[int, jax.Array] = {}
        new_cfgs: dict[int, pir.PIRConfig] = {}
        new_stack = self._stack
        stack_invalidated = False
        for b, idxs in sorted(by_bucket.items()):
            rows = self.cfgs[b].m
            need = max(new_used[int(cols[i])] for i in idxs)
            if need > rows:
                sub, cfg, hint = self._stage_rebuild_bucket(
                    b, cols, new_cols, new_used)
                new_sub_dbs[b] = sub
                new_cfgs[b] = cfg
                if hint is not None:
                    new_hints[b] = hint
                stack_invalidated = True
                new_stack = None      # mirror the eager path: no more patches
                updates.append(BucketUpdate(bucket=b, rebuilt=True,
                                            cols=np.zeros(0, np.int64)))
                continue
            pos = np.array([part.position(b, int(cols[i])) for i in idxs],
                           np.int64)
            new_sub = jnp.asarray(new_cols[:rows, idxs])
            delta_h = self._delta(b, pos, new_sub)   # reads OLD sub-DB rows
            if self.mesh is not None:      # host-side view: in-place write
                host_writes.append((b, pos, new_cols[:rows, idxs]))
            elif donate:
                # deferred to apply(): the donating scatter must not consume
                # the live sub-DB while the patch can still be abandoned
                deferred_scatters.append((b, jnp.asarray(pos), new_sub))
            else:
                new_sub_dbs[b] = ops.scatter_columns(
                    self.sub_dbs[b], jnp.asarray(pos), new_sub)
            if new_stack is not None:
                # patch the cached sharded layout with ONE fused scatter
                # (scatter output keeps the operand's sharding); the value
                # is transposed because jax moves the advanced-index dims
                # (bucket scalar + column array) to the front.  The stack
                # is laid out in height-aware order, so bucket b lives at
                # stack slot _slot[b].
                new_stack = new_stack.at[
                    int(self._slot[b]), :rows, jnp.asarray(pos)].set(
                        new_sub.T)
            if self.hints:
                # ΔH_b is transient, so the add donates ITS buffer — the
                # live hint stays intact for in-flight decode snapshots
                new_hints[b] = ops.add_delta(self.hints[b], delta_h)
            updates.append(BucketUpdate(bucket=b, rebuilt=False, cols=pos))

        def apply():
            for b, sub in new_sub_dbs.items():
                self.sub_dbs[b] = sub
            for b, pos, new_sub in deferred_scatters:
                self.sub_dbs[b] = ops.scatter_columns(
                    self.sub_dbs[b], pos, new_sub, donate=True)
            for b, pos, vals in host_writes:
                self.sub_dbs[b][:, pos] = vals
            for b, cfg in new_cfgs.items():
                self.cfgs[b] = cfg
            for b, hint in new_hints.items():
                self.hints[b] = hint
            if stack_invalidated:
                # a rebuilt bucket changes heights → the LPT permutation is
                # stale; stack, order and inverse recompute together
                self._stack = self._order = self._slot = None
            else:
                self._stack = new_stack

        return StagedBucketPatch(updates=updates, _apply=apply)

    def _delta(self, bucket: int, pos: np.ndarray, new_sub: jax.Array
               ) -> jax.Array:
        """ΔH_b for replacing local columns `pos`, pow-of-two bucketed like
        `PIRServer.update_columns` so streamed batches reuse compiled shapes."""
        db = self.sub_dbs[bucket]
        old_sub = db[:, pos]
        j = int(pos.shape[0])
        bucket_w = 1 << max(0, (j - 1).bit_length())
        pad = min(bucket_w, self.cfgs[bucket].n) - j
        pos_g = jnp.asarray(pos)
        if pad > 0:
            # column 0 padded on both sides contributes exactly ΔH = 0
            pos_g = jnp.concatenate([pos_g, jnp.zeros(pad, pos_g.dtype)])
            unchanged = jnp.repeat(db[:, :1], pad, axis=1)
            new_g = jnp.concatenate([new_sub, unchanged], axis=1)
            old_g = jnp.concatenate([old_sub, unchanged], axis=1)
        else:
            new_g, old_g = new_sub, old_sub
        a_p = self.a_matrix(bucket)[pos_g]
        return ops.delta_gemm(new_g, old_g, a_p, impl=self.impl)

    def _stage_rebuild_bucket(self, bucket: int, cols: np.ndarray,
                              new_cols: np.ndarray, new_used: dict[int, int]
                              ) -> tuple[object, pir.PIRConfig,
                                         jax.Array | None]:
        """Overflow path: re-truncate, re-pack and re-hint ONE bucket.

        Returns the staged (sub_db, cfg, hint-or-None) triple; the caller
        publishes.  The fresh hint GEMM is dispatched, not waited on — the
        serving loop forces it the first time a query decodes against it.
        """
        part = self.partition
        mem = part.members[bucket]
        old = np.asarray(self.sub_dbs[bucket])
        col_src: dict[int, np.ndarray] = {int(j): old[:, p]
                                          for p, j in enumerate(mem)}
        need = {int(j): (int(np.nonzero(c)[0][-1]) + 1 if c.any() else 1)
                for j, c in col_src.items()}
        for idx, j in enumerate(cols):
            j = int(j)
            if j in col_src:
                col_src[j] = new_cols[:, idx]
                need[j] = new_used[j]
        rows = _round_rows(max(need.values(), default=1))
        sub = np.zeros((rows, part.width), np.uint8)
        for p, j in enumerate(mem):
            src = col_src[int(j)]
            take = min(rows, len(src))
            sub[:take, p] = src[:take]
        sub_out = sub if self.mesh is not None else jnp.asarray(sub)
        # A_b depends only on (n, k), so it survives the row-budget change.
        cfg = dataclasses.replace(self.cfgs[bucket], m=rows)
        hint = (ops.hint_gemm(sub_out, self.a_matrix(bucket), impl=self.impl)
                if self.hints else None)
        return sub_out, cfg, hint
