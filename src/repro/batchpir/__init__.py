"""Batch-PIR subsystem: κ private cluster fetches in ~one database pass.

Layering (mirrors the core protocol split):

  partition — public 3-way cuckoo bucketization of the cluster axis
  server    — per-bucket replica sub-DBs + hints, one-pass batched answer
  client    — cuckoo placement, per-bucket one-hot/dummy encryption, decode

`BatchPIR` bundles the three for in-process use, exactly like
`PirRagSystem` bundles the base protocol roles.  Enable it on a built
system with `PirRagSystem.enable_batch()`; `multi_probe > 1` queries then
route through it automatically.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.batchpir.client import (BatchAccounting, BatchPIRClient,
                                   BatchQueryState, KeyedQueryState)
from repro.batchpir.partition import (CuckooPartition, KeyedLayout,
                                      PlacementError)
from repro.batchpir.server import BatchPIRServer, BucketUpdate

__all__ = [
    "BatchAccounting", "BatchPIR", "BatchPIRClient", "BatchPIRServer",
    "BatchQueryState", "BucketUpdate", "CuckooPartition", "KeyedLayout",
    "KeyedQueryState", "PlacementError", "build",
]


@dataclasses.dataclass
class BatchPIR:
    """The assembled subsystem plus the knobs needed to rebuild it."""
    partition: CuckooPartition
    server: BatchPIRServer
    client: BatchPIRClient
    kappa: int                  # max probes the geometry was sized for
    seed: int
    setup_seconds: float


def build(matrix: np.ndarray, used_bytes: np.ndarray, params, *,
          kappa: int = 8, n_buckets: int | None = None, seed: int = 101,
          a_seed: int = 7, impl: str = "auto",
          mesh=None, mesh_axes=None) -> BatchPIR:
    """Bucketize a chunk-transposed DB and hint every bucket (offline).

    With ``mesh=`` the buckets spread over the device mesh on the answer
    path (`BatchPIRServer` sharding) — cryptographic outputs are bit-
    identical either way.
    """
    t0 = time.perf_counter()
    n_buckets = n_buckets if n_buckets is not None else 3 * kappa
    part = CuckooPartition.build(matrix.shape[1], n_buckets, seed)
    server = BatchPIRServer(matrix, used_bytes, part, params,
                            a_seed=a_seed, impl=impl,
                            mesh=mesh, mesh_axes=mesh_axes)
    server.install_hints()
    client = BatchPIRClient.from_server(server)
    return BatchPIR(partition=part, server=server, client=client,
                    kappa=kappa, seed=seed,
                    setup_seconds=time.perf_counter() - t0)
