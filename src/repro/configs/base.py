"""Architecture registry: ArchSpec + per-family shape/input-spec machinery.

Every assigned architecture is a config module exposing ``ARCH: ArchSpec``.
``input_specs(arch, shape)`` returns ShapeDtypeStructs only — full-size inputs
are NEVER allocated; smoke tests use ``ARCH.smoke`` reduced configs.

LM shape policy (see DESIGN.md): ``decode_*``/``long_*`` lower `serve_step`
(one token against a seq_len KV cache).  `long_500k` is decode-only — O(seq)
per step — so it runs for the full-attention archs too; the formally-skipped
quadratic prefill at 500k is never compiled (marked † in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train | prefill | decode | serve | retrieval
    meta: dict

    def describe(self) -> str:
        return f"{self.name}[{self.kind}] " + " ".join(
            f"{k}={v}" for k, v in self.meta.items())


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                  # lm | gnn | recsys | pir
    model: Callable[[str], Any]  # shape_name → model config (full size)
    smoke: Callable[[str], Any]  # shape_name → reduced config
    shapes: dict[str, ShapeSpec]
    source: str = ""
    notes: str = ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode",
                           {"seq_len": 524288, "global_batch": 1,
                            "note": "decode-only†: O(seq) serve_step; "
                                    "quadratic prefill skipped "
                                    "(full-attention arch)"}),
}


def lm_input_specs(cfg, shape: ShapeSpec) -> dict:
    B = shape.meta["global_batch"]
    S = shape.meta["seq_len"]
    if shape.kind == "train":
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "decode":
        return {"tokens": sds((B,), jnp.int32),
                "lengths": sds((B,), jnp.int32)}
    raise ValueError(shape.kind)


def lm_flops_per_step(cfg, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N_active·D (+ attention quadratic term)."""
    from repro.models import transformer as tf
    B = shape.meta["global_batch"]
    S = shape.meta["seq_len"]
    d, hd = cfg.d_model, cfg.head_dim
    per_layer_attn_p = (cfg.n_heads * 2 + cfg.n_kv_heads * 2) * d * hd
    if cfg.moe is not None:
        n_moe = cfg.n_layers // cfg.moe.every
        n_dense = cfg.n_layers - n_moe
        act_ffn = (n_dense * 3 * d * cfg.d_ff
                   + n_moe * 3 * d * cfg.moe.d_ff
                   * (cfg.moe.top_k + cfg.moe.n_shared))
    else:
        act_ffn = cfg.n_layers * 3 * d * cfg.d_ff
    n_active = (cfg.vocab * d * 2 + cfg.n_layers * per_layer_attn_p + act_ffn)
    if shape.kind == "train":
        tokens = B * S
        mult = 6  # fwd 2 + bwd 4
        attn = 6 * cfg.n_layers * B * S * S * cfg.n_heads * hd  # 2·(qk+av)·3
        return mult * n_active * tokens + attn / 2  # causal halves scores
    if shape.kind == "prefill":
        tokens = B * S
        attn = 2 * cfg.n_layers * B * S * S * cfg.n_heads * hd / 2
        return 2 * n_active * tokens + attn
    # decode: 1 token/seq, attention linear in S
    attn = 2 * cfg.n_layers * B * 2 * S * cfg.n_heads * hd
    return 2 * n_active * B + attn


# ---------------------------------------------------------------------------
# GNN family (SchNet)
# ---------------------------------------------------------------------------

def _minibatch_sizes(batch_nodes=1024, fanout=(15, 10)):
    h1 = batch_nodes * fanout[0]
    h2 = h1 * fanout[1]
    return batch_nodes + h1 + h2, h1 + h2           # (nodes, edges)


_MB_NODES, _MB_EDGES = _minibatch_sizes()

def _pad512(n: int) -> int:
    """Edge buffers pad to the 512-device mesh LCM (masked edges are inert —
    they scatter into node 0 with weight from a masked distance)."""
    return ((n + 511) // 512) * 512


GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               {"n_nodes": _pad512(2708),
                                "n_nodes_raw": 2708,
                                "n_edges": _pad512(10556),
                                "n_edges_raw": 10556,
                                "d_feat": 1433, "n_classes": 7}),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train",
                              {"n_nodes": _pad512(_MB_NODES),
                               "n_nodes_raw": _MB_NODES,
                               "n_edges": _pad512(_MB_EDGES),
                               "n_edges_raw": _MB_EDGES,
                               "d_feat": 100, "n_classes": 47,
                               "batch_nodes": 1024, "fanout": "15-10",
                               "src_graph_nodes": 232965,
                               "src_graph_edges": 114615892}),
    "ogb_products": ShapeSpec("ogb_products", "train",
                              {"n_nodes": _pad512(2449029),
                               "n_nodes_raw": 2449029,
                               "n_edges": _pad512(61859140),
                               "n_edges_raw": 61859140,
                               "d_feat": 100, "n_classes": 47}),
    "molecule": ShapeSpec("molecule", "train",
                          {"n_nodes": 30, "n_edges": 64, "batch": 128}),
}


def gnn_input_specs(cfg, shape: ShapeSpec) -> dict:
    m = shape.meta
    if shape.name == "molecule":
        return {"z": sds((m["batch"], m["n_nodes"]), jnp.int32),
                "pos": sds((m["batch"], m["n_nodes"], 3), jnp.float32),
                "energy": sds((m["batch"],), jnp.float32)}
    return {"node_feat": sds((m["n_nodes"], m["d_feat"]), jnp.float32),
            "src": sds((m["n_edges"],), jnp.int32),
            "dst": sds((m["n_edges"],), jnp.int32),
            "edge_dist": sds((m["n_edges"],), jnp.float32),
            "labels": sds((m["n_nodes"],), jnp.int32),
            "label_mask": sds((m["n_nodes"],), jnp.bool_)}


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1_000_000}),
}


def recsys_input_specs(cfg, shape: ShapeSpec) -> dict:
    B = shape.meta["batch"]
    if cfg.kind == "mind":
        if shape.kind == "retrieval":
            return {"hist": sds((1, cfg.hist_len), jnp.int32),
                    "hist_mask": sds((1, cfg.hist_len), jnp.bool_),
                    "candidates": sds((_pad512(shape.meta["n_candidates"]),),
                                      jnp.int32)}
        return {"hist": sds((B, cfg.hist_len), jnp.int32),
                "hist_mask": sds((B, cfg.hist_len), jnp.bool_),
                "target": sds((B,), jnp.int32)}
    if shape.kind == "retrieval":
        out = {"sparse": sds((cfg.n_sparse,), jnp.int32),
               "candidates": sds((_pad512(shape.meta["n_candidates"]),),
                                 jnp.int32)}
        if cfg.n_dense:
            out["dense"] = sds((cfg.n_dense,), jnp.float32)
        return out
    out = {"sparse": sds((B, cfg.n_sparse), jnp.int32)}
    if cfg.n_dense:
        out["dense"] = sds((B, cfg.n_dense), jnp.float32)
    if shape.kind == "train":
        out["label"] = sds((B,), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def smoke_shape(shape: ShapeSpec) -> ShapeSpec:
    """Shrink a shape spec for CPU smoke tests (same kind/structure)."""
    m = dict(shape.meta)
    for key, cap in [("seq_len", 64), ("global_batch", 8), ("batch", 8),
                     ("n_candidates", 64), ("n_nodes", 40), ("n_edges", 120),
                     ("d_feat", 24), ("batch_nodes", 8)]:
        if key in m:
            m[key] = min(m[key], cap)
    if "n_classes" in m:
        m["n_classes"] = min(m["n_classes"], 7)
    return ShapeSpec(shape.name, shape.kind, m)


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> ArchSpec:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchSpec]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all():
    from repro.configs import (dcn_v2, dlrm_rm2, kimi_k2_1t,  # noqa: F401
                               llama4_maverick_400b, mind, phi4_mini,
                               pir_serve, qwen2_7b, qwen3_4b, schnet_arch,
                               xdeepfm)


def input_specs(arch: ArchSpec, shape_name: str) -> dict:
    shape = arch.shapes[shape_name]
    cfg = arch.model(shape_name)
    if arch.family == "lm":
        return lm_input_specs(cfg, shape)
    if arch.family == "gnn":
        return gnn_input_specs(cfg, shape)
    if arch.family == "recsys":
        return recsys_input_specs(cfg, shape)
    if arch.family == "pir":
        from repro.configs.pir_serve import pir_input_specs
        return pir_input_specs(cfg, shape)
    raise ValueError(arch.family)
