"""dcn-v2 [recsys] — n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512 interaction=cross (full-rank W).  [arXiv:2008.13535; paper]
Vocab 10⁶/field (unpinned by assignment)."""
import dataclasses

from repro.configs import base
from repro.models.recsys import RecSysConfig

FULL = RecSysConfig(
    name="dcn-v2", kind="dcn", n_dense=13, n_sparse=26, embed_dim=16,
    vocab_per_field=1_000_000, n_cross_layers=3,
    top_mlp=(1024, 1024, 512),
)

SMOKE = dataclasses.replace(FULL, name="dcn-smoke", vocab_per_field=100,
                            embed_dim=8, top_mlp=(32, 16),
                            n_cross_layers=2)

ARCH = base.register(base.ArchSpec(
    name="dcn-v2", family="recsys",
    model=lambda shape: FULL, smoke=lambda shape: SMOKE,
    shapes=base.RECSYS_SHAPES,
    source="arXiv:2008.13535; paper",
))
