"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]"""
from repro.configs import base
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="phi4-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=200064, rope_theta=10_000.0, tie_embeddings=True,
)

SMOKE = LMConfig(
    name="phi4-smoke",
    n_layers=3, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=512, rope_theta=10_000.0,
    attn_chunk_q=16, attn_chunk_kv=16, ce_chunk=16, remat=False,
)

ARCH = base.register(base.ArchSpec(
    name="phi4-mini-3.8b",
    family="lm",
    model=lambda shape: FULL,
    smoke=lambda shape: SMOKE,
    shapes=base.LM_SHAPES,
    source="arXiv:2412.08905; hf",
))
