"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Construction notes (DESIGN.md §Known deviations #3): Llama-4 interleaves
dense/MoE 1:1 and keeps one shared expert; with the assigned 128e/top-1 and
d_ff=8192 this lands at ≈401B total / ≈17B active params, matching the
"400b-a17b" designation.  Param-count pinned in tests/test_configs.py.
"""
from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, n_shared=1, every=2,
                  capacity_factor=1.25),
)

SMOKE = LMConfig(
    name="llama4-smoke",
    n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=512, rope_theta=500_000.0,
    moe=MoEConfig(n_experts=8, top_k=1, d_ff=128, n_shared=1, every=2,
                  capacity_factor=2.0),
    attn_chunk_q=16, attn_chunk_kv=16, ce_chunk=16, remat=False,
)

ARCH = base.register(base.ArchSpec(
    name="llama4-maverick-400b-a17b",
    family="lm",
    model=lambda shape: FULL,
    smoke=lambda shape: SMOKE,
    shapes=base.LM_SHAPES,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    notes="MoE interleaved 1:1 with dense, +1 shared expert (early-fusion "
          "modality frontend is out of scope for the LM backbone).",
))
