"""mind [recsys] — embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest.  [arXiv:1904.08030; unverified]
Item vocab 10⁶ (unpinned); history length 50 per the shape regime table.
`retrieval_cand` is MIND's native task: max-over-interests dot against 10⁶
candidates — and the arch where PIR-RAG composes (private candidate fetch,
examples/private_recsys.py)."""
import dataclasses

from repro.configs import base
from repro.models.recsys import RecSysConfig

FULL = RecSysConfig(
    name="mind", kind="mind", n_dense=0, n_sparse=1, embed_dim=64,
    vocab_per_field=1_000_000, n_interests=4, capsule_iters=3, hist_len=50,
)

SMOKE = dataclasses.replace(FULL, name="mind-smoke", vocab_per_field=200,
                            embed_dim=16, hist_len=12)

ARCH = base.register(base.ArchSpec(
    name="mind", family="recsys",
    model=lambda shape: FULL, smoke=lambda shape: SMOKE,
    shapes=base.RECSYS_SHAPES,
    source="arXiv:1904.08030; unverified",
))
