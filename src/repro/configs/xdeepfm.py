"""xdeepfm [recsys] — n_sparse=39 embed_dim=10 cin_layers=200-200-200
mlp=400-400 interaction=cin.  [arXiv:1803.05170; paper]
Vocab 10⁶/field (unpinned by assignment); no dense features in the assigned
spec (n_dense=0)."""
import dataclasses

from repro.configs import base
from repro.models.recsys import RecSysConfig

FULL = RecSysConfig(
    name="xdeepfm", kind="xdeepfm", n_dense=0, n_sparse=39, embed_dim=10,
    vocab_per_field=1_000_000, cin_layers=(200, 200, 200),
    dnn_mlp=(400, 400),
)

SMOKE = dataclasses.replace(FULL, name="xdeepfm-smoke", vocab_per_field=100,
                            n_sparse=8, embed_dim=4, cin_layers=(16, 16),
                            dnn_mlp=(32,))

ARCH = base.register(base.ArchSpec(
    name="xdeepfm", family="recsys",
    model=lambda shape: FULL, smoke=lambda shape: SMOKE,
    shapes=base.RECSYS_SHAPES,
    source="arXiv:1803.05170; paper",
))
