"""pir_serve — the PAPER'S OWN workload as a first-class arch (11th config).

Production-scale PIR-RAG serving point: n=4096 clusters × 2 MiB cluster
content ⇒ an 8.6 GB chunk-transposed u8 database (≈5.7M docs at 1.5 KB).
The online step is the batched modular GEMM  ans = D·Q (mod 2^32); the
offline step is the hint GEMM  H = D·A.

Distribution (beyond-paper, DESIGN.md §3): DB rows shard over pod×model —
the online hot path has ZERO collectives; the "data" axis shards the query
batch.  Roofline: 4·B int8-MACs per DB byte ⇒ HBM-bound below B≈60,
MXU-bound above.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs import base
from repro.configs.base import ShapeSpec, sds


@dataclasses.dataclass(frozen=True)
class PIRServeConfig:
    name: str
    m: int                      # DB rows (bytes per cluster)
    n: int                      # clusters
    lwe_k: int = 1024
    q_switch: int | None = 1 << 16


FULL = PIRServeConfig(name="pir_serve", m=2 * 1024 * 1024, n=4096)
SMOKE = PIRServeConfig(name="pir-smoke", m=2048, n=64)

PIR_SHAPES = {
    "online_b64": ShapeSpec("online_b64", "serve", {"batch": 64}),
    "online_b512": ShapeSpec("online_b512", "serve", {"batch": 512}),
    "hint_setup": ShapeSpec("hint_setup", "setup", {"k": 1024}),
}


def pir_input_specs(cfg: PIRServeConfig, shape: ShapeSpec) -> dict:
    # the DB itself is the step's *state* (sharded server-resident matrix)
    if shape.kind == "serve":
        return {"queries": sds((cfg.n, shape.meta["batch"]), jnp.uint32)}
    return {"a_mat": sds((cfg.n, cfg.lwe_k), jnp.uint32)}


ARCH = base.register(base.ArchSpec(
    name="pir_serve", family="pir",
    model=lambda shape: FULL, smoke=lambda shape: SMOKE,
    shapes=PIR_SHAPES,
    source="this paper (§3) + SimplePIR (USENIX Sec'23)",
    notes="Row-sharded zero-collective serving; int8 MXU roofline.",
))
