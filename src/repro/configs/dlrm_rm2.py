"""dlrm-rm2 [recsys] — n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1 interaction=dot.
[arXiv:1906.00091; paper]

Per-field vocab is not pinned by the assignment; we use 10⁶ rows/field
(26M × 64 fp32 ≈ 6.7 GB of tables, row-sharded over "model").
bot_mlp lists include the input width; top_mlp widths follow the interaction
output (DLRM repo convention).
"""
import dataclasses

from repro.configs import base
from repro.models.recsys import RecSysConfig

FULL = RecSysConfig(
    name="dlrm-rm2", kind="dlrm", n_dense=13, n_sparse=26, embed_dim=64,
    vocab_per_field=1_000_000,
    bot_mlp=(13, 512, 256, 64), top_mlp=(512, 512, 256, 1),
)

SMOKE = dataclasses.replace(FULL, name="dlrm-smoke", vocab_per_field=100,
                            bot_mlp=(13, 32, 16), top_mlp=(32, 16, 1),
                            embed_dim=16)

ARCH = base.register(base.ArchSpec(
    name="dlrm-rm2", family="recsys",
    model=lambda shape: FULL, smoke=lambda shape: SMOKE,
    shapes=base.RECSYS_SHAPES,
    source="arXiv:1906.00091; paper",
))
