"""schnet [gnn] — n_interactions=3 d_hidden=64 rbf=300 cutoff=10.
[arXiv:1706.08566; paper]

Shape-dependent frontends (the interaction trunk is the assigned config):
  molecule      → atom-number embedding + 3D positions (faithful SchNet)
  graph shapes  → node-feature linear + per-edge scalar distance (cfconv over
                  an explicit edge list via segment_sum; JAX has no CSR SpMM)
PIR-RAG applicability: none (DESIGN.md §Arch-applicability) — built without
the technique, full dry-run/roofline coverage.
"""
import dataclasses

from repro.configs import base
from repro.models.schnet import SchNetConfig

_TRUNK = dict(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)


def _full(shape: str) -> SchNetConfig:
    m = base.GNN_SHAPES[shape].meta
    if shape == "molecule":
        return SchNetConfig(name="schnet", mode="molecule", n_out=1,
                            n_species=100, **_TRUNK)
    return SchNetConfig(name="schnet", mode="graph", d_feat=m["d_feat"],
                        n_out=m["n_classes"], **_TRUNK)


def _smoke(shape: str) -> SchNetConfig:
    full = _full(shape)
    return dataclasses.replace(full, n_interactions=2, d_hidden=16, n_rbf=16,
                               d_feat=min(full.d_feat, 24) if
                               full.mode == "graph" else 0)


ARCH = base.register(base.ArchSpec(
    name="schnet",
    family="gnn",
    model=_full,
    smoke=_smoke,
    shapes=base.GNN_SHAPES,
    source="arXiv:1706.08566; paper",
    notes="minibatch_lg uses the real fanout-[15,10] CSR sampler "
          "(data/graph_sampler.py).",
))
