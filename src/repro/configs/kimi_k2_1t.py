"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8.  [arXiv:2501.kimi2; unverified]

Assignment sheet wins over the model card: public K2 uses MLA attention; the
assigned spec says GQA(kv=8), so GQA it is (DESIGN.md §Known deviations #4).
d_ff=2048 is the per-expert width; +1 shared expert per the K2 report.
All-MoE stack (every=1) → ≈1.04T total / ≈33B active params (pinned in
tests).  Trains with Adafactor + bf16 params so state fits 512×16GB.
"""
import jax.numpy as jnp

from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840, rope_theta=500_000.0,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared=1, every=1,
                  capacity_factor=1.25),
    param_dtype=jnp.bfloat16,     # 1T fp32 params cannot fit 512×16GB
)

SMOKE = LMConfig(
    name="kimi-smoke",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=64, vocab=512, rope_theta=500_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1, every=1,
                  capacity_factor=2.0),
    attn_chunk_q=16, attn_chunk_kv=16, ce_chunk=16, remat=False,
)

ARCH = base.register(base.ArchSpec(
    name="kimi-k2-1t-a32b",
    family="lm",
    model=lambda shape: FULL,
    smoke=lambda shape: SMOKE,
    shapes=base.LM_SHAPES,
    source="arXiv:2501.kimi2; unverified",
    notes="GQA per assignment (public K2 uses MLA); bf16 params + Adafactor.",
))
