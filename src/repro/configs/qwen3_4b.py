"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936
— qk_norm, GQA.  head_dim=128 (decoupled from d_model/n_heads, per Qwen3).
[hf:Qwen/Qwen3-8B; hf]"""
from repro.configs import base
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen3-4b",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936, rope_theta=1_000_000.0, qk_norm=True,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="qwen3-smoke",
    n_layers=3, d_model=48, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, rope_theta=1_000_000.0, qk_norm=True,
    attn_chunk_q=16, attn_chunk_kv=16, ce_chunk=16, remat=False,
)

ARCH = base.register(base.ArchSpec(
    name="qwen3-4b",
    family="lm",
    model=lambda shape: FULL,
    smoke=lambda shape: SMOKE,
    shapes=base.LM_SHAPES,
    source="hf:Qwen/Qwen3-8B; hf",
))
