"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias.  [arXiv:2407.10671; hf]"""
from repro.configs import base
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, rope_theta=1_000_000.0, qkv_bias=True,
)

SMOKE = LMConfig(
    name="qwen2-smoke",
    n_layers=3, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=512, rope_theta=1_000_000.0, qkv_bias=True,
    attn_chunk_q=16, attn_chunk_kv=16, ce_chunk=16, remat=False,
)

ARCH = base.register(base.ArchSpec(
    name="qwen2-7b",
    family="lm",
    model=lambda shape: FULL,
    smoke=lambda shape: SMOKE,
    shapes=base.LM_SHAPES,
    source="arXiv:2407.10671; hf",
))
