"""Logical-axis sharding: models name their dims, strategies map them to mesh.

Models annotate activations with ``logical(x, "batch", "seq", "d_model")`` and
declare parameter dimension names via ``*_param_axes`` pytrees.  A *rule set*
maps logical names → mesh axes (or None = replicate); the dry-run/launchers
install rules + mesh via ``use_rules``.  With no rules installed everything is
a no-op, so unit tests on 1 device never touch device state.

Rule tables are the entire distribution strategy:

  LM_TRAIN (FSDP+TP+EP)     params sharded over data+model (ZeRO-3 style),
                            batch over data(+pod), heads/ffn/experts over model
  LM_DECODE (TP + split-S)  KV-cache sequence over data, heads over model
  RECSYS / GNN / PIR        see tables below.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> tuple[Mesh | None, Mapping[str, Any] | None]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Mapping[str, Any]):
    """Install a mesh + logical-axis rule table for code under this scope."""
    old = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = old


def axis_size(name: str) -> int:
    """Mesh extent a logical axis is sharded over (1 without rules).

    Model code uses this to pick *structural* group counts that must match
    the physical sharding (e.g. MoE dispatch groups = batch shards, so each
    data shard sorts only its own tokens).
    """
    mesh, rules = _current()
    if mesh is None or rules is None:
        return 1
    ax = rules.get(name)
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for(*names: str | None) -> P:
    _, rules = _current()
    if rules is None:
        return P()
    return P(*[rules.get(n) if n is not None else None for n in names])


def logical(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint by logical dim names (no-op w/o rules)."""
    mesh, rules = _current()
    if mesh is None or rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(*names)))


def specs_from_axes(axes_tree: Any) -> Any:
    """Map a pytree of logical-dim-name tuples → PartitionSpec tree."""
    return jax.tree.map(
        lambda names: spec_for(*names),
        axes_tree,
        is_leaf=lambda v: isinstance(v, tuple),
    )


def shardings_from_axes(mesh: Mesh, axes_tree: Any, rules: Mapping[str, Any]
                        ) -> Any:
    """NamedSharding tree for in_shardings= (usable outside use_rules)."""
    def one(names):
        return NamedSharding(
            mesh, P(*[rules.get(n) if n is not None else None
                      for n in names]))
    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda v: isinstance(v, tuple))


# ---------------------------------------------------------------------------
# Strategy rule tables (logical axis → mesh axis).  "pod" composes with
# "data" for batch-like axes on the multi-pod mesh via tuple axes.
# ---------------------------------------------------------------------------

def _maybe_pod(multi_pod: bool, *axes: str):
    return (("pod",) + axes) if multi_pod else axes


def lm_train_rules(multi_pod: bool = False, *,
                   fsdp_only: bool = False) -> dict[str, Any]:
    """fsdp_only: pure ZeRO-3 — batch and params shard over data×model, no
    TP/SP.  The right strategy for ≤8B dense models on a 256-chip pod: TP=16
    activation wire (AG+RS per sublayer) costs ~20× the compute at 50 GB/s
    links, while ZeRO-3's whole-step weight traffic is ~2·params.  MoE models
    keep TP+SP+EP (experts need the model axis)."""
    if fsdp_only:
        every = _maybe_pod(multi_pod, "data", "model")
        return {
            # batch (256 seqs) covers data×model exactly; the pod axis takes
            # the SEQUENCE dim (context parallelism) — params/grads still
            # shard over all three axes (ZeRO-3).
            # vocab→model: a replicated embed+head (+Adam moments) costs
            # ~9 GiB/device on 200k-vocab models
            "batch": ("data", "model"), "seq": "pod" if multi_pod else None,
            "vocab": "model", "d_model": None,
            "heads": None, "kv_heads": None, "d_ff": None, "experts": None,
            "expert_cap": None, "fsdp": every, "head_dim": None,
            "emb_rows": None, "nodes": None, "edges": None,
            "graph_batch": None, "fields": None, "chunks": None,
            "clusters": None,
        }
    batch = _maybe_pod(multi_pod, "data")
    return {
        "batch": batch,            # data parallel over data (+pod)
        # Megatron-style sequence parallelism: the residual stream is
        # seq-sharded over 'model' at block boundaries (all-gathered at each
        # sublayer entry, reduce-scattered at its exit).  Same wire volume as
        # the plain TP all-reduces, but the scan's saved activation stacks
        # shrink by the model-axis width — this is what lets kimi-k2/llama4
        # train without gradient-accumulation re-gathers.
        "seq": "model",
        "vocab": "model",          # TP embedding/logits
        "d_model": None,
        "heads": "model",          # TP attention
        # kv_heads stay replicated in training: 4–8 KV heads over a 16-wide
        # model axis means padding + per-chunk re-gathers (measured); the
        # wk/wv params are small
        "kv_heads": None,
        "d_ff": "model",           # TP MLP
        "experts": "model",        # EP
        "expert_cap": None,
        # FSDP: shard the *other* param dim over data(+pod) — ZeRO-3 style
        "fsdp": batch,
        "head_dim": None,
        "emb_rows": "model",
        "nodes": batch, "edges": batch, "graph_batch": batch,
        "fields": None,
        "chunks": "model", "clusters": None,
    }


def lm_decode_rules(multi_pod: bool = False, *, shard_seq: bool = False
                    ) -> dict[str, Any]:
    batch = _maybe_pod(multi_pod, "data")
    rules = lm_train_rules(multi_pod)
    rules.update({
        "batch": batch,
        # weights stay 2D-sharded (model × data) at serve time too: a 1T
        # MoE at 16-way TP would need 130 GB/device.  XLA all-gathers the
        # per-layer slices inside the scan (ZeRO-3-style serving).
        "fsdp": batch,
        "seq": None,               # no SP during decode (single token)
        "cache_seq": ("data",) if shard_seq else None,  # split-S attention
    })
    return rules


def recsys_rules(multi_pod: bool = False) -> dict[str, Any]:
    # batch shards over BOTH data and model: recsys MLP params are small
    # (replicated), so leaving 'model' idle would replicate the interaction
    # compute 16× (measured via useful-FLOPs ratio 0.06)
    batch = _maybe_pod(multi_pod, "data", "model")
    return {
        "batch": batch,
        "emb_rows": "model",       # model-parallel embedding tables
        "dim": None, "fields": None, "d_ff": "model", "fsdp": None,
        "candidates": ("data", "model") if not multi_pod else
                      ("pod", "data", "model"),
        "interests": None,
    }


def gnn_rules(multi_pod: bool = False) -> dict[str, Any]:
    batch = _maybe_pod(multi_pod, "data")
    return {
        "batch": batch,
        "edges": _maybe_pod(multi_pod, "data", "model"),
        # node tensors shard row-wise too: with nodes replicated, the
        # atom-wise dense layers replicate over all 256/512 devices
        # (useful-FLOPs ratio 0.01 on ogb_products)
        "nodes": _maybe_pod(multi_pod, "data", "model"),
        "d_hidden": None, "rbf": None, "fsdp": None,
    }


def pir_rules(multi_pod: bool = False) -> dict[str, Any]:
    return {
        # DB rows over EVERY axis; queries replicated (n·B u32 ≈ 8 MB —
        # trivial broadcast).  Sharding the query batch over 'data' instead
        # keeps per-device arithmetic intensity at 4·b_local ops/byte and
        # leaves b=512 memory-bound; full row-sharding + replicated queries
        # reaches the int8 compute roofline with zero collectives.
        "chunks": _maybe_pod(multi_pod, "data", "model"),
        "clusters": None,
        "qbatch": None,
        "lwe_k": None,
    }
