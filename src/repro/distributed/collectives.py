"""Explicit shard_map building blocks for the model-parallel hot paths.

pjit+constraints handles most of the framework; these are the places where
we want the communication pattern pinned down rather than inferred:

  * ``sharded_embedding_lookup`` — row-sharded tables: local masked gather +
    one psum (the classic model-parallel embedding; avoids XLA materializing
    an all-gathered table).
  * ``split_s_decode_attention`` — flash-decoding: KV cache sharded along
    sequence; per-shard online-softmax partials combined with pmax/psum.
  * ``ring_psum`` — reduce via collective_permute ring, used by the gradient
    compression path so the wire format stays int8 end-to-end.
  * ``row_shard_gemm`` / ``row_shard_delta_gemm`` — the PIR serving strategy
    (`sharding.pir_rules`): the packed database row-shards over the mesh,
    queries replicate, every shard answers its own row slice.  ZERO
    collectives on the hot path — the modular GEMM's contraction dim (the
    cluster axis) is never split, so per-shard answers are already final.
  * ``corpus_shard_kmeans`` / ``row_shard_assign`` / ``row_shard_sqdist`` —
    the sharded OFFLINE build: the corpus row-shards over the same mesh the
    serving DB uses.  K-means runs the block-canonical core from
    `core.clustering` per shard with one tiled all-gather of the per-block
    partial sums per Lloyd iteration (gather + fixed-order local reduce, not
    psum, so the float combine order is pinned and the fit is bit-identical
    to the single-device build); assignment/distance sweeps are row-local
    and collective-free like the serving GEMM.

Each has an 8-device subprocess test (tests/test_sharded.py /
tests/test_sharded_pir.py) asserting bitwise/allclose equality with the
single-device reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def sharded_embedding_lookup(mesh: Mesh, axis: str):
    """Returns lookup(table, idx) with table row-sharded over `axis`.

    table: (V, d) sharded P(axis, None); idx: (B,) replicated → (B, d)
    replicated.  Each shard gathers only its local rows; one psum combines.
    """
    def local(table_shard, idx):
        size = table_shard.shape[0]
        lo = jax.lax.axis_index(axis) * size
        local_idx = idx - lo
        ok = (local_idx >= 0) & (local_idx < size)
        safe = jnp.clip(local_idx, 0, size - 1)
        rows = jnp.take(table_shard, safe, axis=0)
        rows = jnp.where(ok[:, None], rows, 0)
        return jax.lax.psum(rows, axis)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axis, None), P()),
                     out_specs=P())


def split_s_decode_attention(mesh: Mesh, axis: str, *, scale: float):
    """Returns attn(q, k, v, lengths) with K/V sharded on the seq axis.

    q: (B, H, hd) replicated; k/v: (B, T, H, hd) sharded P(None, axis);
    lengths: (B,) replicated.  Per-shard online softmax partials (m, l, o)
    are combined with pmax/psum — numerically identical to global softmax.
    """
    def local(q, k_shard, v_shard, lengths):
        t_local = k_shard.shape[1]
        lo = jax.lax.axis_index(axis) * t_local
        s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                       k_shard.astype(jnp.float32)) * scale
        tpos = lo + jnp.arange(t_local)
        mask = tpos[None, None, :] < lengths[:, None, None]
        s = jnp.where(mask, s, -1e30)
        m_loc = jnp.max(s, axis=-1)                          # (B, H)
        m_glob = jax.lax.pmax(m_loc, axis)
        p = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bht,bthd->bhd", p,
                           v_shard.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, axis)
        o_glob = jax.lax.psum(o_loc, axis)
        return o_glob / jnp.maximum(l_glob[..., None], 1e-30)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(None, axis), P(None, axis), P()),
                     out_specs=P())


@functools.lru_cache(maxsize=None)
def row_shard_gemm(mesh: Mesh, axes: tuple[str, ...], *, impl: str = "auto",
                   q_switch: int | None = None):
    """Returns ans(db, q): the row-sharded modular GEMM  D·q  (mod 2^32).

    db: (m, n) uint8 sharded P(axes, None) — each device holds a row slice
    D_s.  q: (n, b) uint32 replicated.  Returns (m, b) sharded P(axes, None)
    (uint16 when ``q_switch`` ≤ 2^16 — the modulus switch runs shard-local
    too, so the downlink leaves each shard already compressed).

    Row sharding never splits the contraction dim, so each shard's answer
    slice  ans_s = D_s·q  is final: no psum, no all-gather — the compiled
    HLO contains no collective ops at all (asserted in tests).  This is the
    whole-system serving strategy argued in ``sharding.pir_rules``:
    replicating the query batch (n·b·4 bytes) is a trivial broadcast next
    to streaming the per-shard DB bytes, and it keeps per-device arithmetic
    intensity at the full-batch 4·b ops/byte.
    """
    from repro.core import lwe
    from repro.kernels import ops

    def local(db_shard, q):
        ans = ops.modmatmul(db_shard, q, impl=impl)
        if q_switch is not None:
            ans = lwe.switch_modulus(ans, q_switch)
        return ans

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(axes, None), P()),
                             out_specs=P(axes, None)))


@functools.lru_cache(maxsize=None)
def row_shard_delta_gemm(mesh: Mesh, axes: tuple[str, ...], *,
                         impl: str = "auto"):
    """Returns delta(new, old, a_j): row-sharded ΔH = (new−old)·A_J.

    new/old: (m, J) uint8 sharded P(axes, None); a_j: (J, k) uint32
    replicated.  Each shard patches only its own hint rows — the live-index
    delta never leaves the shard that owns those DB rows, so mutation
    commits are collective-free exactly like the answer path.
    """
    from repro.kernels import ops

    def local(new_shard, old_shard, a_j):
        return ops.delta_gemm(new_shard, old_shard, a_j, impl=impl)

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(axes, None), P(axes, None), P()),
                             out_specs=P(axes, None)))


@functools.lru_cache(maxsize=None)
def row_shard_scatter(mesh: Mesh, axes: tuple[str, ...], *,
                      donate: bool = False):
    """Returns scatter(db, cols, new_cols): row-sharded column replacement.

    db: (m, n) uint8 sharded P(axes, None); cols: (J,) int replicated;
    new_cols: (m, J) uint8 sharded P(axes, None).  Each shard swaps the
    touched columns of its own row slice — the column axis is never split,
    so, like every other op on the PIR serving path, there are zero
    collectives and the result is bit-identical to the single-device
    scatter.

    ``donate=True`` donates the DB operand so XLA writes the J touched
    columns into the live buffer instead of copying all m·n bytes per epoch
    commit — the in-place half of the shadow-epoch commit path.  Callers
    must treat the input array as consumed.
    """
    def local(db_shard, cols, new_shard):
        return db_shard.at[:, cols].set(new_shard)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axes, None), P(), P(axes, None)),
                   out_specs=P(axes, None))
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def bucket_shard_gemm(mesh: Mesh, axes: tuple[str, ...]):
    """Returns ans(stack, qs): bucket-sharded batch-PIR GEMM (mod 2^32).

    stack: (B, m, W) uint8 sharded P(axes, None, None) — buckets spread
    across devices, each device owning B/shards whole sub-DBs.  qs:
    (B, W, C) uint32 sharded the same way (a bucket's queries live with its
    sub-DB).  Returns (B, m, C) uint32 sharded P(axes, None, None).

    Bucket-parallel, not row-parallel: every bucket's GEMM is complete on
    its owning device, so — like ``row_shard_gemm`` — there are zero
    collectives.  The local op is the plain u32 batched matmul (XLA integer
    matmul wraps mod 2^32, the same oracle `kernels.ref` uses), bitwise
    equal to the per-bucket loop in ``ops.bucketed_modmatmul``.
    """
    def local(stack_shard, q_shard):
        return jnp.einsum("bmw,bwc->bmc", stack_shard.astype(jnp.uint32),
                          q_shard.astype(jnp.uint32))

    spec = P(axes, None, None)
    return jax.jit(shard_map(local, mesh=mesh, in_specs=(spec, spec),
                             out_specs=spec))


def balanced_bucket_order(heights, n_shards: int) -> "np.ndarray":
    """LPT bucket→device packing for `bucket_shard_gemm`; returns an order.

    heights: per-bucket useful row counts (sub-DB heights before the stack
    pads them to a common m').  The bucket count pads up to a multiple of
    ``n_shards`` with zero-height virtual buckets, then buckets assign
    longest-first to the least-loaded device, each device taking exactly
    B'/n_shards buckets.  The result is a (B',) int64 permutation laid out
    device-major: stacking ``dbs`` in this order makes the contiguous
    per-device slices carry near-equal useful-row totals, so a skewed
    height distribution no longer parks most of the real work on one
    device while the rest multiply zero padding.

    Deterministic and permutation-stable: ties break by (height desc,
    bucket index asc) and by lowest device id, and the per-device load
    totals depend only on the sorted height sequence — permuting the
    input heights permutes the assignment but reproduces the same load
    multiset.  Reordering the bucket axis never changes any bucket's GEMM
    (each answer is complete on its owning device), so callers that index
    answers through the inverse permutation stay bit-identical to the
    unsorted layout.
    """
    import numpy as np
    h = np.asarray(heights, np.int64)
    b_pad = (-len(h)) % n_shards
    if b_pad:
        h = np.concatenate([h, np.zeros(b_pad, np.int64)])
    cap = len(h) // n_shards
    by_h = np.lexsort((np.arange(len(h)), -h))      # height desc, index asc
    loads = np.zeros(n_shards, np.int64)
    counts = np.zeros(n_shards, np.int64)
    slots: list[list[int]] = [[] for _ in range(n_shards)]
    for b in by_h:
        open_devs = np.nonzero(counts < cap)[0]
        dev = int(open_devs[np.argmin(loads[open_devs])])
        slots[dev].append(int(b))
        loads[dev] += h[b]
        counts[dev] += 1
    return np.concatenate([np.asarray(s, np.int64) for s in slots])


def shard_row_loads(heights, n_shards: int, order=None) -> "np.ndarray":
    """Per-device useful-row totals of a bucket stack layout.

    With ``order=None`` this scores the sequential (unsorted) layout
    `ops.stack_buckets` produces by default; passing the permutation from
    `balanced_bucket_order` scores the height-aware layout.  The
    max/mean of the returned (n_shards,) vector is the imbalance metric
    the recsys benchmark reports.
    """
    import numpy as np
    h = np.asarray(heights, np.int64)
    b_pad = (-len(h)) % n_shards
    if b_pad:
        h = np.concatenate([h, np.zeros(b_pad, np.int64)])
    if order is not None:
        h = h[np.asarray(order)]
    return h.reshape(n_shards, -1).sum(axis=1)


def _shard_count(mesh: Mesh, axes: tuple[str, ...]) -> int:
    """Shard count via the one shared axis rule (`resolve_mesh_axes`)."""
    from repro.core import clustering
    return clustering.resolve_mesh_axes(mesh, axes)[1]


@functools.lru_cache(maxsize=None)
def corpus_shard_kmeans(mesh: Mesh, axes: tuple[str, ...], *, k: int,
                        iters: int, n_blocks: int, n: int,
                        impl: str = "xla"):
    """Returns fit(key, x, valid): the corpus-sharded K-means fit.

    x: (N_pad, d) f32 sharded P(axes, None) — N_pad a multiple of
    ``n_blocks``, which is a multiple of the shard count, so every device
    owns a contiguous run of canonical blocks.  valid: (N_pad,) bool sharded
    P(axes) masks padding rows; ``n`` is the true corpus size.  key is
    replicated.  Returns (centroids (k, d) replicated, assignment (N_pad,)
    i32 sharded P(axes), inertia () replicated).

    Each device runs `clustering._kmeans_core` on its row slice: kmeans++
    draws sample from the all-gathered global D² vector with the replicated
    key (every shard picks the identical index; the chosen row travels via
    an exact masked-gather psum), and each Lloyd iteration all-gathers the
    per-block partial sums/counts and reduces them locally in canonical
    block order — the bit-identity contract with the single-device
    `clustering.kmeans_fit(..., n_blocks=n_blocks)`.
    """
    from repro.core import clustering

    shards = _shard_count(mesh, axes)
    assert n_blocks % shards == 0, (n_blocks, shards)

    def local(key, x_shard, valid_shard):
        return clustering._kmeans_core(
            key, x_shard, valid_shard, k=k, iters=iters,
            blocks=n_blocks // shards, n=n, impl=impl, axis=axes)

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(), P(axes, None), P(axes)),
                             out_specs=(P(), P(axes), P()),
                             check_rep=False))


@functools.lru_cache(maxsize=None)
def row_shard_assign(mesh: Mesh, axes: tuple[str, ...], *,
                     impl: str = "xla"):
    """Returns assign(x, cents): the row-sharded nearest-centroid sweep.

    x: (N_pad, d) f32 sharded P(axes, None); cents: (k, d) f32 replicated.
    Returns (assignment (N_pad,) i32, min-d² (N_pad,) f32) sharded P(axes).
    Assignment is row-local, so there are zero collectives, and each shard
    dispatches `kernels.ops.kmeans_assign` — the fused Pallas distance+
    argmin kernel when ``impl`` routes to it — over its own slice.
    """
    from repro.kernels import ops

    def local(x_shard, cents):
        return ops.kmeans_assign(x_shard, cents, impl=impl)

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(axes, None), P()),
                             out_specs=(P(axes), P(axes))))


@functools.lru_cache(maxsize=None)
def row_shard_sqdist(mesh: Mesh, axes: tuple[str, ...], *, n_blocks: int):
    """Returns d2(x, cents): row-sharded block-canonical squared distances.

    x: (N_pad, d) f32 sharded P(axes, None), N_pad a multiple of
    ``n_blocks``; cents: (k, d) f32 replicated.  Returns (N_pad, k) f32
    sharded P(axes, None).  Each shard runs the same per-block GEMM the
    host path uses (`clustering._blocked_sqdist_host` body), zero
    collectives — the distances `balanced_assign` consumes are bit-stable
    across mesh layouts.
    """
    from repro.core import clustering

    shards = _shard_count(mesh, axes)
    assert n_blocks % shards == 0, (n_blocks, shards)

    def local(x_shard, cents):
        rows, d = x_shard.shape
        blocks = n_blocks // shards
        xb = x_shard.reshape(blocks, rows // blocks, d)
        return jax.lax.map(
            lambda b: clustering.pairwise_sqdist(b, cents), xb
        ).reshape(rows, cents.shape[0])

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(axes, None), P()),
                             out_specs=P(axes, None)))


def ring_psum(mesh: Mesh, axis: str):
    """All-reduce built from collective_permute (explicit ring; int-friendly).

    x sharded P(axis, ...) — each device's block is its contribution; every
    device ends with the elementwise sum of all blocks.
    """
    n = mesh.shape[axis]

    def local(x):
        def body(i, val):
            acc, buf = val
            buf = jax.lax.ppermute(
                buf, axis, [(j, (j + 1) % n) for j in range(n)])
            return acc + buf, buf
        acc, _ = jax.lax.fori_loop(0, n - 1, body, (x, x))
        return acc

    return shard_map(local, mesh=mesh, in_specs=P(axis, None),
                     out_specs=P(axis, None), check_rep=False)


def row_shard_health_check(faults, n_devices: int) -> list[tuple[int, int]]:
    """Guard the "collectives.row_shard.loss" fault site for a fleet tick.

    The replica layer (repro.fleet.replica) calls this once per tick in
    place of a real per-device heartbeat RPC; `faults` is a duck-typed
    injector (or None) whose due events name the device losing its
    row-shard cells.  Returns [(device, down_ticks), ...] — empty on every
    un-faulted tick, at the cost of one counter increment, so the no-fault
    health check adds no clock reads or collectives to the serving path.
    """
    if faults is None:
        return []
    return [(ev.device % n_devices, ev.down_ticks)
            for ev in faults.fire("collectives.row_shard.loss")]
