"""Explicit shard_map building blocks for the model-parallel hot paths.

pjit+constraints handles most of the framework; these are the three places
where we want the communication pattern pinned down rather than inferred:

  * ``sharded_embedding_lookup`` — row-sharded tables: local masked gather +
    one psum (the classic model-parallel embedding; avoids XLA materializing
    an all-gathered table).
  * ``split_s_decode_attention`` — flash-decoding: KV cache sharded along
    sequence; per-shard online-softmax partials combined with pmax/psum.
  * ``ring_psum`` — reduce via collective_permute ring, used by the gradient
    compression path so the wire format stays int8 end-to-end.

Each has an 8-device subprocess test (tests/test_sharded.py) asserting
bitwise/allclose equality with the single-device reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def sharded_embedding_lookup(mesh: Mesh, axis: str):
    """Returns lookup(table, idx) with table row-sharded over `axis`.

    table: (V, d) sharded P(axis, None); idx: (B,) replicated → (B, d)
    replicated.  Each shard gathers only its local rows; one psum combines.
    """
    def local(table_shard, idx):
        size = table_shard.shape[0]
        lo = jax.lax.axis_index(axis) * size
        local_idx = idx - lo
        ok = (local_idx >= 0) & (local_idx < size)
        safe = jnp.clip(local_idx, 0, size - 1)
        rows = jnp.take(table_shard, safe, axis=0)
        rows = jnp.where(ok[:, None], rows, 0)
        return jax.lax.psum(rows, axis)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axis, None), P()),
                     out_specs=P())


def split_s_decode_attention(mesh: Mesh, axis: str, *, scale: float):
    """Returns attn(q, k, v, lengths) with K/V sharded on the seq axis.

    q: (B, H, hd) replicated; k/v: (B, T, H, hd) sharded P(None, axis);
    lengths: (B,) replicated.  Per-shard online softmax partials (m, l, o)
    are combined with pmax/psum — numerically identical to global softmax.
    """
    def local(q, k_shard, v_shard, lengths):
        t_local = k_shard.shape[1]
        lo = jax.lax.axis_index(axis) * t_local
        s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                       k_shard.astype(jnp.float32)) * scale
        tpos = lo + jnp.arange(t_local)
        mask = tpos[None, None, :] < lengths[:, None, None]
        s = jnp.where(mask, s, -1e30)
        m_loc = jnp.max(s, axis=-1)                          # (B, H)
        m_glob = jax.lax.pmax(m_loc, axis)
        p = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bht,bthd->bhd", p,
                           v_shard.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, axis)
        o_glob = jax.lax.psum(o_loc, axis)
        return o_glob / jnp.maximum(l_glob[..., None], 1e-30)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(None, axis), P(None, axis), P()),
                     out_specs=P())


def ring_psum(mesh: Mesh, axis: str):
    """All-reduce built from collective_permute (explicit ring; int-friendly).

    x sharded P(axis, ...) — each device's block is its contribution; every
    device ends with the elementwise sum of all blocks.
    """
    n = mesh.shape[axis]

    def local(x):
        def body(i, val):
            acc, buf = val
            buf = jax.lax.ppermute(
                buf, axis, [(j, (j + 1) % n) for j in range(n)])
            return acc + buf, buf
        acc, _ = jax.lax.fori_loop(0, n - 1, body, (x, x))
        return acc

    return shard_map(local, mesh=mesh, in_specs=P(axis, None),
                     out_specs=P(axis, None), check_rep=False)
