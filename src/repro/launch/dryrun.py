import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: XLA pins the device
# count at first init.  Only the dry-run gets 512 placeholder devices —
# tests/benches see 1 (this env var is set nowhere else).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build the FULL config's step (state/inputs as
ShapeDtypeStructs — nothing is allocated), install the arch's sharding rule
table on the production mesh, `.lower().compile()`, and record:

  * memory_analysis()            — per-device bytes: proves fit
  * cost_analysis()              — XLA's raw counters (while bodies ×1)
  * hlo_analysis.analyze()       — trip-scaled dot FLOPs, HBM-traffic floor,
                                   per-kind collective wire bytes
  * wall times, HLO size, collective op counts

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
launch/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
        --shape train_4k --mesh pod
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import base as cfgbase
from repro.distributed import sharding as sh
from repro.launch import hlo_analysis, steps as steps_lib
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, *, rules_override: dict | None = None,
             tag: str = "") -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    arch = cfgbase.get(arch_name)
    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "mesh_shape": [2, 16, 16] if multi_pod else [16, 16],
        "n_devices": 512 if multi_pod else 256,
        "family": arch.family, "ok": False, "tag": tag,
    }
    t_start = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if not multi_pod:
            # single-pod mesh uses 256 of the 512 host devices
            mesh = jax.make_mesh((16, 16), ("data", "model"),
                                 devices=jax.devices()[:256])
        bundle = steps_lib.make_bundle(arch, shape_name, smoke=False)
        rules = dict(bundle.rules_for(multi_pod))
        if rules_override:
            rules.update(rules_override)
        state_sh = sh.shardings_from_axes(mesh, bundle.state_axes, rules)
        batch_sh = sh.shardings_from_axes(
            mesh, bundle.batch_axes, rules)
        specs = steps_lib.input_specs_for(arch, shape_name, smoke=False)

        def wrapped(state, batch):
            with sh.use_rules(mesh, rules):
                return bundle.fn(state, batch)

        jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,) if bundle.donate_state else ())
        t0 = time.perf_counter()
        lowered = jitted.lower(bundle.state_spec, specs)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_per_device_bytes": int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):      # jax < 0.5 wraps it in a list
            ca = ca[0] if ca else {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed",
                                     "transcendentals")}
        txt = compiled.as_text()
        rec["hlo_bytes"] = len(txt)
        stats = hlo_analysis.analyze(txt)
        rec["hlo"] = {
            "dot_flops_per_device": stats.dot_flops,
            "dot_traffic_bytes_per_device": stats.dot_traffic_bytes,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
            "n_whiles": stats.n_whiles,
            "max_trip": stats.max_trip,
        }
        rec["lower_s"] = t1 - t0
        rec["compile_s"] = t2 - t1
        rec["ok"] = True
    except Exception as e:  # record failures, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.perf_counter() - t_start

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        out_dir, f"{arch_name}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    peak = rec.get("memory", {}).get("peak_per_device_bytes", 0) / 2**30
    print(f"[dryrun] {status} {arch_name}:{shape_name}:{mesh_name}{suffix} "
          f"peak={peak:.2f}GiB compile={rec.get('compile_s', 0):.1f}s",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for name, arch in sorted(cfgbase.all_archs().items()):
            for shape in arch.shapes:
                cells.append((name, shape))
    else:
        assert args.arch, "--arch or --all"
        arch = cfgbase.get(args.arch)
        shapes = [args.shape] if args.shape else list(arch.shapes)
        cells = [(args.arch, s) for s in shapes]

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch_name, shape_name in cells:
        for multi_pod in meshes:
            rec = run_cell(arch_name, shape_name, multi_pod, args.out)
            n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
