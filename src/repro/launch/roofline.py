"""Three-term roofline from the dry-run artifacts (TPU v5e constants).

    compute    = dot_FLOPs_per_device / peak            (int8 peak for PIR)
    memory     = HBM_traffic_floor_per_device / HBM_bw
    collective = wire_bytes_per_device / link_bw

All inputs are PER DEVICE (post-SPMD HLO shapes are local), so no division
by chip count is needed.  MODEL_FLOPS is the analytic useful work (6·N_active·D
for LMs; closed forms per family below); MODEL/HLO is the useful-compute
ratio (captures remat recompute, capacity padding, causal waste, etc.).

Caveat recorded in every table: the CPU host backend canonicalizes bf16→f32
before SPMD partitioning, so bf16 activation traffic/collectives are counted
at 4 bytes; TPU-native wire volume for those tensors is ~0.5× ("adj" column).

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_BF16 = 197e12          # v5e bf16 FLOP/s per chip
PEAK_INT8 = 394e12          # v5e int8 OPS per chip (PIR kernel)
HBM_BW = 819e9              # bytes/s per chip
LINK_BW = 50e9              # bytes/s per ICI link

BF16_ADJ = 0.5              # CPU-backend bf16→f32 canonicalization correction


def model_flops(rec: dict) -> float:
    """Analytic useful FLOPs for the whole step, GLOBAL (all chips)."""
    from repro.configs import base as cfgbase
    arch = cfgbase.get(rec["arch"])
    shape = arch.shapes[rec["shape"]]
    cfg = arch.model(rec["shape"])
    fam = rec["family"]
    if fam == "lm":
        return cfgbase.lm_flops_per_step(cfg, shape)
    if fam == "pir":
        b = shape.meta.get("batch", cfg.lwe_k)
        return 2.0 * cfg.m * cfg.n * b * 4          # int8 ops, 4 limbs
    if fam == "gnn":
        m = shape.meta
        d = cfg.d_hidden
        if rec["shape"] == "molecule":
            pairs = m["batch"] * m["n_nodes"] ** 2
            per_edge = 2 * (cfg.n_rbf * d + d * d)
            f = pairs * per_edge + m["batch"] * m["n_nodes"] * 6 * d * d
        else:
            per_edge = 2 * (cfg.n_rbf * d + d * d) + 2 * d
            per_node = 2 * 2 * d * d + 2 * d * (d // 2)
            f = (m.get("n_edges_raw", m["n_edges"]) * per_edge
                 + m["n_nodes"] * per_node) * cfg.n_interactions
        return 3.0 * f                               # train: fwd+bwd
    if fam == "recsys":
        m = shape.meta
        if shape.kind == "retrieval" and cfg.kind == "mind":
            # interests extracted ONCE; per-candidate work is K·d dots
            return (_recsys_fwd_flops(cfg)
                    + 2.0 * cfg.n_interests * cfg.embed_dim
                    * m["n_candidates"])
        B = m.get("n_candidates", m.get("batch", 1))
        f = _recsys_fwd_flops(cfg)
        mult = 3.0 if shape.kind == "train" else 1.0
        total = mult * f * B
        if cfg.kind == "mind" and shape.kind == "train":
            # in-batch sampled softmax: the (B, B) score GEMM dominates
            total += mult * 2.0 * B * B * cfg.embed_dim
        return total
    return 0.0


def _mlp_flops(sizes) -> float:
    return sum(2.0 * sizes[i] * sizes[i + 1] for i in range(len(sizes) - 1))


def _recsys_fwd_flops(cfg) -> float:
    F, d = cfg.n_sparse, cfg.embed_dim
    if cfg.kind == "dlrm":
        inter = 2.0 * (F + 1) ** 2 * d
        top_in = cfg.bot_mlp[-1] + (F + 1) * F // 2
        return (_mlp_flops(cfg.bot_mlp) + inter
                + _mlp_flops([top_in] + list(cfg.top_mlp)))
    if cfg.kind == "dcn":
        d_in = cfg.n_dense + F * d
        return (cfg.n_cross_layers * 2.0 * d_in * d_in
                + _mlp_flops([d_in] + list(cfg.top_mlp))
                + 2.0 * (d_in + cfg.top_mlp[-1]))
    if cfg.kind == "xdeepfm":
        hs = [F] + list(cfg.cin_layers)
        cin = sum(2.0 * hs[i] * F * d * hs[i + 1] for i in range(len(hs) - 1))
        return cin + _mlp_flops([F * d] + list(cfg.dnn_mlp) + [1])
    if cfg.kind == "mind":
        L, K = cfg.hist_len, cfg.n_interests
        return (2.0 * L * d * d                       # bilinear
                + cfg.capsule_iters * 4.0 * L * K * d + 2.0 * K * d)
    return 0.0


def terms(rec: dict) -> dict:
    hlo = rec["hlo"]
    fam = rec["family"]
    n_dev = rec["n_devices"]
    flops_dev = sum(hlo["dot_flops_per_device"].values())
    int_flops = sum(v for k, v in hlo["dot_flops_per_device"].items()
                    if k.startswith(("u", "s")))
    peak = PEAK_INT8 if (fam == "pir" or int_flops > flops_dev / 2) \
        else PEAK_BF16
    if fam == "pir":
        flops_dev *= 4.0      # u32 dot lowers as 4 int8 limb GEMMs on MXU

    compute = flops_dev / peak
    memory = hlo["dot_traffic_bytes_per_device"] / HBM_BW
    collective = sum(hlo["collective_bytes_per_device"].values()) / LINK_BW

    mf = model_flops(rec)
    mf_dev = mf / n_dev
    useful = mf_dev / flops_dev if flops_dev else 0.0
    out = dict(
        compute_s=compute, memory_s=memory, collective_s=collective,
        peak_used="int8" if peak == PEAK_INT8 else "bf16",
        model_flops_global=mf, useful_ratio=useful,
        peak_gib=rec["memory"]["peak_per_device_bytes"] / 2**30,
    )
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    out["bottleneck"] = dom[0]
    out["step_s_lower_bound"] = max(compute, memory, collective)
    # roofline fraction: useful work at peak vs achievable step time
    ideal = mf_dev / peak
    out["roofline_frac"] = (ideal / out["step_s_lower_bound"]
                            if out["step_s_lower_bound"] else 0.0)
    # bf16-adjusted (TPU-native) collective/memory estimate
    out["memory_s_adj"] = memory * (BF16_ADJ if fam != "pir" else 1.0)
    out["collective_s_adj"] = collective * (BF16_ADJ if fam != "pir"
                                            else 1.0)
    out["step_s_adj"] = max(compute, out["memory_s_adj"],
                            out["collective_s_adj"])
    out["roofline_frac_adj"] = (ideal / out["step_s_adj"]
                                if out["step_s_adj"] else 0.0)
    return out


def load_records(d: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict], mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | peak GiB | compute s | memory s | coll s | "
        "bottleneck | useful | roofline | roofline(adj) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec["mesh"] != mesh or not rec.get("ok") or rec.get("tag"):
            continue
        t = terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t['peak_gib']:.1f} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {t['bottleneck']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.2f} "
            f"| {t['roofline_frac_adj']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(table(recs, args.mesh))
    bad = [r for r in recs if not r.get("ok")]
    if bad:
        print(f"\n{len(bad)} FAILED cells:")
        for r in bad:
            print(" ", r["arch"], r["shape"], r["mesh"],
                  r.get("error", "")[:100])


if __name__ == "__main__":
    main()
