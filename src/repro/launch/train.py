"""Fault-tolerant training driver.

Production posture (DESIGN.md §7):
  * checkpoint-every-N with atomic async saves (repro.checkpoint.store)
  * restore-on-start: a restarted job resumes from the latest step with
    bitwise-identical data (seekable step-indexed batches)
  * step-time watchdog: straggler/anomaly detection (median × factor)
  * SimulatedFailure injection for the restart integration test
  * elastic: restore() accepts any target mesh/shardings

Run as a module for the CPU-scale example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import statistics
import time
from typing import Callable

import jax

from repro.checkpoint import store


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests kill the trainer here)."""


class FaultTolerantTrainer:
    def __init__(self, step_fn: Callable, init_state: Callable, *,
                 ckpt_dir: str, ckpt_every: int = 25, keep: int = 3,
                 watchdog_factor: float = 5.0, shardings=None,
                 log: Callable[[str], None] = print):
        self.step_fn = jax.jit(step_fn, donate_argnums=0)
        self.init_state = init_state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.watchdog_factor = watchdog_factor
        self.shardings = shardings
        self.log = log
        self.saver = store.AsyncSaver()
        self.step_times: list[float] = []

    def _restore_or_init(self, key) -> tuple:
        latest = store.latest_step(self.ckpt_dir)
        if latest is not None:
            self.log(f"[trainer] restoring step {latest} from "
                     f"{self.ckpt_dir}")
            return store.restore(self.ckpt_dir, step=latest,
                                 shardings=self.shardings), latest + 1
        return self.init_state(key), 0

    def run(self, batch_at: Callable[[int], dict], n_steps: int, *,
            seed: int = 0, fail_at: int | None = None) -> tuple:
        """batch_at(step) must be deterministic — resume repeats it exactly."""
        state, start = self._restore_or_init(jax.random.PRNGKey(seed))
        metrics = None
        for step in range(start, n_steps):
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch_at(step))
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            if (step + 1) % self.ckpt_every == 0:
                self.saver.save(self.ckpt_dir, state, step=step,
                                keep=self.keep)
            if fail_at is not None and step == fail_at:
                self.saver.wait()
                raise SimulatedFailure(f"injected failure at step {step}")
        self.saver.wait()
        if metrics is not None:
            store.save(self.ckpt_dir, state, step=n_steps - 1,
                       keep=self.keep)
        return state, metrics

    def _watchdog(self, step: int, dt: float):
        if len(self.step_times) >= 5:
            med = statistics.median(self.step_times[-20:])
            if dt > self.watchdog_factor * med:
                self.log(f"[watchdog] step {step} took {dt:.3f}s "
                         f"(median {med:.3f}s) — straggler/anomaly")
        self.step_times.append(dt)


def main():  # pragma: no cover - exercised via examples
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import base as cfgbase
    from repro.launch import steps as steps_lib

    arch = cfgbase.get(args.arch)
    shape = args.shape or {"lm": "train_4k", "gnn": "full_graph_sm",
                           "recsys": "train_batch"}[arch.family]
    bundle = steps_lib.make_bundle(arch, shape, smoke=args.smoke)
    trainer = FaultTolerantTrainer(bundle.fn, bundle.init_state,
                                   ckpt_dir=args.ckpt_dir,
                                   ckpt_every=args.ckpt_every)

    def batch_at(step):
        return steps_lib.materialize_inputs(
            arch, shape, jax.random.PRNGKey(args.seed * 100003 + step),
            smoke=args.smoke)

    t0 = time.perf_counter()
    _, metrics = trainer.run(batch_at, args.steps, seed=args.seed)
    print(f"[trainer] done {args.steps} steps in "
          f"{time.perf_counter() - t0:.1f}s; final metrics "
          f"{jax.tree.map(float, metrics)}")


if __name__ == "__main__":
    main()
