"""PIR serving driver: deadline-batched private retrieval + live mutations.

Production posture: requests queue; a batch is cut when either `max_batch`
accumulate or the oldest request reaches `deadline_ms` (p99-latency control —
the serving-side straggler mitigation).  All queries in a batch become ONE
modular GEMM (ans = D·[q_1 … q_B]), which is the regime where the TPU kernel
is MXU-bound (EXPERIMENTS §Perf-A).

Live-index mode (`live=LiveIndex(...)`): corpus mutations stream in via
`submit_mutation` and are committed *between* query batches, so a GEMM never
races a column swap.  Each request records the epoch of the hint it was
encrypted against; a commit advances the epoch, so requests already queued
become stale — the loop rejects them, the (simulated) client syncs its
HintCache and re-encrypts, and the retry is served in the next batch.
`stale_retries` counts these, the freshness/latency trade-off made visible.

Per-query LWE secrets come from ONE `jax.random.split` stream threaded
through the loop (`fold_in` per query inside `query_batch`) — wall-clock
seeding could collide secrets across batches, which is a security bug, not
just a testing nuisance.

    PYTHONPATH=src python -m repro.launch.serve --docs 2000 --requests 64
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Callable

import jax
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    query_emb: np.ndarray
    t_arrival: float
    epoch: int = 0                 # hint epoch the query was formed against
    retries: int = 0
    top_k: int = 5                 # per-request result size
    multi_probe: int = 1           # clusters to fetch (>1 → batch-PIR able)


@dataclasses.dataclass
class Response:
    rid: int
    top: list
    t_done: float
    batch_size: int
    epoch: int = 0
    retries: int = 0


class DeadlineBatcher:
    """Cut a batch at max_batch or when the head request ages past deadline."""

    def __init__(self, *, max_batch: int = 64, deadline_ms: float = 20.0):
        self.max_batch = max_batch
        self.deadline_ms = deadline_ms
        self.queue: deque[Request] = deque()

    def submit(self, req: Request):
        self.queue.append(req)

    def requeue(self, req: Request):
        """Put a rejected request back at the head (it keeps its arrival)."""
        self.queue.appendleft(req)

    def ready(self, now: float) -> bool:
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        age_ms = (now - self.queue[0].t_arrival) * 1e3
        return age_ms >= self.deadline_ms

    def cut(self) -> list[Request]:
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        return batch


class PIRServeLoop:
    """Deadline-batched serving; optionally wraps a LiveIndex for mutations.

    `system` may be a PirRagSystem (static corpus) or, with `live=...`, the
    LiveIndex whose `.system` is queried at its current epoch.  A system
    built with ``mesh=`` serves every batch through the sharded
    zero-collective answer path; the loop itself is layout-agnostic (its
    batching, epoch admission and key-stream logic never look at the mesh).
    """

    def __init__(self, system, *, max_batch: int = 64,
                 deadline_ms: float = 20.0,
                 clock: Callable[[], float] = time.perf_counter,
                 live=None, seed: int = 0):
        self.live = live if live is not None else (
            system if hasattr(system, "epochs") else None)
        self.system = system if self.live is None else self.live.system
        self.batcher = DeadlineBatcher(max_batch=max_batch,
                                       deadline_ms=deadline_ms)
        self.clock = clock
        self.responses: list[Response] = []
        self.mutations: deque = deque()
        self.stale_retries = 0
        self._key = jax.random.PRNGKey(seed)   # per-batch query-key stream

    @property
    def epoch(self) -> int:
        return self.live.epoch if self.live is not None else 0

    def submit(self, rid: int, query_emb: np.ndarray, *, top_k: int = 5,
               multi_probe: int = 1):
        """A client submits a query formed against the CURRENT epoch's hint."""
        self.batcher.submit(Request(rid, query_emb, self.clock(),
                                    epoch=self.epoch, top_k=top_k,
                                    multi_probe=multi_probe))

    def submit_mutation(self, mut):
        assert self.live is not None, "mutations need a LiveIndex"
        self.mutations.append(mut)

    def _commit_mutations(self):
        """Fold queued mutations into one epoch between query batches."""
        if self.live is None or not self.mutations:
            return None
        while self.mutations:
            self.live.journal.append(self.mutations.popleft())
        return self.live.commit()

    def tick(self, force: bool = False) -> int:
        """Serve one batch if ready; returns number of requests served.

        force=True flushes a partial batch regardless of the deadline
        (used by drain) WITHOUT touching the configured deadline_ms.
        """
        self._commit_mutations()
        now = self.clock()
        if not self.batcher.ready(now) and not (force and self.batcher.queue):
            return 0
        batch = self.batcher.cut()

        # Epoch admission control: a query encrypted against a superseded
        # hint would decode garbage, so reject it; the client syncs its
        # cached hint (HintCache.sync) and re-encrypts against the head.
        cur = self.epoch
        fresh = [r for r in batch if r.epoch == cur]
        for r in reversed([r for r in batch if r.epoch != cur]):
            self.stale_retries += 1
            r.epoch = cur
            r.retries += 1
            self.batcher.requeue(r)
        if not fresh:
            return 0

        system = self.live.system if self.live is not None else self.system
        # One GEMM per distinct multi_probe value: single-probe requests
        # share the classic column-stacked GEMM; multi-probe requests share
        # the bucketed batch-PIR GEMM (all clients in one streamed pass).
        groups: dict[int, list[Request]] = {}
        for r in fresh:
            groups.setdefault(r.multi_probe, []).append(r)
        for mp in sorted(groups):
            reqs = groups[mp]
            embs = np.stack([r.query_emb for r in reqs])
            self._key, kq = jax.random.split(self._key)
            results = system.query_batch(
                embs, top_k=[r.top_k for r in reqs], multi_probe=mp, key=kq)
            t = self.clock()
            for req, top in zip(reqs, results):
                # batch_size = this group's GEMM width, not the tick total
                self.responses.append(Response(req.rid, top, t, len(reqs),
                                               epoch=cur, retries=req.retries))
        return len(fresh)

    def drain(self):
        """Serve everything still queued, force-flushing partial batches."""
        while self.batcher.queue or self.mutations:
            self.tick(force=True)


def main():  # pragma: no cover - exercised by examples/tests
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=50.0)
    ap.add_argument("--mutate-every", type=int, default=0,
                    help="if >0, replace a random doc every N requests "
                         "(exercises the live-index delta path)")
    ap.add_argument("--multi-probe", type=int, default=1,
                    help="clusters fetched per query; >1 routes through "
                         "the batch-PIR subsystem (one bucketed pass)")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--shard", type=int, default=0,
                    help="row-shard the server DB over this many local "
                         "devices (0 = single-device; zero-collective "
                         "answer path, bit-identical results)")
    args = ap.parse_args()

    from repro.core import pipeline
    from repro.data import corpus as corpus_lib
    from repro.update import LiveIndex, journal as journal_lib

    mesh = None
    if args.shard > 1:
        n_dev = len(jax.devices())
        assert args.shard <= n_dev, (args.shard, n_dev)
        mesh = jax.make_mesh((args.shard,), ("chunks",),
                             devices=jax.devices()[:args.shard])

    corp = corpus_lib.make_corpus(0, args.docs, emb_dim=64, n_topics=24)
    rng = np.random.default_rng(0)
    if args.mutate_every > 0:
        live = LiveIndex.build(corp.texts, corp.embeddings,
                               n_clusters=24, impl="xla", mesh=mesh)
        loop = PIRServeLoop(live, max_batch=args.max_batch,
                            deadline_ms=args.deadline_ms)
    else:
        live = None
        system = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                             n_clusters=24, impl="xla",
                                             mesh=mesh)
        loop = PIRServeLoop(system, max_batch=args.max_batch,
                            deadline_ms=args.deadline_ms)

    if args.multi_probe > 1:
        loop.system.enable_batch(kappa=max(4, args.multi_probe))

    t0 = time.perf_counter()
    for rid in range(args.requests):
        q = corp.embeddings[rng.integers(0, args.docs)]
        loop.submit(rid, q, top_k=args.top_k, multi_probe=args.multi_probe)
        if live is not None and args.mutate_every and rid % args.mutate_every == 0:
            d = int(rng.integers(0, args.docs))
            loop.submit_mutation(journal_lib.replace(
                d, f"refreshed doc {d}".encode(), corp.embeddings[d]))
        loop.tick()
    loop.drain()
    dt = time.perf_counter() - t0
    if not loop.responses:
        print(f"served 0 requests in {dt:.2f}s")
        return
    lat = [r.t_done - t0 for r in loop.responses]
    sizes = [r.batch_size for r in loop.responses]
    print(f"served {len(loop.responses)} requests in {dt:.2f}s; "
          f"mean batch {np.mean(sizes):.1f}; "
          f"p50/p99 completion {np.percentile(lat, 50):.2f}/"
          f"{np.percentile(lat, 99):.2f}s"
          + (f"; epoch {loop.epoch}; stale retries {loop.stale_retries}"
             if live is not None else ""))


if __name__ == "__main__":
    main()
