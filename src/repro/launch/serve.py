"""PIR serving driver: deadline-batched private retrieval.

Production posture: requests queue; a batch is cut when either `max_batch`
accumulate or the oldest request reaches `deadline_ms` (p99-latency control —
the serving-side straggler mitigation).  All queries in a batch become ONE
modular GEMM (ans = D·[q_1 … q_B]), which is the regime where the TPU kernel
is MXU-bound (EXPERIMENTS §Perf-A).

    PYTHONPATH=src python -m repro.launch.serve --docs 2000 --requests 64
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Callable

import jax
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    query_emb: np.ndarray
    t_arrival: float


@dataclasses.dataclass
class Response:
    rid: int
    top: list
    t_done: float
    batch_size: int


class DeadlineBatcher:
    """Cut a batch at max_batch or when the head request ages past deadline."""

    def __init__(self, *, max_batch: int = 64, deadline_ms: float = 20.0):
        self.max_batch = max_batch
        self.deadline_ms = deadline_ms
        self.queue: deque[Request] = deque()

    def submit(self, req: Request):
        self.queue.append(req)

    def ready(self, now: float) -> bool:
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        age_ms = (now - self.queue[0].t_arrival) * 1e3
        return age_ms >= self.deadline_ms

    def cut(self) -> list[Request]:
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        return batch


class PIRServeLoop:
    def __init__(self, system, *, max_batch: int = 64,
                 deadline_ms: float = 20.0,
                 clock: Callable[[], float] = time.perf_counter):
        self.system = system
        self.batcher = DeadlineBatcher(max_batch=max_batch,
                                       deadline_ms=deadline_ms)
        self.clock = clock
        self.responses: list[Response] = []

    def submit(self, rid: int, query_emb: np.ndarray):
        self.batcher.submit(Request(rid, query_emb, self.clock()))

    def tick(self) -> int:
        """Serve one batch if ready; returns number of requests served."""
        now = self.clock()
        if not self.batcher.ready(now):
            return 0
        batch = self.batcher.cut()
        embs = np.stack([r.query_emb for r in batch])
        results = self.system.query_batch(embs, top_k=5,
                                          seed=int(now * 1e3) % 99991)
        t = self.clock()
        for req, top in zip(batch, results):
            self.responses.append(Response(req.rid, top, t, len(batch)))
        return len(batch)

    def drain(self):
        while self.batcher.queue:
            self.tick()
            # force the deadline on the final partial batch
            self.batcher.deadline_ms = 0.0


def main():  # pragma: no cover - exercised by examples/tests
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=50.0)
    args = ap.parse_args()

    from repro.core import pipeline
    from repro.data import corpus as corpus_lib

    corp = corpus_lib.make_corpus(0, args.docs, emb_dim=64, n_topics=24)
    system = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                         n_clusters=24, impl="xla")
    loop = PIRServeLoop(system, max_batch=args.max_batch,
                        deadline_ms=args.deadline_ms)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        q = corp.embeddings[rng.integers(0, args.docs)]
        loop.submit(rid, q)
        loop.tick()
    loop.drain()
    dt = time.perf_counter() - t0
    lat = [r.t_done - t0 for r in loop.responses]
    sizes = [r.batch_size for r in loop.responses]
    print(f"served {len(loop.responses)} requests in {dt:.2f}s; "
          f"mean batch {np.mean(sizes):.1f}; "
          f"p50/p99 completion {np.percentile(lat, 50):.2f}/"
          f"{np.percentile(lat, 99):.2f}s")


if __name__ == "__main__":
    main()
