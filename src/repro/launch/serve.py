"""Thin CLI over the serving engines in `repro.serve`.

The engines themselves live in `repro.serve.engine` (synchronous reference
loop + pipelined plan/dispatch/complete engine with shadow-epoch commits);
this module keeps the historical import surface alive and parses flags:

    PYTHONPATH=src python -m repro.launch.serve --docs 2000 --requests 64 \
        --engine pipelined --mutate-every 8

`--engine sync` serves through the blocking reference loop; `pipelined`
(default) overlaps batch N's answer GEMM with decoding batch N−depth and
encoding batch N+1, and commits mutations via shadow buffers + pointer
swap.  Results are bit-identical either way — only the timeline changes.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

# Re-exported for backward compatibility: the serving classes began life in
# this module and tests/examples import them from here.
from repro.serve.engine import (DeadlineBatcher, PIRServeLoop,  # noqa: F401
                                PipelinedServeLoop, Request, Response)


def main():  # pragma: no cover - exercised by examples/tests
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=50.0)
    ap.add_argument("--engine", choices=("sync", "pipelined", "fleet"),
                    default="pipelined",
                    help="blocking reference loop vs plan/dispatch/complete "
                         "pipeline (bit-identical responses); `fleet` wraps "
                         "the pipelined engine in a replica group with "
                         "failover + journal-replay recovery (docs/fleet.md)")
    ap.add_argument("--depth", type=int, default=2,
                    help="pipelined engine: max batches in flight")
    ap.add_argument("--mutate-every", type=int, default=0,
                    help="if >0, replace a random doc every N requests "
                         "(exercises the live-index delta path)")
    ap.add_argument("--multi-probe", type=int, default=1,
                    help="clusters fetched per query; >1 routes through "
                         "the batch-PIR subsystem (one bucketed pass)")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--shard", type=int, default=0,
                    help="shard over this many local devices (0 = single-"
                         "device).  Covers the OFFLINE build too: K-means "
                         "fits mesh-parallel and the DB is packed and "
                         "placed shard-by-shard (docs/architecture.md), "
                         "then served through the zero-collective answer "
                         "path — results bit-identical either way")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet engine: replica ranks (R full copies of "
                         "the index on disjoint device rows)")
    ap.add_argument("--shard-loss", metavar="TICK:DEV:TICKS", default=None,
                    help="fleet engine: inject one shard loss, e.g. "
                         "'8:0:16' = device 0 down for 16 fleet ticks "
                         "starting at tick 8 (exercises failover + "
                         "journal-replay failback)")
    ap.add_argument("--chaos", type=int, metavar="SEED", default=None,
                    help="fleet engine: inject a seeded random fault plan "
                         "(shard loss, answer drops/delays, commit "
                         "failures, chain corruption)")
    ap.add_argument("--generate", type=int, metavar="N", default=0,
                    help="if >0, close the RAG loop: feed each request's "
                         "retrieved docs through the tiny byte-level LM "
                         "and emit N tokens per response (docs/rag.md); "
                         "the pipelined engine defers + coalesces "
                         "generation micro-batches")
    ap.add_argument("--gen-coalesce", type=int, default=4,
                    help="pipelined/fleet engines: parked generation "
                         "groups merged into one decode micro-batch")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export a Chrome-trace (chrome://tracing / "
                         "Perfetto) of the run's spans to this path; "
                         "privacy-scrubbed at record time "
                         "(docs/observability.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the metrics registry (counters/gauges/"
                         "histograms) as JSON after the run")
    args = ap.parse_args()

    from repro.core import pipeline
    from repro.data import corpus as corpus_lib
    from repro.update import LiveIndex, journal as journal_lib

    mesh = None
    if args.shard > 1:
        n_dev = len(jax.devices())
        assert args.shard <= n_dev, (args.shard, n_dev)
        mesh = jax.make_mesh((args.shard,), ("chunks",),
                             devices=jax.devices()[:args.shard])

    from repro.obs import Obs

    corp = corpus_lib.make_corpus(0, args.docs, emb_dim=64, n_topics=24)
    rng = np.random.default_rng(0)
    loop_cls = (PipelinedServeLoop if args.engine == "pipelined"
                else PIRServeLoop)
    obs = Obs(trace=args.trace is not None)
    loop_kw = dict(max_batch=args.max_batch, deadline_ms=args.deadline_ms,
                   obs=obs)
    gen = None
    if args.generate > 0:
        from repro.rag import Generator
        gen = Generator.tiny(seed=0, max_new_tokens=args.generate)
        loop_kw["generator"] = gen
    if args.engine in ("pipelined", "fleet"):
        loop_kw["depth"] = args.depth
        if gen is not None:
            loop_kw["gen_coalesce"] = args.gen_coalesce
    group = None
    if args.engine == "fleet":
        from repro.fleet import FaultPlan, FleetServeLoop, ReplicaGroup
        faults = None
        if args.shard_loss is not None:
            at, dev, down = (int(x) for x in args.shard_loss.split(":"))
            faults = FaultPlan.single_shard_loss(
                at_tick=at, device=dev, down_ticks=down).compile()
        elif args.chaos is not None:
            faults = FaultPlan.random(
                args.chaos, n_events=6, horizon=max(args.requests // 2, 8),
                n_devices=args.replicas * 4).compile()
        live = LiveIndex.build(corp.texts, corp.embeddings,
                               n_clusters=24, impl="xla", mesh=mesh)
        group = ReplicaGroup.from_live(live, n_replicas=args.replicas,
                                       n_shards=4)
        loop = FleetServeLoop(group, faults=faults, **loop_kw)
    elif args.mutate_every > 0:
        live = LiveIndex.build(corp.texts, corp.embeddings,
                               n_clusters=24, impl="xla", mesh=mesh)
        loop = loop_cls(live, **loop_kw)
    else:
        live = None
        system = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                             n_clusters=24, impl="xla",
                                             mesh=mesh)
        loop = loop_cls(system, **loop_kw)

    if args.multi_probe > 1:
        loop.system.enable_batch(kappa=max(4, args.multi_probe))

    t0 = time.perf_counter()
    for rid in range(args.requests):
        q = corp.embeddings[rng.integers(0, args.docs)]
        loop.submit(rid, q, top_k=args.top_k, multi_probe=args.multi_probe)
        if live is not None and args.mutate_every and rid % args.mutate_every == 0:
            d = int(rng.integers(0, args.docs))
            loop.submit_mutation(journal_lib.replace(
                d, f"refreshed doc {d}".encode(), corp.embeddings[d]))
        loop.tick()
    loop.drain()
    dt = time.perf_counter() - t0
    if not loop.responses:
        print(f"served 0 requests in {dt:.2f}s")
        return
    lat = [r.t_done - t0 for r in loop.responses]
    sizes = [r.batch_size for r in loop.responses]
    print(f"[{args.engine}] served {len(loop.responses)} requests "
          f"in {dt:.2f}s; mean batch {np.mean(sizes):.1f}; "
          f"p50/p99 completion {np.percentile(lat, 50):.2f}/"
          f"{np.percentile(lat, 99):.2f}s"
          + (f"; epoch {loop.epoch}; stale retries {loop.stale_retries}"
             if live is not None else ""))
    if gen is not None:
        rags = [r.rag for r in loop.responses if r.rag is not None]
        n_tok = sum(len(r.tokens) for r in loop.responses
                    if r.tokens is not None)
        print(f"generation: {n_tok} tokens across "
              f"{len(loop.responses)} responses; "
              f"{sum(g.prompt_tokens for g in rags)} prompt tokens; "
              f"mean gen stage "
              f"{1e3 * float(np.mean([g.generate_s for g in rags])):.1f}ms")
    if group is not None:
        stale = sum(r.staleness > 0 for r in loop.responses)
        print(f"fleet: authority rank {group.authority_rank}; "
              f"{group.failovers} failover(s), {group.failbacks} "
              f"failback(s), {loop.failed_requests} failed, "
              f"{stale} served stale, "
              f"{len(group.replay_reports)} journal replay(s)")
    if args.trace is not None:
        from repro.obs import span_coverage
        obs.export_chrome(args.trace)
        cov = span_coverage(obs.tracer.spans)
        print(f"trace: {len(obs.tracer.spans)} spans + "
              f"{len(obs.tracer.instants)} instants -> {args.trace} "
              f"(root-span coverage {cov:.1%})")
    if args.metrics:
        import json
        print(json.dumps(obs.metrics_dict(), indent=1))


if __name__ == "__main__":
    main()
