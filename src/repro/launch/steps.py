"""Uniform step builders per family: one StepBundle per (arch × shape).

A StepBundle carries everything the dry-run / smoke tests / drivers need:
the step callable, state + input ShapeDtypeStruct trees, logical-axis trees
(→ PartitionSpecs via distributed/sharding), and a real initializer for
reduced configs.  Full-size state is ONLY ever expressed as specs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.distributed import sharding
from repro.models import recsys as rec
from repro.models import schnet as sch
from repro.models import transformer as tf
from repro.optim import optimizers as opt_lib


@dataclasses.dataclass
class StepBundle:
    name: str
    kind: str
    fn: Callable                  # (state, batch) → (new_state, out) | out
    state_spec: Any               # pytree of ShapeDtypeStruct
    state_axes: Any               # logical-axes tree (tuples)
    batch_axes: dict[str, tuple]
    rules: dict[str, Any]         # mesh-axis rule table (single-pod default)
    init_state: Callable[[jax.Array], Any] | None = None
    donate_state: bool = True

    def rules_for(self, multi_pod: bool) -> dict[str, Any]:
        return self._rules_builder(multi_pod)

    _rules_builder: Callable[[bool], dict] = None  # set by make_bundle


# ---------------------------------------------------------------------------
# Optimizer state axes
# ---------------------------------------------------------------------------

def _adamw_axes(param_axes):
    return {"mu": param_axes, "nu": param_axes, "step": ()}


def _adafactor_axes(param_axes, param_spec, min_dim=128):
    def one(ax, spec):
        if spec.ndim >= 2 and spec.shape[-1] >= min_dim and \
                spec.shape[-2] >= min_dim:
            return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        return {"v": ax}
    return {"v": jax.tree.map(one, param_axes, param_spec,
                              is_leaf=lambda v: isinstance(v, tuple)),
            "step": ()}


def pick_optimizer(cfg) -> opt_lib.Optimizer:
    """Adafactor for ≥100B configs (state must fit), AdamW otherwise."""
    if getattr(cfg, "moe", None) is not None and cfg.d_model >= 5000:
        return opt_lib.adafactor(1e-2)
    return opt_lib.adamw(3e-4, weight_decay=0.1)


def _opt_axes(optimizer, param_axes, param_spec):
    if optimizer.name == "adafactor":
        return _adafactor_axes(param_axes, param_spec)
    if optimizer.name == "adamw":
        return _adamw_axes(param_axes)
    return {"step": ()}


# ---------------------------------------------------------------------------
# LM bundles
# ---------------------------------------------------------------------------

def _lm_train(arch, shape, cfg) -> StepBundle:
    optimizer = pick_optimizer(cfg)
    p_axes = tf.param_axes(cfg)
    p_spec = tf.param_spec(cfg)

    n_mb = cfg.n_microbatch

    def grad_fn(p, mb):
        return jax.value_and_grad(
            lambda p: tf.lm_loss(p, mb, cfg), has_aux=True)(p)

    def step(state, batch):
        if n_mb == 1:
            (loss, metrics), grads = grad_fn(state["params"], batch)
        else:
            # gradient accumulation: activations live for ONE microbatch;
            # grads accumulate in param dtype, sharded like params
            B = batch["tokens"].shape[0]
            mbs = jax.tree.map(
                lambda x: x.reshape(n_mb, B // n_mb, *x.shape[1:]), batch)

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state["params"], mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                              state["params"])
            (grads, loss), _ = jax.lax.scan(
                mb_step, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            loss = loss / n_mb
            metrics = {}
        new_p, new_opt = optimizer.update(grads, state["opt"],
                                          state["params"])
        return ({"params": new_p, "opt": new_opt},
                {"loss": loss, **metrics})

    def init_state(key):
        params = tf.init(key, cfg)
        return {"params": params, "opt": optimizer.init(params)}

    opt_spec = jax.eval_shape(optimizer.init, p_spec)
    # dense ≤8B models: pure ZeRO-3 over all 256/512 chips (TP activation
    # wire would dominate 20×); MoE giants keep TP+SP+EP
    fsdp_only = cfg.moe is None
    rules_builder = functools.partial(sharding.lm_train_rules,
                                      fsdp_only=fsdp_only)
    b = StepBundle(
        name=f"{arch.name}:{shape.name}", kind="train", fn=step,
        state_spec={"params": p_spec, "opt": opt_spec},
        state_axes={"params": p_axes,
                    "opt": _opt_axes(optimizer, p_axes, p_spec)},
        batch_axes={"tokens": ("batch", None), "labels": ("batch", None)},
        rules=rules_builder(False), init_state=init_state)
    b._rules_builder = rules_builder
    return b


def _lm_prefill(arch, shape, cfg) -> StepBundle:
    B, S = shape.meta["global_batch"], shape.meta["seq_len"]
    p_axes = tf.param_axes(cfg)

    def step(params, batch):
        cache = tf.init_cache(cfg, B, S)
        logits, cache = tf.prefill(params, batch["tokens"], cache, cfg)
        return logits, cache

    b = StepBundle(
        name=f"{arch.name}:{shape.name}", kind="prefill", fn=step,
        state_spec=tf.param_spec(cfg), state_axes=p_axes,
        batch_axes={"tokens": ("batch", None)},
        rules=sharding.lm_decode_rules(False),
        init_state=lambda key: tf.init(key, cfg), donate_state=False)
    b._rules_builder = lambda mp: sharding.lm_decode_rules(mp)
    return b


def _lm_decode(arch, shape, cfg) -> StepBundle:
    B, S = shape.meta["global_batch"], shape.meta["seq_len"]
    p_axes = tf.param_axes(cfg)
    long_ctx = shape.name == "long_500k"

    def step(state, batch):
        logits, new_cache = tf.decode_step(
            state["params"], state["cache"], batch["tokens"],
            batch["lengths"], cfg)
        return {"params": state["params"], "cache": new_cache}, logits

    def rules_builder(mp: bool):
        r = sharding.lm_decode_rules(mp)
        if long_ctx:
            # B=1: split-S over every axis, replicate batch
            r["cache_seq"] = (("pod", "data", "model") if mp
                              else ("data", "model"))
            r["batch"] = None
            r["kv_heads"] = None
        else:
            r["cache_seq"] = "model"
            r["kv_heads"] = None
        return r

    def init_state(key):
        return {"params": tf.init(key, cfg),
                "cache": tf.init_cache(cfg, B, S)}

    b = StepBundle(
        name=f"{arch.name}:{shape.name}", kind="decode", fn=step,
        state_spec={"params": tf.param_spec(cfg),
                    "cache": tf.cache_spec(cfg, B, S)},
        state_axes={"params": p_axes, "cache": tf.cache_axes(cfg)},
        batch_axes={"tokens": ("batch",), "lengths": ("batch",)},
        rules=rules_builder(False), init_state=init_state)
    b._rules_builder = rules_builder
    return b


# ---------------------------------------------------------------------------
# GNN bundles
# ---------------------------------------------------------------------------

def _gnn_train(arch, shape, cfg) -> StepBundle:
    optimizer = opt_lib.adamw(1e-3)
    p_axes = sch.param_axes(cfg)
    p_spec = jax.eval_shape(lambda k: sch.init(k, cfg), jax.random.PRNGKey(0))
    loss_fn = (sch.molecule_loss if cfg.mode == "molecule"
               else sch.graph_loss)

    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg))(state["params"])
        new_p, new_opt = optimizer.update(grads, state["opt"],
                                          state["params"])
        return {"params": new_p, "opt": new_opt}, {"loss": loss}

    def init_state(key):
        params = sch.init(key, cfg)
        return {"params": params, "opt": optimizer.init(params)}

    if cfg.mode == "molecule":
        batch_axes = {"z": ("batch", None), "pos": ("batch", None, None),
                      "energy": ("batch",)}
    else:
        batch_axes = {"node_feat": ("nodes", None), "src": ("edges",),
                      "dst": ("edges",), "edge_dist": ("edges",),
                      "labels": ("nodes",), "label_mask": ("nodes",)}
    opt_spec = jax.eval_shape(optimizer.init, p_spec)
    b = StepBundle(
        name=f"{arch.name}:{shape.name}", kind="train", fn=step,
        state_spec={"params": p_spec, "opt": opt_spec},
        state_axes={"params": p_axes,
                    "opt": _opt_axes(optimizer, p_axes, p_spec)},
        batch_axes=batch_axes, rules=sharding.gnn_rules(False),
        init_state=init_state)
    b._rules_builder = sharding.gnn_rules
    return b


# ---------------------------------------------------------------------------
# RecSys bundles
# ---------------------------------------------------------------------------

def _recsys_bundle(arch, shape, cfg) -> StepBundle:
    p_axes = rec.param_axes(cfg)
    p_spec = jax.eval_shape(lambda k: rec.init(k, cfg), jax.random.PRNGKey(0))

    if shape.kind == "train":
        optimizer = opt_lib.adamw(1e-3)

        def step(state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: rec.loss(p, batch, cfg))(state["params"])
            new_p, new_opt = optimizer.update(grads, state["opt"],
                                              state["params"])
            return {"params": new_p, "opt": new_opt}, {"loss": loss}

        def init_state(key):
            params = rec.init(key, cfg)
            return {"params": params, "opt": optimizer.init(params)}

        state_spec = {"params": p_spec,
                      "opt": jax.eval_shape(optimizer.init, p_spec)}
        state_axes = {"params": p_axes,
                      "opt": _opt_axes(optimizer, p_axes, p_spec)}
        donate = True
    else:
        if shape.kind == "retrieval":
            def step(params, batch):
                user = {k: v for k, v in batch.items() if k != "candidates"}
                return rec.retrieval_score(params, user,
                                           batch["candidates"], cfg)
        else:
            def step(params, batch):
                return rec.serve(params, batch, cfg)
        state_spec, state_axes = p_spec, p_axes
        init_state = lambda key: rec.init(key, cfg)  # noqa: E731
        donate = False

    specs = cfgbase.recsys_input_specs(cfg, shape)
    batch_axes = {}
    for k, v in specs.items():
        if k == "candidates":
            batch_axes[k] = ("candidates",)
        elif v.ndim >= 1 and v.shape[0] == shape.meta.get("batch", -1) \
                and shape.kind != "retrieval":
            batch_axes[k] = ("batch",) + (None,) * (v.ndim - 1)
        else:
            batch_axes[k] = (None,) * v.ndim
    b = StepBundle(
        name=f"{arch.name}:{shape.name}", kind=shape.kind, fn=step,
        state_spec=state_spec, state_axes=state_axes, batch_axes=batch_axes,
        rules=sharding.recsys_rules(False), init_state=init_state,
        donate_state=donate)
    b._rules_builder = sharding.recsys_rules
    return b


# ---------------------------------------------------------------------------
# PIR bundles (the paper's serving step)
# ---------------------------------------------------------------------------

def _pir_bundle(arch, shape, cfg) -> StepBundle:
    from repro.core import lwe
    from repro.kernels import ref

    if shape.kind == "serve":
        def step(db, batch):
            ans = ref.modmatmul_ref(db, batch["queries"])
            if cfg.q_switch is not None:
                ans = lwe.switch_modulus(ans, cfg.q_switch)
            return ans
        batch_axes = {"queries": ("clusters", "qbatch")}
    else:
        def step(db, batch):
            return ref.modmatmul_ref(db, batch["a_mat"])
        batch_axes = {"a_mat": ("clusters", "lwe_k")}

    def rules_builder(mp: bool):
        r = sharding.pir_rules(mp)
        if shape.kind == "setup":
            # hint GEMM has no query-batch dim: DB rows span EVERY axis or
            # the data shards replicate the whole m×n×k GEMM 16×
            r["chunks"] = (("pod", "data", "model") if mp
                           else ("data", "model"))
        return r

    b = StepBundle(
        name=f"{arch.name}:{shape.name}", kind=shape.kind, fn=step,
        state_spec=cfgbase.sds((cfg.m, cfg.n), jnp.uint8),
        state_axes=("chunks", "clusters"),
        batch_axes=batch_axes, rules=rules_builder(False),
        init_state=None, donate_state=False)
    b._rules_builder = rules_builder
    return b


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def make_bundle(arch: cfgbase.ArchSpec, shape_name: str,
                *, smoke: bool = False) -> StepBundle:
    shape = arch.shapes[shape_name]
    if smoke:
        shape = cfgbase.smoke_shape(shape)
    cfg = (arch.smoke if smoke else arch.model)(shape_name)
    if arch.family == "lm":
        if shape.kind == "train":
            return _lm_train(arch, shape, cfg)
        if shape.kind == "prefill":
            return _lm_prefill(arch, shape, cfg)
        return _lm_decode(arch, shape, cfg)
    if arch.family == "gnn":
        return _gnn_train(arch, shape, cfg)
    if arch.family == "recsys":
        return _recsys_bundle(arch, shape, cfg)
    if arch.family == "pir":
        return _pir_bundle(arch, shape, cfg)
    raise ValueError(arch.family)


def input_specs_for(arch: cfgbase.ArchSpec, shape_name: str,
                    *, smoke: bool = False) -> dict:
    shape = arch.shapes[shape_name]
    if smoke:
        shape = cfgbase.smoke_shape(shape)
    cfg = (arch.smoke if smoke else arch.model)(shape_name)
    if arch.family == "lm":
        return cfgbase.lm_input_specs(cfg, shape)
    if arch.family == "gnn":
        return cfgbase.gnn_input_specs(cfg, shape)
    if arch.family == "recsys":
        return cfgbase.recsys_input_specs(cfg, shape)
    from repro.configs.pir_serve import pir_input_specs
    return pir_input_specs(cfg, shape)


def materialize_inputs(arch: cfgbase.ArchSpec, shape_name: str, key,
                       *, smoke: bool = True) -> dict:
    """Random concrete inputs matching the specs (bounded ids per family)."""
    shape = arch.shapes[shape_name]
    if smoke:
        shape = cfgbase.smoke_shape(shape)
    cfg = (arch.smoke if smoke else arch.model)(shape_name)
    specs = input_specs_for(arch, shape_name, smoke=smoke)

    def bound(name: str) -> int:
        if arch.family == "lm":
            return cfg.vocab
        if arch.family == "recsys":
            return cfg.vocab_per_field
        if arch.family == "gnn":
            if name in ("src", "dst"):
                return shape.meta["n_nodes"]
            if name == "labels":
                return shape.meta.get("n_classes", cfg.n_out)
            if name == "z":
                return cfg.n_species
        return 1 << 30

    out = {}
    for name, spec in specs.items():
        key, k = jax.random.split(key)
        if spec.dtype == jnp.int32:
            if name == "lengths":
                hi = shape.meta["seq_len"]
                out[name] = jax.random.randint(k, spec.shape, hi // 2, hi - 1,
                                               dtype=jnp.int32)
            elif name == "z":
                out[name] = jax.random.randint(k, spec.shape, 1, bound(name),
                                               dtype=jnp.int32)
            else:
                out[name] = jax.random.randint(k, spec.shape, 0, bound(name),
                                               dtype=jnp.int32)
        elif spec.dtype == jnp.bool_:
            out[name] = jax.random.bernoulli(k, 0.8, spec.shape)
        elif spec.dtype == jnp.uint8:
            out[name] = jax.random.randint(k, spec.shape, 0, 256,
                                           dtype=jnp.int32).astype(jnp.uint8)
        elif spec.dtype == jnp.uint32:
            out[name] = jax.random.bits(k, spec.shape, dtype=jnp.uint32)
        elif name == "edge_dist":
            out[name] = jax.random.uniform(k, spec.shape, jnp.float32, 0.1,
                                           cfg.cutoff * 0.95)
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out
