"""Structural analysis of compiled (post-SPMD) HLO text.

``jax`` exposes `compiled.cost_analysis()`, but XLA's HloCostAnalysis counts
while-loop bodies ONCE — a scan over 61 transformer blocks under-reports
FLOPs by 61× (verified empirically; see tests/test_hlo_analysis.py).  This
module parses the HLO text into computations, builds the call graph
(while bodies/conditions, fusions, to_apply), extracts static trip counts
from loop-condition constants, and accumulates with multiplicity:

  * dot FLOPs (per dtype)        — 2·prod(result)·prod(contracting dims)
  * dot operand/result bytes     — an HBM-traffic floor (weights must stream)
  * collective wire bytes        — ring formulas per op type:
        all-reduce          2·S·(g−1)/g
        all-gather          S_out·(g−1)/g
        reduce-scatter      S_in·(g−1)/g
        all-to-all          S·(g−1)/g
        collective-permute  S
    with g = replica-group size, S = per-device bytes (post-SPMD shapes are
    local, so these are per-device wire volumes).

Elementwise FLOPs are ignored (≤1% for these architectures — documented).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    """All `dtype[d0,d1,...]` shapes in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dtype, shape))
    return out


def _nbytes(shapes) -> int:
    return sum(_DTYPE_BYTES[d] * int(math.prod(s) if s else 1)
               for d, s in shapes)


@dataclasses.dataclass
class Computation:
    name: str
    shapes: dict         # instr name -> list[(dtype, shape)]
    dots: list           # (result_shapes, lhs_name, contracting_sizes, dtype)
    collectives: list    # (kind, result_bytes, operand_bytes, group_size)
    whiles: list         # (body_name, cond_name)
    calls: list          # other referenced computations (×1)
    constants: list      # integer constants seen (trip-count extraction)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), {}, [], [], [], [], [])
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # result type = text before the opcode
        shapes = _parse_shapes(rest.split("(")[0]) if "(" in rest else \
            _parse_shapes(rest)
        cur.shapes[name] = shapes

        for const_m in re.finditer(r"constant\((\d+)\)", rest):
            cur.constants.append(int(const_m.group(1)))

        opcode_m = re.search(r"\s([a-z][a-z0-9\-_]*)\(", " " + rest)
        opcode = opcode_m.group(1) if opcode_m else ""
        if opcode.startswith("dot_general") or opcode == "dot_general":
            opcode = "dot"

        if opcode == "dot":
            args_m = re.search(r"dot\(([^)]*)\)", rest)
            args = args_m.group(1) if args_m else ""
            # newer HLO prints operand types inline with layout annotations
            # ("u32[8192,4096]{1,0} %call") whose commas defeat naive
            # splitting — pull names by sigil and shapes by pattern instead
            operands = (re.findall(r"%([\w.\-]+)", args)
                        or [a.strip() for a in args.split(",") if a.strip()])
            inline = _parse_shapes(args)
            lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
            cdims = [int(x) for x in lhs_c.group(1).split(",")] if (
                lhs_c and lhs_c.group(1)) else []
            cur.dots.append((shapes, operands, cdims, inline))
        elif opcode in _COLLECTIVES or any(
                rest.startswith(c) or f" {c}(" in rest
                for c in _COLLECTIVES):
            kind = next((c for c in _COLLECTIVES if f"{c}(" in rest), None)
            if kind:
                g = _group_size(rest)
                args_m = re.search(re.escape(kind) + r"\(([^)]*)\)", rest)
                args = args_m.group(1) if args_m else ""
                inline = _parse_shapes(args)
                if inline:
                    op_bytes = _nbytes(inline)
                else:
                    operands = re.findall(r"%([\w.\-]+)", args) or [
                        a.strip() for a in args.split(",") if a.strip()]
                    op_bytes = sum(_nbytes(cur.shapes.get(o, []))
                                   for o in operands)
                cur.collectives.append((kind, _nbytes(shapes), op_bytes, g))
        if "while(" in rest:
            b = re.search(r"body=%?([\w.\-]+)", rest)
            c = re.search(r"condition=%?([\w.\-]+)", rest)
            if b and c:
                cur.whiles.append((b.group(1), c.group(1)))
        for ref in re.finditer(
                r"(?:calls|to_apply|true_computation|false_computation)"
                r"=%?([\w.\-]+)", rest):
            cur.calls.append(ref.group(1))
        bm = re.search(r"branch_computations=\{([^}]*)\}", rest)
        if bm:
            cur.calls.extend(x.strip().lstrip("%")
                             for x in bm.group(1).split(","))
    return comps


def _group_size(rest: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]*)\}", rest)
    if m and m.group(1):
        return len(m.group(1).split(","))
    return 1


def _dot_flops_bytes(comp: Computation) -> tuple[dict, int]:
    flops = defaultdict(float)
    traffic = 0
    for shapes, operands, cdims, inline in comp.dots:
        if not shapes:
            continue
        dtype, rshape = shapes[0]
        out_elems = math.prod(rshape) if rshape else 1
        k = 1
        # contraction extent from the lhs shape — prefer the inline operand
        # type (always local/post-SPMD); fall back to name lookup for HLO
        # styles that print bare `%name` operands
        lhs = inline[:1] or (comp.shapes.get(operands[0], [])
                             if operands else [])
        if lhs and cdims:
            _, lshape = lhs[0]
            for cd in cdims:
                if cd < len(lshape):
                    k *= lshape[cd]
        flops[dtype] += 2.0 * out_elems * k
        # HBM traffic floor: both operands + result stream at least once
        traffic += _nbytes(shapes)
        if inline:
            traffic += _nbytes(inline[:2])
        else:
            for o in operands[:2]:
                traffic += _nbytes(comp.shapes.get(o, []))
    return flops, traffic


def _trip_count(comps: dict, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None or not cond.constants:
        return 1
    return max(1, max(cond.constants))


@dataclasses.dataclass
class HLOStats:
    dot_flops: dict[str, float]        # per dtype, per device, trip-scaled
    dot_traffic_bytes: float           # HBM floor per device
    collective_bytes: dict[str, float]  # wire bytes per device by op kind
    collective_counts: dict[str, int]
    n_whiles: int
    max_trip: int

    @property
    def total_flops(self) -> float:
        return sum(self.dot_flops.values())

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HLOStats:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    flops: dict[str, float] = defaultdict(float)
    traffic = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, int] = defaultdict(int)
    n_whiles = 0
    max_trip = 1
    seen_stack: list[str] = []

    def visit(comp: Computation, mult: float):
        nonlocal traffic, n_whiles, max_trip
        if comp.name in seen_stack:       # recursion guard
            return
        seen_stack.append(comp.name)
        f, t = _dot_flops_bytes(comp)
        for k, v in f.items():
            flops[k] += v * mult
        traffic += t * mult
        for kind, out_b, in_b, g in comp.collectives:
            if g <= 1:
                continue
            if kind == "all-reduce":
                wire = 2.0 * out_b * (g - 1) / g
            elif kind == "all-gather":
                wire = out_b * (g - 1) / g
            elif kind == "reduce-scatter":
                wire = max(in_b, out_b * g) * (g - 1) / g
            elif kind == "all-to-all":
                wire = out_b * (g - 1) / g
            else:                          # collective-permute
                wire = out_b
            coll_bytes[kind] += wire * mult
            coll_counts[kind] += int(round(mult))
        for body, cond in comp.whiles:
            trip = _trip_count(comps, cond)
            n_whiles += 1
            max_trip = max(max_trip, trip)
            if body in comps:
                visit(comps[body], mult * trip)
        for callee in comp.calls:
            if callee in comps:
                visit(comps[callee], mult)
        seen_stack.pop()

    visit(entry, 1.0)
    return HLOStats(dot_flops=dict(flops), dot_traffic_bytes=traffic,
                    collective_bytes=dict(coll_bytes),
                    collective_counts=dict(coll_counts),
                    n_whiles=n_whiles, max_trip=max_trip)
