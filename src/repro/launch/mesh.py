"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; the multi-pod mesh adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))
