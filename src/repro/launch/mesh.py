"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; the multi-pod mesh adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_replica_meshes(n_replicas: int, n_shards: int, devices=None):
    """Per-rank row-shard meshes over DISJOINT device sets.

    Rank r of the replica fleet gets devices [r·S, (r+1)·S) as its own
    ("chunks",) mesh — the same axis convention the sharded build uses —
    so one lost device takes out exactly one rank's shard cell and never
    touches the sibling replica (the placement invariant
    `repro.fleet.replica` fails over on).  Requires R·S ≤ len(devices).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    assert n_replicas * n_shards <= len(devices), (
        n_replicas, n_shards, len(devices))
    return [jax.make_mesh((n_shards,), ("chunks",),
                          devices=devices[r * n_shards:(r + 1) * n_shards])
            for r in range(n_replicas)]
