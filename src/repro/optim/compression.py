"""int8 error-feedback gradient compression for the data-parallel all-reduce.

1-bit/8-bit SGD lineage (Seide et al.; Bernstein et al. signSGD-EF): each
worker quantizes its local gradient to int8 with a per-tensor scale, keeps
the quantization residual as local state ("error feedback"), and all-reduces
the int8 payload (4× less DP wire traffic than fp32, 2× less than bf16).
The residual is added back before the next quantization, so the *long-run*
gradient estimate is unbiased and convergence matches uncompressed SGD to
first order (tested on a quadratic + an MLP in tests/test_optim.py).

Composes with any repro.optim optimizer: wrap the grads before `update`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization → (q, scale)."""
    g = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residuals):
    """Error-feedback compress: returns (int8 tree, scales, new residuals)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return q, scale, corrected - deq
    out = jax.tree.map(one, grads, residuals)
    q = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda v: isinstance(v, tuple))
    s = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda v: isinstance(v, tuple))
    r = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda v: isinstance(v, tuple))
    return q, s, r


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_mean_grads(grads, residuals, axis: str | None):
    """Per-worker compress → psum(int32) → dequantize mean.

    Inside shard_map/pmap over `axis`: the all-reduce payload is int8-derived
    int32 counts + one fp32 scale per tensor.  With axis=None acts locally
    (single-worker fallback, still exercising the quantizer).
    """
    q, s, new_r = ef_compress_tree(grads, residuals)
    if axis is not None:
        n = jax.lax.psum(1, axis)
        # scales differ per worker → reduce q·scale is wrong; instead psum the
        # int payload per-worker-scaled by broadcasting max scale first.
        s_max = jax.tree.map(lambda x: jax.lax.pmax(x, axis), s)
        # requantize against the shared scale so int32 psum is exact
        def requant(qi, si, smax):
            val = dequantize_int8(qi, si)
            q2 = jnp.clip(jnp.round(val / smax), -127, 127).astype(jnp.int32)
            return q2
        q32 = jax.tree.map(requant, q, s, s_max)
        summed = jax.tree.map(lambda x: jax.lax.psum(x, axis), q32)
        mean = jax.tree.map(
            lambda x, smax: x.astype(jnp.float32) * smax / n, summed, s_max)
    else:
        mean = jax.tree.map(dequantize_int8, q, s)
    return mean, new_r
