"""Optimizers (no optax): AdamW, Adafactor, clipping, schedules.

Adafactor (Shazeer & Stern '18) is the default for the ≥400B MoE configs: its
factored second moment turns optimizer state from 2× params into ~(rows+cols),
which is what lets the 1T-param Kimi-K2 training state fit a 512×16 GB fleet
(see EXPERIMENTS.md §Dry-run memory table).

API: ``opt.init(params) → state``; ``opt.update(grads, state, params) →
(new_params, new_state)``.  All states are pytrees of arrays → they
checkpoint/reshard through repro.checkpoint like any other state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    name: str = "opt"


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float | Callable = 1e-3, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            new_p = p.astype(jnp.float32) - lr_t * (
                upd + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda v: isinstance(v, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda v: isinstance(v, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda v: isinstance(v, tuple))
        return new_p, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update, name="adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; no first moment)
# ---------------------------------------------------------------------------

def adafactor(lr: float | Callable = 1e-2, *, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              min_dim_factored: int = 128) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and \
            p.shape[-2] >= min_dim_factored

    def init(params):
        def one(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params), "step": jnp.zeros((),
                                                                  jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)
        lr_t = lr_fn(step)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                  eps)[..., None])
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                denom = jnp.sqrt(vv)
                new_v = {"v": vv}
            u = g / jnp.maximum(denom, eps)
            # update clipping (RMS ≤ clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return new_p, new_v

        out = jax.tree.map(upd, params, grads, state["v"],
                           is_leaf=lambda v: isinstance(v, dict)
                           and ("v" in v or "vr" in v))
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda v: isinstance(v, tuple))
        new_v = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda v: isinstance(v, tuple))
        return new_p, {"v": new_v, "step": step}

    return Optimizer(init=init, update=update, name="adafactor")


def sgd(lr: float = 0.1) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, {"step": state["step"] + 1}

    return Optimizer(init=init, update=update, name="sgd")
