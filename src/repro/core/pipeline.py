"""PIR-RAG end-to-end system (paper §3): offline setup + online private query.

Offline (server): embed → K-means → chunk-transposed DB → PIR hint.
Online (client): embed query → pick cluster from PUBLIC centroids →
LWE-encrypted one-hot → server modular GEMV → decrypt whole cluster →
local exact re-rank → top-K documents, content in hand ("RAG-Ready").

The server never sees the query embedding, the chosen cluster, or the ranked
results; its entire view is one pseudorandom uint32 vector per query.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunking, clustering, pir, rerank


@dataclasses.dataclass
class InflightBatch:
    """A dispatched serving batch: answer GEMM(s) in flight, decode deferred.

    Produced by `PirRagSystem.query_batch_async` — the plan stage has
    encoded every client query and the dispatch stage has enqueued the
    server GEMM(s); JAX async dispatch means the device crunches while the
    Python caller goes on to cut/encode the next batch.  `complete()` does
    the decode + re-rank (the first operation that forces the device
    values) and returns exactly what `query_batch` would have.

    Everything decode needs — client hint, per-bucket hints/configs, LWE
    states — is captured at PLAN time, so the batch stays decodable (and
    bit-identical to the synchronous path) even after a later epoch commit
    swaps the live system's buffers: epoch snapshots, not live pointers.
    """
    _complete: Callable[[], list]
    pending: tuple = ()            # device arrays the GEMM stage produced
    done: bool = False

    def complete(self) -> list:
        """Decode + re-rank; same return value as `query_batch`."""
        assert not self.done, "InflightBatch.complete() called twice"
        out = self._complete()
        self.done = True
        return out


def _fresh_client_key() -> jax.Array:
    """Root of a client-side key stream: one OS-entropy draw, then splits."""
    return jax.random.PRNGKey(int.from_bytes(os.urandom(7), "little"))


# Independent fold_in streams off the ONE public build seed.  Cluster seeding
# and LWE setup (the public matrix A's seed) must never share a stream:
# with a shared key, changing k-means knobs would silently re-derive A — and
# with it every hint, query and cached client state.  (Regression-pinned in
# tests/test_pipeline.py.)
_STREAM_KMEANS = 0
_STREAM_LWE = 1


def _derive_build_streams(seed: int) -> tuple[jax.Array, int]:
    """(kmeans key, a_seed) — two independent streams from one build seed."""
    root = jax.random.PRNGKey(seed)
    k_km = jax.random.fold_in(root, _STREAM_KMEANS)
    a_seed = int(jax.random.randint(jax.random.fold_in(root, _STREAM_LWE),
                                    (), 0, jnp.iinfo(jnp.int32).max))
    return k_km, a_seed


@dataclasses.dataclass
class QueryStats:
    uplink_bytes: int
    downlink_bytes: int
    client_ms: float
    server_ms: float
    cluster_index: int            # known to client only
    mode: str = "legacy"          # "legacy" (P one-hots) | "batch" (cuckoo)
    probes: int = 1               # clusters privately fetched
    n_buckets: int = 0            # batch mode: bucket queries sent (incl dummies)
    hint_bytes: int = 0           # one-time hint downlink of the path used


@dataclasses.dataclass
class LookupStats:
    """Accounting for one keyed embedding lookup (`PirRagSystem.lookup`)."""
    uplink_bytes: int
    downlink_bytes: int
    client_ms: float
    server_ms: float
    kappa: int                    # rows requested (multiset size)
    groups: int                   # distinct id groups privately fetched
    mode: str = "batch"           # "batch" (cuckoo) | "legacy" (G one-hots)
    n_buckets: int = 0            # batch mode: bucket queries sent (incl dummies)
    hint_bytes: int = 0           # one-time hint downlink of the path used


@dataclasses.dataclass
class PirRagSystem:
    """Bundles server-public state (centroids) and the two protocol roles."""
    centroids: np.ndarray         # PUBLIC: (n_clusters, d)
    db: chunking.ChunkedDB
    cfg: pir.PIRConfig
    server: pir.PIRServer
    hint: jax.Array               # client-side after one-time download
    setup_seconds: float          # total offline time
    index_seconds: float = 0.0    # clustering + packing (no crypto)
    hint_seconds: float = 0.0     # hint GEMM (int8-roofline op on TPU)
    assignment: np.ndarray | None = None  # (N,) doc→cluster (live index)
    batch: object | None = None           # batchpir.BatchPIR once enabled
    keyed: object | None = None           # batchpir.KeyedLayout (keyed system)
    mesh: object | None = None            # device mesh (sharded serving)
    mesh_axes: tuple | None = None        # mesh axes the DB rows shard over
    _qkey: jax.Array | None = None        # split stream for keyless queries

    # -- offline ------------------------------------------------------------

    @classmethod
    def build(cls, texts: Sequence[bytes], embeddings: np.ndarray, *,
              n_clusters: int, kmeans_iters: int = 25, chunk_size: int = 256,
              balance_factor: float | None = None, seed: int = 0,
              impl: str = "auto", q_switch: int | None = 1 << 16,
              doc_ids: Sequence[int] | None = None,
              mesh=None, mesh_axes: tuple | None = None,
              build_blocks: int | None = None,
              ) -> "PirRagSystem":
        """Offline setup: embed → K-means → chunk-transposed DB → PIR hint.

        texts: N byte strings; embeddings: (N, d) f32.  ``seed`` feeds two
        independent `fold_in` streams — cluster seeding and the public LWE
        matrix seed (`cfg.a_seed`) — so clustering knobs can never perturb
        key material.

        ``mesh=`` shards the ENTIRE build over the device mesh the server
        uses: K-means fits with the corpus row-sharded
        (`clustering.kmeans_fit_sharded`, one all-gather per Lloyd
        iteration), the balanced-assign distance sweep runs per shard, and
        column packing emits per-shard row slices that are placed directly
        on their owning devices — the row-sharded DB is constructed in
        place, never materialized on (or resharded through) one device.
        Everything downstream — centroids, assignment, packed columns,
        hint, answers, top-k — is bit-identical to the mesh=None build
        (property-tested under the 8-fake-device harness) whenever the
        shard count divides ``build_blocks`` (default
        ``lcm(clustering.BUILD_BLOCKS, shards)``, i.e. any power-of-two
        mesh up to 8 matches the unsharded build exactly).
        """
        t0 = time.perf_counter()
        k_km, a_seed = _derive_build_streams(seed)
        axes, shards = (clustering.resolve_mesh_axes(mesh, mesh_axes)
                        if mesh is not None else (None, 1))
        blocks = (build_blocks if build_blocks is not None
                  else math.lcm(clustering.BUILD_BLOCKS, shards))
        embf = np.asarray(embeddings, np.float32)
        if mesh is None:
            km = clustering.kmeans_fit(k_km, jnp.asarray(embf),
                                       k=n_clusters, iters=kmeans_iters,
                                       n_blocks=blocks, impl=impl)
        else:
            km = clustering.kmeans_fit_sharded(
                k_km, embf, k=n_clusters, iters=kmeans_iters, mesh=mesh,
                mesh_axes=axes, n_blocks=blocks, impl=impl)
        cents = np.asarray(km.centroids)
        if balance_factor is not None:
            cap = int(np.ceil(len(texts) / n_clusters * balance_factor))
            d2 = clustering.blocked_sqdist(embf, cents, n_blocks=blocks,
                                           mesh=mesh, mesh_axes=axes)
            assign = clustering.balanced_assign(embf, cents, cap,
                                                d2=np.asarray(d2))
        else:
            assign = np.asarray(km.assignment)
        db = chunking.build_chunked_db(texts, embf, assign, n_clusters,
                                       chunk_size, doc_ids=doc_ids,
                                       n_row_shards=shards)
        cfg = pir.make_config(db.m, db.n, impl=impl, q_switch=q_switch,
                              a_seed=a_seed)
        server = pir.PIRServer(
            cfg, db.row_shards if db.row_shards is not None
            else jnp.asarray(db.matrix), mesh=mesh, mesh_axes=axes)
        t_index = time.perf_counter()
        hint = jax.block_until_ready(server.setup())
        if mesh is not None:
            # the client's one-time hint download: gathered off the mesh so
            # all client-side decode math stays host-local
            hint = jnp.asarray(np.asarray(hint))
        t_end = time.perf_counter()
        return cls(centroids=cents, db=db, cfg=cfg, server=server, hint=hint,
                   setup_seconds=t_end - t0, index_seconds=t_index - t0,
                   hint_seconds=t_end - t_index, assignment=assign,
                   mesh=mesh, mesh_axes=server.mesh_axes,
                   _qkey=_fresh_client_key())

    @classmethod
    def build_keyed(cls, table: np.ndarray, *, group_size: int | None = None,
                    kappa: int = 8, n_buckets: int | None = None,
                    chunk_size: int = 256, seed: int = 0,
                    batch_seed: int = 101, impl: str = "auto",
                    q_switch: int | None = 1 << 16,
                    mesh=None, mesh_axes: tuple | None = None,
                    ) -> "PirRagSystem":
        """Offline setup for KEYED serving: a private embedding-table index.

        table: (V, d) f32 embedding rows.  A recsys lookup is keyed — the
        client knows row IDS, not contents — so there is no k-means: row i
        lands in group ``i // group_size`` (`batchpir.KeyedLayout`,
        default group_size ≈ √V), each group packs into one chunk-transposed
        column through the standard codec with the row's raw f32 bytes as
        the record payload, and the batch-PIR subsystem is enabled
        immediately (keyed serving IS batched serving — a DLRM request
        carries κ sparse ids).  `lookup` then recovers rows bit-identical
        to ``table[ids]``.

        ``centroids`` are the per-group row means: the keyed path never
        consults them, but they keep the legacy embedding-similarity
        `query` well-formed on a keyed system.  ``seed`` feeds the same
        two-stream discipline as `build` (the k-means stream is simply
        unused); ``mesh=`` row-shards the flat DB and spreads buckets
        across devices exactly as in the document build.
        """
        t0 = time.perf_counter()
        from repro import batchpir
        table = np.ascontiguousarray(table, np.float32)
        layout = batchpir.KeyedLayout.build(table.shape[0], table.shape[1],
                                            group_size)
        _, a_seed = _derive_build_streams(seed)
        axes, shards = (clustering.resolve_mesh_axes(mesh, mesh_axes)
                        if mesh is not None else (None, 1))
        assign = np.arange(layout.n_rows, dtype=np.int64) // layout.group_size
        texts = [layout.row_text(table[i]) for i in range(layout.n_rows)]
        # per-group means; bincount over segments keeps it one pass
        sums = np.zeros((layout.n_groups, layout.dim), np.float64)
        np.add.at(sums, assign, table)
        cnts = np.bincount(assign, minlength=layout.n_groups)[:, None]
        cents = (sums / np.maximum(cnts, 1)).astype(np.float32)
        db = chunking.build_chunked_db(texts, table, assign, layout.n_groups,
                                       chunk_size, n_row_shards=shards)
        cfg = pir.make_config(db.m, db.n, impl=impl, q_switch=q_switch,
                              a_seed=a_seed)
        server = pir.PIRServer(
            cfg, db.row_shards if db.row_shards is not None
            else jnp.asarray(db.matrix), mesh=mesh, mesh_axes=axes)
        t_index = time.perf_counter()
        hint = jax.block_until_ready(server.setup())
        if mesh is not None:
            hint = jnp.asarray(np.asarray(hint))
        t_hint = time.perf_counter()
        sys = cls(centroids=cents, db=db, cfg=cfg, server=server, hint=hint,
                  setup_seconds=t_hint - t0, index_seconds=t_index - t0,
                  hint_seconds=t_hint - t_index, assignment=assign,
                  keyed=layout, mesh=mesh, mesh_axes=server.mesh_axes,
                  _qkey=_fresh_client_key())
        sys.enable_batch(kappa=kappa, n_buckets=n_buckets, seed=batch_seed)
        sys.setup_seconds += sys.batch.setup_seconds
        return sys

    # -- key stream ----------------------------------------------------------

    def next_query_key(self) -> jax.Array:
        """Fresh LWE key material for one query, from ONE split stream.

        The stream root is drawn from OS entropy ONCE (never from the
        public build seed — LWE secrets must be unpredictable to the
        server) and then split per query, the same discipline PIRServeLoop
        uses per batch, so ad-hoc keyless callers can neither collide
        secrets within a process nor share them across processes.
        """
        if self._qkey is None:                     # systems built pre-stream
            self._qkey = _fresh_client_key()
        self._qkey, key = jax.random.split(self._qkey)
        return key

    # -- batch-PIR (multi-probe amortization) --------------------------------

    def enable_batch(self, *, kappa: int = 8, n_buckets: int | None = None,
                     seed: int = 101) -> "object":
        """Bucketize the DB for batch-PIR; multi_probe>1 then routes there.

        A sharded system passes its mesh through: buckets spread across the
        same devices the flat DB row-shards over.
        """
        from repro import batchpir
        self.batch = batchpir.build(
            self.db.matrix, self.db.used_bytes, self.cfg.params,
            kappa=kappa, n_buckets=n_buckets, seed=seed,
            a_seed=self.cfg.a_seed, impl=self.cfg.impl,
            mesh=self.mesh, mesh_axes=self.mesh_axes)
        return self.batch

    # -- keyed lookups (recsys serving) --------------------------------------

    def _require_keyed(self):
        if self.keyed is None or self.batch is None:
            raise ValueError("keyed lookups need a build_keyed() system")
        return self.keyed, self.batch

    def lookup(self, ids, *, key: jax.Array | None = None
               ) -> tuple[np.ndarray, LookupStats]:
        """Privately fetch embedding rows `ids` → ((κ, d) f32, accounting).

        ``ids`` is a multiset (duplicates fine); rows come back in caller
        order, bit-identical to ``table[ids]``.  The server sees B
        pseudorandom bucket ciphertexts — independent of κ, of duplicate
        structure, and of which ids were asked — and streams its bucketed
        DB once regardless of κ.  A structurally unplaceable distinct-group
        set (negligible probability) falls back to the legacy path: one
        flat-PIR one-hot per distinct group, still private, just without
        the one-pass amortization.
        """
        layout, bp = self._require_keyed()
        key = key if key is not None else self.next_query_key()
        from repro.batchpir import PlacementError
        t0 = time.perf_counter()
        try:
            qs, state = bp.client.query_rows(key, layout, ids)
        except PlacementError:
            return self._lookup_legacy(ids, key, t0)
        batch = jax.block_until_ready(qs)
        t1 = time.perf_counter()
        ans = [jax.block_until_ready(a) for a in bp.server.answer_batch(batch)]
        t2 = time.perf_counter()
        rows = bp.client.recover_rows(ans, state)
        t3 = time.perf_counter()
        acc = bp.client.accounting(state.base)
        stats = LookupStats(
            uplink_bytes=acc.uplink_bytes, downlink_bytes=acc.downlink_bytes,
            client_ms=1e3 * ((t1 - t0) + (t3 - t2)),
            server_ms=1e3 * (t2 - t1), kappa=len(state.ids),
            groups=len(state.base.placement), mode="batch",
            n_buckets=acc.n_buckets, hint_bytes=acc.hint_bytes)
        return rows, stats

    def _lookup_legacy(self, ids, key: jax.Array, t0: float
                       ) -> tuple[np.ndarray, LookupStats]:
        """Flat-PIR fallback: one one-hot query per DISTINCT id group."""
        layout = self.keyed
        ids = [int(i) for i in ids]
        groups = layout.groups_of(ids)
        client = pir.PIRClient(self.cfg, self.hint)
        qs, states = [], []
        for j, g in enumerate(groups):
            qu, st = client.query(jax.random.fold_in(key, j), int(g))
            qs.append(qu)
            states.append(st)
        if qs:
            batch = jax.block_until_ready(jnp.stack(qs, axis=1))
            t1 = time.perf_counter()
            ans = jax.block_until_ready(self.server.answer(batch))
        else:
            t1 = time.perf_counter()
            ans = None
        t2 = time.perf_counter()
        cols = {g: np.asarray(client.recover(ans[:, j], states[j]))
                for j, g in enumerate(groups)}
        rows = [layout.decode_row(cols[layout.group_of(i)], i) for i in ids]
        out = (np.stack(rows) if rows
               else np.zeros((0, layout.dim), np.float32))
        t3 = time.perf_counter()
        g = len(groups)
        stats = LookupStats(
            uplink_bytes=g * self.cfg.uplink_bytes,
            downlink_bytes=g * self.cfg.downlink_bytes,
            client_ms=1e3 * ((t1 - t0) + (t3 - t2)),
            server_ms=1e3 * (t2 - t1), kappa=len(ids), groups=g,
            mode="legacy", hint_bytes=self.cfg.hint_bytes)
        return out, stats

    def lookup_batch(self, ids_batch, *, seed: int | None = None,
                     key: jax.Array | None = None) -> list[np.ndarray]:
        """Batched keyed serving: C clients' bucket queries, one bucketed GEMM.

        ids_batch: a sequence of id multisets, one per client.  Returns one
        (κ_i, d) f32 array per client, bit-identical to ``table[ids_i]``.
        """
        return self.lookup_batch_async(ids_batch, seed=seed,
                                       key=key).complete()

    def lookup_batch_async(self, ids_batch, *, seed: int | None = None,
                           key: jax.Array | None = None) -> InflightBatch:
        """Plan + dispatch a keyed serving batch; decode deferred.

        The keyed mirror of `query_batch_async`: per-client placement
        failures fall back to that client's legacy lookup, everyone else
        stacks along the column axis of the shared bucketed GEMM, and the
        per-bucket hints/configs are snapshotted at plan time so
        `complete()` decodes against this batch's epoch even if a live
        commit lands in between.
        """
        layout, bp = self._require_keyed()
        if key is None:
            key = (jax.random.PRNGKey(seed) if seed is not None
                   else self.next_query_key())
        from repro.batchpir import PlacementError

        per_client, fallback = [], {}
        for i, ids in enumerate(ids_batch):
            k_i = jax.random.fold_in(key, i)
            try:
                per_client.append(bp.client.query_rows(k_i, layout, ids))
            except PlacementError:
                t0 = time.perf_counter()
                fallback[i] = self._lookup_legacy(ids, k_i, t0)[0]
                per_client.append(None)

        live = [i for i, pc in enumerate(per_client) if pc is not None]
        answers: list = []
        if live:
            stacked = jnp.stack([per_client[i][0] for i in live], axis=2)
            answers = bp.server.answer_batch(stacked)   # per bucket (m_b, C)
        hints = list(bp.client.hints)
        cfgs = list(bp.client.cfgs)

        def complete():
            out: list[np.ndarray | None] = [None] * len(per_client)
            for c_idx, i in enumerate(live):
                ans_i = [a[:, c_idx] for a in answers]
                out[i] = bp.client.recover_rows(ans_i, per_client[i][1],
                                                hints=hints, cfgs=cfgs)
            for i, rows in fallback.items():
                out[i] = rows
            return out

        return InflightBatch(_complete=complete, pending=tuple(answers))

    # -- online -------------------------------------------------------------

    def query(self, query_emb: np.ndarray, *, top_k: int = 10,
              multi_probe: int = 1, key: jax.Array | None = None,
              mode: str = "auto"
              ) -> tuple[list[tuple[int, float, bytes]], QueryStats]:
        """One fully private retrieval; returns top-k docs + accounting.

        multi_probe=P (beyond-paper): privately fetch the P nearest clusters.
        Recovers the boundary recall that single-cluster pruning loses (the
        paper's quality gap vs Graph-PIR); the server learns nothing either
        way, including the P cluster identities.  Two server shapes:

          legacy — P one-hot queries into ONE GEMM over the full DB: server
                   work and uplink/downlink scale P×.
          batch  — with `enable_batch()`: cuckoo-place the P clusters into
                   buckets and send one (real or dummy) query per bucket;
                   the server streams its bucketed DB once regardless of P.

        mode="auto" routes multi_probe>1 through batch-PIR when enabled,
        falling back to legacy on (negligible-probability) placement
        failure; "legacy"/"batch" force a path.
        """
        key = key if key is not None else self.next_query_key()

        t0 = time.perf_counter()
        d2 = clustering.pairwise_sqdist(
            jnp.asarray(query_emb, jnp.float32)[None, :],
            jnp.asarray(self.centroids))[0]
        order = np.argsort(np.asarray(d2))[:max(1, multi_probe)]

        if mode not in ("auto", "legacy", "batch"):
            raise ValueError(f"unknown query mode {mode!r}")
        use_batch = self.batch is not None and (
            mode == "batch" or (mode == "auto" and len(order) > 1))
        if use_batch:
            from repro.batchpir import PlacementError
            try:
                return self._query_via_batch(query_emb, order, top_k, key, t0)
            except PlacementError:
                if mode == "batch":
                    raise
        elif mode == "batch":
            raise ValueError("enable_batch() before mode='batch' queries")

        client = pir.PIRClient(self.cfg, self.hint)
        qs, states = [], []
        for j, cl in enumerate(order):
            qu, st = client.query(jax.random.fold_in(key, j), int(cl))
            qs.append(qu)
            states.append(st)
        batch = jax.block_until_ready(jnp.stack(qs, axis=1))
        t1 = time.perf_counter()

        ans = jax.block_until_ready(self.server.answer(batch))
        t2 = time.perf_counter()

        docs = []
        for j, st in enumerate(states):
            col = np.asarray(client.recover(ans[:, j], st))
            docs.extend(chunking.deserialize_docs(col, self.db.emb_dim))
        top = rerank.rerank(np.asarray(query_emb, np.float32), docs, top_k)
        t3 = time.perf_counter()

        p = len(order)
        stats = QueryStats(
            uplink_bytes=p * self.cfg.uplink_bytes,
            downlink_bytes=p * self.cfg.downlink_bytes,
            client_ms=1e3 * ((t1 - t0) + (t3 - t2)),
            server_ms=1e3 * (t2 - t1),
            cluster_index=int(order[0]),
            mode="legacy", probes=p, hint_bytes=self.cfg.hint_bytes)
        return top, stats

    def _query_via_batch(self, query_emb: np.ndarray, order: np.ndarray,
                         top_k: int, key: jax.Array, t0: float
                         ) -> tuple[list[tuple[int, float, bytes]], QueryStats]:
        """Batch-PIR leg of `query`: one bucketed pass for all probes."""
        bp = self.batch
        qs, state = bp.client.query(key, [int(c) for c in order])
        batch = jax.block_until_ready(qs)
        t1 = time.perf_counter()

        ans = [jax.block_until_ready(a) for a in bp.server.answer_batch(batch)]
        t2 = time.perf_counter()

        cols = bp.client.recover(ans, state)
        docs = []
        for c in order:
            docs.extend(chunking.deserialize_docs(cols[int(c)],
                                                  self.db.emb_dim))
        top = rerank.rerank(np.asarray(query_emb, np.float32), docs, top_k)
        t3 = time.perf_counter()

        acc = bp.client.accounting(state)
        stats = QueryStats(
            uplink_bytes=acc.uplink_bytes,
            downlink_bytes=acc.downlink_bytes,
            client_ms=1e3 * ((t1 - t0) + (t3 - t2)),
            server_ms=1e3 * (t2 - t1),
            cluster_index=int(order[0]),
            mode="batch", probes=len(order),
            n_buckets=acc.n_buckets, hint_bytes=acc.hint_bytes)
        return top, stats

    def query_batch(self, query_embs: np.ndarray, *,
                    top_k: int | Sequence[int] = 10,
                    multi_probe: int = 1,
                    seed: int | None = None, key: jax.Array | None = None
                    ) -> list[list[tuple[int, float, bytes]]]:
        """Batched serving: stack B clients' encrypted queries into one GEMM.

        top_k may be per-request (a sequence aligned with `query_embs`).
        multi_probe>1 with `enable_batch()` routes every client through the
        batch-PIR subsystem: all clients' per-bucket queries stack along the
        column axis of the SAME bucketed GEMM, so the server still streams
        its bucketed DB once per serving batch.

        Per-query LWE secrets are derived by `fold_in` from ONE caller key
        (or from `seed` if given; otherwise the system's split stream), so
        secrets never collide across batches or ad-hoc callers.
        """
        return self.query_batch_async(query_embs, top_k=top_k,
                                      multi_probe=multi_probe, seed=seed,
                                      key=key).complete()

    def query_batch_async(self, query_embs: np.ndarray, *,
                          top_k: int | Sequence[int] = 10,
                          multi_probe: int = 1,
                          seed: int | None = None,
                          key: jax.Array | None = None) -> InflightBatch:
        """Plan + dispatch a serving batch; decode deferred to `complete()`.

        The pipelined serving engine's staged entry point: the returned
        `InflightBatch` has the answer GEMM already enqueued on the device
        and carries plan-time snapshots of everything decode needs, so the
        caller can encode/cut further batches (or publish an epoch commit)
        while this one computes.  `query_batch` is literally
        ``query_batch_async(...).complete()`` — the two paths cannot
        diverge.
        """
        if key is None:
            key = (jax.random.PRNGKey(seed) if seed is not None
                   else self.next_query_key())
        n_req = len(query_embs)
        top_ks = ([int(top_k)] * n_req if np.isscalar(top_k)
                  else [int(t) for t in top_k])
        assert len(top_ks) == n_req, (len(top_ks), n_req)

        if multi_probe > 1 and self.batch is not None:
            return self._query_batch_via_batchpir_async(query_embs, top_ks,
                                                        multi_probe, key)

        # Legacy path: P one-hot columns per request (P=1 is the classic
        # one-column-per-client GEMM) — never silently fewer probes than
        # asked for just because batch-PIR isn't enabled.
        p = max(1, multi_probe)
        # plan: the client object snapshots cfg + hint at THIS epoch
        client = pir.PIRClient(self.cfg, self.hint)
        emb_dim = self.db.emb_dim
        d2 = np.asarray(clustering.pairwise_sqdist(
            jnp.asarray(query_embs, jnp.float32),
            jnp.asarray(self.centroids)))
        orders = np.argsort(d2, axis=1)[:, :p]               # (B, P)
        qs, states = [], []
        for b in range(len(query_embs)):
            for j, c in enumerate(orders[b]):
                qu, st = client.query(jax.random.fold_in(key, b * p + j),
                                      int(c))
                qs.append(qu)
                states.append(st)
        # dispatch: enqueue the GEMM AND the batched recover — the whole
        # answer→plaintext chain rides the device stream, so `complete`
        # is pure host work (one ready-array fetch + parse + rerank) and
        # never queues behind other in-flight device chains
        ans = self.server.answer(jnp.stack(qs, axis=1))      # (m, B·P)
        cols = client.recover_batch(
            ans, jnp.stack([st.secret for st in states], axis=1))

        def complete():
            cols_np = np.asarray(cols)
            out = []
            for b in range(len(query_embs)):
                docs = []
                for j in range(p):
                    docs.extend(chunking.deserialize_docs(
                        cols_np[:, b * p + j], emb_dim))
                out.append(rerank.rerank(
                    np.asarray(query_embs[b], np.float32), docs, top_ks[b]))
            return out

        return InflightBatch(_complete=complete, pending=(cols,))

    def _query_batch_via_batchpir_async(self, query_embs: np.ndarray,
                                        top_ks: list[int], multi_probe: int,
                                        key: jax.Array) -> InflightBatch:
        """Multi-probe serving batch: C clients × B buckets, one GEMM call.

        Per-client placement failures (negligible probability) fall back to
        that client's legacy multi-probe query; everyone else still shares
        the bucketed pass.  Decode state — the per-bucket hints and configs,
        which a later commit patches IN the shared lists — is snapshotted at
        plan time so `complete()` decodes against this batch's epoch.
        """
        from repro.batchpir import PlacementError
        bp = self.batch
        emb_dim = self.db.emb_dim
        d2 = np.asarray(clustering.pairwise_sqdist(
            jnp.asarray(query_embs, jnp.float32),
            jnp.asarray(self.centroids)))
        orders = np.argsort(d2, axis=1)[:, :multi_probe]

        per_client, fallback = [], {}
        for i in range(len(query_embs)):
            k_i = jax.random.fold_in(key, i)
            try:
                qs, st = bp.client.query(k_i, [int(c) for c in orders[i]])
                per_client.append((qs, st))
            except PlacementError:
                fallback[i] = self.query(query_embs[i], top_k=top_ks[i],
                                         multi_probe=multi_probe, key=k_i,
                                         mode="legacy")[0]
                per_client.append(None)

        live = [i for i, pc in enumerate(per_client) if pc is not None]
        answers: list = []
        if live:
            stacked = jnp.stack([per_client[i][0] for i in live], axis=2)
            answers = bp.server.answer_batch(stacked)   # per bucket (m_b, C)
        # plan-time decode snapshot (shallow list copies pin the epoch's
        # hint/config ARRAYS; commits replace list elements, never mutate)
        hints = list(bp.client.hints)
        cfgs = list(bp.client.cfgs)

        def complete():
            out: list[list | None] = [None] * len(query_embs)
            for c_idx, i in enumerate(live):
                ans_i = [a[:, c_idx] for a in answers]
                cols = bp.client.recover(ans_i, per_client[i][1],
                                         hints=hints, cfgs=cfgs)
                docs = []
                for cl in orders[i]:
                    docs.extend(chunking.deserialize_docs(cols[int(cl)],
                                                          emb_dim))
                out[i] = rerank.rerank(np.asarray(query_embs[i], np.float32),
                                       docs, top_ks[i])
            for i, top in fallback.items():
                out[i] = top
            return out

        return InflightBatch(_complete=complete, pending=tuple(answers))
