"""PIR-RAG end-to-end system (paper §3): offline setup + online private query.

Offline (server): embed → K-means → chunk-transposed DB → PIR hint.
Online (client): embed query → pick cluster from PUBLIC centroids →
LWE-encrypted one-hot → server modular GEMV → decrypt whole cluster →
local exact re-rank → top-K documents, content in hand ("RAG-Ready").

The server never sees the query embedding, the chosen cluster, or the ranked
results; its entire view is one pseudorandom uint32 vector per query.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunking, clustering, pir, rerank


@dataclasses.dataclass
class QueryStats:
    uplink_bytes: int
    downlink_bytes: int
    client_ms: float
    server_ms: float
    cluster_index: int            # known to client only


@dataclasses.dataclass
class PirRagSystem:
    """Bundles server-public state (centroids) and the two protocol roles."""
    centroids: np.ndarray         # PUBLIC: (n_clusters, d)
    db: chunking.ChunkedDB
    cfg: pir.PIRConfig
    server: pir.PIRServer
    hint: jax.Array               # client-side after one-time download
    setup_seconds: float          # total offline time
    index_seconds: float = 0.0    # clustering + packing (no crypto)
    hint_seconds: float = 0.0     # hint GEMM (int8-roofline op on TPU)
    assignment: np.ndarray | None = None  # (N,) doc→cluster (live index)

    # -- offline ------------------------------------------------------------

    @classmethod
    def build(cls, texts: Sequence[bytes], embeddings: np.ndarray, *,
              n_clusters: int, kmeans_iters: int = 25, chunk_size: int = 256,
              balance_factor: float | None = None, seed: int = 0,
              impl: str = "auto", q_switch: int | None = 1 << 16,
              doc_ids: Sequence[int] | None = None,
              ) -> "PirRagSystem":
        t0 = time.perf_counter()
        emb_j = jnp.asarray(embeddings, jnp.float32)
        km = clustering.kmeans_fit(jax.random.PRNGKey(seed), emb_j,
                                   k=n_clusters, iters=kmeans_iters)
        cents = np.asarray(km.centroids)
        if balance_factor is not None:
            cap = int(np.ceil(len(texts) / n_clusters * balance_factor))
            assign = clustering.balanced_assign(
                np.asarray(embeddings, np.float32), cents, cap)
        else:
            assign = np.asarray(km.assignment)
        db = chunking.build_chunked_db(texts, np.asarray(embeddings, np.float32),
                                       assign, n_clusters, chunk_size,
                                       doc_ids=doc_ids)
        cfg = pir.make_config(db.m, db.n, impl=impl, q_switch=q_switch)
        server = pir.PIRServer(cfg, jnp.asarray(db.matrix))
        t_index = time.perf_counter()
        hint = jax.block_until_ready(server.setup())
        t_end = time.perf_counter()
        return cls(centroids=cents, db=db, cfg=cfg, server=server, hint=hint,
                   setup_seconds=t_end - t0, index_seconds=t_index - t0,
                   hint_seconds=t_end - t_index, assignment=assign)

    # -- online -------------------------------------------------------------

    def query(self, query_emb: np.ndarray, *, top_k: int = 10,
              multi_probe: int = 1, key: jax.Array | None = None
              ) -> tuple[list[tuple[int, float, bytes]], QueryStats]:
        """One fully private retrieval; returns top-k docs + accounting.

        multi_probe=P (beyond-paper): privately fetch the P nearest clusters
        in ONE batched server GEMM round.  Recovers the boundary recall that
        single-cluster pruning loses (the paper's quality gap vs Graph-PIR)
        at P× downlink — the server still learns nothing, including P's
        cluster identities.
        """
        key = key if key is not None else jax.random.PRNGKey(
            np.random.default_rng().integers(2**31))
        client = pir.PIRClient(self.cfg, self.hint)

        t0 = time.perf_counter()
        d2 = clustering.pairwise_sqdist(
            jnp.asarray(query_emb, jnp.float32)[None, :],
            jnp.asarray(self.centroids))[0]
        order = np.argsort(np.asarray(d2))[:max(1, multi_probe)]
        qs, states = [], []
        for j, cl in enumerate(order):
            qu, st = client.query(jax.random.fold_in(key, j), int(cl))
            qs.append(qu)
            states.append(st)
        batch = jax.block_until_ready(jnp.stack(qs, axis=1))
        t1 = time.perf_counter()

        ans = jax.block_until_ready(self.server.answer(batch))
        t2 = time.perf_counter()

        docs = []
        for j, st in enumerate(states):
            col = np.asarray(client.recover(ans[:, j], st))
            docs.extend(chunking.deserialize_docs(col, self.db.emb_dim))
        top = rerank.rerank(np.asarray(query_emb, np.float32), docs, top_k)
        t3 = time.perf_counter()

        p = len(order)
        stats = QueryStats(
            uplink_bytes=p * self.cfg.uplink_bytes,
            downlink_bytes=p * self.cfg.downlink_bytes,
            client_ms=1e3 * ((t1 - t0) + (t3 - t2)),
            server_ms=1e3 * (t2 - t1),
            cluster_index=int(order[0]))
        return top, stats

    def query_batch(self, query_embs: np.ndarray, *, top_k: int = 10,
                    seed: int = 0, key: jax.Array | None = None
                    ) -> list[list[tuple[int, float, bytes]]]:
        """Batched serving: stack B encrypted queries into one server GEMM.

        Per-query LWE secrets are derived by `fold_in` from ONE caller key
        (or, absent a key, from `seed` as a fallback); the serve loop threads
        a split stream through here so secrets never collide across batches.
        """
        if key is None:
            key = jax.random.PRNGKey(seed)
        client = pir.PIRClient(self.cfg, self.hint)
        clusters = np.asarray(clustering.assign_to_centroids(
            jnp.asarray(query_embs, jnp.float32), jnp.asarray(self.centroids)))
        qs, states = [], []
        for b, c in enumerate(clusters):
            qu, st = client.query(jax.random.fold_in(key, b), int(c))
            qs.append(qu)
            states.append(st)
        ans = self.server.answer(jnp.stack(qs, axis=1))      # (m, B)
        out = []
        for b, st in enumerate(states):
            col = np.asarray(client.recover(ans[:, b], st))
            docs = chunking.deserialize_docs(col, self.db.emb_dim)
            out.append(rerank.rerank(np.asarray(query_embs[b], np.float32),
                                     docs, top_k))
        return out
