"""Chunk-transposed database construction (paper §3.2).

Each cluster's documents are serialized into one byte column; the corpus
becomes an (m × n) uint8 matrix whose column j is cluster j.  Retrieving a
cluster ≡ privately reading one column ≡ one modular GEMV — this data layout
is the paper's key systems contribution.

Per-document record (little-endian), so the client can re-rank locally after
decryption without any further server interaction:

    [doc_id : u32][text_len : u32][emb_scale : f32][emb_off : f32]
    [emb_q  : u8 × emb_dim]  [text : u8 × text_len]

Column layout: [n_docs : u32][record ...][zero padding to m rows].
m = max serialized cluster size, rounded up to `chunk_size` (the PIR rows are
byte-granular because the plaintext modulus is p = 256; `chunk_size` is the
padding/alignment granule).

Live-index support (update/): columns are individually re-serializable via
``pack_column`` / ``rebuild_columns`` so a streaming mutation touching
clusters J re-packs only those columns.  ``used_bytes`` tracks per-column
occupancy — the capacity accounting that decides when an insert overflows
`m` and forces a full rebuild instead of a sparse delta.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
from typing import Mapping, Sequence

import numpy as np

_HDR = 16  # doc_id + text_len + scale + offset

#: (doc_id, embedding f32 (d,), text bytes) — the canonical document triple.
DocTriple = tuple[int, np.ndarray, bytes]


class ColumnOverflowError(ValueError):
    """A re-packed column no longer fits in the m-row budget (rebuild needed)."""

    def __init__(self, cluster: int, need: int, have: int):
        super().__init__(f"cluster {cluster} needs {need} bytes > m={have}")
        self.cluster = cluster
        self.need = need
        self.have = have


@dataclasses.dataclass(frozen=True)
class ChunkedDB:
    """The chunk-transposed corpus: one uint8 byte column per cluster.

    ``matrix`` is the canonical (m, n) host view.  When the DB was packed
    for a row-sharded server (``build_chunked_db(n_row_shards=S)``),
    ``row_shards`` holds S equal-height row-slice VIEWS of one shared
    allocation (padded up to a multiple of S rows), so per-shard device
    transfers and the host mirror alias the same bytes — in-place column
    patches stay visible through both.
    """
    matrix: np.ndarray            # (m, n) uint8, chunk-transposed
    emb_dim: int
    chunk_size: int
    n_docs: int
    cluster_sizes: np.ndarray     # (n,) docs per cluster
    pad_fraction: float           # wasted bytes / total bytes (reported)
    used_bytes: np.ndarray | None = None   # (n,) serialized bytes per column
    row_shards: tuple[np.ndarray, ...] | None = None  # S × (m_pad/S, n) views

    @property
    def m(self) -> int:
        """Rows: bytes per column (max serialized cluster, chunk-rounded)."""
        return self.matrix.shape[0]

    @property
    def n(self) -> int:
        """Columns: number of clusters."""
        return self.matrix.shape[1]


def quantize_embedding(emb: np.ndarray) -> tuple[np.ndarray, float, float]:
    """Per-doc affine u8 quantization (client re-ranking tolerates ≤0.4% err)."""
    lo, hi = float(emb.min()), float(emb.max())
    scale = (hi - lo) / 255.0 if hi > lo else 1.0
    q = np.clip(np.round((emb - lo) / scale), 0, 255).astype(np.uint8)
    return q, scale, lo


def dequantize_embedding(q: np.ndarray, scale: float, off: float) -> np.ndarray:
    """Inverse of `quantize_embedding`: u8 (d,) → f32 (d,)."""
    return q.astype(np.float32) * scale + off


def serialize_doc(doc_id: int, emb: np.ndarray, text: bytes) -> bytes:
    """One document's wire record (see module docstring for the layout)."""
    q, scale, off = quantize_embedding(emb)
    hdr = (np.uint32(doc_id).tobytes() + np.uint32(len(text)).tobytes()
           + np.float32(scale).tobytes() + np.float32(off).tobytes())
    return hdr + q.tobytes() + text


def deserialize_docs(col: np.ndarray, emb_dim: int
                     ) -> list[tuple[int, np.ndarray, bytes]]:
    """Parse one decrypted column back into (doc_id, embedding, text)."""
    buf = col.tobytes()
    n_docs = int(np.frombuffer(buf[:4], np.uint32)[0])
    out = []
    ofs = 4
    for _ in range(n_docs):
        doc_id = int(np.frombuffer(buf[ofs:ofs + 4], np.uint32)[0])
        tlen = int(np.frombuffer(buf[ofs + 4:ofs + 8], np.uint32)[0])
        scale = float(np.frombuffer(buf[ofs + 8:ofs + 12], np.float32)[0])
        off = float(np.frombuffer(buf[ofs + 12:ofs + 16], np.float32)[0])
        ofs += _HDR
        q = np.frombuffer(buf[ofs:ofs + emb_dim], np.uint8)
        ofs += emb_dim
        text = buf[ofs:ofs + tlen]
        ofs += tlen
        out.append((doc_id, dequantize_embedding(q, scale, off), text))
    return out


def record_bytes(emb_dim: int, text_len: int) -> int:
    """Serialized size of one record: 16-byte header + emb + text."""
    return _HDR + emb_dim + text_len


def column_payload_bytes(emb_dim: int, text_lens: Sequence[int]) -> int:
    """Serialized size of a column holding docs with the given text lengths."""
    return 4 + sum(record_bytes(emb_dim, t) for t in text_lens)


def pack_column(docs: Sequence[DocTriple]) -> bytes:
    """Serialize one cluster's documents into its column payload.

    Canonical ordering (ascending doc_id) is enforced so an incremental
    column rebuild is byte-identical to a from-scratch pack of the same
    document set — the invariant the delta-hint path relies on.
    """
    docs = sorted(docs, key=lambda d: d[0])
    parts = [np.uint32(len(docs)).tobytes()]
    parts += [serialize_doc(int(i), emb, text) for i, emb, text in docs]
    return b"".join(parts)


def rebuild_columns(m: int, docs_by_col: Mapping[int, Sequence[DocTriple]]
                    ) -> tuple[np.ndarray, np.ndarray, dict[int, int]]:
    """Re-serialize the given clusters into fresh m-row columns.

    Returns (sorted cluster ids (J,), new columns (m, J) u8, used bytes per
    cluster).  Raises ColumnOverflowError when a payload exceeds m — the
    caller's signal to fall back to a full rebuild (m must grow).
    """
    cols = np.asarray(sorted(docs_by_col), np.int64)
    out = np.zeros((m, len(cols)), np.uint8)
    used: dict[int, int] = {}
    for idx, j in enumerate(cols):
        payload = pack_column(docs_by_col[int(j)])
        if len(payload) > m:
            raise ColumnOverflowError(int(j), len(payload), m)
        out[:len(payload), idx] = np.frombuffer(payload, np.uint8)
        used[int(j)] = len(payload)
    return cols, out, used


def build_chunked_db(texts: Sequence[bytes], embeddings: np.ndarray,
                     assignment: np.ndarray, n_clusters: int,
                     chunk_size: int = 256,
                     doc_ids: Sequence[int] | None = None, *,
                     n_row_shards: int = 1,
                     pack_workers: int | None = None) -> ChunkedDB:
    """Pack the corpus into the chunk-transposed uint8 matrix.

    `doc_ids` (default: positional 0..N-1) lets a live-index full rebuild
    preserve stable external document ids across a sparse id space.

    ``n_row_shards=S`` packs for a row-sharded server: rows pad up to a
    multiple of S and the fill runs one independent row-slice per shard (a
    column's rows [lo, hi) are just ``payload[lo:hi]``, so shard slices need
    no cross-shard state — on a multi-host build each host packs only its
    slice).  The slices are views of one allocation, exposed as
    ``ChunkedDB.row_shards`` for direct per-device placement
    (`PIRServer` assembles them without a single-device materialize).
    Packed bytes are identical for every S; ``matrix`` is always the
    unpadded (m, n) view.

    ``pack_workers`` sizes the thread pool for column serialization and
    shard fills (default: one per shard, serial when S == 1).
    """
    n_docs, emb_dim = embeddings.shape
    assert len(texts) == n_docs
    ids = np.arange(n_docs) if doc_ids is None else np.asarray(doc_ids)
    assert len(ids) == n_docs
    assert n_row_shards >= 1

    def _pack(j: int) -> bytes:
        members = np.nonzero(assignment == j)[0]
        return pack_column(
            [(int(ids[i]), embeddings[i], texts[i]) for i in members])

    workers = pack_workers if pack_workers is not None else n_row_shards
    if workers > 1:
        with concurrent.futures.ThreadPoolExecutor(workers) as ex:
            columns = list(ex.map(_pack, range(n_clusters)))
    else:
        columns = [_pack(j) for j in range(n_clusters)]
    sizes = np.bincount(np.asarray(assignment), minlength=n_clusters
                        ).astype(np.int64)

    raw = max(len(c) for c in columns)
    m = ((raw + chunk_size - 1) // chunk_size) * chunk_size
    m_pad = m + (-m) % n_row_shards
    full = np.zeros((m_pad, n_clusters), np.uint8)
    rows_per = m_pad // n_row_shards
    used = np.asarray([len(c) for c in columns], np.int64)

    def _fill(s: int) -> None:
        lo, hi = s * rows_per, (s + 1) * rows_per
        block = full[lo:hi]
        for j, c in enumerate(columns):
            if len(c) > lo:
                block[: min(hi, len(c)) - lo, j] = np.frombuffer(
                    c, np.uint8, count=min(hi, len(c)) - lo, offset=lo)

    if n_row_shards > 1:
        with concurrent.futures.ThreadPoolExecutor(workers) as ex:
            list(ex.map(_fill, range(n_row_shards)))
    else:
        _fill(0)

    pad_frac = 1.0 - int(used.sum()) / float(m * n_clusters)
    shards = (tuple(full[s * rows_per:(s + 1) * rows_per]
                    for s in range(n_row_shards))
              if n_row_shards > 1 else None)
    return ChunkedDB(matrix=full[:m], emb_dim=emb_dim, chunk_size=chunk_size,
                     n_docs=n_docs, cluster_sizes=sizes,
                     pad_fraction=pad_frac, used_bytes=used,
                     row_shards=shards)
