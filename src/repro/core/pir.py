"""SimplePIR-style single-server PIR over a chunk-transposed database.

Protocol roles (honest-but-curious server):

  offline   server:  hint H = D·A  (one-time; A from a public seed)
            client:  downloads H (m×k u32) once
  online    client:  qu = A·s + e + Δ·onehot(i)          — uplink n·4 bytes
            server:  ans = D·qu (mod 2^32)               — ONE modular GEMV
            client:  decode(ans − H·s) → column i of D   — the whole cluster

The answer step is the system hot loop; it dispatches to the Pallas MXU
kernel on TPU (`kernels/ops.modmatmul`).  Batched serving stacks queries from
many clients into the column dimension, turning the GEMV into a GEMM.

Beyond-paper: modulus-switched responses (q → 2^16) halve the downlink at a
rounding-noise cost accounted in `lwe.noise_budget_ok`.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import lwe
from repro.kernels import ops

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class PIRConfig:
    m: int                       # DB rows (cluster content bytes / entry)
    n: int                       # DB cols (number of clusters)
    params: lwe.LWEParams
    a_seed: int = 7              # public seed for the LWE matrix A
    impl: str = "auto"           # kernel dispatch for the server GEMM

    def __post_init__(self):
        if not lwe.noise_budget_ok(self.params, self.n):
            raise ValueError(
                f"LWE noise budget violated for n={self.n}, p={self.params.p}")

    @property
    def uplink_bytes(self) -> int:
        return self.n * 4

    @property
    def downlink_bytes(self) -> int:
        qs = self.params.q_switch
        per = 2 if (qs is not None and qs <= 1 << 16) else 4
        return self.m * per

    @property
    def hint_bytes(self) -> int:
        return self.m * self.params.k * 4


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

class PIRServer:
    """Holds the plaintext DB (u8, entries < p) and answers encrypted queries."""

    def __init__(self, cfg: PIRConfig, db: jax.Array):
        assert db.shape == (cfg.m, cfg.n), (db.shape, (cfg.m, cfg.n))
        assert db.dtype == jnp.uint8
        self.cfg = cfg
        self.db = db
        self._a_mat: jax.Array | None = None   # lazy; immutable per config

    @property
    def a_matrix(self) -> jax.Array:
        """The public LWE matrix A (seed-derived, cached across commits)."""
        if self._a_mat is None:
            self._a_mat = lwe.gen_public_matrix(
                self.cfg.a_seed, self.cfg.n, self.cfg.params.k)
        return self._a_mat

    def setup(self) -> jax.Array:
        """Offline hint H = D·A ∈ Z_q^{m×k} (the heavy one-time GEMM)."""
        return ops.hint_gemm(self.db, self.a_matrix, impl=self.cfg.impl)

    def answer(self, qu: jax.Array) -> jax.Array:
        """Online answer: D·qu mod 2^32.  qu: (n,) or (n, batch) uint32."""
        ans = ops.modmatmul(self.db, qu, impl=self.cfg.impl)
        if self.cfg.params.q_switch is not None:
            ans = lwe.switch_modulus(ans, self.cfg.params.q_switch)
        return ans

    def update_columns(self, cols: jax.Array, new_cols: jax.Array
                       ) -> jax.Array:
        """Replace DB columns J and return the exact hint delta.

        The hint is linear in the database, so a mutation confined to columns
        J patches it with a sparse GEMM instead of a full rebuild:

            ΔH = ΔD[:,J] · A[J,:]  =  D_new[:,J]·A[J,:] − D_old[:,J]·A[J,:]

        Both products go through the same `ops.modmatmul` kernel path as the
        offline hint, so `H + ΔH` is bit-identical to `setup()` on the
        updated DB (all arithmetic exact mod 2^32).

        cols: (J,) int column indices.  new_cols: (m, J) uint8.
        Returns ΔH: (m, k) uint32.

        The GEMM is bucketed: J is padded up to a power of two with columns
        whose "new" contents equal their current contents, so padding slots
        cancel exactly in ΔH while streamed mutation batches of varying size
        reuse a handful of compiled shapes instead of recompiling per batch.
        """
        cols = jnp.asarray(cols)
        new_cols = jnp.asarray(new_cols)
        j = int(cols.shape[0])
        assert new_cols.shape == (self.cfg.m, j)
        assert new_cols.dtype == jnp.uint8
        old_cols = self.db[:, cols]
        self.db = self.db.at[:, cols].set(new_cols)  # true columns only

        bucket = 1 << max(0, (j - 1).bit_length())
        pad = min(bucket, self.cfg.n) - j
        if pad > 0:
            # pad with column 0 on BOTH sides of the subtraction: its new
            # and old contents are identical, so it contributes ΔH = 0
            cols_g = jnp.concatenate([cols, jnp.zeros(pad, cols.dtype)])
            unchanged = jnp.repeat(self.db[:, :1], pad, axis=1)
            new_g = jnp.concatenate([new_cols, unchanged], axis=1)
            old_g = jnp.concatenate([old_cols, unchanged], axis=1)
        else:
            cols_g, new_g, old_g = cols, new_cols, old_cols
        a_j = self.a_matrix[cols_g]                        # (J', k)
        return ops.delta_gemm(new_g, old_g, a_j, impl=self.cfg.impl)


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientQueryState:
    secret: jax.Array            # s ∈ Z_q^k
    index: int                   # queried column (kept client-side!)


class PIRClient:
    """Client side: query formulation and response decoding."""

    def __init__(self, cfg: PIRConfig, hint: jax.Array):
        assert hint.shape == (cfg.m, cfg.params.k)
        self.cfg = cfg
        self.hint = hint
        self._a_mat = lwe.gen_public_matrix(cfg.a_seed, cfg.n, cfg.params.k)

    def query(self, key: jax.Array, index: int) -> tuple[jax.Array,
                                                          ClientQueryState]:
        """Encrypt a one-hot selector for column `index`."""
        k_sec, k_err = jax.random.split(key)
        s = lwe.keygen(k_sec, self.cfg.params)
        onehot = jnp.zeros((self.cfg.n,), U32).at[index].set(1)
        qu = lwe.encrypt_vector(k_err, s, self._a_mat, onehot,
                                self.cfg.params.delta, self.cfg.params.sigma)
        return qu, ClientQueryState(secret=s, index=index)

    def recover(self, ans: jax.Array, state: ClientQueryState) -> jax.Array:
        """Decode the server answer into the plaintext column (m,) u8."""
        p = self.cfg.params
        if p.q_switch is not None:
            vals = lwe.decode_switched(ans, self.hint, state.secret, p)
        else:
            rec = lwe.hint_strip(ans, self.hint, state.secret)
            vals = lwe.decode(rec, p)
        return vals.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Convenience: parameter selection for a corpus
# ---------------------------------------------------------------------------

def make_config(m: int, n: int, *, impl: str = "auto",
                q_switch: int | None = 1 << 16) -> PIRConfig:
    params = lwe.choose_params(n, want_p=256, q_switch=q_switch)
    return PIRConfig(m=m, n=n, params=params, impl=impl)


def server_flops(cfg: PIRConfig, batch: int = 1) -> int:
    """int8-MAC count of one online answer (limb-decomposed)."""
    return 2 * cfg.m * cfg.n * batch * lwe.Q_BITS // 8


def server_bytes(cfg: PIRConfig) -> int:
    """HBM traffic floor of one answer: the DB streamed once."""
    return cfg.m * cfg.n
