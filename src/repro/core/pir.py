"""SimplePIR-style single-server PIR over a chunk-transposed database.

Protocol roles (honest-but-curious server):

  offline   server:  hint H = D·A  (one-time; A from a public seed)
            client:  downloads H (m×k u32) once
  online    client:  qu = A·s + e + Δ·onehot(i)          — uplink n·4 bytes
            server:  ans = D·qu (mod 2^32)               — ONE modular GEMV
            client:  decode(ans − H·s) → column i of D   — the whole cluster

The answer step is the system hot loop; it dispatches to the Pallas MXU
kernel on TPU (`kernels/ops.modmatmul`).  Batched serving stacks queries from
many clients into the column dimension, turning the GEMV into a GEMM.

Beyond-paper: modulus-switched responses (q → 2^16) halve the downlink at a
rounding-noise cost accounted in `lwe.noise_budget_ok`.

Sharded serving (beyond-paper, `distributed.sharding.pir_rules`): pass
``mesh=`` to row-shard the packed DB over the device mesh.  Queries
replicate; every shard computes its own hint rows H_s = D_s·A and answer
slice ans_s = D_s·qu with ZERO collectives (the contraction dim — the
cluster axis — is never split), and the client decodes the concatenation.
All sharded arithmetic is the same exact mod-2^32 kernel path, so results
are bit-identical to the single-device layout (property-tested under the
8-fake-device harness in tests/test_sharded_pir.py).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import lwe
from repro.kernels import ops

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class PIRConfig:
    m: int                       # DB rows (cluster content bytes / entry)
    n: int                       # DB cols (number of clusters)
    params: lwe.LWEParams
    a_seed: int = 7              # public seed for the LWE matrix A
    impl: str = "auto"           # kernel dispatch for the server GEMM

    def __post_init__(self):
        if not lwe.noise_budget_ok(self.params, self.n):
            raise ValueError(
                f"LWE noise budget violated for n={self.n}, p={self.params.p}")

    @property
    def uplink_bytes(self) -> int:
        """Query size: one u32 ciphertext entry per DB column (n·4)."""
        return self.n * 4

    @property
    def downlink_bytes(self) -> int:
        """Response size: m words — 2 B each when modulus-switched ≤ 2^16."""
        qs = self.params.q_switch
        per = 2 if (qs is not None and qs <= 1 << 16) else 4
        return self.m * per

    @property
    def hint_bytes(self) -> int:
        """One-time client download: the (m, k) u32 hint H = D·A."""
        return self.m * self.params.k * 4


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

class PIRServer:
    """Holds the plaintext DB (u8, entries < p) and answers encrypted queries.

    With ``mesh=`` the DB row-shards over the mesh (the ``chunks`` logical
    axis of `sharding.pir_rules`); rows are zero-padded up to a multiple of
    the shard count so `shard_map` sees equal slices.  The padding rows are
    all-zero on both the DB and the hint, so answers/decodes are unaffected
    — every public method still speaks global (m, ...) shapes.

    ``db`` accepts three layouts (all (m, n) uint8 semantics):

      * a jax array — committed/resharded as before;
      * a host numpy array — padded host-side and transferred straight into
        the sharded layout (no device-0 commit);
      * a list/tuple of S per-shard host row slices ((m_pad/S, n) each,
        e.g. ``ChunkedDB.row_shards``) — each slice is placed directly on
        its owning device and assembled with
        `jax.make_array_from_single_device_arrays`, so the full DB is never
        materialized on (or resharded through) a single device.  This is
        the sharded offline build's in-place construction path.
    """

    def __init__(self, cfg: PIRConfig, db, *,
                 mesh=None, mesh_axes: tuple[str, ...] | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.mesh_axes: tuple[str, ...] | None = None
        self._row_pad = 0
        if mesh is not None:
            from repro.core import clustering
            axes, shards = clustering.resolve_mesh_axes(mesh, mesh_axes)
            self.mesh_axes = axes
            self.n_shards = shards
            self._row_pad = (-cfg.m) % shards
            self._db_sharding = NamedSharding(mesh,
                                              PartitionSpec(axes, None))
            self._replicated = NamedSharding(mesh, PartitionSpec())
            if isinstance(db, (list, tuple)):
                db = self._assemble_row_shards(db)
            elif isinstance(db, np.ndarray):
                assert db.shape == (cfg.m, cfg.n), (db.shape, (cfg.m, cfg.n))
                assert db.dtype == np.uint8
                if self._row_pad:
                    padded = np.zeros((cfg.m + self._row_pad, cfg.n),
                                      np.uint8)
                    padded[:cfg.m] = db
                    db = padded
                db = jax.device_put(db, self._db_sharding)
            else:
                assert db.shape == (cfg.m, cfg.n), (db.shape, (cfg.m, cfg.n))
                assert db.dtype == jnp.uint8
                if self._row_pad:
                    db = jnp.pad(jnp.asarray(db),
                                 ((0, self._row_pad), (0, 0)))
                db = jax.device_put(db, self._db_sharding)
        else:
            self.n_shards = 1
            if isinstance(db, np.ndarray):
                db = jnp.asarray(db)
            assert db.shape == (cfg.m, cfg.n), (db.shape, (cfg.m, cfg.n))
            assert db.dtype == jnp.uint8
        self.db = db
        self._a_mat: jax.Array | None = None   # lazy; immutable per config
        self._answer_fn = None                 # cached shard_map'd hot path
        self._hint_fn = None
        self._delta_fn = None

    def _assemble_row_shards(self, shards) -> jax.Array:
        """Place per-shard host row slices device-by-device and assemble.

        shards: S host arrays of shape (m_pad/S, n) u8 in row order (row
        padding, if any, lives in the last slice).  Each slice transfers to
        exactly the device that owns its rows under the P(axes, None)
        sharding — the global array exists only as the assembled sharded
        view, never on one device.
        """
        m_pad = self.cfg.m + self._row_pad
        rows_per = m_pad // self.n_shards
        assert len(shards) == self.n_shards, (len(shards), self.n_shards)
        shape = (m_pad, self.cfg.n)
        arrays = []
        dmap = self._db_sharding.addressable_devices_indices_map(shape)
        for dev, idx in dmap.items():
            lo = idx[0].start or 0
            block = np.ascontiguousarray(shards[lo // rows_per])
            assert block.shape == (rows_per, self.cfg.n), (
                block.shape, (rows_per, self.cfg.n))
            assert block.dtype == np.uint8
            arrays.append(jax.device_put(block, dev))
        return jax.make_array_from_single_device_arrays(
            shape, self._db_sharding, arrays)

    @property
    def a_matrix(self) -> jax.Array:
        """The public LWE matrix A (seed-derived, cached across commits)."""
        if self._a_mat is None:
            self._a_mat = lwe.gen_public_matrix(
                self.cfg.a_seed, self.cfg.n, self.cfg.params.k)
        return self._a_mat

    def setup(self) -> jax.Array:
        """Offline hint H = D·A ∈ Z_q^{m×k} (the heavy one-time GEMM).

        Sharded servers compute per-shard hint rows H_s = D_s·A in place
        (zero collectives) and return the global (m, k) view; the client
        downloads it once, exactly like the single-device hint.
        """
        if self.mesh is None:
            return ops.hint_gemm(self.db, self.a_matrix, impl=self.cfg.impl)
        if self._hint_fn is None:
            from repro.distributed import collectives
            self._hint_fn = collectives.row_shard_gemm(
                self.mesh, self.mesh_axes, impl=self.cfg.impl)
        a_rep = jax.device_put(self.a_matrix, self._replicated)
        return self._hint_fn(self.db, a_rep)[:self.cfg.m]

    def answer(self, qu: jax.Array) -> jax.Array:
        """Online answer: D·qu mod 2^32.  qu: (n,) or (n, batch) uint32.

        Sharded servers replicate qu and run the shard_map'd row GEMM —
        each device answers its own row slice, no collectives.
        """
        if self.mesh is None:
            ans = ops.modmatmul(self.db, qu, impl=self.cfg.impl)
            if self.cfg.params.q_switch is not None:
                ans = lwe.switch_modulus(ans, self.cfg.params.q_switch)
            return ans
        if self._answer_fn is None:
            from repro.distributed import collectives
            self._answer_fn = collectives.row_shard_gemm(
                self.mesh, self.mesh_axes, impl=self.cfg.impl,
                q_switch=self.cfg.params.q_switch)
        was_vec = qu.ndim == 1
        q2 = qu[:, None] if was_vec else qu
        ans = self._answer_fn(self.db,
                              jax.device_put(q2, self._replicated))
        ans = ans[:self.cfg.m]
        return ans[:, 0] if was_vec else ans

    def _pad_new_cols(self, cols: jax.Array, new_cols: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
        """Validate shapes and extend new columns with the shard-pad rows."""
        cols = jnp.asarray(cols)
        new_cols = jnp.asarray(new_cols)
        assert new_cols.shape == (self.cfg.m, int(cols.shape[0]))
        assert new_cols.dtype == jnp.uint8
        if self._row_pad:
            # DB padding rows are zero and stay zero across mutations
            new_cols = jnp.pad(new_cols, ((0, self._row_pad), (0, 0)))
        return cols, new_cols

    def stage_delta(self, cols: jax.Array, new_cols: jax.Array) -> jax.Array:
        """Dispatch the hint delta ΔH = (D_new−D_old)[:,J]·A[J,:] for a
        column swap WITHOUT touching ``self.db``.

        Reads the old columns from the live DB (so it must run before any
        donating scatter of the same swap) and returns the (m, k) u32 ΔH
        as an in-flight device value.  Pow-of-two bucketed like
        `update_columns` so streamed batches reuse compiled shapes; pad
        slots carry the live DB's column 0 on BOTH sides of the
        subtraction, contributing exactly ΔH = 0.
        """
        cols, new_cols = self._pad_new_cols(cols, new_cols)
        j = int(cols.shape[0])
        old_cols = self.db[:, cols]
        bucket = 1 << max(0, (j - 1).bit_length())
        pad = min(bucket, self.cfg.n) - j
        if pad > 0:
            cols_g = jnp.concatenate([cols, jnp.zeros(pad, cols.dtype)])
            unchanged = jnp.repeat(self.db[:, :1], pad, axis=1)
            new_g = jnp.concatenate([new_cols, unchanged], axis=1)
            old_g = jnp.concatenate([old_cols, unchanged], axis=1)
        else:
            cols_g, new_g, old_g = cols, new_cols, old_cols
        a_j = self.a_matrix[cols_g]                        # (J', k)
        if self.mesh is None:
            return ops.delta_gemm(new_g, old_g, a_j, impl=self.cfg.impl)
        if self._delta_fn is None:
            from repro.distributed import collectives
            self._delta_fn = collectives.row_shard_delta_gemm(
                self.mesh, self.mesh_axes, impl=self.cfg.impl)
        return self._delta_fn(
            jax.device_put(new_g, self._db_sharding),
            jax.device_put(old_g, self._db_sharding),
            jax.device_put(a_j, self._replicated))[:self.cfg.m]

    def stage_scatter(self, cols: jax.Array, new_cols: jax.Array, *,
                      donate: bool = False) -> jax.Array:
        """The patched DB array for a column swap; ``self.db`` unassigned.

        ``donate=True`` donates the live DB buffer into the scatter — the
        caller must assign the result to ``self.db`` immediately (the
        live-index publish step does) and no NEW Python-side use of the old
        array may follow; computations already enqueued keep the buffer
        alive at the runtime level.
        """
        cols, new_cols = self._pad_new_cols(cols, new_cols)
        if self.mesh is None:
            return ops.scatter_columns(self.db, cols, new_cols,
                                       donate=donate)
        if donate:
            from repro.distributed import collectives
            scatter = collectives.row_shard_scatter(
                self.mesh, self.mesh_axes, donate=True)
            return scatter(self.db, cols,
                           jax.device_put(new_cols, self._db_sharding))
        return jax.device_put(self.db.at[:, cols].set(new_cols),
                              self._db_sharding)

    def stage_update(self, cols: jax.Array, new_cols: jax.Array, *,
                     donate: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
        """Compute (new_db, ΔH) for a column swap WITHOUT publishing it.

        The shadow-epoch half of `update_columns`: `stage_delta` (which
        reads the old columns first) then `stage_scatter`.  With
        ``donate=True`` the live buffer is consumed HERE, so only callers
        that assign ``self.db`` unconditionally afterwards may pass it —
        `update_columns` does; the live-index stage path instead defers the
        donating scatter to its publish step so an aborted or dropped
        staged epoch never strands ``self.db`` on a deleted buffer.
        """
        delta_h = self.stage_delta(cols, new_cols)
        return self.stage_scatter(cols, new_cols, donate=donate), delta_h

    def update_columns(self, cols: jax.Array, new_cols: jax.Array, *,
                       donate: bool = False) -> jax.Array:
        """Replace DB columns J and return the exact hint delta.

        The hint is linear in the database, so a mutation confined to columns
        J patches it with a sparse GEMM instead of a full rebuild:

            ΔH = ΔD[:,J] · A[J,:]  =  D_new[:,J]·A[J,:] − D_old[:,J]·A[J,:]

        Both products go through the same `ops.modmatmul` kernel path as the
        offline hint, so `H + ΔH` is bit-identical to `setup()` on the
        updated DB (all arithmetic exact mod 2^32).

        cols: (J,) int column indices.  new_cols: (m, J) uint8.
        Returns ΔH: (m, k) uint32.

        The GEMM is bucketed: J is padded up to a power of two with columns
        whose "new" contents equal their current contents, so padding slots
        cancel exactly in ΔH while streamed mutation batches of varying size
        reuse a handful of compiled shapes instead of recompiling per batch.

        Sharded servers scatter the new columns into the row-sharded DB and
        run the delta GEMM shard_map'd: each shard patches only the hint
        rows it owns, so the live-index commit is collective-free like the
        answer path.

        ``donate=True`` patches the DB buffer in place (see `stage_update`);
        callers must not retain the pre-update ``self.db`` array.
        """
        new_db, delta_h = self.stage_update(cols, new_cols, donate=donate)
        self.db = new_db
        return delta_h


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientQueryState:
    secret: jax.Array            # s ∈ Z_q^k
    index: int                   # queried column (kept client-side!)


class PIRClient:
    """Client side: query formulation and response decoding."""

    def __init__(self, cfg: PIRConfig, hint: jax.Array):
        assert hint.shape == (cfg.m, cfg.params.k)
        self.cfg = cfg
        self.hint = hint
        self._a_mat = lwe.gen_public_matrix(cfg.a_seed, cfg.n, cfg.params.k)

    def query(self, key: jax.Array, index: int) -> tuple[jax.Array,
                                                          ClientQueryState]:
        """Encrypt a one-hot selector for column `index`."""
        k_sec, k_err = jax.random.split(key)
        s = lwe.keygen(k_sec, self.cfg.params)
        onehot = jnp.zeros((self.cfg.n,), U32).at[index].set(1)
        qu = lwe.encrypt_vector(k_err, s, self._a_mat, onehot,
                                self.cfg.params.delta, self.cfg.params.sigma)
        return qu, ClientQueryState(secret=s, index=index)

    def recover(self, ans: jax.Array, state: ClientQueryState) -> jax.Array:
        """Decode the server answer into the plaintext column (m,) u8."""
        p = self.cfg.params
        if p.q_switch is not None:
            vals = lwe.decode_switched(ans, self.hint, state.secret, p)
        else:
            rec = lwe.hint_strip(ans, self.hint, state.secret)
            vals = lwe.decode(rec, p)
        return vals.astype(jnp.uint8)

    def recover_batch(self, ans: jax.Array, secrets: jax.Array) -> jax.Array:
        """Decode C answers at once: ans (m, C), secrets (k, C) → (m, C) u8.

        Every LWE decode op is exact integer arithmetic and shape
        polymorphic (the hint strip is one (m,k)·(k,C) matmul), so column
        j here is BIT-IDENTICAL to ``recover(ans[:, j], state_j)`` — the
        batched form exists so the serving pipeline can enqueue recovery
        on the device stream at dispatch time instead of paying C
        dispatch round-trips at the complete stage.
        """
        p = self.cfg.params
        if p.q_switch is not None:
            vals = lwe.decode_switched(ans, self.hint, secrets, p)
        else:
            rec = lwe.hint_strip(ans, self.hint, secrets)
            vals = lwe.decode(rec, p)
        return vals.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Convenience: parameter selection for a corpus
# ---------------------------------------------------------------------------

def make_config(m: int, n: int, *, impl: str = "auto",
                q_switch: int | None = 1 << 16,
                a_seed: int = 7) -> PIRConfig:
    """PIRConfig for an (m, n) database with auto-chosen LWE parameters.

    ``a_seed`` seeds the public LWE matrix A (shared by server and every
    client; `PirRagSystem.build` derives it from its build seed on a stream
    independent of cluster seeding).
    """
    params = lwe.choose_params(n, want_p=256, q_switch=q_switch)
    return PIRConfig(m=m, n=n, params=params, impl=impl, a_seed=a_seed)


def server_flops(cfg: PIRConfig, batch: int = 1) -> int:
    """int8-MAC count of one online answer (limb-decomposed)."""
    return 2 * cfg.m * cfg.n * batch * lwe.Q_BITS // 8


def server_bytes(cfg: PIRConfig) -> int:
    """HBM traffic floor of one answer: the DB streamed once."""
    return cfg.m * cfg.n
