"""SimplePIR-style single-server PIR over a chunk-transposed database.

Protocol roles (honest-but-curious server):

  offline   server:  hint H = D·A  (one-time; A from a public seed)
            client:  downloads H (m×k u32) once
  online    client:  qu = A·s + e + Δ·onehot(i)          — uplink n·4 bytes
            server:  ans = D·qu (mod 2^32)               — ONE modular GEMV
            client:  decode(ans − H·s) → column i of D   — the whole cluster

The answer step is the system hot loop; it dispatches to the Pallas MXU
kernel on TPU (`kernels/ops.modmatmul`).  Batched serving stacks queries from
many clients into the column dimension, turning the GEMV into a GEMM.

Beyond-paper: modulus-switched responses (q → 2^16) halve the downlink at a
rounding-noise cost accounted in `lwe.noise_budget_ok`.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import lwe
from repro.kernels import ops

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class PIRConfig:
    m: int                       # DB rows (cluster content bytes / entry)
    n: int                       # DB cols (number of clusters)
    params: lwe.LWEParams
    a_seed: int = 7              # public seed for the LWE matrix A
    impl: str = "auto"           # kernel dispatch for the server GEMM

    def __post_init__(self):
        if not lwe.noise_budget_ok(self.params, self.n):
            raise ValueError(
                f"LWE noise budget violated for n={self.n}, p={self.params.p}")

    @property
    def uplink_bytes(self) -> int:
        return self.n * 4

    @property
    def downlink_bytes(self) -> int:
        qs = self.params.q_switch
        per = 2 if (qs is not None and qs <= 1 << 16) else 4
        return self.m * per

    @property
    def hint_bytes(self) -> int:
        return self.m * self.params.k * 4


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

class PIRServer:
    """Holds the plaintext DB (u8, entries < p) and answers encrypted queries."""

    def __init__(self, cfg: PIRConfig, db: jax.Array):
        assert db.shape == (cfg.m, cfg.n), (db.shape, (cfg.m, cfg.n))
        assert db.dtype == jnp.uint8
        self.cfg = cfg
        self.db = db

    def setup(self) -> jax.Array:
        """Offline hint H = D·A ∈ Z_q^{m×k} (the heavy one-time GEMM)."""
        a_mat = lwe.gen_public_matrix(self.cfg.a_seed, self.cfg.n,
                                      self.cfg.params.k)
        return ops.hint_gemm(self.db, a_mat, impl=self.cfg.impl)

    def answer(self, qu: jax.Array) -> jax.Array:
        """Online answer: D·qu mod 2^32.  qu: (n,) or (n, batch) uint32."""
        ans = ops.modmatmul(self.db, qu, impl=self.cfg.impl)
        if self.cfg.params.q_switch is not None:
            ans = lwe.switch_modulus(ans, self.cfg.params.q_switch)
        return ans


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientQueryState:
    secret: jax.Array            # s ∈ Z_q^k
    index: int                   # queried column (kept client-side!)


class PIRClient:
    """Client side: query formulation and response decoding."""

    def __init__(self, cfg: PIRConfig, hint: jax.Array):
        assert hint.shape == (cfg.m, cfg.params.k)
        self.cfg = cfg
        self.hint = hint
        self._a_mat = lwe.gen_public_matrix(cfg.a_seed, cfg.n, cfg.params.k)

    def query(self, key: jax.Array, index: int) -> tuple[jax.Array,
                                                          ClientQueryState]:
        """Encrypt a one-hot selector for column `index`."""
        k_sec, k_err = jax.random.split(key)
        s = lwe.keygen(k_sec, self.cfg.params)
        onehot = jnp.zeros((self.cfg.n,), U32).at[index].set(1)
        qu = lwe.encrypt_vector(k_err, s, self._a_mat, onehot,
                                self.cfg.params.delta, self.cfg.params.sigma)
        return qu, ClientQueryState(secret=s, index=index)

    def recover(self, ans: jax.Array, state: ClientQueryState) -> jax.Array:
        """Decode the server answer into the plaintext column (m,) u8."""
        p = self.cfg.params
        if p.q_switch is not None:
            vals = lwe.decode_switched(ans, self.hint, state.secret, p)
        else:
            rec = lwe.hint_strip(ans, self.hint, state.secret)
            vals = lwe.decode(rec, p)
        return vals.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Convenience: parameter selection for a corpus
# ---------------------------------------------------------------------------

def make_config(m: int, n: int, *, impl: str = "auto",
                q_switch: int | None = 1 << 16) -> PIRConfig:
    params = lwe.choose_params(n, want_p=256, q_switch=q_switch)
    return PIRConfig(m=m, n=n, params=params, impl=impl)


def server_flops(cfg: PIRConfig, batch: int = 1) -> int:
    """int8-MAC count of one online answer (limb-decomposed)."""
    return 2 * cfg.m * cfg.n * batch * lwe.Q_BITS // 8


def server_bytes(cfg: PIRConfig) -> int:
    """HBM traffic floor of one answer: the DB streamed once."""
    return cfg.m * cfg.n
