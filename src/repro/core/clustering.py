"""K-means clustering for the offline corpus-partitioning phase.

kmeans++ seeding + Lloyd iterations, fully in JAX (assignment is one GEMM per
iteration, so the same code shards over the corpus axis under pjit at scale).
A host-side *balanced* assignment pass is provided as a beyond-paper option:
PIR-RAG's downlink cost is `max_cluster_bytes`, so capping cluster occupancy
directly shrinks the dominant cost of the paper's own architecture.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class KMeansResult(NamedTuple):
    centroids: jax.Array      # (k, d) f32
    assignment: jax.Array     # (N,) i32
    inertia: jax.Array        # () f32, final mean squared distance


def pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """||x_i - c_j||² as a GEMM: (N, k)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    return x2 - 2.0 * (x @ c.T) + c2


def kmeanspp_init(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """D²-weighted seeding (Arthur & Vassilvitskii)."""
    n, d = x.shape

    k0, key = jax.random.split(key)
    first = x[jax.random.randint(k0, (), 0, n)]
    cents = jnp.zeros((k, d), x.dtype).at[0].set(first)
    mind2 = jnp.sum((x - first) ** 2, axis=1)

    def body(i, state):
        cents, mind2, key = state
        key, kc = jax.random.split(key)
        # sample ∝ D²; categorical over logits = log D²
        idx = jax.random.categorical(kc, jnp.log(mind2 + 1e-12))
        c_new = x[idx]
        cents = cents.at[i].set(c_new)
        mind2 = jnp.minimum(mind2, jnp.sum((x - c_new) ** 2, axis=1))
        return cents, mind2, key

    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, mind2, key))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_fit(key: jax.Array, x: jax.Array, *, k: int,
               iters: int = 25) -> KMeansResult:
    """kmeans++ init then `iters` Lloyd steps. Empty clusters keep centroids."""
    cents0 = kmeanspp_init(key, x, k)

    def lloyd(cents, _):
        d2 = pairwise_sqdist(x, cents)
        assign = jnp.argmin(d2, axis=1)
        one = jnp.ones((x.shape[0],), x.dtype)
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        cnts = jax.ops.segment_sum(one, assign, num_segments=k)
        new = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1)[:, None],
                        cents)
        inertia = jnp.mean(jnp.min(d2, axis=1))
        return new, inertia

    cents, inertias = jax.lax.scan(lloyd, cents0, None, length=iters)
    assign = jnp.argmin(pairwise_sqdist(x, cents), axis=1)
    return KMeansResult(cents, assign.astype(jnp.int32), inertias[-1])


def assign_to_centroids(x: jax.Array, cents: jax.Array,
                        *, impl: str = "xla") -> jax.Array:
    """Nearest-centroid assignment (the client-side cluster pick).

    impl="pallas" uses the fused distance+argmin kernel
    (kernels/kmeans_assign.py) — on TPU it avoids materializing the (N, K)
    distance matrix in HBM for corpus-scale assignment sweeps."""
    if impl == "pallas":
        from repro.kernels import ops
        return ops.kmeans_assign(x, cents, impl="pallas")[0]
    return jnp.argmin(pairwise_sqdist(x, cents), axis=1).astype(jnp.int32)


def balanced_assign(x: np.ndarray, cents: np.ndarray, cap: int,
                    batch: int = 65536) -> np.ndarray:
    """Greedy capacity-capped assignment (host-side, offline).

    Docs are visited in order of confidence (margin to their best centroid);
    a doc whose best cluster is full spills to the nearest non-full one.
    Bounds `max_cluster_bytes`, the PIR-RAG downlink driver.
    """
    n, k = x.shape[0], cents.shape[0]
    if cap * k < n:
        raise ValueError(f"cap {cap} × k {k} < N {n}")
    # distances in batches to bound memory
    d2 = np.empty((n, k), np.float32)
    for s in range(0, n, batch):
        xb = x[s:s + batch]
        d2[s:s + batch] = (
            (xb * xb).sum(1, keepdims=True) - 2 * xb @ cents.T
            + (cents * cents).sum(1)[None, :])
    best = d2.min(axis=1)
    order = np.argsort(best)          # most-confident docs claim slots first
    pref = np.argsort(d2, axis=1)     # per-doc centroid preference list
    counts = np.zeros(k, np.int64)
    out = np.full(n, -1, np.int32)
    for i in order:
        for j in pref[i]:
            if counts[j] < cap:
                out[i] = j
                counts[j] += 1
                break
    assert (out >= 0).all()
    return out
