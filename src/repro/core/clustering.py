"""K-means clustering for the offline corpus-partitioning phase.

kmeans++ seeding + Lloyd iterations, fully in JAX.  The implementation is
*block-canonical*: the corpus is split into ``n_blocks`` equal row blocks and
every reduction (centroid sums, counts, inertia) is computed per block and
combined in a pinned block order.  That makes the result a function of
``n_blocks`` alone, not of how the blocks are placed — the single-device path
(`kmeans_fit`) and the mesh path (`kmeans_fit_sharded`, blocks spread over the
corpus axis with `shard_map` building blocks in `distributed/collectives`)
execute the identical per-block programs and the identical fixed-order
combine, so a sharded offline build is **bit-identical** to the single-device
build (tested under the 8-fake-device harness in tests/test_sharded_build.py).

Why not `psum` for the centroid sums: float addition is non-associative and a
psum's reduction tree is backend-defined.  The sharded path instead
all-gathers the per-block partial sums (one collective per Lloyd iteration)
and reduces them locally in canonical block order — the same `(n_blocks, k,
d)` → `(k, d)` reduction the single-device path runs.

A host-side *balanced* assignment pass is provided as a beyond-paper option:
PIR-RAG's downlink cost is `max_cluster_bytes`, so capping cluster occupancy
directly shrinks the dominant cost of the paper's own architecture.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

#: Canonical number of corpus row blocks used by the offline build.  Bit
#: identity between single-device and sharded builds holds whenever both use
#: the same block count; `PirRagSystem.build` picks ``lcm(BUILD_BLOCKS, S)``
#: for S shards, so every mesh width dividing BUILD_BLOCKS (1, 2, 4, 8)
#: reproduces the unsharded build exactly.
BUILD_BLOCKS = 8


class KMeansResult(NamedTuple):
    centroids: jax.Array      # (k, d) f32
    assignment: jax.Array     # (N,) i32
    inertia: jax.Array        # () f32, final mean squared distance


def pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """||x_i - c_j||² as a GEMM.  x: (N, d) f32, c: (k, d) f32 → (N, k) f32."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    return x2 - 2.0 * (x @ c.T) + c2


def resolve_mesh_axes(mesh, mesh_axes=None) -> tuple[tuple[str, ...], int]:
    """(axes, shard count) for a mesh — the one axis-defaulting rule.

    Every sharded entry point (kmeans fit, assignment/distance sweeps, the
    build facade, PIRServer) resolves ``mesh_axes=None`` to all mesh axes
    through here, so axis defaulting and shard counting cannot drift apart
    between the build stages that must agree on the row layout.
    """
    axes = (tuple(mesh_axes) if mesh_axes is not None
            else tuple(mesh.axis_names))
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    return axes, shards


def _pad_rows_np(x: np.ndarray, mult: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side row pad to a multiple of ``mult``; (padded, valid mask)."""
    n = x.shape[0]
    pad = (-n) % mult
    xp = np.zeros((n + pad, x.shape[1]), np.float32)
    xp[:n] = np.asarray(x, np.float32)
    return xp, np.arange(n + pad) < n


# ---------------------------------------------------------------------------
# Block-canonical core (shared verbatim by the host and shard_map paths)
# ---------------------------------------------------------------------------

def _flat_axis_index(axis) -> jax.Array:
    """Row-major flat shard index across the (possibly tuple) mesh axes."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    idx = jnp.int32(0)
    for a in names:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def _gather_blocks(v: jax.Array, axis) -> jax.Array:
    """Identity on the host path; tiled all-gather along axis 0 on the mesh.

    Per-shard stacks of block partials concatenate in shard order, which is
    exactly canonical block order (each shard owns a contiguous block range).
    """
    if axis is None:
        return v
    return jax.lax.all_gather(v, axis, axis=0, tiled=True)


def _fetch_row(x: jax.Array, idx: jax.Array, axis) -> jax.Array:
    """Global row fetch x[idx].  Sharded: masked local gather + one psum.

    The psum adds exactly one non-zero contribution to all-zero ones, so it
    is exact in any reduction order — safe for the bit-identity contract.
    """
    if axis is None:
        return x[idx]
    rows = x.shape[0]
    lo = _flat_axis_index(axis) * rows
    li = idx - lo
    ok = (li >= 0) & (li < rows)
    row = jnp.where(ok, x[jnp.clip(li, 0, rows - 1)], 0.0)
    return jax.lax.psum(row, axis)


def _kmeanspp(key: jax.Array, x: jax.Array, valid: jax.Array, k: int,
              n: int, axis) -> jax.Array:
    """D²-weighted seeding (Arthur & Vassilvitskii) over the caller's rows.

    x: (rows, d) f32 — the full (padded) corpus on the host path, this
    shard's contiguous row slice under shard_map.  valid: (rows,) bool masks
    padding rows out of the D² distribution.  The categorical draw needs the
    global D² vector, so the sharded path all-gathers it once per step and
    every shard samples the identical index from the replicated key.
    """
    k0, key = jax.random.split(key)
    first = _fetch_row(x, jax.random.randint(k0, (), 0, n), axis)
    mind2 = jnp.sum((x - first) ** 2, axis=1)
    cents = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)

    def body(i, state):
        cents, mind2, key = state
        key, kc = jax.random.split(key)
        g = _gather_blocks(mind2, axis)
        gv = _gather_blocks(valid, axis)
        logits = jnp.where(gv, jnp.log(g + 1e-12), -jnp.inf)
        idx = jax.random.categorical(kc, logits)
        c_new = _fetch_row(x, idx, axis)
        cents = cents.at[i].set(c_new)
        mind2 = jnp.minimum(mind2, jnp.sum((x - c_new) ** 2, axis=1))
        return cents, mind2, key

    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, mind2, key))
    return cents


def _block_stats(xb: jax.Array, vb: jax.Array, cents: jax.Array, k: int,
                 impl: str):
    """One block's Lloyd partials: (sums (k, d), counts (k,), Σ min-d²).

    Assignment goes through `kernels.ops.kmeans_assign`, so the fused Pallas
    distance+argmin kernel serves both the host and the sharded build when
    ``impl`` routes to it.  Padding rows land in an overflow segment k that
    is sliced off, so they contribute nothing.
    """
    assign, mind2 = ops.kmeans_assign(xb, cents, impl=impl)
    seg = jnp.where(vb, assign, k)
    ones = jnp.where(vb, 1.0, 0.0).astype(xb.dtype)
    sums = jax.ops.segment_sum(xb, seg, num_segments=k + 1)[:k]
    cnts = jax.ops.segment_sum(ones, seg, num_segments=k + 1)[:k]
    w = jnp.sum(jnp.where(vb, mind2, 0.0))
    return sums, cnts, w


def _kmeans_core(key: jax.Array, x: jax.Array, valid: jax.Array, *, k: int,
                 iters: int, blocks: int, n: int, impl: str, axis=None):
    """kmeans++ then `iters` Lloyd steps over this caller's row slice.

    x: (rows, d) f32 with rows divisible by ``blocks`` (the LOCAL block
    count); valid: (rows,) bool.  ``axis`` names the shard_map corpus axis
    (None on the host path).  Returns (centroids (k, d) — identical on every
    shard, local assignment (rows,) i32, inertia ()).
    """
    rows, d = x.shape
    xb = x.reshape(blocks, rows // blocks, d)
    vb = valid.reshape(blocks, rows // blocks)
    cents0 = _kmeanspp(key, x, valid, k, n, axis)

    def lloyd(cents, _):
        sums, cnts, w = jax.lax.map(
            lambda t: _block_stats(t[0], t[1], cents, k, impl), (xb, vb))
        sums = _gather_blocks(sums, axis)      # (n_blocks, k, d) global order
        cnts = _gather_blocks(cnts, axis)
        w = _gather_blocks(w, axis)
        tot, cnt = jnp.sum(sums, axis=0), jnp.sum(cnts, axis=0)
        new = jnp.where(cnt[:, None] > 0,
                        tot / jnp.maximum(cnt, 1)[:, None], cents)
        return new, jnp.sum(w) / n

    cents, inertias = jax.lax.scan(lloyd, cents0, None, length=iters)
    assign = jax.lax.map(
        lambda b: ops.kmeans_assign(b, cents, impl=impl)[0], xb)
    return cents, assign.reshape(rows).astype(jnp.int32), inertias[-1]


@functools.partial(jax.jit,
                   static_argnames=("k", "iters", "blocks", "n", "impl"))
def _kmeans_fit_host(key, x, valid, *, k, iters, blocks, n, impl):
    return _kmeans_core(key, x, valid, k=k, iters=iters, blocks=blocks,
                        n=n, impl=impl, axis=None)


def _pad_rows(x: np.ndarray | jax.Array, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(jnp.asarray(x), ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return jnp.asarray(x), jnp.arange(n + pad) < n


def kmeans_fit(key: jax.Array, x: jax.Array, *, k: int, iters: int = 25,
               n_blocks: int = 1, impl: str = "xla") -> KMeansResult:
    """kmeans++ init then `iters` Lloyd steps.  Empty clusters keep centroids.

    x: (N, d) f32.  ``n_blocks`` picks the canonical reduction granularity
    (see module docstring); any fixed value gives a deterministic result, and
    matching `kmeans_fit_sharded`'s block count reproduces the sharded fit
    bit-for-bit.  ``impl`` dispatches the assignment kernel
    (`ops.kmeans_assign`): "xla" everywhere, "pallas"/"auto" for the fused
    TPU kernel.
    """
    xp, valid = _pad_rows(jnp.asarray(x, jnp.float32), n_blocks)
    cents, assign, inertia = _kmeans_fit_host(
        key, xp, valid, k=k, iters=iters, blocks=n_blocks,
        n=x.shape[0], impl=impl)
    return KMeansResult(cents, assign[: x.shape[0]], inertia)


def kmeans_fit_sharded(key: jax.Array, x: np.ndarray, *, k: int,
                       iters: int = 25, mesh, mesh_axes=None,
                       n_blocks: int | None = None,
                       impl: str = "xla") -> KMeansResult:
    """`kmeans_fit` with the corpus row-sharded over a device mesh.

    x: (N, d) f32 (host or device); rows are padded and placed P(axes, None)
    so each device owns a contiguous run of canonical blocks.  One
    all-gather of the per-block partials per Lloyd iteration (plus one per
    kmeans++ step) — see `distributed.collectives.corpus_shard_kmeans`.
    ``n_blocks`` defaults to ``lcm(BUILD_BLOCKS, shards)`` and must be a
    multiple of the shard count.  Bit-identical to
    ``kmeans_fit(..., n_blocks=same)`` on one device.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.distributed import collectives

    axes, shards = resolve_mesh_axes(mesh, mesh_axes)
    if n_blocks is None:
        n_blocks = math.lcm(BUILD_BLOCKS, shards)
    if n_blocks % shards:
        raise ValueError(f"n_blocks {n_blocks} not divisible by {shards} shards")

    n = x.shape[0]
    xp, valid = _pad_rows_np(x, n_blocks)
    xs = jax.device_put(xp, NamedSharding(mesh, PartitionSpec(axes, None)))
    vs = jax.device_put(valid, NamedSharding(mesh, PartitionSpec(axes)))
    fit = collectives.corpus_shard_kmeans(mesh, axes, k=k, iters=iters,
                                          n_blocks=n_blocks, n=n, impl=impl)
    cents, assign, inertia = fit(key, xs, vs)
    return KMeansResult(cents, assign[:n], inertia)


# ---------------------------------------------------------------------------
# Assignment sweeps
# ---------------------------------------------------------------------------

def assign_to_centroids(x: jax.Array, cents: jax.Array, *, impl: str = "xla",
                        mesh=None, mesh_axes=None) -> jax.Array:
    """Nearest-centroid assignment (the client-side cluster pick).  (N,) i32.

    impl="pallas" uses the fused distance+argmin kernel
    (kernels/kmeans_assign.py) — on TPU it avoids materializing the (N, K)
    distance matrix in HBM for corpus-scale assignment sweeps.  ``mesh=``
    row-shards the sweep (x P(axes, None), centroids replicated, zero
    collectives) through `collectives.row_shard_assign`, routing the same
    kernel per shard; assignment is row-local, so the result is bit-identical
    to the single-device sweep.
    """
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.distributed import collectives
        axes, shards = resolve_mesh_axes(mesh, mesh_axes)
        n = x.shape[0]
        xp, _ = _pad_rows(jnp.asarray(x, jnp.float32), shards)
        xs = jax.device_put(xp, NamedSharding(mesh, PartitionSpec(axes, None)))
        cr = jax.device_put(jnp.asarray(cents, jnp.float32),
                            NamedSharding(mesh, PartitionSpec()))
        fn = collectives.row_shard_assign(mesh, axes, impl=impl)
        return fn(xs, cr)[0][:n]
    if impl == "pallas":
        return ops.kmeans_assign(x, cents, impl="pallas")[0]
    return jnp.argmin(pairwise_sqdist(x, cents), axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("blocks",))
def _blocked_sqdist_host(x, cents, *, blocks):
    rows, d = x.shape
    xb = x.reshape(blocks, rows // blocks, d)
    return jax.lax.map(lambda b: pairwise_sqdist(b, cents), xb
                       ).reshape(rows, cents.shape[0])


def blocked_sqdist(x: np.ndarray, cents: np.ndarray, *,
                   n_blocks: int = BUILD_BLOCKS, mesh=None,
                   mesh_axes=None) -> jax.Array:
    """(N, k) f32 squared distances in canonical block order.

    The GEMM runs one (rows/n_blocks, d)·(d, k) block at a time, so the
    result is identical whether the blocks execute on one device (lax.map)
    or spread over a mesh (`collectives.row_shard_sqdist`) — the distance
    input `balanced_assign` needs to stay bit-stable across build layouts.
    """
    n = x.shape[0]
    if mesh is None:
        xp, _ = _pad_rows(jnp.asarray(x, jnp.float32), n_blocks)
        return _blocked_sqdist_host(xp, jnp.asarray(cents, jnp.float32),
                                    blocks=n_blocks)[:n]
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.distributed import collectives
    axes, shards = resolve_mesh_axes(mesh, mesh_axes)
    if n_blocks % shards:
        raise ValueError(f"n_blocks {n_blocks} not divisible by {shards} shards")
    xp, _ = _pad_rows_np(x, n_blocks)
    xs = jax.device_put(xp, NamedSharding(mesh, PartitionSpec(axes, None)))
    cr = jax.device_put(jnp.asarray(cents, jnp.float32),
                        NamedSharding(mesh, PartitionSpec()))
    fn = collectives.row_shard_sqdist(mesh, axes, n_blocks=n_blocks)
    return fn(xs, cr)[:n]


def balanced_assign(x: np.ndarray, cents: np.ndarray, cap: int,
                    batch: int = 65536, *,
                    d2: np.ndarray | None = None) -> np.ndarray:
    """Greedy capacity-capped assignment (host-side, offline).  (N,) i32.

    Docs are visited in order of confidence (margin to their best centroid);
    a doc whose best cluster is full spills to the nearest non-full one.
    Bounds `max_cluster_bytes`, the PIR-RAG downlink driver.

    ``d2`` (N, k) f32 overrides the internal batched numpy distance pass —
    the offline build supplies `blocked_sqdist` output here so the greedy
    walk sees bit-identical distances on every mesh layout (the walk itself
    is a deterministic function of d2 and input order).
    """
    n, k = x.shape[0], cents.shape[0]
    if cap * k < n:
        raise ValueError(f"cap {cap} × k {k} < N {n}")
    if d2 is None:
        # distances in batches to bound memory
        d2 = np.empty((n, k), np.float32)
        for s in range(0, n, batch):
            xb = x[s:s + batch]
            d2[s:s + batch] = (
                (xb * xb).sum(1, keepdims=True) - 2 * xb @ cents.T
                + (cents * cents).sum(1)[None, :])
    else:
        d2 = np.asarray(d2, np.float32)
        assert d2.shape == (n, k), (d2.shape, (n, k))
    best = d2.min(axis=1)
    order = np.argsort(best)          # most-confident docs claim slots first
    # Deferred acceptance with the confidence order as every cluster's
    # common priority: all free docs propose at once, each cluster keeps
    # its `cap` best-priority holders, losers re-propose next round.
    # Under a common strict priority this converges to EXACTLY the
    # sequential walk's assignment (serial dictatorship ≡ deferred
    # acceptance; regression-pinned against `_balanced_assign_walk` in
    # tests/test_clustering.py).  The walk's preference lists never
    # materialize: a full cluster's worst-held rank `thr[c]` only tightens
    # over rounds, so the set of clusters that could still accept rank r is
    # exactly {c : thr[c] >= r} — past rejectors are excluded for free —
    # and "first viable preference" is a masked argmin over d2.  That
    # replaces the old walk's full (N, k) argsort (its single most
    # expensive op) and its Python loop over N docs with one vectorized
    # argmin per round over the shrinking free set.  Exact distance ties
    # break lowest-cluster-first (argmin's first-occurrence rule — the
    # stable preference order).
    d2r = d2[order]                   # rank-major distances (rank r = row r)
    free = np.arange(n)               # ranks still proposing (all, initially)
    held: list[np.ndarray] = [np.empty(0, np.int64) for _ in range(k)]
    thr = np.full(k, n, np.int64)     # full cluster's worst held rank
    while free.size:
        sub = d2r if free.size == n else d2r[free]
        if (thr == n).all():          # nothing full yet (always round 1)
            props = sub.argmin(1)
        else:
            masked = np.where(thr[None, :] >= free[:, None], sub, np.inf)
            props = masked.argmin(1)
            # cap·k ≥ n ⇒ some cluster is below cap (thr = n) and viable
            assert np.isfinite(
                masked[np.arange(free.size), props]).all()
        srt = np.lexsort((free, props))       # by cluster, then priority
        f, p = free[srt], props[srt]
        bounds = np.flatnonzero(np.diff(p)) + 1
        rejected: list[np.ndarray] = []
        for c, g in zip(p[np.concatenate(([0], bounds))],
                        np.split(f, bounds)):
            merged = np.sort(np.concatenate((held[c], g)))
            held[c] = merged[:cap]
            if merged.size >= cap:
                thr[c] = held[c][-1]
            if merged.size > cap:
                rejected.append(merged[cap:])
        free = (np.concatenate(rejected) if rejected
                else np.empty(0, np.int64))
    out = np.full(n, -1, np.int32)
    for c in range(k):
        out[order[held[c]]] = c
    assert (out >= 0).all()
    return out


def _balanced_assign_walk(x: np.ndarray, cents: np.ndarray, cap: int,
                          batch: int = 65536, *,
                          d2: np.ndarray | None = None) -> np.ndarray:
    """The original O(N·k) sequential greedy walk `balanced_assign` replaced.

    Kept as the behavioural reference: the vectorized deferred-acceptance
    implementation must produce identical assignments on identical inputs
    (the equality regression in tests/test_clustering.py), since packed
    columns — and therefore hints, queries and answers — depend on it
    byte-for-byte.
    """
    n, k = x.shape[0], cents.shape[0]
    if cap * k < n:
        raise ValueError(f"cap {cap} × k {k} < N {n}")
    if d2 is None:
        d2 = np.empty((n, k), np.float32)
        for s in range(0, n, batch):
            xb = x[s:s + batch]
            d2[s:s + batch] = (
                (xb * xb).sum(1, keepdims=True) - 2 * xb @ cents.T
                + (cents * cents).sum(1)[None, :])
    else:
        d2 = np.asarray(d2, np.float32)
        assert d2.shape == (n, k), (d2.shape, (n, k))
    best = d2.min(axis=1)
    order = np.argsort(best)
    # kind="stable" pins the preference order on exact distance ties to
    # lowest-cluster-first — the tie-break argmin gives for free — where the
    # original quicksort left it unspecified (and numpy-version-dependent).
    pref = np.argsort(d2, axis=1, kind="stable")
    counts = np.zeros(k, np.int64)
    out = np.full(n, -1, np.int32)
    for i in order:
        for j in pref[i]:
            if counts[j] < cap:
                out[i] = j
                counts[j] += 1
                break
    assert (out >= 0).all()
    return out
