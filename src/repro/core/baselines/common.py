"""Shared machinery for the baseline private-search architectures.

Two pieces both baselines (and the RAG-ready accounting) need:

* ``DocContentPIR`` — a per-document PIR database (one column per doc).  This
  is the "retrieve-THEN-fetch" tail the paper charges to Graph-PIR and
  Tiptoe: after they produce ids, each document's content still costs one
  PIR query here.  PIR-RAG avoids it by construction.
* Signed low-bit embedding quantization with offset correction, so encrypted
  inner products run through the same u8×u32 modular-GEMM kernel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunking, lwe, pir


# ---------------------------------------------------------------------------
# Low-bit signed quantization for homomorphic scoring
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantScheme:
    """Symmetric signed quantizer stored as shifted-unsigned for the u8 kernel.

    value v → round(v / scale) ∈ [−levels, levels], stored as +levels.
    The inner product of two shifted vectors expands to
        Σ(d+L)(q+L) = Σ d·q + L·Σd + L·Σq + d_dim·L²
    so the client (which knows Σq) removes the offsets given the public
    per-doc row sums Σd — doc-side constants that reveal nothing about a
    query.
    """
    levels: int            # e.g. 15 → 5-bit signed
    scale: float

    def quantize(self, v: np.ndarray) -> np.ndarray:
        q = np.clip(np.round(v / self.scale), -self.levels, self.levels)
        return (q + self.levels).astype(np.uint8)

    def unshift(self, stored: np.ndarray) -> np.ndarray:
        return stored.astype(np.int64) - self.levels


def fit_quant(embs: np.ndarray, levels: int) -> QuantScheme:
    amax = float(np.abs(embs).max()) or 1.0
    return QuantScheme(levels=levels, scale=amax / levels)


# ---------------------------------------------------------------------------
# Encrypted-embedding query (the Tiptoe-style uplink; also reused by tests)
# ---------------------------------------------------------------------------

def encrypt_embedding(key: jax.Array, q_shifted: np.ndarray,
                      params: lwe.LWEParams, a_mat: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """LWE-encrypt a shifted-unsigned quantized embedding, coordinate-wise."""
    k_sec, k_err = jax.random.split(key)
    s = lwe.keygen(k_sec, params)
    msg = jnp.asarray(q_shifted.astype(np.uint32))
    ct = lwe.encrypt_vector(k_err, s, a_mat, msg, params.delta, params.sigma)
    return ct, s


# ---------------------------------------------------------------------------
# Per-document content PIR (the expensive tail of retrieve-then-fetch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DocContentPIR:
    """One PIR column per document; fetching doc i = PIR query for column i."""
    cfg: pir.PIRConfig
    server: pir.PIRServer
    hint: jax.Array
    emb_dim: int

    @classmethod
    def build(cls, texts, embeddings: np.ndarray, *, impl: str = "xla",
              chunk_size: int = 64) -> "DocContentPIR":
        n_docs, emb_dim = embeddings.shape
        recs = [chunking.serialize_doc(i, embeddings[i], texts[i])
                for i in range(n_docs)]
        raw = max(len(r) for r in recs)
        m = ((raw + chunk_size - 1) // chunk_size) * chunk_size
        mat = np.zeros((m, n_docs), np.uint8)
        for i, r in enumerate(recs):
            mat[:len(r), i] = np.frombuffer(r, np.uint8)
        cfg = pir.make_config(m, n_docs, impl=impl)
        server = pir.PIRServer(cfg, jnp.asarray(mat))
        hint = server.setup()
        return cls(cfg=cfg, server=server, hint=hint, emb_dim=emb_dim)

    def fetch(self, key: jax.Array, doc_id: int
              ) -> tuple[int, np.ndarray, bytes]:
        client = pir.PIRClient(self.cfg, self.hint)
        qu, state = client.query(key, doc_id)
        ans = self.server.answer(qu)
        col = np.asarray(client.recover(ans, state))
        buf = col.tobytes()
        did = int(np.frombuffer(buf[:4], np.uint32)[0])
        tlen = int(np.frombuffer(buf[4:8], np.uint32)[0])
        scale = float(np.frombuffer(buf[8:12], np.float32)[0])
        off = float(np.frombuffer(buf[12:16], np.float32)[0])
        qv = np.frombuffer(buf[16:16 + self.emb_dim], np.uint8)
        text = buf[16 + self.emb_dim:16 + self.emb_dim + tlen]
        return did, chunking.dequantize_embedding(qv, scale, off), text

    def fetch_many(self, seed: int, doc_ids) -> list[tuple[int, np.ndarray,
                                                           bytes]]:
        """K sequential private fetches — the retrieve-then-fetch tail cost."""
        return [self.fetch(jax.random.PRNGKey(seed * 9973 + t), int(d))
                for t, d in enumerate(doc_ids)]

    @property
    def per_fetch_uplink(self) -> int:
        return self.cfg.uplink_bytes

    @property
    def per_fetch_downlink(self) -> int:
        return self.cfg.downlink_bytes
