"""Tiptoe-style private-scoring baseline (paper §4.1 baseline 2).

Clustered corpus; the client picks a cluster from public centroids and sends
(a) the cluster id IN THE CLEAR — the documented leak of this architecture —
and (b) its query embedding LWE-encrypted coordinate-wise, quantized to a few
signed bits.  The server homomorphically computes similarity scores for every
document in that cluster (one u8×u32 GEMV through the same modular kernel)
and returns ONLY encrypted scores.  The client decrypts, ranks, and — for a
RAG workflow — still owes K private content fetches (``DocContentPIR``).

Why its quality trails (paper Fig. 3, NDCG 0.513): homomorphic scoring must
fit `Σ d_i·q_i` inside the plaintext modulus *after* LWE noise, forcing
coarse (≈5-bit) embedding quantization server-side.  We reproduce that
mechanism rather than hard-coding the number.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering, lwe
from repro.core.baselines import common
from repro.kernels import ops


@dataclasses.dataclass
class TiptoeStats:
    uplink_bytes: int
    downlink_bytes: int
    server_ms: float
    cluster_index: int            # visible to the server (the leak)


@dataclasses.dataclass
class TiptoeSystem:
    centroids: np.ndarray                     # public
    params: lwe.LWEParams
    quant: common.QuantScheme
    cluster_mats: list[np.ndarray]            # per-cluster (n_docs_c, d) u8
    cluster_doc_ids: list[np.ndarray]
    cluster_rowsums: list[np.ndarray]         # public Σd per doc (offset corr.)
    hints: list[jax.Array]                    # per-cluster D_c · A
    a_seed: int
    emb_dim: int
    setup_seconds: float
    impl: str = "xla"

    # -- offline --------------------------------------------------------------

    @classmethod
    def build(cls, embeddings: np.ndarray, *, n_clusters: int,
              levels: int = 15, kmeans_iters: int = 25, seed: int = 0,
              impl: str = "xla") -> "TiptoeSystem":
        t0 = time.perf_counter()
        n, d = embeddings.shape
        km = clustering.kmeans_fit(jax.random.PRNGKey(seed),
                                   jnp.asarray(embeddings, jnp.float32),
                                   k=n_clusters, iters=kmeans_iters)
        cents, assign = np.asarray(km.centroids), np.asarray(km.assignment)

        # Plaintext modulus is capped at 2^16 (kernel: u8 DB entries, here
        # ≤ 2·levels).  Shifted-unsigned products reach d·(2L)² and LWE noise
        # adds z·σ·√d·(2L); shrink L until both fit — this is exactly the
        # quantization coarsening that costs Tiptoe its ranking quality.
        p = 1 << 16
        params = lwe.LWEParams(p=p, q_switch=None)
        L = levels
        while L > 1:
            vmax = d * (2 * L) ** 2
            noise = params.z_tail * params.sigma * np.sqrt(d) * (2 * L)
            if vmax < p and noise < lwe.Q / (2 * p):
                break
            L -= 1
        else:
            raise ValueError("no feasible tiptoe quantization")
        levels = L
        quant = common.fit_quant(embeddings, levels)

        mats, ids, rowsums, hints = [], [], [], []
        a_seed = seed + 101
        a_mat = lwe.gen_public_matrix(a_seed, d, params.k)
        for j in range(n_clusters):
            members = np.nonzero(assign == j)[0]
            dq = quant.quantize(embeddings[members]) if len(members) else \
                np.zeros((0, d), np.uint8)
            mats.append(dq)
            ids.append(members.astype(np.int64))
            rowsums.append(dq.astype(np.int64).sum(axis=1))
            if len(members):
                hints.append(ops.hint_gemm(jnp.asarray(dq), a_mat, impl=impl))
            else:
                hints.append(jnp.zeros((0, params.k), jnp.uint32))
        return cls(centroids=cents, params=params, quant=quant,
                   cluster_mats=mats, cluster_doc_ids=ids,
                   cluster_rowsums=rowsums, hints=hints, a_seed=a_seed,
                   emb_dim=d, setup_seconds=time.perf_counter() - t0,
                   impl=impl)

    # -- online ---------------------------------------------------------------

    def search(self, query_emb: np.ndarray, *, top_k: int = 10,
               key: jax.Array | None = None
               ) -> tuple[np.ndarray, TiptoeStats]:
        key = key if key is not None else jax.random.PRNGKey(0)
        cl = int(clustering.assign_to_centroids(
            jnp.asarray(query_emb, jnp.float32)[None],
            jnp.asarray(self.centroids))[0])
        dmat = self.cluster_mats[cl]
        if dmat.shape[0] == 0:
            return np.zeros(0, np.int64), TiptoeStats(0, 0, 0.0, cl)

        # client: encrypt shifted-unsigned quantized query
        q_shift = self.quant.quantize(query_emb.astype(np.float32))
        a_mat = lwe.gen_public_matrix(self.a_seed, self.emb_dim,
                                      self.params.k)
        ct, s = common.encrypt_embedding(key, q_shift, self.params, a_mat)

        # server: encrypted scores for every doc in the (known) cluster
        t0 = time.perf_counter()
        ans = jax.block_until_ready(
            ops.modmatmul(jnp.asarray(dmat), ct, impl=self.impl))
        server_ms = 1e3 * (time.perf_counter() - t0)

        # client: decrypt, de-offset, rank
        rec = lwe.hint_strip(ans, self.hints[cl], s)
        raw = np.asarray(lwe.decode(rec, self.params)).astype(np.int64)
        half = self.params.p // 2
        raw = np.where(raw >= half, raw - self.params.p, raw)   # center mod p
        L = self.quant.levels
        sum_q = int(q_shift.astype(np.int64).sum())
        # Σ(d+L)(q+L) = Σdq + L·Σd + L·Σq + dim·L²  →  Σdq =
        scores = (raw - L * self.cluster_rowsums[cl] - L * sum_q
                  - self.emb_dim * L * L)
        order = np.argsort(-scores)[:top_k]
        ids = self.cluster_doc_ids[cl][order]
        stats = TiptoeStats(
            uplink_bytes=self.emb_dim * 4,
            downlink_bytes=int(dmat.shape[0]) * 4,
            server_ms=server_ms, cluster_index=cl)
        return ids, stats
