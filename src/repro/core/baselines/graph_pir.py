"""Graph-PIR baseline (PACMANN-inspired, paper §4.1 baseline 1).

A k-NN similarity graph is built over the document embeddings; retrieval is a
private best-first beam traversal.  At every hop the client PIR-fetches the
*records* (quantized embedding + adjacency list) of the beam's unvisited
candidates — batched into one server GEMM per hop — scores them locally, and
expands.  The server sees only pseudorandom query vectors, never which nodes
are walked.

Trade-off profile (reproduced in benchmarks/):
  + best search quality (fine-grained traversal, not confined to one cluster)
  + query time ~flat in corpus size (hops × record fetch)
  − heavy one-time graph build, hint scales with n_docs
  − returns IDs: RAG still owes K content fetches (DocContentPIR).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pir
from repro.core.baselines import common


@dataclasses.dataclass
class GraphPIRStats:
    hops: int
    uplink_bytes: int
    downlink_bytes: int
    fetched_nodes: int
    server_ms: float


def build_knn_graph(embs: np.ndarray, k: int) -> np.ndarray:
    """Exact k-NN adjacency (n, k) by cosine; brute force at bench scales."""
    n = embs.shape[0]
    nn = embs / (np.linalg.norm(embs, axis=1, keepdims=True) + 1e-12)
    sims = nn @ nn.T
    np.fill_diagonal(sims, -np.inf)
    return np.argsort(-sims, axis=1)[:, :k].astype(np.uint32)


def build_nav_graph(embs: np.ndarray, k: int, n_random: int,
                    seed: int = 0) -> np.ndarray:
    """k-NN edges + NSW-style random long links for navigability.

    Pure k-NN graphs fragment across topic clusters; a few uniform long-range
    edges per node (small-world construction) make greedy traversal reach any
    region — the same reason HNSW keeps upper layers.
    """
    n = embs.shape[0]
    knn = build_knn_graph(embs, k)
    rng = np.random.default_rng(seed)
    rand = rng.integers(0, n, (n, n_random), dtype=np.uint32)
    return np.concatenate([knn, rand], axis=1)


def _serialize_node(emb: np.ndarray, nbrs: np.ndarray) -> bytes:
    from repro.core.chunking import quantize_embedding
    q, scale, off = quantize_embedding(emb)
    return (np.float32(scale).tobytes() + np.float32(off).tobytes()
            + q.tobytes() + nbrs.astype(np.uint32).tobytes())


@dataclasses.dataclass
class GraphPIRSystem:
    cfg: pir.PIRConfig
    server: pir.PIRServer
    hint: jax.Array
    entry_points: np.ndarray      # public medoid ids
    emb_dim: int
    graph_degree: int
    setup_seconds: float
    n_docs: int
    index_seconds: float = 0.0    # graph construction (no crypto)
    hint_seconds: float = 0.0

    @classmethod
    def build(cls, embeddings: np.ndarray, *, degree: int = 12,
              n_random: int = 4, n_entry: int = 8, impl: str = "xla",
              seed: int = 0) -> "GraphPIRSystem":
        t0 = time.perf_counter()
        n, d = embeddings.shape
        graph = build_nav_graph(embeddings, degree, n_random, seed=seed)
        total_deg = degree + n_random
        recs = [_serialize_node(embeddings[i], graph[i]) for i in range(n)]
        m = len(recs[0])
        mat = np.zeros((m, n), np.uint8)
        for i, r in enumerate(recs):
            mat[:, i] = np.frombuffer(r, np.uint8)
        cfg = pir.make_config(m, n, impl=impl)
        server = pir.PIRServer(cfg, jnp.asarray(mat))
        t_index = time.perf_counter()
        hint = jax.block_until_ready(server.setup())
        t_hint_done = time.perf_counter()
        # entry points: medoids of a coarse k-means (spread over the corpus)
        from repro.core import clustering
        km = clustering.kmeans_fit(jax.random.PRNGKey(seed),
                                   jnp.asarray(embeddings, jnp.float32),
                                   k=min(n_entry, n), iters=8)
        d2 = np.asarray(clustering.pairwise_sqdist(
            jnp.asarray(embeddings, jnp.float32), km.centroids))
        entries = np.unique(d2.argmin(axis=0))
        return cls(cfg=cfg, server=server, hint=hint,
                   entry_points=entries.astype(np.int64), emb_dim=d,
                   graph_degree=total_deg,
                   setup_seconds=time.perf_counter() - t0, n_docs=n,
                   index_seconds=t_index - t0,
                   hint_seconds=t_hint_done - t_index)

    def _decode_node(self, col: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.core.chunking import dequantize_embedding
        buf = col.tobytes()
        scale = float(np.frombuffer(buf[0:4], np.float32)[0])
        off = float(np.frombuffer(buf[4:8], np.float32)[0])
        q = np.frombuffer(buf[8:8 + self.emb_dim], np.uint8)
        nbrs = np.frombuffer(
            buf[8 + self.emb_dim:8 + self.emb_dim + 4 * self.graph_degree],
            np.uint32)
        return dequantize_embedding(q, scale, off), nbrs

    def search(self, query_emb: np.ndarray, *, top_k: int = 10,
               beam: int = 8, max_hops: int = 6, seed: int = 0
               ) -> tuple[np.ndarray, GraphPIRStats]:
        """Private best-first traversal; one batched PIR fetch per hop."""
        client = pir.PIRClient(self.cfg, self.hint)
        qn = query_emb / (np.linalg.norm(query_emb) + 1e-12)

        scored: dict[int, float] = {}
        nbrs_of: dict[int, np.ndarray] = {}
        frontier = list(dict.fromkeys(int(e) for e in self.entry_points))
        up = down = fetched = 0
        server_ms = 0.0
        hops = 0
        for hop in range(max_hops):
            cand = [c for c in frontier if c not in scored][:beam]
            if not cand:
                break
            hops += 1
            qs, states = [], []
            for t, node in enumerate(cand):
                qu, st = client.query(
                    jax.random.PRNGKey(seed * 31337 + hop * 97 + t), node)
                qs.append(qu)
                states.append(st)
            t0 = time.perf_counter()
            ans = jax.block_until_ready(self.server.answer(
                jnp.stack(qs, axis=1)))
            server_ms += 1e3 * (time.perf_counter() - t0)
            up += len(cand) * self.cfg.uplink_bytes
            down += len(cand) * self.cfg.downlink_bytes
            fetched += len(cand)

            for j, (node, st) in enumerate(zip(cand, states)):
                col = np.asarray(client.recover(ans[:, j], st))
                emb, nbrs = self._decode_node(col)
                scored[node] = float(
                    emb @ qn / (np.linalg.norm(emb) + 1e-12))
                nbrs_of[node] = nbrs
            # best-first expansion: next frontier = unvisited neighbours of
            # the best `beam` nodes scored so far, in score order
            best = sorted(scored, key=lambda n: -scored[n])[:beam]
            frontier = [int(x) for n in best for x in nbrs_of[n]
                        if int(x) not in scored]
        ids = np.array(sorted(scored, key=lambda n: -scored[n])[:top_k],
                       np.int64)
        return ids, GraphPIRStats(hops=hops, uplink_bytes=up,
                                  downlink_bytes=down, fetched_nodes=fetched,
                                  server_ms=server_ms)
