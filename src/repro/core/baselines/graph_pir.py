"""Graph-PIR baseline (PACMANN-inspired, paper §4.1 baseline 1).

A k-NN similarity graph is built over the document embeddings; retrieval is a
private best-first beam traversal.  At every hop the client PIR-fetches the
*records* (quantized embedding + adjacency list) of its best unvisited
candidates — batched into one server GEMM per hop — scores them locally, and
expands.  The server sees only pseudorandom query vectors, never which nodes
are walked.

Candidate ranking is the crux of private traversal: a neighbour's embedding
is unknown until fetched, so a naive walk ranks candidates by their parent's
score — "blind greedy", which both dead-ends (when the current best beam's
neighbourhoods are exhausted the walk stops with promising candidates still
unvisited) and wastes its fetch budget circling the entry region.  Instead,
each node record carries a compact SimHash *sketch* (64 sign bits of a
public random projection) of every neighbour — the same trick DiskANN uses
with PQ codes — so the client ranks the candidate pool by each candidate's
OWN estimated similarity before spending a PIR fetch on it.  Sketches ride
inside the PIR-fetched records and the projection is public, so the server's
view is unchanged.

Trade-off profile (reproduced in benchmarks/):
  + best search quality (fine-grained traversal, not confined to one cluster)
  + query time ~flat in corpus size (hops × record fetch)
  − heavy one-time graph build, hint scales with n_docs
  − returns IDs: RAG still owes K content fetches (DocContentPIR).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pir
from repro.core.baselines import common


@dataclasses.dataclass
class GraphPIRStats:
    hops: int
    uplink_bytes: int
    downlink_bytes: int
    fetched_nodes: int
    server_ms: float


def build_knn_graph(embs: np.ndarray, k: int) -> np.ndarray:
    """Exact k-NN adjacency (n, k) by cosine; brute force at bench scales."""
    n = embs.shape[0]
    nn = embs / (np.linalg.norm(embs, axis=1, keepdims=True) + 1e-12)
    sims = nn @ nn.T
    np.fill_diagonal(sims, -np.inf)
    return np.argsort(-sims, axis=1)[:, :k].astype(np.uint32)


def build_nav_graph(embs: np.ndarray, k: int, n_random: int,
                    seed: int = 0) -> np.ndarray:
    """k-NN edges + NSW-style random long links for navigability.

    Pure k-NN graphs fragment across topic clusters; a few uniform long-range
    edges per node (small-world construction) make greedy traversal reach any
    region — the same reason HNSW keeps upper layers.
    """
    n = embs.shape[0]
    knn = build_knn_graph(embs, k)
    rng = np.random.default_rng(seed)
    rand = rng.integers(0, n, (n, n_random), dtype=np.uint32)
    return np.concatenate([knn, rand], axis=1)


_SKETCH_BITS = 64               # default SimHash sign bits per node (8 B)


def sketch_matrix(seed: int, d: int, bits: int = _SKETCH_BITS) -> np.ndarray:
    """Public random projection for the navigation sketches (client+server
    derive it from a shared seed, like the LWE matrix A).  `bits` sets the
    sketch width: wider sketches estimate cosine similarity more tightly
    but inflate every node record by `degree · bits/8` bytes — the tuning
    surface benchmarks/graph_bench.py sweeps."""
    assert bits % 8 == 0 and bits > 0, bits
    return np.random.default_rng(seed ^ 0x51E7C4).standard_normal(
        (bits, d)).astype(np.float32)


def embed_sketches(embs: np.ndarray, proj: np.ndarray) -> np.ndarray:
    """(n, d) embeddings → (n, 8) uint8 packed sign bits of proj·emb."""
    nn = embs / (np.linalg.norm(embs, axis=1, keepdims=True) + 1e-12)
    bits = (nn @ proj.T) > 0
    return np.packbits(bits, axis=1)


def _serialize_node(emb: np.ndarray, nbrs: np.ndarray,
                    nbr_sketches: np.ndarray) -> bytes:
    from repro.core.chunking import quantize_embedding
    q, scale, off = quantize_embedding(emb)
    return (np.float32(scale).tobytes() + np.float32(off).tobytes()
            + q.tobytes() + nbrs.astype(np.uint32).tobytes()
            + nbr_sketches.astype(np.uint8).tobytes())


@dataclasses.dataclass
class GraphPIRSystem:
    cfg: pir.PIRConfig
    server: pir.PIRServer
    hint: jax.Array
    entry_points: np.ndarray      # public medoid ids
    emb_dim: int
    graph_degree: int
    setup_seconds: float
    n_docs: int
    index_seconds: float = 0.0    # graph construction (no crypto)
    hint_seconds: float = 0.0
    sketch_seed: int = 0          # public seed of the navigation projection
    sketch_bits: int = _SKETCH_BITS   # SimHash width carried per neighbour

    @classmethod
    def build(cls, embeddings: np.ndarray, *, degree: int = 12,
              n_random: int = 4, n_entry: int = 8, impl: str = "xla",
              seed: int = 0, sketch_bits: int = _SKETCH_BITS
              ) -> "GraphPIRSystem":
        t0 = time.perf_counter()
        n, d = embeddings.shape
        graph = build_nav_graph(embeddings, degree, n_random, seed=seed)
        total_deg = degree + n_random
        sketches = embed_sketches(embeddings,
                                  sketch_matrix(seed, d, sketch_bits))
        recs = [_serialize_node(embeddings[i], graph[i], sketches[graph[i]])
                for i in range(n)]
        m = len(recs[0])
        mat = np.zeros((m, n), np.uint8)
        for i, r in enumerate(recs):
            mat[:, i] = np.frombuffer(r, np.uint8)
        cfg = pir.make_config(m, n, impl=impl)
        server = pir.PIRServer(cfg, jnp.asarray(mat))
        t_index = time.perf_counter()
        hint = jax.block_until_ready(server.setup())
        t_hint_done = time.perf_counter()
        # entry points: medoids of a coarse k-means (spread over the corpus)
        from repro.core import clustering
        km = clustering.kmeans_fit(jax.random.PRNGKey(seed),
                                   jnp.asarray(embeddings, jnp.float32),
                                   k=min(n_entry, n), iters=8)
        d2 = np.asarray(clustering.pairwise_sqdist(
            jnp.asarray(embeddings, jnp.float32), km.centroids))
        entries = np.unique(d2.argmin(axis=0))
        return cls(cfg=cfg, server=server, hint=hint,
                   entry_points=entries.astype(np.int64), emb_dim=d,
                   graph_degree=total_deg,
                   setup_seconds=time.perf_counter() - t0, n_docs=n,
                   index_seconds=t_index - t0,
                   hint_seconds=t_hint_done - t_index, sketch_seed=seed,
                   sketch_bits=sketch_bits)

    def _decode_node(self, col: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """→ (embedding (d,), neighbour ids (deg,), sketches (deg, 8) u8)."""
        from repro.core.chunking import dequantize_embedding
        buf = col.tobytes()
        scale = float(np.frombuffer(buf[0:4], np.float32)[0])
        off = float(np.frombuffer(buf[4:8], np.float32)[0])
        q = np.frombuffer(buf[8:8 + self.emb_dim], np.uint8)
        ofs = 8 + self.emb_dim
        nbrs = np.frombuffer(buf[ofs:ofs + 4 * self.graph_degree], np.uint32)
        ofs += 4 * self.graph_degree
        sk = np.frombuffer(
            buf[ofs:ofs + (self.sketch_bits // 8) * self.graph_degree],
            np.uint8).reshape(self.graph_degree, self.sketch_bits // 8)
        return dequantize_embedding(q, scale, off), nbrs, sk

    def search(self, query_emb: np.ndarray, *, top_k: int = 10,
               beam: int = 8, max_hops: int = 6, seed: int = 0
               ) -> tuple[np.ndarray, GraphPIRStats]:
        """Private best-first traversal; one batched PIR fetch per hop.

        The candidate pool persists across hops (no dead ends: a hop that
        exhausts one region backtracks to the best unvisited candidate seen
        anywhere), is deduplicated (a node never spends two fetch slots),
        and is ranked by each candidate's own sketch similarity to the
        query rather than its parent's score, so the walk crosses low-score
        regions and long-range links whenever the sketches say the far side
        looks better.
        """
        client = pir.PIRClient(self.cfg, self.hint)
        qn = query_emb / (np.linalg.norm(query_emb) + 1e-12)
        proj = sketch_matrix(self.sketch_seed, self.emb_dim,
                             self.sketch_bits)
        qbits = np.unpackbits(embed_sketches(qn[None, :], proj)[0])

        def sketch_sim(packed: np.ndarray) -> float:
            """Fraction of agreeing sign bits ≈ 1 − angle/π (SimHash)."""
            return float(
                (np.unpackbits(packed) == qbits).mean())

        scored: dict[int, float] = {}
        # pool: candidate → own estimated similarity; entries are fetched
        # first regardless (their sketches are unknown until decoded)
        pool: dict[int, float] = {int(e): float("inf")
                                  for e in self.entry_points}
        up = down = fetched = 0
        server_ms = 0.0
        hops = 0
        for hop in range(max_hops):
            cand = sorted(pool, key=lambda c: -pool[c])[:beam]
            if not cand:
                break
            hops += 1
            qs, states = [], []
            for t, node in enumerate(cand):
                qu, st = client.query(
                    jax.random.PRNGKey(seed * 31337 + hop * 97 + t), node)
                qs.append(qu)
                states.append(st)
            t0 = time.perf_counter()
            ans = jax.block_until_ready(self.server.answer(
                jnp.stack(qs, axis=1)))
            server_ms += 1e3 * (time.perf_counter() - t0)
            up += len(cand) * self.cfg.uplink_bytes
            down += len(cand) * self.cfg.downlink_bytes
            fetched += len(cand)

            for j, (node, st) in enumerate(zip(cand, states)):
                col = np.asarray(client.recover(ans[:, j], st))
                emb, nbrs, sketches = self._decode_node(col)
                scored[node] = float(
                    emb @ qn / (np.linalg.norm(emb) + 1e-12))
                pool.pop(node, None)
                for x, sk in zip(nbrs, sketches):
                    x = int(x)
                    if x not in scored and x not in pool:
                        pool[x] = sketch_sim(sk)
        ids = np.array(sorted(scored, key=lambda n: -scored[n])[:top_k],
                       np.int64)
        return ids, GraphPIRStats(hops=hops, uplink_bytes=up,
                                  downlink_bytes=down, fetched_nodes=fetched,
                                  server_ms=server_ms)
