"""Regev-style LWE linearly-homomorphic encryption over Z_{2^32}.

This is the lattice primitive underneath PIR-RAG's SimplePIR-style protocol
(Henzinger et al., USENIX Sec'23).  All ciphertext arithmetic is uint32 with
wraparound, i.e. the ciphertext modulus is q = 2^32 *implicitly* — XLA integer
ops are modular, so ``jnp.matmul`` on uint32 computes exactly mod q (verified
bitwise in tests/test_lwe.py).

Scheme (secret dim k, plaintext modulus p, Δ = q // p, error σ):

    A  ~ U(Z_q^{n×k})            public, derived from a shared seed
    s  ~ U(Z_q^k)                secret
    e  ~ round(N(0, σ²))^n       fresh per query
    ct = A·s + e + Δ·msg         (n,) uint32, msg ∈ Z_p^n

The server's homomorphic op is a plaintext matrix product D·ct which the
client strips with the hint H = D·A:

    D·ct − H·s = D·e + Δ·(D·msg)      → round to recover D·msg  (mod p)

Security point (k=1024, q=2^32, σ=6.4) is the standard ≈128-bit SimplePIR /
Tiptoe parameterization; we take it as given rather than re-running a lattice
estimator.  Correctness margins ARE re-derived here (`noise_budget_ok`).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

U32 = jnp.uint32
Q_BITS = 32
Q = 1 << Q_BITS  # ciphertext modulus (implicit via uint32 wraparound)


@dataclasses.dataclass(frozen=True)
class LWEParams:
    """Parameters of the LWE scheme.

    k:        secret dimension.
    p:        plaintext modulus (DB entries live in Z_p; p ≤ 2^16).
    sigma:    gaussian error std-dev.
    z_tail:   tail factor for correctness bound (≈ erfc⁻¹ based; 6 ⇒ ~2^-29
              per-coefficient failure).
    q_switch: response modulus for downlink modulus switching (None = off).
              2^16 halves the response vs raw q = 2^32.
    """

    k: int = 1024
    p: int = 256
    sigma: float = 6.4
    z_tail: float = 6.0
    q_switch: int | None = 1 << 16

    @property
    def delta(self) -> int:
        return Q // self.p

    @property
    def plaintext_bits(self) -> int:
        return int(math.log2(self.p))

    def __post_init__(self):
        if self.p & (self.p - 1):
            raise ValueError("p must be a power of two")
        if self.p > (1 << 16):
            raise ValueError("p > 2^16 unsupported (limb decomposition)")
        if self.q_switch is not None and self.q_switch & (self.q_switch - 1):
            raise ValueError("q_switch must be a power of two")


def noise_bound(params: LWEParams, n_inner: int) -> float:
    """High-probability bound on |<db_row, e>| for db entries in [0, p).

    Each of the n_inner error coords is N(0, σ²); the inner product with a
    row of entries ≤ p−1 has std ≤ σ·(p−1)·√n_inner.
    """
    return params.z_tail * params.sigma * (params.p - 1) * math.sqrt(n_inner)


def noise_budget_ok(params: LWEParams, n_inner: int) -> bool:
    """True iff decoding succeeds whp for a DB with n_inner columns."""
    budget = params.delta / 2.0
    slack = 0.0
    if params.q_switch is not None:
        # Two roundings (answer + hint·s), each ≤ 0.5 in q_switch units,
        # i.e. ≤ q / (2·q_switch) in q units — plus one for safety.
        slack = 3.0 * Q / (2.0 * params.q_switch)
    return noise_bound(params, n_inner) + slack < budget


def choose_params(n_inner: int, *, want_p: int = 256,
                  q_switch: int | None = 1 << 16) -> LWEParams:
    """Largest safe plaintext modulus ≤ want_p for an n_inner-column DB."""
    p = want_p
    while p >= 2:
        params = LWEParams(p=p, q_switch=q_switch)
        if noise_budget_ok(params, n_inner):
            return params
        p >>= 1
    raise ValueError(f"no safe plaintext modulus for n_inner={n_inner}")


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def gen_public_matrix(seed: int, n: int, k: int) -> jax.Array:
    """Public LWE matrix A ∈ Z_q^{n×k}, derived from a shared seed."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 0x5157)
    return jax.random.bits(key, (n, k), dtype=U32)


def keygen(key: jax.Array, params: LWEParams) -> jax.Array:
    """Uniform secret s ∈ Z_q^k (Regev; hint subtraction is exact)."""
    return jax.random.bits(key, (params.k,), dtype=U32)


def sample_error(key: jax.Array, shape, sigma: float) -> jax.Array:
    """Rounded-gaussian error, represented mod q (negatives wrap)."""
    e = jnp.round(sigma * jax.random.normal(key, shape, dtype=jnp.float32))
    return e.astype(jnp.int32).astype(U32)


# ---------------------------------------------------------------------------
# Encrypt / decrypt
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def encrypt_vector(key: jax.Array, s: jax.Array, a_mat: jax.Array,
                   msg: jax.Array, delta: jnp.uint32,
                   sigma: float) -> jax.Array:
    """ct = A·s + e + Δ·msg   (all uint32 wraparound).

    msg entries are plaintext residues (for PIR: a one-hot selector).
    """
    e = sample_error(key, (a_mat.shape[0],), sigma)
    mask = jnp.matmul(a_mat, s.astype(U32))  # exact mod 2^32
    return mask + e + jnp.uint32(delta) * msg.astype(U32)


def hint_strip(ans: jax.Array, hint: jax.Array, s: jax.Array) -> jax.Array:
    """ans − H·s (mod q): leaves Δ·(D·msg) + D·e."""
    return ans - jnp.matmul(hint, s.astype(U32))


def decode(rec: jax.Array, params: LWEParams) -> jax.Array:
    """Round Δ·x + noise → x ∈ Z_p (wrapping add handles negative noise)."""
    half = jnp.uint32(params.delta // 2)
    return ((rec + half) >> jnp.uint32(Q_BITS - params.plaintext_bits)).astype(
        U32) % jnp.uint32(params.p)


# ---------------------------------------------------------------------------
# Modulus switching (downlink compression — beyond-paper optimization)
# ---------------------------------------------------------------------------

def switch_modulus(x: jax.Array, q_switch: int) -> jax.Array:
    """Round x from Z_{2^32} to Z_{q_switch} (power of two)."""
    shift = Q_BITS - int(math.log2(q_switch))
    half = jnp.uint32(1 << (shift - 1))
    return ((x + half) >> jnp.uint32(shift)).astype(
        jnp.uint16 if q_switch <= 1 << 16 else U32)


def decode_switched(ans_sw: jax.Array, hint: jax.Array, s: jax.Array,
                    params: LWEParams) -> jax.Array:
    """Decode a modulus-switched answer.

    The client computes H·s exactly in Z_q, switches it to q_switch, and
    subtracts there; Δ maps to Δ·q_switch/q.
    """
    qs = params.q_switch
    assert qs is not None
    log_qs = int(math.log2(qs))
    hs_sw = switch_modulus(jnp.matmul(hint, s.astype(U32)), qs).astype(U32)
    rec = (ans_sw.astype(U32) - hs_sw) % jnp.uint32(qs)
    delta_sw = qs // params.p
    half = jnp.uint32(delta_sw // 2)
    return ((rec + half) % jnp.uint32(qs) >> jnp.uint32(
        log_qs - params.plaintext_bits)) % jnp.uint32(params.p)
