"""Client-side finalization: exact re-rank of the decrypted cluster."""
from __future__ import annotations

import numpy as np


def cosine_scores(query_emb: np.ndarray, doc_embs: np.ndarray) -> np.ndarray:
    qn = query_emb / (np.linalg.norm(query_emb) + 1e-12)
    dn = doc_embs / (np.linalg.norm(doc_embs, axis=1, keepdims=True) + 1e-12)
    return dn @ qn


def rerank(query_emb: np.ndarray,
           docs: list[tuple[int, np.ndarray, bytes]],
           top_k: int) -> list[tuple[int, float, bytes]]:
    """Top-k (doc_id, score, text) among the fetched cluster's documents."""
    if not docs:
        return []
    embs = np.stack([d[1] for d in docs])
    scores = cosine_scores(query_emb, embs)
    order = np.argsort(-scores)[:top_k]
    return [(docs[i][0], float(scores[i]), docs[i][2]) for i in order]
