"""Atomic, mesh-agnostic pytree checkpointing (no orbax available offline).

Layout:  <root>/step_<N>/ {manifest.json, leaf_00000.npy, ...}

Guarantees engineered for fault tolerance at fleet scale:
  * atomicity     — written to `step_N.tmp`, fsync'd, then os.rename;
                    a crash mid-save can never corrupt the latest step
  * integrity     — per-leaf CRC32 in the manifest, verified on restore
  * mesh-agnostic — leaves are saved as GLOBAL arrays (host-assembled) and
                    restored with caller-provided shardings, so a checkpoint
                    written on a 512-chip mesh restores on any other mesh
                    (elastic restart; tested 8→4 devices)
  * bf16-safe     — bfloat16 leaves round-trip via a uint16 view (numpy has
                    no native bf16 serialization)
  * async         — `save_async` copies to host then writes on a worker
                    thread; `wait()` joins before the next save
  * GC            — keep-last-k retention
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

try:
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    _BF16 = None


# ---------------------------------------------------------------------------
# Tree <-> manifest structure
# ---------------------------------------------------------------------------

def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _structure(tree, counter) -> Any:
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v, counter)
                          for k, v in sorted(tree.items())}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_structure(v, counter) for v in tree]}
    i = counter[0]
    counter[0] += 1
    return {"__kind__": "leaf", "index": i}


def _rebuild(struct, leaves):
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, leaves) for k, v in struct["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, leaves) for v in struct["items"]]
        return seq if kind == "list" else tuple(seq)
    return leaves[struct["index"]]


def _leaf_order(tree) -> list:
    """Leaves in the same order _structure numbers them (sorted dict keys)."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_leaf_order(tree[k]))
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            out.extend(_leaf_order(v))
    else:
        out.append(tree)
    return out


# ---------------------------------------------------------------------------
# Save / restore
# ---------------------------------------------------------------------------

def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(x))
    if _BF16 is not None and arr.dtype == _BF16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_numpy(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16" and _BF16 is not None:
        return arr.view(_BF16)
    return arr


def save(root: str, state, *, step: int = 0, keep: int | None = None) -> str:
    """Synchronous atomic save; returns the finalized directory."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    counter = [0]
    struct = _structure(state, counter)
    leaves = _leaf_order(state)
    entries = []
    for i, leaf in enumerate(leaves):
        arr, dtype = _to_numpy(leaf)
        fname = f"leaf_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        np.save(path, arr)
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        entries.append({"file": fname, "dtype": dtype,
                        "shape": list(arr.shape), "crc32": crc})
    manifest = {"step": step, "n_leaves": len(leaves), "structure": struct,
                "leaves": entries, "format": 1}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    if keep is not None:
        gc(root, keep)
    return final


class AsyncSaver:
    """Host-copies state synchronously, writes on a background thread."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, root: str, state, *, step: int, keep: int | None = None):
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()

        def work():
            self.last_path = save(root, host_state, step=step, keep=keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(root: str) -> int | None:
    s = steps(root)
    return s[-1] if s else None


def gc(root: str, keep: int):
    for s in steps(root)[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"),
                      ignore_errors=True)


def restore(root: str, *, step: int | None = None, shardings=None):
    """Load a checkpoint; optionally place leaves with target shardings.

    `shardings` may be a pytree (matching the state) of NamedSharding — this
    is the elastic-restart path: any mesh, any partitioning.
    """
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for e in manifest["leaves"]:
        path = os.path.join(d, e["file"])
        with open(path, "rb") as f:
            data = f.read()
        if zlib.crc32(data) != e["crc32"]:
            raise IOError(f"checksum mismatch in {path}")
        arr = _from_numpy(np.load(path), e["dtype"])
        leaves.append(arr)
    state = _rebuild(manifest["structure"], leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else
            jax.numpy.asarray(x), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state
