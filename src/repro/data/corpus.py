"""Synthetic corpora with planted semantic structure.

Offline containers have no MS MARCO / bge checkpoints, so quality experiments
use Gaussian-mixture embeddings with *known* topic structure and exact
brute-force relevance labels.  The Fig-3 claims we validate are the quality
*hierarchy* between architectures (graph ≻ cluster-fetch ≻ score-only), which
is a property of the retrieval geometry, not of any particular encoder.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Corpus:
    texts: list[bytes]
    embeddings: np.ndarray        # (N, d) f32, unit-norm — what systems index
    latent: np.ndarray            # (N, d) f32 — ground-truth semantics
    topics: np.ndarray            # (N,) int
    d: int


@dataclasses.dataclass
class QuerySet:
    embeddings: np.ndarray        # (Q, d) f32
    relevant: list[np.ndarray]    # per query: doc ids, ranked by true score
    gains: list[np.ndarray]       # graded relevance aligned with `relevant`


def _unit(x: np.ndarray) -> np.ndarray:
    return x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)


def make_corpus(seed: int, n_docs: int, *, emb_dim: int = 96,
                n_topics: int = 32, text_len: tuple[int, int] = (64, 256),
                topic_spread: float = 0.5,
                encoder_noise: float = 0.0) -> Corpus:
    """All noise ratios are NORM ratios (per-coordinate noise scaled by 1/√d
    so geometry is dimension-independent).

    encoder_noise > 0 separates ground-truth semantics (`latent`) from what
    the systems index (`embeddings` = unit(latent + noise)) — emulating an
    imperfect text encoder.  This is what makes relevance straddle cluster
    boundaries, the regime where graph traversal out-recalls cluster pruning
    (paper Fig. 3)."""
    rng = np.random.default_rng(seed)
    topic_centers = _unit(rng.standard_normal((n_topics, emb_dim)))
    topics = rng.integers(0, n_topics, n_docs)
    latent = _unit(topic_centers[topics]
                   + topic_spread / np.sqrt(emb_dim)
                   * rng.standard_normal((n_docs, emb_dim)))
    if encoder_noise > 0:
        emb = _unit(latent + encoder_noise / np.sqrt(emb_dim)
                    * rng.standard_normal((n_docs, emb_dim)))
    else:
        emb = latent
    texts = []
    for i in range(n_docs):
        ln = int(rng.integers(*text_len))
        body = f"doc:{i} topic:{topics[i]} " .encode()
        filler = rng.integers(97, 123, max(0, ln - len(body))).astype(np.uint8)
        texts.append((body + filler.tobytes())[:ln])
    return Corpus(texts=texts, embeddings=emb.astype(np.float32),
                  latent=latent.astype(np.float32), topics=topics, d=emb_dim)


def make_queries(seed: int, corpus: Corpus, n_queries: int, *,
                 n_relevant: int = 50, noise: float = 0.25,
                 topical: bool = False) -> QuerySet:
    """Queries perturbed from random docs.

    relevance oracle:
      topical=False — global cosine top-L (vector-benchmark style, SIFT-like)
      topical=True  — cosine top-L *within the anchor's topic* (MS-MARCO-like
        passage relevance: the relevant set is concentrated in one semantic
        region, which is the regime cluster-pruned search is designed for)
    """
    rng = np.random.default_rng(seed)
    anchors = rng.integers(0, len(corpus.texts), n_queries)
    q_lat = _unit(corpus.latent[anchors]
                  + noise / np.sqrt(corpus.d)
                  * rng.standard_normal((n_queries, corpus.d)))
    # the system sees the query through the same imperfect encoder: add a
    # random perturbation of the same norm as the doc-side encoder gap
    enc_scale = float(np.linalg.norm(corpus.latent - corpus.embeddings,
                                     axis=1).mean())
    if enc_scale > 0:
        q = _unit(q_lat + enc_scale
                  * _unit(rng.standard_normal((n_queries, corpus.d)))
                  ).astype(np.float32)
    else:
        q = q_lat.astype(np.float32)
    rel, gains = [], []
    for i in range(n_queries):
        if topical:
            topic = corpus.topics[anchors[i]]
            pool = np.nonzero(corpus.topics == topic)[0]
        else:
            pool = np.arange(len(corpus.texts))
        # ground truth lives in LATENT space
        scores = q_lat[i] @ corpus.latent[pool].T
        L = min(n_relevant, len(pool))
        top = pool[np.argsort(-scores)[:L]]
        rel.append(top.astype(np.int64))
        gains.append(np.linspace(1.0, 0.1, L).astype(np.float32))
    return QuerySet(embeddings=q, relevant=rel, gains=gains)
