"""Retrieval quality metrics (NDCG@k, Precision@k, Recall@k) — paper Fig. 3."""
from __future__ import annotations

import numpy as np


def dcg(gains_in_rank_order: np.ndarray) -> float:
    if gains_in_rank_order.size == 0:
        return 0.0
    discounts = 1.0 / np.log2(np.arange(2, gains_in_rank_order.size + 2))
    return float(np.sum(gains_in_rank_order * discounts))


def ndcg_at_k(retrieved: np.ndarray, relevant: np.ndarray,
              gains: np.ndarray, k: int) -> float:
    gain_of = {int(d): float(g) for d, g in zip(relevant, gains)}
    got = np.array([gain_of.get(int(d), 0.0) for d in retrieved[:k]])
    ideal = np.sort(gains)[::-1][:k]
    denom = dcg(ideal)
    return dcg(got) / denom if denom > 0 else 0.0


def precision_at_k(retrieved: np.ndarray, relevant: np.ndarray, k: int) -> float:
    rel = set(int(d) for d in relevant)
    hits = sum(1 for d in retrieved[:k] if int(d) in rel)
    return hits / float(k)


def recall_at_k(retrieved: np.ndarray, relevant: np.ndarray, k: int) -> float:
    rel = set(int(d) for d in relevant)
    if not rel:
        return 0.0
    hits = sum(1 for d in retrieved[:k] if int(d) in rel)
    return hits / float(len(rel))
