"""CSR neighbor sampler for GNN minibatch training (GraphSAGE fanout).

`minibatch_lg` requires a *real* sampler: seeds → fanout-[15,10] two-hop
neighborhoods drawn from a CSR adjacency, emitted as fixed-size padded
(src, dst, nodes) buffers so the jitted step sees static shapes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray           # (N+1,)
    indices: np.ndarray          # (E,)
    n_nodes: int

    @classmethod
    def random(cls, seed: int, n_nodes: int, avg_degree: int) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        degrees = rng.poisson(avg_degree, n_nodes).clip(1)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(degrees, out=indptr[1:])
        indices = rng.integers(0, n_nodes, int(indptr[-1]))
        return cls(indptr=indptr, indices=indices.astype(np.int64),
                   n_nodes=n_nodes)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


@dataclasses.dataclass
class SampledSubgraph:
    """Fixed-size padded subgraph; edge (src→dst) ids index `nodes`."""
    nodes: np.ndarray            # (max_nodes,) global ids (padded w/ 0)
    node_mask: np.ndarray        # (max_nodes,) bool
    src: np.ndarray              # (max_edges,) local ids
    dst: np.ndarray              # (max_edges,) local ids
    edge_mask: np.ndarray        # (max_edges,) bool
    seeds_local: np.ndarray      # (n_seeds,) local ids of the seed nodes


def sample_fanout(graph: CSRGraph, seeds: np.ndarray, fanout: list[int],
                  *, seed: int = 0,
                  max_nodes: int | None = None,
                  max_edges: int | None = None) -> SampledSubgraph:
    """Multi-hop uniform fanout sampling (with replacement when deg>fanout)."""
    rng = np.random.default_rng(seed)
    n_seeds = len(seeds)
    cap_nodes = n_seeds
    cap_edges = 0
    layer = n_seeds
    for f in fanout:
        layer *= f
        cap_nodes += layer
        cap_edges += layer
    max_nodes = max_nodes or cap_nodes
    max_edges = max_edges or cap_edges

    local_of: dict[int, int] = {}
    nodes: list[int] = []

    def local(u: int) -> int:
        if u not in local_of:
            local_of[u] = len(nodes)
            nodes.append(u)
        return local_of[u]

    for s in seeds:
        local(int(s))
    src_l, dst_l = [], []
    frontier = [int(s) for s in seeds]
    for f in fanout:
        nxt = []
        for u in frontier:
            nbrs = graph.neighbors(u)
            if len(nbrs) == 0:
                continue
            picks = rng.choice(nbrs, size=min(f, len(nbrs)),
                               replace=len(nbrs) < f)
            for v in picks:
                v = int(v)
                src_l.append(local(v))       # message flows v → u
                dst_l.append(local(u))
                nxt.append(v)
        frontier = nxt
    n_nodes, n_edges = len(nodes), len(src_l)
    assert n_nodes <= max_nodes and n_edges <= max_edges, \
        (n_nodes, max_nodes, n_edges, max_edges)

    out_nodes = np.zeros(max_nodes, np.int64)
    out_nodes[:n_nodes] = nodes
    node_mask = np.zeros(max_nodes, bool)
    node_mask[:n_nodes] = True
    src = np.zeros(max_edges, np.int32)
    dst = np.zeros(max_edges, np.int32)
    emask = np.zeros(max_edges, bool)
    src[:n_edges] = src_l
    dst[:n_edges] = dst_l
    emask[:n_edges] = True
    return SampledSubgraph(nodes=out_nodes, node_mask=node_mask, src=src,
                           dst=dst, edge_mask=emask,
                           seeds_local=np.arange(n_seeds, dtype=np.int32))
