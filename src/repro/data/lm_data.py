"""Deterministic synthetic LM data: seekable by step (fault-tolerant resume).

Sequences follow per-sequence affine recurrences x_{t+1} = (a·x_t + b) mod V
with (a, b) drawn per sequence — a genuinely learnable next-token task (the
train_embedder example drives loss down on it), and a pure function of
(seed, step) so a restarted trainer sees bitwise-identical batches.
"""
from __future__ import annotations

import numpy as np


def batch_at(seed: int, step: int, *, batch: int, seq: int,
             vocab: int, n_offsets: int = 16) -> dict[str, np.ndarray]:
    """Per-sequence offset recurrence x_{t+1} = (x_t + b) mod V.

    b is drawn from a small public set, so it is exactly inferable from any
    single transition — an in-context task a small LM demonstrably learns
    (free-multiplier affine recurrences are not: the (a, b) posterior stays
    multimodal and training plateaus at the uniform baseline)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    offsets = np.linspace(1, vocab - 1, n_offsets, dtype=np.int64)
    b = offsets[rng.integers(0, n_offsets, (batch, 1))]
    x0 = rng.integers(0, vocab, (batch, 1))
    toks = np.empty((batch, seq + 1), np.int64)
    toks[:, :1] = x0
    for t in range(seq):
        toks[:, t + 1:t + 2] = (toks[:, t:t + 1] + b) % vocab
    return {"tokens": toks[:, :seq].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def doc_tokens(seed: int, doc_id: int, *, length: int, vocab: int
               ) -> np.ndarray:
    """Deterministic per-document token stream (corpus building)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, doc_id]))
    return rng.integers(0, vocab, length).astype(np.int32)
