"""Serving engines for PIR-RAG: deadline batching, pipelining, shadow epochs.

`engine` holds the two serve loops (synchronous reference + pipelined
production engine) and the shared policy core; `epochs` holds the
shadow-commit machinery.  `launch.serve` is the thin CLI over this package.
"""
from repro.serve.engine import (DeadlineBatcher, PIRServeLoop,
                                PipelinedServeLoop, Request, Response)
from repro.serve.epochs import ShadowCommitter

__all__ = ["DeadlineBatcher", "PIRServeLoop", "PipelinedServeLoop",
           "Request", "Response", "ShadowCommitter"]
