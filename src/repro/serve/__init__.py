"""Serving engines for PIR-RAG: deadline batching, pipelining, shadow epochs.

`engine` holds the two serve loops (synchronous reference + pipelined
production engine) and the shared policy core; `epochs` holds the
shadow-commit machinery.  Every response carries its batch's latency
components (`BatchTiming`: queue/encode/gemm/decode) and the engines expose
backlog observability (`DeadlineBatcher.depth` / `oldest_age_ms`) plus
control hooks (`commit_gate`, `PipelinedServeLoop.set_depth`) that
`repro.traffic` drives.  `launch.serve` is the thin CLI over this package.
"""
from repro.serve.engine import (BatchTiming, DeadlineBatcher, PIRServeLoop,
                                PipelinedServeLoop, Request, Response)
from repro.serve.epochs import ShadowCommitter

__all__ = ["BatchTiming", "DeadlineBatcher", "PIRServeLoop",
           "PipelinedServeLoop", "Request", "Response", "ShadowCommitter"]
