"""PIR serving engines: deadline batching, epoch admission, pipelining.

Two engines share one policy core (batching, epoch admission control, the
per-batch LWE key stream):

`PIRServeLoop` — the synchronous reference.  Each tick commits pending
mutations, cuts a batch, runs the answer GEMM and decodes it before
returning: correct, simple, and the bit-exactness oracle for everything
else — but the device sits idle while the host encodes, deserializes and
re-ranks.

`PipelinedServeLoop` — the production engine.  Each tick is split into
plan → dispatch → complete stages and exploits JAX async dispatch so the
three overlap across batches:

    tick T:   publish shadow commit (pointer swap — `serve.epochs`)
              plan batch N      (cut, admit, encode)        host
              dispatch batch N  (answer GEMM enqueued)      device
              complete batch N-depth (decode, re-rank)      host+device

While batch N's GEMM streams the database on the device, the host is
decoding batch N−depth and will cut/encode batch N+1 — the serve loop no
longer blocks host-side on every answer before cutting the next batch.
Mutation commits stage their patches into shadow buffers and publish with
a pointer swap (`update.live.stage/publish`), so a commit never stops the
world and in-flight batches keep decoding against their epoch's snapshot.

Responses are BIT-IDENTICAL to the synchronous loop — same payloads,
epochs, retry counts, in the same order (property-tested under random
interleavings of submits/mutations/drains, single-device and sharded):
pipelining moves work in time, never across an epoch boundary.

Both engines optionally close the RAG loop: pass ``generator=`` (a
`repro.rag.generate.Generator`) and every served query batch runs the
tokenize → prefill → decode completion stage before its responses land
(`Response.tokens` + `RagTiming`).  Under the pipelined engine batch N's
generation runs while batch N+1's retrieval GEMM is already dispatched —
retrieval for the next query overlaps decode of the previous one, which
is what `benchmarks/rag_bench.py` measures as overlapped RAG-Ready
Latency.  Generated tokens are bit-identical across engines (they depend
only on retrieved docs, rids and the generator seed, never on timing).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from typing import Callable, Iterable

import jax
import numpy as np

from repro.fleet.faults import InjectedCommitFault
from repro.fleet.retry import DEFAULT_POLICY, RetryPolicy
from repro.obs import Obs
from repro.serve.epochs import ShadowCommitter


@dataclasses.dataclass
class Request:
    rid: int
    query_emb: np.ndarray | None   # None for keyed embedding lookups
    t_arrival: float
    epoch: int = 0                 # hint epoch the query was formed against
    retries: int = 0
    top_k: int = 5                 # per-request result size
    multi_probe: int = 1           # clusters to fetch (>1 → batch-PIR able)
    lookup_ids: tuple | None = None  # keyed row ids (recsys lookup request)


@dataclasses.dataclass(frozen=True)
class BatchTiming:
    """Per-batch latency components, shared by every response in the batch.

    ``t_plan`` is when the batch's encode began — a request's queue time is
    ``t_plan − t_arrival``.  ``encode_s`` is host-side query formulation +
    GEMM enqueue; ``gemm_s`` is the complete-stage wait for device results
    (under the pipelined engine this is the RESIDUAL wait after overlap,
    often ~0); ``decode_s`` is host-side decode + re-rank.
    """
    t_plan: float
    encode_s: float
    gemm_s: float
    decode_s: float


@dataclasses.dataclass(frozen=True)
class RagTiming:
    """Per-batch generation-stage components (shared by the batch).

    Seconds on the loop clock, one entry per `rag.*` span: `tokenize_s`
    is host-side doc decode + prompt packing, `prefill_s` the prompt
    forward filling the KV cache, `generate_s` the decode step loop.
    `prompt_tokens` is the batch's summed TRUE prompt length (before
    padding); `new_tokens` the fixed per-request generation length.
    """
    tokenize_s: float
    prefill_s: float
    generate_s: float
    prompt_tokens: int
    new_tokens: int


@dataclasses.dataclass
class Response:
    rid: int
    top: list
    t_done: float
    batch_size: int
    epoch: int = 0
    retries: int = 0
    t_arrival: float = 0.0               # copied from the request
    timing: BatchTiming | None = None    # its batch's latency components
    failed: bool = False                 # terminal: retry budget/deadline hit
    staleness: int = 0                   # epochs behind the fleet head (failover)
    tokens: tuple | None = None          # generated ids (loops with a generator)
    rag: RagTiming | None = None         # generation components (ditto)


class DeadlineBatcher:
    """Cut a batch at max_batch or when the head request ages past deadline."""

    def __init__(self, *, max_batch: int = 64, deadline_ms: float = 20.0):
        self.max_batch = max_batch
        self.deadline_ms = deadline_ms
        self.queue: deque[Request] = deque()

    @property
    def depth(self) -> int:
        """Requests currently queued (admission-controller observable)."""
        return len(self.queue)

    def oldest_age_ms(self, now: float) -> float:
        """Age of the head request in ms (0.0 when the queue is empty).

        The backlog gauge: under open-loop overload the head age grows
        without bound unless something sheds or defers load — operators
        and the admission controller both watch this.
        """
        if not self.queue:
            return 0.0
        return (now - self.queue[0].t_arrival) * 1e3

    def shed_tail(self, n: int) -> list[Request]:
        """Remove up to `n` requests from the TAIL and return them.

        Load shedding drops the youngest requests: the head of the queue
        has waited longest and is closest to its deadline, so it keeps its
        place.  The caller (admission controller) owns accounting shed
        requests into the SLO summary.
        """
        shed = []
        while self.queue and len(shed) < n:
            shed.append(self.queue.pop())
        shed.reverse()                   # back in arrival order
        return shed

    def submit(self, req: Request):
        """Append an arriving request (FIFO tail)."""
        self.queue.append(req)

    def requeue(self, req: Request):
        """Put ONE rejected request back at the head (it keeps its arrival)."""
        self.queue.appendleft(req)

    def requeue_front(self, reqs: Iterable[Request]):
        """Put rejected requests back at the head, preserving THEIR order.

        The batcher owns retry ordering: callers hand over the stale
        requests in cut order and this re-queues them FIFO ahead of
        everything younger.  (Naively calling `requeue` in iteration order
        would reverse same-epoch retries relative to each other.)
        """
        self.queue.extendleft(reversed(list(reqs)))

    def ready(self, now: float) -> bool:
        """True when a batch should be cut: size or head-age trigger."""
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        age_ms = (now - self.queue[0].t_arrival) * 1e3
        return age_ms >= self.deadline_ms

    def cut(self) -> list[Request]:
        """Dequeue up to max_batch requests in arrival order."""
        batch = []
        while self.queue and len(batch) < self.max_batch:
            batch.append(self.queue.popleft())
        return batch


class PIRServeLoop:
    """Synchronous deadline-batched serving; optionally wraps a LiveIndex.

    `system` may be a PirRagSystem (static corpus) or, with `live=...`, the
    LiveIndex whose `.system` is queried at its current epoch.  A system
    built with ``mesh=`` serves every batch through the sharded
    zero-collective answer path; the loop itself is layout-agnostic (its
    batching, epoch admission and key-stream logic never look at the mesh).
    """

    #: span attribute naming this engine (registered enum in repro.obs.scrub)
    ENGINE = "sync"

    def __init__(self, system, *, max_batch: int = 64,
                 deadline_ms: float = 20.0,
                 clock: Callable[[], float] = time.perf_counter,
                 live=None, seed: int = 0, obs: Obs | None = None,
                 retry: RetryPolicy | None = DEFAULT_POLICY,
                 faults=None, generator=None):
        self.live = live if live is not None else (
            system if hasattr(system, "epochs") else None)
        self.system = system if self.live is None else self.live.system
        self.batcher = DeadlineBatcher(max_batch=max_batch,
                                       deadline_ms=deadline_ms)
        self.clock = clock
        # Observability: spans time every tick stage (BatchTiming is built
        # from their boundaries) and the registry carries serving counters.
        # The default Obs(trace=False) keeps the timeline without retaining
        # spans; pass Obs(trace=True, clock=<same clock>) to export traces.
        self.obs = obs if obs is not None else Obs(clock=clock, trace=False)
        if self.live is not None:
            self.live.set_obs(self.obs)
        self.responses: list[Response] = []
        self.mutations: deque = deque()
        self.stale_retries = 0
        # Bounded retry: every re-admission (stale reject, dropped answer)
        # charges the request's budget; exhaustion or a blown deadline
        # yields a TERMINAL failed response instead of another requeue.
        # retry=None restores the historical unbounded behaviour.
        self.retry = retry
        self.failed_requests = 0
        # Fault-injection hook (repro.fleet.faults.FaultInjector): guards
        # the answer drop/delay sites post-admission, and arms the wrapped
        # live index's commit-stage and hint-chain sites with the SAME
        # injector (one invocation-counter space per run).  None (default)
        # keeps the tick fault-free with zero extra clock reads.
        # Generation completion stage (repro.rag.generate.Generator):
        # when set, every served QUERY batch runs tokenize → prefill →
        # decode before its responses land, and `Response.tokens`/`.rag`
        # carry the generated ids + stage timing (t_done moves to the end
        # of generation, so SLO latency covers the full RAG answer).
        # None (the default) keeps the retrieval-only path byte-identical
        # to loops without the hook — zero extra clock reads.
        self.generator = generator
        self.faults = faults
        if faults is not None and self.live is not None:
            self.live.faults = faults
            self.live.epochs.faults = faults
        self._backoff: list = []      # (ready_t, seq, Request) min-heap
        self._delayed: list = []      # (ready_t, seq, Request) min-heap
        self._seq = 0                 # heap tiebreak = admission order
        self._tick_no = 0
        # Commit-failure retry state: an injected stage failure leaves the
        # journal batch pending; the commit is retried with exponential
        # tick backoff instead of being lost or hammered.
        self._commit_retry = False
        self._commit_attempts = 0
        self._commit_not_before = 0   # tick number gating the next attempt
        # Admission hook: when set, pending mutations fold into an epoch
        # only on ticks where commit_gate() is True — the controller defers
        # commits under backlog so queued requests don't go stale mid-wait
        # (freshness degrades instead of latency; see traffic.admission).
        self.commit_gate: Callable[[], bool] | None = None
        self._key = jax.random.PRNGKey(seed)   # per-batch query-key stream

    @property
    def epoch(self) -> int:
        """Published epoch requests are admitted at (0 for static corpora)."""
        return self.live.epoch if self.live is not None else 0

    def submit(self, rid: int, query_emb: np.ndarray, *, top_k: int = 5,
               multi_probe: int = 1, epoch: int | None = None):
        """A client submits a query formed against its cached hint's epoch.

        ``epoch=None`` (the default) models a freshly synced client and
        stamps the published head; the traffic generator passes each
        session's actual cached epoch, so lazily syncing clients hit the
        stale-reject/retry path exactly as they would in production.
        """
        self.batcher.submit(Request(rid, query_emb, self.clock(),
                                    epoch=self.epoch if epoch is None
                                    else epoch, top_k=top_k,
                                    multi_probe=multi_probe))

    def submit_lookup(self, rid: int, ids, *, epoch: int | None = None):
        """A client submits a keyed embedding lookup (row id multiset).

        Lookups batch through the same deadline/admission policy as
        queries; each tick serves all queued lookups in ONE bucketed pass
        of the keyed batch-PIR subsystem.  Needs a `build_keyed` system.
        """
        self.batcher.submit(Request(rid, None, self.clock(),
                                    epoch=self.epoch if epoch is None
                                    else epoch,
                                    lookup_ids=tuple(int(i) for i in ids)))

    def submit_mutation(self, mut):
        """Queue a journal record; folded into an epoch at the next tick."""
        assert self.live is not None, "mutations need a LiveIndex"
        self.mutations.append(mut)

    def _commit_mutations(self):
        """Fold queued mutations into one epoch between query batches.

        An injected stage failure (`InjectedCommitFault`) leaves the batch
        pending in the journal; the commit retries on a later tick under
        exponential tick backoff (`_commit_failed`) — bounded by the fault
        plan, never dropped.
        """
        if self.live is None or not (self.mutations or self._commit_retry):
            return None
        if self.commit_gate is not None and not self.commit_gate():
            return None                  # deferred: serve stale-epoch answers
        if self._tick_no < self._commit_not_before:
            return None                  # backing off after a failed commit
        while self.mutations:
            self.live.journal.append(self.mutations.popleft())
        try:
            patch = self.live.commit()
        except InjectedCommitFault:
            self._commit_failed()
            return None
        self._commit_retry = False
        self._commit_attempts = 0
        return patch

    def _commit_failed(self):
        """Record one failed commit attempt and arm the tick backoff."""
        self._commit_attempts += 1
        self._commit_retry = True
        self._commit_not_before = (self._tick_no
                                   + min(2 ** (self._commit_attempts - 1), 16))
        self.obs.counter("fleet.commit_failures").inc()

    # -- policy core shared by both engines ----------------------------------

    def _admit(self, batch: list[Request], cur: int,
               now: float) -> list[Request]:
        """Epoch admission control: reject-and-requeue stale requests.

        A query encrypted against a superseded hint would decode garbage,
        so it is rejected; the client syncs its cached hint
        (HintCache.sync) and re-encrypts against the head.  Retried
        requests go back to the queue head in their original FIFO order —
        after their backoff, if the policy sets one — UNLESS the retry
        charge exhausts their budget or deadline, which ends them with a
        terminal failed response (no more ping-pong under epoch churn).
        """
        fresh = [r for r in batch if r.epoch == cur]
        stale = [r for r in batch if r.epoch != cur]
        if stale:
            self.stale_retries += len(stale)
            self.obs.counter("serve.stale_retries").inc(len(stale))
            for r in stale:
                r.retries += 1
            kept, give_up = self._split_budget(stale, now)
            for r in kept:
                r.epoch = cur
            self._requeue_retries(kept, now)
            self._fail(give_up, cur, now)
        return fresh

    def _split_budget(self, reqs: list[Request],
                      now: float) -> tuple[list[Request], list[Request]]:
        """(still in budget, out of budget) under the retry policy."""
        if self.retry is None:
            return reqs, []
        kept, give_up = [], []
        for r in reqs:
            if (self.retry.exhausted(r.retries)
                    or self.retry.past_deadline(r.t_arrival, now)):
                give_up.append(r)
            else:
                kept.append(r)
        return kept, give_up

    def _requeue_retries(self, reqs: list[Request], now: float):
        """Requeue retried requests, honouring the policy's backoff."""
        if not reqs:
            return
        if self.retry is None or self.retry.backoff_base_ms <= 0:
            self.batcher.requeue_front(reqs)
            return
        immediate = []
        for r in reqs:
            d = self.retry.backoff_s(r.rid, r.retries)
            if d <= 0:
                immediate.append(r)
            else:
                self._seq += 1
                heapq.heappush(self._backoff, (now + d, self._seq, r))
        if immediate:
            self.batcher.requeue_front(immediate)

    def _fail(self, reqs: list[Request], epoch: int, now: float):
        """Terminal failure: emit failed responses (never silence)."""
        if not reqs:
            return
        self.failed_requests += len(reqs)
        self.obs.counter("serve.failed").inc(len(reqs))
        hist = self.obs.histogram("serve.retries",
                                  bounds=(1, 2, 4, 8, 16, 32, 64))
        for r in reqs:
            hist.record(r.retries)
            self.responses.append(Response(
                r.rid, [], now, 0, epoch=epoch, retries=r.retries,
                t_arrival=r.t_arrival, failed=True))

    def _release_held(self, now: float, force: bool = False):
        """Move matured backoff/delayed requests back to the queue head.

        Pure heap pops against the tick's existing `now` read — with empty
        heaps (the no-fault, no-backoff path) this is two truthiness
        checks, so the response stream stays bit-identical.  Maturity is
        respected even during drain (the clock keeps advancing there, so
        held requests always mature); `force` only matters to callers via
        the batcher-ready bypass, not here.
        """
        del force
        due = []
        for heap in (self._delayed, self._backoff):
            while heap and heap[0][0] <= now:
                due.append(heapq.heappop(heap))
        if due:
            due.sort(key=lambda e: e[1])   # original admission order
            self.batcher.requeue_front([r for _, _, r in due])

    def _inject_answer_faults(self, fresh: list[Request], cur: int,
                              now: float) -> list[Request]:
        """Guard the answer drop/delay sites on the just-cut batch.

        A DROP loses the whole batch pre-dispatch: each request is charged
        one retry and re-queued (or terminally failed).  A DELAY holds the
        batch in the delayed heap for the event's `delay_s` of loop-clock
        time — late, not lost, so no retry is charged.
        """
        if self.faults is None or not fresh:
            return fresh
        if self.faults.fire("serve.answer.drop"):
            self.obs.counter("fleet.answer_drops").inc(len(fresh))
            for r in fresh:
                r.retries += 1
            kept, give_up = self._split_budget(fresh, now)
            self._requeue_retries(kept, now)
            self._fail(give_up, cur, now)
            return []
        delay = self.faults.fire("serve.answer.delay")
        if delay:
            self.obs.counter("fleet.answer_delays").inc(len(fresh))
            ready = now + max(ev.delay_s for ev in delay)
            for r in fresh:
                self._seq += 1
                heapq.heappush(self._delayed, (ready, self._seq, r))
            return []
        return fresh

    def _probe_groups(self, fresh: list[Request]
                      ) -> list[tuple[tuple[str, int], list[Request]]]:
        """One GEMM per request kind/shape: single-probe queries share the
        classic column-stacked GEMM; each distinct multi_probe value shares
        the bucketed batch-PIR GEMM; keyed lookups share the keyed bucketed
        GEMM (all clients in one streamed pass).  Keys are ("lookup", 0) or
        ("query", multi_probe) — sorted, so group order is deterministic."""
        groups: dict[tuple[str, int], list[Request]] = {}
        for r in fresh:
            k = (("lookup", 0) if r.lookup_ids is not None
                 else ("query", r.multi_probe))
            groups.setdefault(k, []).append(r)
        return [(k, groups[k]) for k in sorted(groups)]

    def _plan_group(self, system, kind: tuple[str, int],
                    reqs: list[Request], kq):
        """Encode + dispatch one request group → its `InflightBatch`.

        The one place both engines form batches, so the sync and pipelined
        paths cannot diverge per kind: lookups route through
        `lookup_batch_async` (results are (κ, d) row arrays), queries
        through `query_batch_async` (results are top-k doc lists)."""
        if kind[0] == "lookup":
            return system.lookup_batch_async(
                [r.lookup_ids for r in reqs], key=kq)
        embs = np.stack([r.query_emb for r in reqs])
        return system.query_batch_async(embs, top_k=[r.top_k for r in reqs],
                                        multi_probe=kind[1], key=kq)

    def _serving_system(self):
        return self.live.system if self.live is not None else self.system

    # -- the synchronous tick -------------------------------------------------

    def tick(self, force: bool = False) -> int:
        """Serve one batch if ready; returns number of requests served.

        force=True flushes a partial batch regardless of the deadline
        (used by drain) WITHOUT touching the configured deadline_ms.

        The tick is one root span; plan (encode) / gemm (device wait) /
        complete (decode + re-rank) are nested spans whose boundaries ARE
        the `BatchTiming` components — one timeline, two consumers.
        """
        self._tick_no += 1
        with self.obs.span("serve.tick", engine=self.ENGINE) as tick_sp:
            self.obs.gauge("serve.queue_depth").set(self.batcher.depth)
            self._commit_mutations()
            now = self.clock()
            self._release_held(now, force=force)
            if (not self.batcher.ready(now)
                    and not (force and self.batcher.queue)):
                return 0
            cur = self.epoch
            fresh = self._admit(self.batcher.cut(), cur, now)
            fresh = self._inject_answer_faults(fresh, cur, now)
            if not fresh:
                return 0
            tick_sp.set(batch=len(fresh), epoch=cur)

            system = self._serving_system()
            for kind, reqs in self._probe_groups(fresh):
                self._key, kq = jax.random.split(self._key)
                # query_batch ≡ query_batch_async().complete(); the async
                # form only adds the component span boundaries — responses
                # stay bit-identical to the one-call path
                with self.obs.span("serve.plan", batch=len(reqs),
                                   kind=kind[0],
                                   multi_probe=kind[1]) as sp_plan:
                    infl = self._plan_group(system, kind, reqs, kq)
                with self.obs.span("serve.gemm", batch=len(reqs)) as sp_gemm:
                    jax.block_until_ready(infl.pending)
                with self.obs.span("serve.complete",
                                   batch=len(reqs)) as sp_done:
                    results = infl.complete()
                self._record(reqs, results, cur, sp_done.t1, BatchTiming(
                    t_plan=sp_plan.t0, encode_s=sp_plan.dur,
                    gemm_s=sp_gemm.dur, decode_s=sp_done.dur))
            return len(fresh)

    def _generate_dispatch(self, reqs: list[Request], results: list):
        """Tokenize + prefill + ENQUEUE the decode chain (no device block).

        Returns the in-flight handle `_generate_wait` resolves into ids
        and a `RagTiming`.  Both engines share this; they differ only in
        WHEN they wait: the sync loop blocks immediately (serial
        end-to-end), the pipelined loop parks the handle and blocks at
        the NEXT tick's retire, so the decode chain's device time runs
        while the host encodes/recovers the following batch.
        """
        gen = self.generator
        with self.obs.span("rag.tokenize", batch=len(reqs)) as sp_tok:
            grid, lengths, prompts = gen.pack(results)
        n_prompt = int(lengths.sum())
        self.obs.counter("rag.docs_dropped").inc(
            sum(p.n_docs_dropped for p in prompts))
        with self.obs.span("rag.prefill", batch=len(reqs),
                           prompt_tokens=n_prompt) as sp_pre:
            state = gen.prefill(grid, lengths)
        t0 = self.clock()
        ids_dev = gen.decode_async(state, [r.rid for r in reqs])
        dispatch_s = self.clock() - t0
        return ids_dev, sp_tok.dur, sp_pre.dur, dispatch_s, n_prompt

    def _generate_wait(self, reqs: list[Request], handle
                       ) -> tuple[np.ndarray, RagTiming, float]:
        """Block on a dispatched decode chain → (ids, RagTiming, t_done).

        The `rag.generate` span covers the residual device wait (near
        zero when the pipeline hid it); `generate_s` adds the host-side
        step-dispatch time so the component is the full decode-loop cost
        either way.  Spans carry token COUNTS and timings only — ids and
        text never reach the trace.
        """
        gen = self.generator
        ids_dev, tok_s, pre_s, dispatch_s, n_prompt = handle
        with self.obs.span("rag.generate", batch=len(reqs),
                           new_tokens=gen.max_new_tokens) as sp_gen:
            ids = np.asarray(jax.block_until_ready(ids_dev))
        self.obs.counter("rag.generated_tokens").inc(
            len(reqs) * gen.max_new_tokens)
        rag = RagTiming(tokenize_s=tok_s, prefill_s=pre_s,
                        generate_s=dispatch_s + sp_gen.dur,
                        prompt_tokens=n_prompt,
                        new_tokens=int(gen.max_new_tokens))
        return ids, rag, sp_gen.t1

    def _generate(self, reqs: list[Request], results: list,
                  t_done: float) -> tuple[np.ndarray, RagTiming, float]:
        """Run the generation completion stage on one served query group.

        tokenize → prefill → decode, each under its `rag.*` span.
        Returns (ids (B, N), shared RagTiming, new t_done = end of
        generation).  Tokens depend only on the retrieved docs, rids and
        the generator seed, so sync/pipelined/fleet agree bit-for-bit.
        """
        del t_done                       # superseded: answer isn't ready
        return self._generate_wait(      # ...until generation finishes
            reqs, self._generate_dispatch(reqs, results))

    def _record(self, reqs: list[Request], results: list, epoch: int,
                t_done: float, timing: BatchTiming, staleness: int = 0):
        """Complete one served group: generate (if configured) + append."""
        ids, rag = None, None
        if (self.generator is not None and reqs
                and reqs[0].lookup_ids is None):
            ids, rag, t_done = self._generate(reqs, results, t_done)
        self._append(reqs, results, epoch, t_done, timing, ids, rag,
                     staleness)

    def _append(self, reqs: list[Request], results: list, epoch: int,
                t_done: float, timing: BatchTiming, ids, rag,
                staleness: int = 0):
        """Append one served group's responses (shared batch timing).

        The single append point for every engine and both generation
        postures (inline and deferred) — response construction cannot
        diverge between them.
        """
        self.obs.counter("serve.responses").inc(len(reqs))
        self.obs.histogram("serve.batch_size",
                           bounds=(1, 2, 4, 8, 16, 32, 64, 128)
                           ).record(len(reqs))
        lat_hist = self.obs.histogram("serve.latency_ms")
        retry_hist = self.obs.histogram("serve.retries",
                                        bounds=(1, 2, 4, 8, 16, 32, 64))
        for i, (req, top) in enumerate(zip(reqs, results)):
            lat_hist.record((t_done - req.t_arrival) * 1e3)
            retry_hist.record(req.retries)
            # batch_size = this group's GEMM width, not the tick total
            self.responses.append(Response(
                req.rid, top, t_done, len(reqs), epoch=epoch,
                retries=req.retries, t_arrival=req.t_arrival, timing=timing,
                staleness=staleness,
                tokens=(tuple(int(t) for t in ids[i])
                        if ids is not None else None),
                rag=rag))

    def drain(self):
        """Serve everything still queued, force-flushing partial batches.

        Bypasses the commit gate: drain means "finish ALL the work", so a
        controller deferring commits must not keep it spinning forever.
        """
        gate, self.commit_gate = self.commit_gate, None
        try:
            while (self.batcher.queue or self.mutations
                   or self._backoff or self._delayed or self._commit_retry):
                self.tick(force=True)
        finally:
            self.commit_gate = gate


class PipelinedServeLoop(PIRServeLoop):
    """Plan/dispatch/complete pipelined serving over the same policy core.

    ``depth`` bounds the number of dispatched-but-undecoded batches: the
    tick that pushes batch N completes batch N−depth, so at steady state
    the device always has a GEMM in flight while the host decodes an older
    batch and encodes a younger one.  depth=1 still overlaps one GEMM with
    host work; larger depths additionally ride out commit spikes.

    Mutation commits go through `ShadowCommitter`: patches are computed
    into shadow buffers (donated in place where the aliasing contract
    allows) and published as a pointer swap at the exact tick boundary the
    synchronous loop commits on — which is why responses, epochs and retry
    counts stay bit-identical.
    """

    ENGINE = "pipelined"

    def __init__(self, system, *, depth: int = 2, donate: bool = True,
                 gen_coalesce: int = 1, **kwargs):
        super().__init__(system, **kwargs)
        self.depth = max(1, int(depth))
        self.gen_coalesce = max(1, int(gen_coalesce))
        self._inflight: deque = deque()
        self._gen_pending: deque = deque()
        self._shadow = (ShadowCommitter(self.live, donate=donate)
                        if self.live is not None else None)

    @property
    def inflight(self) -> int:
        """Batches dispatched on device but not yet decoded."""
        return len(self._inflight)

    def set_depth(self, depth: int):
        """Adjust the in-flight bound (admission-controller depth hook).

        Takes effect at the next tick/retire: a shrink retires the excess
        batches then, a grow simply lets more dispatches accumulate.
        Dynamic depth trades completion latency (responses wait behind up
        to `depth` batches) against overlap headroom (commit spikes and
        slow decodes ride out without stalling dispatch).
        """
        self.depth = max(1, int(depth))

    def _commit_mutations(self):
        if self._shadow is None or not (self.mutations or self._commit_retry):
            return None
        if self.commit_gate is not None and not self.commit_gate():
            return None                  # deferred: serve stale-epoch answers
        if self._tick_no < self._commit_not_before:
            return None                  # backing off after a failed commit
        try:
            patch = self._shadow.commit(self.mutations)
        except InjectedCommitFault:
            self._commit_failed()
            return None
        self._commit_retry = False
        self._commit_attempts = 0
        return patch

    def tick(self, force: bool = False) -> int:
        """Plan + dispatch one batch if ready; complete anything past depth.

        Returns the number of requests DISPATCHED (their responses land
        when the pipeline retires them — per-request completion timestamps
        are taken at the complete stage).  The plan span's boundaries seed
        each in-flight batch's `BatchTiming`; its gemm/complete spans are
        opened by the LATER tick that retires it, which is exactly the
        nesting the trace shows (a complete span parented by a younger
        tick than its plan span — the pipeline overlap made visible).
        """
        self._tick_no += 1
        with self.obs.span("serve.tick", engine=self.ENGINE) as tick_sp:
            self.obs.gauge("serve.queue_depth").set(self.batcher.depth)
            self._commit_mutations()
            now = self.clock()
            self._release_held(now, force=force)
            if (not self.batcher.ready(now)
                    and not (force and self.batcher.queue)):
                # idle tick: nothing to dispatch, so retire EVERYTHING in
                # flight — during a traffic lull responses must not sit
                # decoded-but-unreported behind the depth bound
                self._retire(0)
                return 0
            cur = self.epoch
            fresh = self._admit(self.batcher.cut(), cur, now)
            fresh = self._inject_answer_faults(fresh, cur, now)
            if not fresh:
                return 0
            tick_sp.set(batch=len(fresh), epoch=cur)

            system = self._serving_system()
            for kind, reqs in self._probe_groups(fresh):
                self._key, kq = jax.random.split(self._key)
                with self.obs.span("serve.plan", batch=len(reqs),
                                   kind=kind[0],
                                   multi_probe=kind[1]) as sp_plan:
                    infl = self._plan_group(system, kind, reqs, kq)
                self._inflight.append((reqs, cur, infl, sp_plan.t0,
                                       sp_plan.dur))
            self.obs.gauge("serve.inflight").set(len(self._inflight))
            self._retire(self.depth)
            return len(fresh)

    def _record(self, reqs: list[Request], results: list, epoch: int,
                t_done: float, timing: BatchTiming, staleness: int = 0):
        """Park generation instead of blocking the tick on it.

        A query group retiring with a generator lands on ``_gen_pending``;
        `_retire_gen` completes it on a LATER tick, coalescing up to
        ``gen_coalesce`` parked groups into ONE generation micro-batch —
        retrieval for the next batches proceeds while generation waits,
        and the coalesced micro-batch pays one prefill + one decode-step
        chain where the serial engine pays one PER GROUP.  Tokens are
        bit-identical to the sync engine's: per-row transformer math does
        not depend on who shares the batch (pinned by the rag serve
        tests), and sampled rows key off (seed, rid, step) only.
        Responses simply land a tick later, like retrieval responses
        already do in this engine.
        """
        if (self.generator is not None and reqs
                and reqs[0].lookup_ids is None):
            self._gen_pending.append((reqs, results, epoch, timing,
                                      staleness))
            return
        super()._record(reqs, results, epoch, t_done, timing, staleness)

    def _retire_gen(self, count: int):
        """Coalesce the `count` oldest parked groups into one micro-batch.

        One pack/prefill/decode chain serves every coalesced group; the
        (B_total, N) id grid is split back per group, which keeps each
        group's epoch/staleness/BatchTiming intact.  The micro-batch's
        RagTiming is shared by its responses, exactly like BatchTiming is
        shared by a retrieval batch.
        """
        groups = [self._gen_pending.popleft() for _ in range(count)]
        reqs_all = [r for g in groups for r in g[0]]
        results_all = [res for g in groups for res in g[1]]
        ids, rag, t_done = self._generate_wait(
            reqs_all, self._generate_dispatch(reqs_all, results_all))
        i = 0
        for reqs, results, epoch, timing, staleness in groups:
            self._append(reqs, results, epoch, t_done, timing,
                         ids[i:i + len(reqs)], rag, staleness)
            i += len(reqs)

    def _retire(self, limit: int):
        """Complete (decode + record) oldest in-flight batches beyond limit.

        The gemm component recorded here is the RESIDUAL device wait at
        retire time: at steady state the GEMM (and the batched recover
        chained behind it) overlapped host work for `depth` ticks
        already, so near-zero gemm_s is the pipeline doing its job (the
        sync engine reports the full device time instead).  Generation
        groups parked by `_record` on EARLIER ticks complete after this
        tick's retrieval completions, in micro-batches of
        ``gen_coalesce`` groups; a partial micro-batch keeps waiting for
        more groups — except on an idle tick or drain (limit 0), which
        flushes everything (during a lull responses must not sit
        generated-but-unreported behind the coalescing bound).
        """
        n_parked = len(self._gen_pending)
        while len(self._inflight) > limit:
            reqs, epoch, infl, t_plan, encode_s = self._inflight.popleft()
            with self.obs.span("serve.gemm", batch=len(reqs)) as sp_gemm:
                jax.block_until_ready(infl.pending)
            with self.obs.span("serve.complete", batch=len(reqs)) as sp_done:
                results = infl.complete()
            self._record(reqs, results, epoch, sp_done.t1, BatchTiming(
                t_plan=t_plan, encode_s=encode_s, gemm_s=sp_gemm.dur,
                decode_s=sp_done.dur))
        while n_parked >= self.gen_coalesce:
            self._retire_gen(self.gen_coalesce)
            n_parked -= self.gen_coalesce
        if limit == 0:
            while self._gen_pending:
                self._retire_gen(min(len(self._gen_pending),
                                     self.gen_coalesce))

    def drain(self):
        """Serve and complete everything: queue, mutations, and pipeline.

        Bypasses the commit gate like the synchronous drain.
        """
        gate, self.commit_gate = self.commit_gate, None
        try:
            while (self.batcher.queue or self.mutations
                   or self._backoff or self._delayed or self._commit_retry):
                self.tick(force=True)
        finally:
            self.commit_gate = gate
        with self.obs.span("serve.drain", engine=self.ENGINE):
            self._retire(0)
