"""Shadow-epoch commit machinery: double-buffered hints, atomic publish.

The synchronous loop's commit is stop-the-world: between two query batches
it re-packs columns, runs the ΔH GEMMs, and swaps state piece by piece —
nothing else can be in flight, because a half-patched hint or DB would
decode garbage.

The pipelined engine instead drives every commit through two phases that
`update.live.LiveIndex` exposes as ``stage()`` / ``publish()``:

stage (shadow)
    The mutation batch is planned and every patch — flat-DB column
    scatter, ΔH hint GEMM, per-bucket batch-PIR patches — is DISPATCHED
    against a *shadow* copy of the per-shard hint + DB buffers.  JAX's
    functional updates make the shadow cheap: the live arrays are operands,
    the patched arrays are fresh outputs, and with buffer donation the
    scatters alias the retiring buffer in place (the donated array is the
    one being superseded; every already-dispatched answer GEMM keeps its
    operand buffer alive at the runtime level).  Queries keep planning,
    answering and decoding at the live epoch for the entire stage.

publish (swap)
    One Python-level pointer swap per buffer family plus the epoch-log
    append.  This is the only instant at which a freshly formed query can
    become stale — the stale-reject window shrinks from the whole
    hint-patch computation to the swap itself.

In-flight batches are unaffected by the swap because the plan stage of
`PirRagSystem.query_batch_async` snapshots everything decode needs (client
hint array, per-bucket hint/config lists); the buffers of epoch e stay
alive exactly as long as some batch formed at epoch e still needs them.
"""
from __future__ import annotations

import time
from collections import deque


class ShadowCommitter:
    """Runs LiveIndex commits in stage/publish form for a serving engine.

    ``donate=True`` routes the flat-DB and bucket sub-DB scatters through
    buffer donation (`PIRServer.stage_update` /
    `BatchPIRServer.stage_update_columns`): the 16 MiB-class DB copy per
    epoch becomes an in-place column write.  Hints are never donated — the
    retiring hint array is exactly what in-flight decode snapshots still
    read — but their ΔH adds donate the transient delta buffer instead, so
    a delta commit allocates no third hint-sized array either.

    Accounts stage vs swap wall-clock so the overlap win is measurable
    (`benchmarks/serve_bench.py` reports both).
    """

    def __init__(self, live, *, donate: bool = True):
        assert live is not None, "shadow commits need a LiveIndex"
        self.live = live
        self.donate = donate
        self.commits = 0
        self.stage_seconds = 0.0     # shadow-patch compute (overlappable)
        self.swap_seconds = 0.0      # pointer swaps (the stale window)

    def commit(self, mutations: deque):
        """Drain `mutations` into the journal and commit them as one epoch.

        Returns the published HintPatch, or None if nothing was pending.
        A batch can already sit in the journal with the deque empty when a
        previous attempt failed after draining (injected commit fault) —
        the retry must still commit it, so the journal's pending watermark
        is part of the guard.
        """
        if not mutations and not self.live.journal.pending():
            return None
        while mutations:
            self.live.journal.append(mutations.popleft())
        t0 = time.perf_counter()
        staged = self.live.stage(donate=self.donate)
        if staged is None:
            return None
        t1 = time.perf_counter()
        patch = self.live.publish(staged)
        t2 = time.perf_counter()
        self.commits += 1
        self.stage_seconds += t1 - t0
        self.swap_seconds += t2 - t1
        return patch
