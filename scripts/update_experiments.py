"""Regenerate the roofline tables at the bottom of EXPERIMENTS.md.

    PYTHONPATH=src python scripts/update_experiments.py
"""
import os
import sys

sys.path.insert(0, "src")

from repro.launch import roofline  # noqa: E402

MARK = "<!-- ROOFLINE TABLES INSERTED BELOW BY scripts/update_experiments.sh -->"


def main():
    d = os.path.join("experiments", "dryrun")
    recs = roofline.load_records(d)
    n_ok = sum(1 for r in recs if r.get("ok"))
    parts = [MARK, ""]
    parts.append(f"Cells compiled OK: **{n_ok}/{len(recs)}**\n")
    for mesh, chips in (("pod", 256), ("multipod", 512)):
        parts.append(f"#### {mesh} mesh ({chips} chips)\n")
        parts.append(roofline.table(recs, mesh))
        parts.append("")
    bad = [r for r in recs if not r.get("ok")]
    if bad:
        parts.append("Failed cells:")
        for r in bad:
            parts.append(f"* {r['arch']}:{r['shape']}:{r['mesh']} — "
                         f"{r.get('error', '')[:140]}")

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    head = text.split(MARK)[0]
    with open("EXPERIMENTS.md", "w") as f:
        f.write(head + "\n".join(parts) + "\n")
    print(f"EXPERIMENTS.md updated ({n_ok}/{len(recs)} cells ok)")


if __name__ == "__main__":
    main()
