"""CI gate: validate a Chrome-trace export against the checked-in schema.

    PYTHONPATH=src python scripts/check_trace.py trace_serve.json

Three layers, all hard failures (exit 1):

  1. structural — `repro.obs.trace.validate_chrome_trace` (name/ph/ts/dur
     shape of every event);
  2. schema — the checked-in ``scripts/trace_schema.json`` subset of the
     Chrome Trace Event Format, enforced by a hand-rolled walker (the CI
     image has no ``jsonschema``; the walker covers exactly the keywords
     the schema uses: type, enum, required, properties,
     additionalProperties, minimum, minLength, if/then const);
  3. privacy — every ``args`` value re-passes the `repro.obs.scrub`
     allowlist, so a trace that somehow recorded a query-derived payload
     fails CI even if the record-time gate were bypassed.

Also sanity-checks span-tree integrity: every non-root ``parent`` id must
name another event's ``sid``, and sids must be unique.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_TYPES = {"object": dict, "array": list, "string": str,
          "integer": int, "boolean": bool, "number": (int, float)}


def _check_type(value, typ) -> bool:
    """One JSON-schema ``type`` check (bool is NOT an integer/number)."""
    if isinstance(typ, list):
        return any(_check_type(value, t) for t in typ)
    py = _TYPES[typ]
    if typ in ("integer", "number") and isinstance(value, bool):
        return False
    return isinstance(value, py)


def _validate(value, schema: dict, path: str, errs: list[str]) -> None:
    """Walk `value` against the schema subset trace_schema.json uses."""
    typ = schema.get("type")
    if typ is not None and not _check_type(value, typ):
        errs.append(f"{path}: expected {typ}, got {type(value).__name__}")
        return
    if "enum" in schema and value not in schema["enum"]:
        errs.append(f"{path}: {value!r} not in {schema['enum']}")
    if "const" in schema and value != schema["const"]:
        errs.append(f"{path}: {value!r} != {schema['const']!r}")
    if isinstance(value, (int, float)) and not isinstance(value, bool):
        if "minimum" in schema and value < schema["minimum"]:
            errs.append(f"{path}: {value} < minimum {schema['minimum']}")
    if isinstance(value, str) and len(value) < schema.get("minLength", 0):
        errs.append(f"{path}: shorter than minLength")
    if isinstance(value, dict):
        for req in schema.get("required", []):
            if req not in value:
                errs.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for k, v in value.items():
            if k in props:
                _validate(v, props[k], f"{path}.{k}", errs)
            elif isinstance(extra, dict):
                _validate(v, extra, f"{path}.{k}", errs)
        cond = schema.get("if")
        if cond is not None:
            matches = not any(
                _fails(value.get(k), sub)
                for k, sub in cond.get("properties", {}).items())
            if matches:
                _validate(value, schema.get("then", {}), path, errs)
    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            _validate(item, schema["items"], f"{path}[{i}]", errs)


def _fails(value, schema: dict) -> bool:
    """True when `value` FAILS `schema` (used for if/then dispatch)."""
    errs: list[str] = []
    _validate(value, schema, "", errs)
    return bool(errs)


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print(f"usage: {argv[0]} TRACE.json")
        return 2
    from repro.obs import PrivacyViolation, scrub
    from repro.obs.trace import validate_chrome_trace

    with open(argv[1]) as f:
        trace = json.load(f)
    errs = validate_chrome_trace(trace)

    with open(os.path.join(os.path.dirname(__file__),
                           "trace_schema.json")) as f:
        schema = json.load(f)
    _validate(trace, schema, "$", errs)

    events = trace.get("traceEvents", [])
    # closed name vocabulary: every event name must be registered in the
    # schema's $spanNames — new instrumentation sites go through the schema
    # (and therefore through privacy review of their attributes) first
    allowed = set(schema.get("$spanNames", []))
    if allowed:
        for i, e in enumerate(events):
            name = e.get("name") if isinstance(e, dict) else None
            if name not in allowed:
                errs.append(f"event {i}: name {name!r} not in "
                            "trace_schema.json $spanNames")
    sids = [e["args"]["sid"] for e in events
            if isinstance(e, dict) and isinstance(e.get("args"), dict)
            and "sid" in e["args"]]
    if len(sids) != len(set(sids)):
        errs.append("duplicate span ids in export")
    known = set(sids)
    for i, e in enumerate(events):
        args = e.get("args", {}) if isinstance(e, dict) else {}
        parent = args.get("parent", -1)
        if parent != -1 and parent not in known:
            errs.append(f"event {i}: parent {parent} names no exported sid")
        for key, val in args.items():
            try:
                scrub(val, where=f"event {i} ({e.get('name')}) {key!r}")
            except PrivacyViolation as exc:
                errs.append(f"PRIVACY: {exc}")

    if errs:
        print(f"{argv[1]}: {len(errs)} problem(s)")
        for e in errs[:50]:
            print("  -", e)
        return 1
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    print(f"{argv[1]}: OK ({n_spans} spans, "
          f"{len(events) - n_spans} instants; schema + privacy clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
