"""CI gate: the public API surface must be documented.

Imports the `repro` packages that form the public serving/build surface and
fails (exit 1) when any exported name — module, public class, public method
defined in that module, or public function — is missing a docstring.  The
convention this enforces (see docs/architecture.md): public docstrings state
shapes, dtypes, and sharding expectations, because almost every object here
is an array contract.

    PYTHONPATH=src python scripts/check_docstrings.py
"""
from __future__ import annotations

import importlib
import inspect
import sys

#: The exported surface ISSUE 5 pins: system facade, both server roles, the
#: live index, the pipelined engine, and the shard_map building blocks —
#: plus the packing/clustering/kernel modules they are built from.
MODULES = [
    "repro.core.pipeline",
    "repro.core.pir",
    "repro.core.clustering",
    "repro.core.chunking",
    "repro.batchpir.partition",
    "repro.batchpir.server",
    "repro.batchpir.client",
    "repro.update.live",
    "repro.update.epochs",
    "repro.serve.engine",
    "repro.serve.epochs",
    "repro.traffic.workload",
    "repro.traffic.slo",
    "repro.traffic.admission",
    "repro.fleet",
    "repro.fleet.faults",
    "repro.fleet.retry",
    "repro.fleet.replica",
    "repro.fleet.recovery",
    "repro.distributed.collectives",
    "repro.kernels.ops",
    "repro.rag",
    "repro.rag.prompt",
    "repro.rag.generate",
    "repro.obs",
    "repro.obs.registry",
    "repro.obs.trace",
    "repro.obs.scrub",
]


def _public_names(mod) -> list[str]:
    if hasattr(mod, "__all__"):
        return list(mod.__all__)
    return [n for n in vars(mod) if not n.startswith("_")]


def _missing(mod) -> list[str]:
    out = []
    if not (mod.__doc__ or "").strip():
        out.append(mod.__name__)
    for name in _public_names(mod):
        obj = getattr(mod, name)
        if inspect.isclass(obj) or inspect.isfunction(obj):
            if getattr(obj, "__module__", None) != mod.__name__:
                continue  # re-export; charged to its home module
            if not (inspect.getdoc(obj) or "").strip():
                out.append(f"{mod.__name__}.{name}")
            if inspect.isclass(obj):
                for mname, meth in vars(obj).items():
                    if mname.startswith("_") and mname != "__init__":
                        continue
                    fn = (meth.__func__ if isinstance(
                        meth, (classmethod, staticmethod)) else meth)
                    if not (inspect.isfunction(fn) or isinstance(
                            fn, property)):
                        continue
                    target = fn.fget if isinstance(fn, property) else fn
                    if target is None or mname == "__init__":
                        # __init__ is documented at the class level here
                        continue
                    if not (inspect.getdoc(target) or "").strip():
                        out.append(f"{mod.__name__}.{name}.{mname}")
    return out


def main() -> int:
    missing: list[str] = []
    for modname in MODULES:
        missing += _missing(importlib.import_module(modname))
    if missing:
        print("missing docstrings on exported names:")
        for m in missing:
            print("  -", m)
        return 1
    print(f"docstrings OK across {len(MODULES)} public modules")
    return 0


if __name__ == "__main__":
    sys.exit(main())
