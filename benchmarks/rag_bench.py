"""True RAG-Ready Latency: retrieval-only vs serial vs overlapped generation.

The paper's headline metric is the end-to-end time to securely fetch
content FOR AN LLM; this bench closes the loop and measures three
postures over the SAME request stream (see docs/rag.md):

  retrieval_only — sync engine, no generator: the pre-RAG baseline (what
                   `serve_bench` measures).
  serial         — sync engine + generator: every tick blocks through
                   retrieve → tokenize → prefill → decode before the next
                   request is even cut.  The naive end-to-end posture,
                   paying one prefill + one decode-step chain PER BATCH.
  overlapped     — pipelined engine (depth ≥ 2) + the SAME generator:
                   generation is deferred past the tick that retrieved
                   its docs, letting `gen_coalesce` groups accumulate and
                   then decode as ONE micro-batch (continuous-batching
                   style).  The win is structural — one pack/prefill/
                   step-chain serves gen_coalesce batches, cutting the
                   per-group dispatch and launch overhead the serial
                   engine pays every tick — and only the pipelined engine
                   can do it: the sync engine must finish each batch
                   before the next is even cut, so it never holds two
                   generation groups at once.  (On multi-core hosts the
                   deferral additionally overlaps the decode chain's
                   device time with the next batch's host-side retrieval.)

Checks: overlapped wall < serial wall, and generated tokens BIT-IDENTICAL
between the serial and overlapped engines (rid → token map equality) —
per-row transformer math does not depend on who shares the micro-batch.

    PYTHONPATH=src python -m benchmarks.rag_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _drive(loop, corp, *, n_req: int, max_batch: int) -> dict:
    """Warm up compile caches, then run the timed closed-batch workload.

    Submits one FULL batch per tick so the batcher is always ready: the
    pipelined engine then holds `depth` batches in flight every tick
    (an idle tick would retire the whole pipeline and erase the overlap
    this bench exists to measure).
    """
    n_docs = len(corp.texts)
    rng = np.random.default_rng(5)
    # warmup: four drained batches so retrieval GEMM + prefill + decode-step
    # shapes all enter the timed region compiled — four, not two, so the
    # pipelined engine's drain accumulates a FULL gen_coalesce micro-batch
    # and compiles the coalesced (gen_coalesce·max_batch) prefill/step fns
    for rid in range(4 * max_batch):
        loop.submit(1_000_000 + rid, corp.embeddings[rid], top_k=3)
        if (rid + 1) % max_batch == 0:
            loop.tick()
    loop.drain()
    n_warm = len(loop.responses)

    arrivals: dict[int, float] = {}
    t0 = time.perf_counter()
    for rid in range(n_req):
        arrivals[rid] = time.perf_counter()
        loop.submit(rid, corp.embeddings[int(rng.integers(0, n_docs))],
                    top_k=3)
        if (rid + 1) % max_batch == 0:
            loop.tick()
    loop.drain()
    wall = time.perf_counter() - t0

    resp = loop.responses[n_warm:]
    lat_ms = [(r.t_done - arrivals[r.rid]) * 1e3 for r in resp]
    out = dict(wall_s=round(wall, 4), served=len(resp),
               throughput_qps=round(len(resp) / wall, 2),
               p50_ms=round(float(np.percentile(lat_ms, 50)), 3),
               p99_ms=round(float(np.percentile(lat_ms, 99)), 3),
               _tokens={r.rid: r.tokens for r in resp},
               _retrieval=[(r.rid, r.epoch, r.retries, r.batch_size,
                            tuple((d, t) for d, _, t in r.top))
                           for r in resp])
    rag = [r.rag for r in resp if r.rag is not None]
    if rag:
        out.update(
            tokenize_ms=round(1e3 * float(np.mean(
                [g.tokenize_s for g in rag])), 3),
            prefill_ms=round(1e3 * float(np.mean(
                [g.prefill_s for g in rag])), 3),
            generate_ms=round(1e3 * float(np.mean(
                [g.generate_s for g in rag])), 3),
            prompt_tokens=int(sum(g.prompt_tokens for g in rag)),
            new_tokens_per_req=int(rag[0].new_tokens))
    return out


def run(*, fast: bool = False) -> dict:
    from repro.core import pipeline
    from repro.data import corpus as corpus_lib
    from repro.rag import Generator
    from repro.serve import PIRServeLoop, PipelinedServeLoop

    if fast:
        shape = dict(n_docs=1500, n_clusters=96, emb_dim=48, max_batch=4,
                     n_req=48, depth=2, gen_coalesce=4, context_budget=96,
                     max_new=16)
    else:
        shape = dict(n_docs=4000, n_clusters=256, emb_dim=48, max_batch=8,
                     n_req=96, depth=2, gen_coalesce=4, context_budget=160,
                     max_new=16)
    corp = corpus_lib.make_corpus(0, shape["n_docs"],
                                  emb_dim=shape["emb_dim"],
                                  n_topics=shape["n_clusters"])

    # One static system + one generator shared by every posture: the bench
    # compares ENGINE timelines, so the corpus, compiled GEMMs and model
    # params must be literally the same objects (no mutations here — the
    # loops never touch a static system).
    system = pipeline.PirRagSystem.build(
        corp.texts, corp.embeddings, n_clusters=shape["n_clusters"],
        impl="xla")
    gen = Generator.tiny(seed=0, context_budget=shape["context_budget"],
                         max_new_tokens=shape["max_new"])
    kw = dict(max_batch=shape["max_batch"], deadline_ms=1e9, seed=0)

    def make_loop(name):
        if name == "retrieval_only":
            return PIRServeLoop(system, **kw)
        if name == "serial":
            return PIRServeLoop(system, generator=gen, **kw)
        return PipelinedServeLoop(system, generator=gen,
                                  depth=shape["depth"],
                                  gen_coalesce=shape["gen_coalesce"], **kw)

    # min-of-N walls per posture: single CI runs jitter by ±15% (thread
    # scheduling), which would drown the ~10% overlap win
    reps = 3
    rows = {}
    for name in ("retrieval_only", "serial", "overlapped"):
        runs = [_drive(make_loop(name), corp, n_req=shape["n_req"],
                       max_batch=shape["max_batch"]) for _ in range(reps)]
        assert all(r["_tokens"] == runs[0]["_tokens"] for r in runs[1:])
        rows[name] = min(runs, key=lambda r: r["wall_s"])

    tokens_identical = rows["serial"].pop("_tokens") == \
        rows["overlapped"].pop("_tokens")
    rows["retrieval_only"].pop("_tokens")
    # generation must leave retrieval outputs untouched: the generator
    # runs share the retrieval-only run's payloads/epochs/batching exactly
    retrieval_untouched = (
        rows["retrieval_only"].pop("_retrieval")
        == rows["serial"].pop("_retrieval")
        == rows["overlapped"].pop("_retrieval"))
    overlap_win = rows["overlapped"]["wall_s"] < rows["serial"]["wall_s"]
    hidden_ms = round(1e3 * (rows["serial"]["wall_s"]
                             - rows["overlapped"]["wall_s"]), 1)
    checks = [
        ("PASS" if overlap_win else "FAIL")
        + ": overlapped RAG-Ready wall < serial end-to-end wall — "
        + "deferred generation coalesces %d groups per decode chain "
        % shape["gen_coalesce"]
        + "(%.3fs vs %.3fs, %.1fms hidden)"
        % (rows["overlapped"]["wall_s"], rows["serial"]["wall_s"],
           hidden_ms),
        ("PASS" if tokens_identical else "FAIL")
        + ": generated tokens bit-identical sync vs pipelined engine",
        ("PASS" if retrieval_untouched else "FAIL")
        + ": retrieval outputs untouched by the generation stage "
        + "(payloads/epochs/batching identical to the retrieval-only run)",
    ]
    return dict(rows=rows, checks=checks, shape=shape,
                tokens_identical=tokens_identical)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    for name, r in res["rows"].items():
        extra = ""
        if "generate_ms" in r:
            extra = (f";tok={r['tokenize_ms']:.1f}ms"
                     f";pre={r['prefill_ms']:.1f}ms"
                     f";gen={r['generate_ms']:.1f}ms")
        print(f"rag_{name},{1e6 / r['throughput_qps']:.0f},"
              f"qps={r['throughput_qps']:.1f};p50={r['p50_ms']:.0f}ms;"
              f"p99={r['p99_ms']:.0f}ms{extra}")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
