"""Batch-PIR amortization benchmark: κ-pass → 1-pass server cost.

Measures the online server op across probe counts κ on the same DB:

  legacy  — `PIRServer.answer` with κ stacked one-hot columns: the server
            pays κ GEMM columns over the FULL database, so time scales ~κ×
            (worse on XLA-CPU, whose u32 GEMM leaves the fast matvec path
            at κ ≥ 2).
  batch   — `BatchPIRServer.answer_batch`: one streamed pass over the
            bucketed replica DB, so time is FLAT in κ.  The pass costs
            ~3× the raw DB bytes (3-way cuckoo replication) minus what
            bucket-local row truncation reclaims from skewed cluster
            sizes — `stored/db` in the output is that measured ratio.

Headline checks (ISSUE 2 acceptance):
  * batch κ=4 is within 1.5× of a single-probe batched query (measured
    ~1.0×: the pass is κ-independent) while the legacy path scales ~4×;
  * batch κ=4 beats legacy κ=4 outright in wall-clock;
  * the quality fixture shows identical nDCG@10 for batch vs legacy at
    P=4 (same clusters fetched ⇒ same rerank pool);
  * a live-index mutation batch patches per-bucket hints bit-identically
    to a from-scratch bucket setup().

    PYTHONPATH=src python -m benchmarks.batchpir_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _skewed_db(rng, m, n):
    """Random u8 DB with a heavy-tailed per-column payload profile (real
    corpora: cluster payloads vary widely), zero-padded to the global m."""
    base = rng.lognormal(0.0, 0.6, n)
    used = np.maximum(256, (base / base.max() * m)).astype(np.int64)
    mat = rng.integers(0, 256, (m, n), dtype=np.uint8)
    for j in range(n):
        mat[used[j]:, j] = 0
    return mat, used


def run_timing(*, m=32768, n=1024, kappas=(1, 2, 4, 8), n_buckets=12,
               seed=0, iters=10) -> dict:
    import jax
    import jax.numpy as jnp
    from repro import batchpir
    from repro.core import pir

    rng = np.random.default_rng(seed)
    mat, used = _skewed_db(rng, m, n)
    cfg = pir.make_config(m, n, impl="xla")
    server = pir.PIRServer(cfg, jnp.asarray(mat))
    bp = batchpir.build(mat, used, cfg.params, kappa=max(kappas),
                        n_buckets=n_buckets, seed=seed + 1, impl="xla")

    qvec = jnp.asarray(rng.integers(0, 2**32, (n,), dtype=np.uint32))
    legacy_pool: list[tuple[str, int, object]] = [
        ("single", 1, lambda: server.answer(qvec))]
    batch_pool: list[tuple[str, int, object]] = []
    for kappa in kappas:
        qk = jnp.asarray(rng.integers(0, 2**32, (n, kappa), dtype=np.uint32))
        legacy_pool.append(("legacy", kappa,
                            lambda qk=qk: server.answer(qk)))
        probes = rng.choice(n, size=kappa, replace=False)
        qs, _ = bp.client.query(jax.random.PRNGKey(kappa), probes)
        batch_pool.append(("batch", kappa,
                           lambda qs=qs: bp.server.answer_batch(qs)))

    # Per-kind interleaved rounds with a min-of-rounds estimator: drift on a
    # shared box hits every κ equally, and keeping the pools separate stops
    # the big legacy GEMMs polluting the cache state of the batch op (whose
    # shape is κ-independent BY CONSTRUCTION — the server cannot even see κ,
    # so any per-κ spread measured here is noise, not signal).
    best: dict[tuple[str, int], float] = {}
    for pool in (batch_pool, legacy_pool):
        for _, _, fn in pool:
            jax.block_until_ready(fn())                 # warm/compile
        for case in pool:
            best[case[:2]] = float("inf")
        for r in range(iters):
            order = rng.permutation(len(pool))
            for i in order:
                kind, kappa, fn = pool[i]
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best[(kind, kappa)] = min(best[(kind, kappa)],
                                          time.perf_counter() - t0)

    t_single = best[("single", 1)]
    batch1 = best[("batch", kappas[0])]
    rows = []
    for kappa in kappas:
        rows.append(dict(
            kappa=kappa,
            legacy_us=best[("legacy", kappa)] * 1e6,
            batch_us=best[("batch", kappa)] * 1e6,
            legacy_vs_single=best[("legacy", kappa)] / t_single,
            batch_vs_single=best[("batch", kappa)] / t_single,
            batch_vs_batch1=best[("batch", kappa)] / batch1))
    return dict(m=m, n=n, n_buckets=n_buckets,
                single_us=t_single * 1e6,
                stored_ratio=bp.server.stored_bytes / float(m * n),
                uplink_batch=bp.server.uplink_bytes,
                downlink_batch=bp.server.downlink_bytes,
                hint_batch=bp.server.hint_bytes,
                rows=rows)


def run_quality(*, n_docs=600, n_clusters=40, probe=4, seed=0) -> dict:
    import jax
    from repro.core import pipeline
    from repro.data import corpus as corpus_lib
    from repro.data import metrics

    corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=96, n_topics=24,
                                  topic_spread=1.0, encoder_noise=0.35)
    qs = corpus_lib.make_queries(1, corp, 8, n_relevant=20, noise=0.5)
    sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                       n_clusters=n_clusters, impl="xla",
                                       seed=seed)
    sysm.enable_batch(kappa=probe, seed=seed + 2)

    def mean_ndcg(mode, p):
        vals = []
        for i in range(len(qs.embeddings)):
            top, _ = sysm.query(qs.embeddings[i], top_k=10, multi_probe=p,
                                mode=mode, key=jax.random.PRNGKey(50 + i))
            ids = np.array([d for d, _, _ in top])
            vals.append(metrics.ndcg_at_k(ids, qs.relevant[i],
                                          qs.gains[i], 10))
        return float(np.mean(vals))

    return dict(probe=probe,
                ndcg_single=mean_ndcg("legacy", 1),
                ndcg_legacy=mean_ndcg("legacy", probe),
                ndcg_batch=mean_ndcg("batch", probe))


def run_patch_identity(*, seed=0) -> dict:
    """Live-index batch: patched bucket hints vs from-scratch setup()."""
    from repro.data import corpus as corpus_lib
    from repro.update import LiveIndex

    corp = corpus_lib.make_corpus(seed + 4, 300, emb_dim=16, n_topics=8)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=8,
                           impl="xla", kmeans_iters=5)
    live.system.enable_batch(kappa=3, n_buckets=9, seed=seed)
    bp = live.system.batch
    for d in (3, 57, 121):
        live.replace(d, f"refreshed {d}".encode(), corp.embeddings[d])
    t0 = time.perf_counter()
    live.commit()
    patch_s = time.perf_counter() - t0
    fresh = bp.server.setup()
    identical = all((np.asarray(h) == np.asarray(f)).all()
                    for h, f in zip(bp.server.hints, fresh))
    return dict(patch_s=patch_s, bit_identical=bool(identical),
                buckets=bp.partition.n_buckets)


def run(fast: bool = False) -> dict:
    timing = (run_timing(m=16384, n=1024, iters=8) if fast
              else run_timing())
    quality = (run_quality(n_docs=400, n_clusters=24) if fast
               else run_quality())
    patch = run_patch_identity()
    k4 = next(r for r in timing["rows"] if r["kappa"] == 4)
    checks = [
        ("batch κ=4 within 1.5× of single-probe batched query "
         f"({k4['batch_vs_batch1']:.2f}×); legacy path scales "
         f"{k4['legacy_vs_single']:.1f}× (≈κ)",
         k4["batch_vs_batch1"] <= 1.5),
        (f"batch κ=4 beats legacy κ=4 outright "
         f"({k4['batch_us']:.0f}µs vs {k4['legacy_us']:.0f}µs)",
         k4["batch_us"] < k4["legacy_us"]),
        (f"equal-or-better nDCG@10 at P=4 "
         f"(batch {quality['ndcg_batch']:.3f} vs "
         f"legacy {quality['ndcg_legacy']:.3f})",
         quality["ndcg_batch"] >= quality["ndcg_legacy"]),
        ("per-bucket hint patch bit-identical to from-scratch setup()",
         patch["bit_identical"]),
    ]
    return dict(timing=timing, quality=quality, patch=patch,
                checks=[(("PASS" if ok else "FAIL") + ": " + msg)
                        for msg, ok in checks])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    out = run(fast=args.fast)
    t = out["timing"]
    print(f"# batch-PIR amortization  m={t['m']} n={t['n']} "
          f"B={t['n_buckets']} stored/db={t['stored_ratio']:.2f}")
    print("kappa,legacy_us,batch_us,legacy_vs_single,batch_vs_batch1")
    for r in t["rows"]:
        print(f"{r['kappa']},{r['legacy_us']:.0f},{r['batch_us']:.0f},"
              f"{r['legacy_vs_single']:.2f},{r['batch_vs_batch1']:.2f}")
    q = out["quality"]
    print(f"ndcg10 single={q['ndcg_single']:.3f} "
          f"legacy_p4={q['ndcg_legacy']:.3f} batch_p4={q['ndcg_batch']:.3f}")
    for c in out["checks"]:
        print(c)


if __name__ == "__main__":
    main()
