"""Observability benchmark: instrumentation overhead + trace coverage.

The obs layer (ISSUE 7) has three measurable promises, all checked here
against the PIPELINED serving engine under mutation load — the same
workload shape `serve_bench` times:

  overhead  — tracing ON (spans retained, Chrome export live) vs the
              default trace-off configuration must cost <2% throughput.
              The span *timestamps* are taken in both arms (the engines
              derive `BatchTiming` from span boundaries either way), so
              the delta isolates retention + attribute scrubbing.  A 2%
              budget is far below host noise on a shared box, so the
              protocol is paired: one index, one state-converging warmup
              drive, then strictly alternating off/on drives with min-of-N
              per arm — contention only ever inflates a wall, so each
              arm's min approaches its quiet-machine time.
  coverage  — the exported root spans (serve.tick / serve.drain) must
              cover >=95% of the run's wall time: any larger gap means
              the engine did un-instrumented work.  Measured on the real
              clock — coverage is a wall-time property.
  privacy   — the export passes `validate_chrome_trace` and a full
              re-scan of every event's args through the scrub allowlist,
              and recording an ndarray raises `PrivacyViolation` (the
              gate is live, not vestigial).

    PYTHONPATH=src python -m benchmarks.obs_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _drive(loop, corp, *, n_req: int, mutate_every: int, max_batch: int,
           journal_lib) -> float:
    """Warm up, run the open-loop workload, return timed wall seconds."""
    n_docs = len(corp.texts)
    rng = np.random.default_rng(3)
    for rid in range(max_batch):
        loop.submit(1_000_000 + rid, corp.embeddings[rid])
    loop.submit_mutation(journal_lib.replace(
        0, b"warmup", corp.embeddings[0]))
    loop.drain()

    t0 = time.perf_counter()
    for rid in range(n_req):
        loop.submit(rid, corp.embeddings[int(rng.integers(0, n_docs))])
        if mutate_every and rid % mutate_every == 0:
            d = int(rng.integers(0, n_docs))
            loop.submit_mutation(journal_lib.replace(
                d, f"refreshed {d}@{rid}".encode(), corp.embeddings[d]))
        loop.tick()
    loop.drain()
    return time.perf_counter() - t0


def _scan_args(trace: dict) -> list[str]:
    """Re-scrub every exported args value; returns violation strings."""
    from repro.obs import PrivacyViolation, scrub
    bad = []
    for i, ev in enumerate(trace["traceEvents"]):
        for key, val in ev.get("args", {}).items():
            try:
                scrub(val, where=f"event {i} ({ev['name']}) arg {key!r}")
            except PrivacyViolation as e:
                bad.append(str(e))
    return bad


def run(*, fast: bool = False) -> dict:
    from repro.data import corpus as corpus_lib
    from repro.obs import Obs, PrivacyViolation, span_coverage, \
        validate_chrome_trace
    from repro.serve import PipelinedServeLoop
    from repro.update import LiveIndex, journal as journal_lib

    if fast:
        shape = dict(n_docs=2000, n_clusters=128, emb_dim=48, max_batch=16,
                     n_req=192, mutate_every=8, depth=2, kmeans_iters=8,
                     pairs=8)
    else:
        shape = dict(n_docs=4000, n_clusters=256, emb_dim=48, max_batch=32,
                     n_req=384, mutate_every=8, depth=2, kmeans_iters=8,
                     pairs=8)
    corp = corpus_lib.make_corpus(0, shape["n_docs"],
                                  emb_dim=shape["emb_dim"],
                                  n_topics=shape["n_clusters"])

    # ONE index shared by every drive: each drive replays the identical
    # seeded submit/mutation schedule, and the replaces rewrite the same
    # docs with the same texts — so after the first (warmup) drive the
    # index state is a fixed point and every timed drive does identical
    # work on identical state, whichever arm it belongs to.
    live = LiveIndex.build(corp.texts, corp.embeddings,
                           n_clusters=shape["n_clusters"], impl="xla",
                           kmeans_iters=shape["kmeans_iters"])

    def one_run(trace: bool) -> tuple[float, Obs]:
        obs = Obs(trace=trace)
        loop = PipelinedServeLoop(live, max_batch=shape["max_batch"],
                                  deadline_ms=1e9, seed=0,
                                  depth=shape["depth"], donate=True,
                                  obs=obs)
        wall = _drive(loop, corp, n_req=shape["n_req"],
                      mutate_every=shape["mutate_every"],
                      max_batch=shape["max_batch"],
                      journal_lib=journal_lib)
        return wall, obs

    one_run(False)  # converge index state + compile everything
    walls_off, traced = [], []
    for _ in range(shape["pairs"]):
        walls_off.append(one_run(False)[0])
        traced.append(one_run(True))
    walls_on = [w for w, _ in traced]
    obs = min(traced, key=lambda t: t[0])[1]
    overhead_pct = (min(walls_on) / min(walls_off) - 1.0) * 100.0

    cov = span_coverage(obs.tracer.spans)
    trace = obs.tracer.to_chrome()
    errs = validate_chrome_trace(trace)
    leaks = _scan_args(trace)
    try:
        obs.span("bench.leak_probe", payload=np.zeros(4)).__exit__(
            None, None, None)
        gate_live = False
    except PrivacyViolation:
        gate_live = True

    checks = [
        ("PASS" if overhead_pct < 2.0 else "FAIL")
        + ": tracing overhead <2% on the pipelined serve workload "
        + "(measured %+.2f%%, paired min-of-%d)"
        % (overhead_pct, shape["pairs"]),
        ("PASS" if cov >= 0.95 else "FAIL")
        + ": root spans cover >=95% of serve wall time "
        + "(measured %.1f%%)" % (cov * 100.0),
        ("PASS" if not errs and not leaks else "FAIL")
        + ": Chrome-trace export structurally valid and every args value "
        + "passes the privacy allowlist (%d format errors, %d leaks)"
        % (len(errs), len(leaks)),
        ("PASS" if gate_live else "FAIL")
        + ": recording an ndarray span attribute raises PrivacyViolation",
    ]
    return dict(
        rows=[dict(name="obs_overhead",
                   wall_off_s=round(min(walls_off), 4),
                   wall_on_s=round(min(walls_on), 4),
                   overhead_pct=round(overhead_pct, 3),
                   coverage=round(cov, 4),
                   n_spans=len(obs.tracer.spans),
                   n_instants=len(obs.tracer.instants))],
        metrics=obs.metrics_dict(),
        checks=checks, shape=shape)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--trace-out", default=None,
                    help="also export the traced run's Chrome trace here")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    for r in res["rows"]:
        print(f"{r['name']},{r['wall_on_s'] * 1e6:.0f},"
              f"overhead={r['overhead_pct']:+.2f}%;"
              f"coverage={r['coverage']:.3f};spans={r['n_spans']}")
    for c in res["checks"]:
        print("#", c)
    if args.trace_out:
        print(json.dumps(res["metrics"], indent=1))


if __name__ == "__main__":
    main()
