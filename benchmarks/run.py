"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits ``name,us_per_call,derived`` CSV rows plus PASS/FAIL validation of the
paper's qualitative claims (EXPERIMENTS.md §Paper-validation mirrors this
output), and writes the machine-readable perf trajectory to
``BENCH_pirrag.json`` at the repo root (kernel µs, fig2/fig3 rows, and the
batch-PIR amortization section); CI uploads that JSON as an artifact per
commit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI-sized)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "experiments", "bench"))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    results = {}

    from benchmarks import kernel_bench, quality, scalability

    print("name,us_per_call,derived")

    # ---- kernel + protocol micro-benchmarks (paper §3.3 hot loop) ----------
    kr = kernel_bench.run(sizes=((4096, 512), (16384, 1024))
                          if args.fast else
                          ((4096, 512), (16384, 1024), (65536, 2048)))
    for r in kr:
        print(f"{r['name']},{r['us_per_call']:.1f},"
              f"tpu_bound={r['tpu_bound']};qps_tpu={r['queries_per_s_tpu']:.0f}")
    pr = kernel_bench.run_protocol(m=16384 if args.fast else 65536)
    for r in pr:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    results["kernel"] = kr + pr

    # ---- Fig 2: scalability -------------------------------------------------
    sizes = (500, 1000, 2000) if args.fast else (500, 1000, 2000, 4000)
    rows = scalability.run(sizes=sizes)
    for r in rows:
        print(f"fig2_{r['system']}_n{r['n_docs']},"
              f"{r['query_s'] * 1e6:.0f},"
              f"setup_s={r['setup_s']:.2f};up={r['uplink']};down={r['downlink']}")
    checks2 = scalability.validate(rows)
    results["scalability"] = {"rows": rows, "checks": checks2}

    # ---- Fig 3: quality + RAG-Ready latency ---------------------------------
    # 12 queries even in --fast: 6 is inside the per-query noise band of the
    # Fig-3a near-tie (see quality.py's variance note)
    qrows = quality.run(n_docs=1500 if args.fast else 5000, n_queries=12)
    for r in qrows:
        print(f"fig3_{r['system']},{r['t_retrieval_s'] * 1e6:.0f},"
              f"ndcg10={r['ndcg10']:.3f};p10={r['p10']:.3f};"
              f"rag_ready_s={r['t_rag_ready_s']:.3f}")
    checks3 = quality.validate(qrows)
    results["quality"] = {"rows": qrows, "checks": checks3}

    # ---- batch-PIR: κ-probe amortization (beyond-paper) ---------------------
    from benchmarks import batchpir_bench
    bres = batchpir_bench.run(fast=args.fast)
    for r in bres["timing"]["rows"]:
        print(f"batchpir_k{r['kappa']},{r['batch_us']:.0f},"
              f"legacy_us={r['legacy_us']:.0f};"
              f"batch_vs_batch1={r['batch_vs_batch1']:.2f}")
    checks_b = bres["checks"]
    results["batchpir"] = bres

    # ---- sharded serving: answer-GEMM scaling 1→8 fake devices --------------
    from benchmarks import sharded_bench
    sres = sharded_bench.run(fast=args.fast)
    for r in sres["answer"]:
        print(f"sharded_answer_d{r['n_devices']},{r['us_per_call']:.1f},"
              f"db_per_dev={r['db_bytes_per_device']};"
              f"qps={r['queries_per_s']:.0f}")
    for r in sres["bucketed"]:
        print(f"sharded_bucketed_d{r['n_devices']},{r['us_per_call']:.1f},"
              f"stored_per_dev={r['stored_bytes_per_device']}")
    checks_s = sres["checks"]
    results["sharded"] = sres

    # ---- sharded offline build: full build 1→8 fake devices -----------------
    from benchmarks import build_bench
    bld = build_bench.run(fast=args.fast)
    print(f"build_host,{bld['host_s'] * 1e6:.0f},reference")
    for r in bld["rows"]:
        print(f"build_d{r['n_devices']},{r['build_s'] * 1e6:.0f},"
              f"index_s={r['index_s']:.2f};hint_s={r['hint_s']:.2f};"
              f"db_per_dev={r['db_bytes_per_device']}")
    checks_bld = bld["checks"]
    results["build"] = bld

    # ---- pipelined serving engine: overlap win under mutation load ----------
    from benchmarks import serve_bench
    vres = serve_bench.run(fast=args.fast)
    for r in vres["rows"]:
        print(f"serve_{r['engine']}_mut{r['mutate_every']},"
              f"{1e6 / r['throughput_qps']:.0f},"
              f"qps={r['throughput_qps']:.1f};p50={r['p50_ms']:.0f}ms;"
              f"p99={r['p99_ms']:.0f}ms;retries={r['retries']};"
              f"qdepth={r['queue_depth_peak']}")
    checks_v = vres["checks"]
    results["serve"] = vres

    # ---- open-loop traffic: SLO attainment, hint chains, admission ----------
    from benchmarks import traffic_bench
    tres = traffic_bench.run(fast=args.fast)
    for r in tres["rows"]:
        print(f"traffic_load{r['load_factor']},"
              f"{1e6 / max(r['served_qps'], 1e-9):.0f},"
              f"attain={r['attainment']:.3f};p50={r['p50_ms']:.0f}ms;"
              f"served_p99={r['served_p99_ms']:.0f}ms;shed={r['shed']}")
    ch = tres["chain"]
    print(f"traffic_hint_chain,{ch['sync_bytes']},"
          f"frac_of_full={ch['frac_of_full']:.4f};"
          f"chain={ch['chain_patches']};raw={ch['raw_patches']}")
    checks_t = tres["checks"]
    results["traffic"] = tres

    # ---- Graph-PIR sketch tuning sweep --------------------------------------
    from benchmarks import graph_bench
    gres = graph_bench.run(fast=args.fast)
    for r in gres["rows"]:
        print(f"graph_sketch{r['sketch_bits']},{r['query_s'] * 1e6:.0f},"
              f"recall10={r['recall10']:.3f};rec_bytes={r['record_bytes']}")
    checks_g = gres["checks"]
    results["graph"] = gres

    print("\n# paper-claim validation")
    for c in (checks2 + checks3 + checks_b + checks_s + checks_bld
              + checks_v + checks_t + checks_g):
        print("#", c)

    with open(os.path.join(args.out, "bench_results.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    # Machine-readable perf trajectory for CI: one JSON at the repo root,
    # uploaded as a workflow artifact per commit.
    root_json = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_pirrag.json")
    with open(root_json, "w") as f:
        json.dump(dict(kernel=results["kernel"],
                       fig2=results["scalability"],
                       fig3=results["quality"],
                       batchpir=bres,
                       sharded=sres,
                       build=bld,
                       serve=vres,
                       traffic=tres,
                       graph=gres), f, indent=1, default=float)
    all_checks = (checks2 + checks3 + checks_b + checks_s + checks_bld
                  + checks_v + checks_t + checks_g)
    n_fail = sum(1 for c in all_checks if c.startswith("FAIL"))
    print(f"\n# {len(all_checks) - n_fail} claims PASS, {n_fail} FAIL")


if __name__ == "__main__":
    main()
