"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits ``name,us_per_call,derived`` CSV rows plus PASS/FAIL validation of the
paper's qualitative claims (EXPERIMENTS.md §Paper-validation mirrors this
output), and writes the machine-readable perf trajectory to
``BENCH_pirrag.json`` at the repo root (kernel µs, fig2/fig3 rows, the
batch-PIR amortization section, and the obs instrumentation-overhead
section); CI uploads that JSON as an artifact per commit.

Every section runs inside a fault boundary: a section that raises is
reported (``meta.failed_sections``), the remaining sections still run and
the JSON is still written — but the process exits non-zero, so CI cannot
green-light a half-empty benchmark artifact.  ``meta`` also stamps the
commit hash, seed, device count and wall clock so any two artifacts are
comparable without spelunking the workflow logs.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

#: Every corpus/workload generator in benchmarks/ derives from this.
BENCH_SEED = 0


def _git_commit() -> str:
    """The current commit hash, or "unknown" outside a git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI-sized)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "experiments", "bench"))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t_start = time.perf_counter()
    results: dict = {}
    all_checks: list[str] = []
    failed: list[dict] = []

    def section(name: str, fn):
        """Run one section behind the fault boundary; record its result."""
        try:
            fn()
        except Exception as e:                      # noqa: BLE001 — the
            # boundary exists to keep one broken section from silently
            # wiping every other section's rows out of the artifact
            import traceback
            traceback.print_exc()
            failed.append({"section": name, "error": f"{type(e).__name__}: {e}"})
            print(f"# SECTION FAILED: {name}: {type(e).__name__}: {e}")

    import jax

    print("name,us_per_call,derived")

    # ---- kernel + protocol micro-benchmarks (paper §3.3 hot loop) ----------
    def sec_kernel():
        from benchmarks import kernel_bench
        kr = kernel_bench.run(sizes=((4096, 512), (16384, 1024))
                              if args.fast else
                              ((4096, 512), (16384, 1024), (65536, 2048)))
        for r in kr:
            print(f"{r['name']},{r['us_per_call']:.1f},"
                  f"tpu_bound={r['tpu_bound']};"
                  f"qps_tpu={r['queries_per_s_tpu']:.0f}")
        pr = kernel_bench.run_protocol(m=16384 if args.fast else 65536)
        for r in pr:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        results["kernel"] = kr + pr
    section("kernel", sec_kernel)

    # ---- Fig 2: scalability -------------------------------------------------
    def sec_fig2():
        from benchmarks import scalability
        sizes = (500, 1000, 2000) if args.fast else (500, 1000, 2000, 4000)
        rows = scalability.run(sizes=sizes)
        for r in rows:
            print(f"fig2_{r['system']}_n{r['n_docs']},"
                  f"{r['query_s'] * 1e6:.0f},"
                  f"setup_s={r['setup_s']:.2f};up={r['uplink']};"
                  f"down={r['downlink']}")
        checks = scalability.validate(rows)
        results["scalability"] = {"rows": rows, "checks": checks}
        all_checks.extend(checks)
    section("scalability", sec_fig2)

    # ---- Fig 3: quality + RAG-Ready latency ---------------------------------
    def sec_fig3():
        from benchmarks import quality
        # 12 queries even in --fast: 6 is inside the per-query noise band of
        # the Fig-3a near-tie (see quality.py's variance note)
        qrows = quality.run(n_docs=1500 if args.fast else 5000, n_queries=12)
        for r in qrows:
            print(f"fig3_{r['system']},{r['t_retrieval_s'] * 1e6:.0f},"
                  f"ndcg10={r['ndcg10']:.3f};p10={r['p10']:.3f};"
                  f"rag_ready_s={r['t_rag_ready_s']:.3f}")
        checks = quality.validate(qrows)
        results["quality"] = {"rows": qrows, "checks": checks}
        all_checks.extend(checks)
    section("quality", sec_fig3)

    # ---- batch-PIR: κ-probe amortization (beyond-paper) ---------------------
    def sec_batchpir():
        from benchmarks import batchpir_bench
        bres = batchpir_bench.run(fast=args.fast)
        for r in bres["timing"]["rows"]:
            print(f"batchpir_k{r['kappa']},{r['batch_us']:.0f},"
                  f"legacy_us={r['legacy_us']:.0f};"
                  f"batch_vs_batch1={r['batch_vs_batch1']:.2f}")
        results["batchpir"] = bres
        all_checks.extend(bres["checks"])
    section("batchpir", sec_batchpir)

    # ---- keyed embedding-PIR: flat-κ recsys serving + LPT packing -----------
    def sec_recsys():
        from benchmarks import recsys_bench
        rres = recsys_bench.run(fast=args.fast)
        for r in rres["lookup"]["rows"]:
            print(f"recsys_k{r['kappa']},{r['server_us']:.0f},"
                  f"vs_k1={r['vs_kappa1']:.2f};up={r['uplink_bytes']}")
        pk = rres["packing"]
        print(f"recsys_packing,{pk['n_shards']},"
              f"seq={pk['imbalance_seq']:.3f};lpt={pk['imbalance_lpt']:.3f}")
        results["recsys"] = rres
        all_checks.extend(rres["checks"])
    section("recsys", sec_recsys)

    # ---- sharded serving: answer-GEMM scaling 1→8 fake devices --------------
    def sec_sharded():
        from benchmarks import sharded_bench
        sres = sharded_bench.run(fast=args.fast)
        for r in sres["answer"]:
            print(f"sharded_answer_d{r['n_devices']},{r['us_per_call']:.1f},"
                  f"db_per_dev={r['db_bytes_per_device']};"
                  f"qps={r['queries_per_s']:.0f}")
        for r in sres["bucketed"]:
            print(f"sharded_bucketed_d{r['n_devices']},"
                  f"{r['us_per_call']:.1f},"
                  f"stored_per_dev={r['stored_bytes_per_device']}")
        results["sharded"] = sres
        all_checks.extend(sres["checks"])
    section("sharded", sec_sharded)

    # ---- sharded offline build: full build 1→8 fake devices -----------------
    def sec_build():
        from benchmarks import build_bench
        bld = build_bench.run(fast=args.fast)
        print(f"build_host,{bld['host_s'] * 1e6:.0f},reference")
        for r in bld["rows"]:
            print(f"build_d{r['n_devices']},{r['build_s'] * 1e6:.0f},"
                  f"index_s={r['index_s']:.2f};hint_s={r['hint_s']:.2f};"
                  f"db_per_dev={r['db_bytes_per_device']}")
        results["build"] = bld
        all_checks.extend(bld["checks"])
    section("build", sec_build)

    # ---- pipelined serving engine: overlap win under mutation load ----------
    def sec_serve():
        from benchmarks import serve_bench
        vres = serve_bench.run(fast=args.fast)
        for r in vres["rows"]:
            print(f"serve_{r['engine']}_mut{r['mutate_every']},"
                  f"{1e6 / r['throughput_qps']:.0f},"
                  f"qps={r['throughput_qps']:.1f};p50={r['p50_ms']:.0f}ms;"
                  f"p99={r['p99_ms']:.0f}ms;retries={r['retries']};"
                  f"qdepth={r['queue_depth_peak']}")
        results["serve"] = vres
        all_checks.extend(vres["checks"])
    section("serve", sec_serve)

    # ---- open-loop traffic: SLO attainment, hint chains, admission ----------
    def sec_traffic():
        from benchmarks import traffic_bench
        tres = traffic_bench.run(fast=args.fast)
        for r in tres["rows"]:
            print(f"traffic_load{r['load_factor']},"
                  f"{1e6 / max(r['served_qps'], 1e-9):.0f},"
                  f"attain={r['attainment']:.3f};p50={r['p50_ms']:.0f}ms;"
                  f"served_p99={r['served_p99_ms']:.0f}ms;shed={r['shed']}")
        ch = tres["chain"]
        print(f"traffic_hint_chain,{ch['sync_bytes']},"
              f"frac_of_full={ch['frac_of_full']:.4f};"
              f"chain={ch['chain_patches']};raw={ch['raw_patches']}")
        results["traffic"] = tres
        all_checks.extend(tres["checks"])
    section("traffic", sec_traffic)

    # ---- fault-tolerant fleet: failover SLO, recovery, no-fault identity ----
    def sec_fleet():
        from benchmarks import fleet_bench
        fres = fleet_bench.run(fast=args.fast)
        ident, loss, rec = fres["identity"], fres["loss"], fres["recovery"]
        print(f"fleet_identity,{ident['n_responses']},"
              f"identical={ident['identical']};"
              f"clock={ident['clock_identical']}")
        print(f"fleet_shard_loss,{1e6 / max(loss['served_qps'], 1e-9):.0f},"
              f"attain={loss['attainment']:.3f};"
              f"p99={loss['served_p99_ms']:.0f}ms;"
              f"failovers={loss['failovers']};"
              f"detect={loss['failover_detect_ticks']}t;"
              f"failed={loss['failed']}")
        print(f"fleet_recovery,{rec['wall_s'] * 1e6:.0f},"
              f"epochs={rec['epochs']};eps={rec['epochs_per_s']:.0f}/s;"
              f"bit_identical={rec['bit_identical']}")
        results["fleet"] = fres
        all_checks.extend(fres["checks"])
    section("fleet", sec_fleet)

    # ---- Graph-PIR sketch tuning sweep --------------------------------------
    def sec_graph():
        from benchmarks import graph_bench
        gres = graph_bench.run(fast=args.fast)
        for r in gres["rows"]:
            print(f"graph_sketch{r['sketch_bits']},{r['query_s'] * 1e6:.0f},"
                  f"recall10={r['recall10']:.3f};"
                  f"rec_bytes={r['record_bytes']}")
        results["graph"] = gres
        all_checks.extend(gres["checks"])
    section("graph", sec_graph)

    # ---- closed RAG loop: retrieval-only vs serial vs overlapped generation -
    def sec_rag():
        from benchmarks import rag_bench
        rres = rag_bench.run(fast=args.fast)
        for name, r in rres["rows"].items():
            extra = ""
            if "generate_ms" in r:
                extra = (f";tok={r['tokenize_ms']:.1f}ms"
                         f";pre={r['prefill_ms']:.1f}ms"
                         f";gen={r['generate_ms']:.1f}ms")
            print(f"rag_{name},{1e6 / r['throughput_qps']:.0f},"
                  f"qps={r['throughput_qps']:.1f};p50={r['p50_ms']:.0f}ms;"
                  f"p99={r['p99_ms']:.0f}ms{extra}")
        results["rag"] = rres
        all_checks.extend(rres["checks"])
    section("rag", sec_rag)

    # ---- observability: instrumentation overhead + span coverage ------------
    def sec_obs():
        from benchmarks import obs_bench
        ores = obs_bench.run(fast=args.fast)
        for r in ores["rows"]:
            print(f"{r['name']},{r['wall_on_s'] * 1e6:.0f},"
                  f"overhead={r['overhead_pct']:+.2f}%;"
                  f"coverage={r['coverage']:.3f};spans={r['n_spans']}")
        results["obs"] = ores
        all_checks.extend(ores["checks"])
    section("obs", sec_obs)

    print("\n# paper-claim validation")
    for c in all_checks:
        print("#", c)

    meta = {
        "commit": _git_commit(),
        "seed": BENCH_SEED,
        "n_devices": jax.device_count(),
        "backend": jax.default_backend(),
        "fast": args.fast,
        "utc": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "wall_s": round(time.perf_counter() - t_start, 2),
        "failed_sections": failed,
    }
    with open(os.path.join(args.out, "bench_results.json"), "w") as f:
        json.dump(dict(meta=meta, **results), f, indent=1, default=float)
    # Machine-readable perf trajectory for CI: one JSON at the repo root,
    # uploaded as a workflow artifact per commit.
    root_json = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_pirrag.json")
    out = {"meta": meta}
    for src, dst in (("kernel", "kernel"), ("scalability", "fig2"),
                     ("quality", "fig3"), ("batchpir", "batchpir"),
                     ("recsys", "recsys"),
                     ("sharded", "sharded"), ("build", "build"),
                     ("serve", "serve"), ("traffic", "traffic"),
                     ("fleet", "fleet"),
                     ("graph", "graph"), ("rag", "rag"), ("obs", "obs")):
        if src in results:
            out[dst] = results[src]
    with open(root_json, "w") as f:
        json.dump(out, f, indent=1, default=float)
    n_fail = sum(1 for c in all_checks if c.startswith("FAIL"))
    print(f"\n# {len(all_checks) - n_fail} claims PASS, {n_fail} FAIL")
    if failed:
        print(f"# {len(failed)} section(s) RAISED: "
              + ", ".join(f["section"] for f in failed))
        sys.exit(1)


if __name__ == "__main__":
    main()
