"""Sharded offline build: 1 → 8 fake devices on one host.

Measures the full offline phase — mesh-parallel K-means (kmeans++ +
blocked Lloyd), balanced/plain assignment, per-shard column packing, and
the sharded hint GEMM — with `PirRagSystem.build(mesh=...)` over submeshes
of 1, 2, 4 and 8 fake CPU devices, against the mesh=None host build as the
reference.

As with `sharded_bench`, fake host devices share one physical CPU, so the
sweep's point is not wall-clock speedup: it validates that (a) the build's
device-resident state (DB rows, hint rows) falls as 1/shards — the
memory-capacity axis that lets a production-sized build run where the
single-device build cannot even materialize its DB — and (b) total build
wall-clock stays flat rather than regressing, i.e. the collectives added
per Lloyd iteration (one tiled all-gather of block partials) and the
per-shard packing/placement add no hidden cost.  Every width is checked
**bit-identical** to the single-device build in-loop: centroids,
assignment, packed columns, hint, and an end-to-end top-k.

XLA pins the host device count at first init, so the sweep runs in a child
interpreter (same pattern as tests/_mesh_harness.py); `run(fast=...)` is
what `benchmarks/run.py` calls to fill the `build` section of
BENCH_pirrag.json.

    PYTHONPATH=src python -m benchmarks.build_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, numpy as np
from repro.core import pipeline
from repro.data import corpus as corpus_lib

n_docs, n_clusters, emb_dim, iters = {n_docs}, {n_clusters}, {emb_dim}, {iters}
corp = corpus_lib.make_corpus(0, n_docs, emb_dim=emb_dim,
                              n_topics=n_clusters)
kw = dict(n_clusters=n_clusters, kmeans_iters=iters, impl="xla", seed=0,
          balance_factor=1.3)

t0 = time.perf_counter()
ref = pipeline.PirRagSystem.build(corp.texts, corp.embeddings, **kw)
host_s = time.perf_counter() - t0
probe = corp.embeddings[7]
top_ref, _ = ref.query(probe, top_k=5, key=jax.random.PRNGKey(11))

rows, checks = [], []
for n_dev in (1, 2, 4, 8):
    mesh = jax.make_mesh((n_dev,), ("chunks",),
                         devices=jax.devices()[:n_dev])
    t0 = time.perf_counter()
    sys_s = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                        mesh=mesh, **kw)
    dt = time.perf_counter() - t0
    identical = (
        np.array_equal(ref.centroids, sys_s.centroids)
        and np.array_equal(ref.assignment, sys_s.assignment)
        and np.array_equal(ref.db.matrix, sys_s.db.matrix)
        and np.array_equal(np.asarray(ref.hint), np.asarray(sys_s.hint))
        and top_ref == sys_s.query(probe, top_k=5,
                                   key=jax.random.PRNGKey(11))[0])
    m_pad = sys_s.db.m + (-sys_s.db.m) % n_dev
    rows.append(dict(
        n_devices=n_dev,
        build_s=dt,
        index_s=sys_s.index_seconds,        # clustering + packing
        hint_s=sys_s.hint_seconds,          # sharded hint GEMM
        db_bytes_per_device=m_pad * sys_s.db.n // n_dev,
        hint_bytes_per_device=sys_s.cfg.hint_bytes // n_dev,
        bit_identical=identical,
    ))

checks.append(("PASS" if all(r["bit_identical"] for r in rows) else "FAIL")
              + ": sharded build bit-identical to single-device build at "
              + "every mesh width (centroids/assignment/columns/hint/top-k)")
cap8 = rows[-1]["db_bytes_per_device"]
checks.append(("PASS" if cap8 * 8 == rows[0]["db_bytes_per_device"] else
               "FAIL") + ": per-device DB bytes scale exactly 1/shards")
worst = max(r["build_s"] for r in rows) / host_s
checks.append(("PASS" if worst < 3.0 else "FAIL")
              + ": sharded build stays within 3x of the host build "
              + "on shared silicon (worst %.2fx)" % worst)
print(json.dumps(dict(rows=rows, host_s=host_s, checks=checks,
                      shape=dict(n_docs=n_docs, n_clusters=n_clusters,
                                 emb_dim=emb_dim, kmeans_iters=iters))))
"""


def run(*, fast: bool = False) -> dict:
    """Run the sweep in a child interpreter; returns the parsed section."""
    params = (dict(n_docs=1500, n_clusters=24, emb_dim=32, iters=10) if fast
              else dict(n_docs=6000, n_clusters=64, emb_dim=64, iters=20))
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD.format(**params)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": "cpu"})
    if proc.returncode != 0:
        raise RuntimeError(proc.stdout + "\n" + proc.stderr)
    return json.loads(proc.stdout.splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    print(f"build_host,{res['host_s'] * 1e6:.0f},reference")
    for r in res["rows"]:
        print(f"build_d{r['n_devices']},{r['build_s'] * 1e6:.0f},"
              f"index_s={r['index_s']:.2f};hint_s={r['hint_s']:.2f};"
              f"db_per_dev={r['db_bytes_per_device']};"
              f"bit_identical={r['bit_identical']}")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
