"""Keyed embedding-PIR benchmark: flat-in-κ serving + height-aware packing.

Three claims from the private-recsys serving design (docs/recsys.md):

  flat-κ   — a DLRM-style request carries κ sparse feature ids, but the
             keyed server answers ALL of them in one bucketed pass whose
             shape is κ-independent: server wall-clock at κ=26 should sit
             inside the noise band of κ=1.
  uplink   — the client always sends B bucket ciphertexts (dummies for
             unused buckets), so measured uplink bytes are identical
             across κ AND across which ids are asked — the access pattern
             leaks nothing through message size.
  packing  — `balanced_bucket_order` (LPT) packs skewed bucket heights
             across devices; per-device useful-row loads should be
             measurably more even than the sequential stack layout.

Rows recovered along the way are asserted bit-identical to ``table[ids]``
(the recsys parity contract), so the timing numbers are for a correct
protocol, not a stub.

    PYTHONPATH=src python -m benchmarks.recsys_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _zipf_ids(rng, n_rows: int, kappa: int) -> np.ndarray:
    """DLRM-skew id multiset: Zipf(1.2) folded into the table."""
    return ((rng.zipf(1.2, size=kappa) - 1) % n_rows).astype(np.int64)


def run_lookup(*, n_rows=4096, dim=32, kappas=(1, 4, 8, 16, 26),
               seed=0, iters=10) -> dict:
    """κ-sweep over one keyed system: server time, uplink, bit-parity."""
    import jax
    from repro.core import pipeline

    rng = np.random.default_rng(seed)
    table = rng.standard_normal((n_rows, dim)).astype(np.float32)
    sysm = pipeline.PirRagSystem.build_keyed(table, kappa=max(kappas),
                                             impl="xla", seed=seed)
    layout, bp = sysm.keyed, sysm.batch

    # Pre-plan one query batch per κ (client-side); time ONLY the server op.
    pool = []
    for kappa in kappas:
        ids = _zipf_ids(rng, n_rows, kappa)
        qs, state = bp.client.query_rows(jax.random.PRNGKey(kappa),
                                         layout, ids)
        pool.append((kappa, ids, jax.block_until_ready(qs), state))

    # Uplink invariance across id CHOICE at fixed κ: two disjoint draws.
    alt = _zipf_ids(rng, n_rows, max(kappas))
    q_alt, _ = bp.client.query_rows(jax.random.PRNGKey(99), layout, alt)

    best: dict[int, float] = {k: float("inf") for k in kappas}
    for kappa, _, qs, _ in pool:
        jax.block_until_ready(bp.server.answer_batch(qs))    # warm/compile
    for _ in range(iters):
        for i in rng.permutation(len(pool)):
            kappa, _, qs, _ = pool[i]
            t0 = time.perf_counter()
            jax.block_until_ready(bp.server.answer_batch(qs))
            best[kappa] = min(best[kappa], time.perf_counter() - t0)

    rows, exact = [], True
    for kappa, ids, qs, state in pool:
        ans = [jax.block_until_ready(a) for a in bp.server.answer_batch(qs)]
        rec = bp.client.recover_rows(ans, state)
        exact &= bool(np.array_equal(rec, table[ids]))
        rows.append(dict(kappa=kappa,
                         server_us=best[kappa] * 1e6,
                         vs_kappa1=best[kappa] / best[kappas[0]],
                         uplink_bytes=int(qs.size * 4)))
    return dict(n_rows=n_rows, dim=dim, n_buckets=bp.partition.n_buckets,
                group_size=layout.group_size,
                hint_bytes=bp.server.hint_bytes,
                uplink_alt_draw=int(q_alt.size * 4),
                rows=rows, bit_exact=exact)


def run_packing(*, n_buckets=48, n_shards=8, seed=0) -> dict:
    """LPT vs sequential bucket→device packing on skewed heights.

    Heights follow the lognormal per-bucket useful-row profile real
    corpora produce (same distribution the batch-PIR bench uses for its
    skewed DB); the score is max/mean of per-device useful-row totals —
    1.0 is a perfect pack, and anything above it is rows one device
    streams while others multiply zero padding.
    """
    from repro.distributed import collectives

    rng = np.random.default_rng(seed)
    base = rng.lognormal(0.0, 0.6, n_buckets)
    heights = np.maximum(128, (base / base.max() * 32768)).astype(np.int64)

    def score(order):
        loads = collectives.shard_row_loads(heights, n_shards, order=order)
        return float(loads.max() / loads.mean())

    order = collectives.balanced_bucket_order(heights, n_shards)
    return dict(n_buckets=n_buckets, n_shards=n_shards,
                imbalance_seq=score(None),
                imbalance_lpt=score(order),
                order_nontrivial=bool((order != np.arange(len(order))).any()))


def run(fast: bool = False) -> dict:
    look = (run_lookup(n_rows=2048, dim=16, iters=6) if fast
            else run_lookup())
    pack = run_packing()
    k_hi = look["rows"][-1]
    uplinks = {r["uplink_bytes"] for r in look["rows"]}
    uplinks.add(look["uplink_alt_draw"])
    checks = [
        (f"server time flat in κ: κ={k_hi['kappa']} at "
         f"{k_hi['vs_kappa1']:.2f}× of κ=1 (≤1.5×)",
         k_hi["vs_kappa1"] <= 1.5),
        (f"uplink independent of κ and of queried ids "
         f"({sorted(uplinks)} B)", len(uplinks) == 1),
        ("recovered rows bit-identical to table[ids] at every κ",
         look["bit_exact"]),
        (f"LPT packing beats sequential layout (max/mean "
         f"{pack['imbalance_lpt']:.3f} vs {pack['imbalance_seq']:.3f})",
         pack["imbalance_lpt"] < pack["imbalance_seq"]
         and pack["order_nontrivial"]),
    ]
    return dict(lookup=look, packing=pack,
                checks=[(("PASS" if ok else "FAIL") + ": " + msg)
                        for msg, ok in checks])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    out = run(fast=args.fast)
    lk = out["lookup"]
    print(f"# keyed embedding-PIR  V={lk['n_rows']} d={lk['dim']} "
          f"B={lk['n_buckets']} gs={lk['group_size']} "
          f"hint={lk['hint_bytes']}B")
    print("kappa,server_us,vs_kappa1,uplink_bytes")
    for r in lk["rows"]:
        print(f"{r['kappa']},{r['server_us']:.0f},{r['vs_kappa1']:.2f},"
              f"{r['uplink_bytes']}")
    pk = out["packing"]
    print(f"packing B={pk['n_buckets']} S={pk['n_shards']} "
          f"seq={pk['imbalance_seq']:.3f} lpt={pk['imbalance_lpt']:.3f}")
    for c in out["checks"]:
        print(c)


if __name__ == "__main__":
    main()
